"""Continuous-batching serving example: queue -> slots -> paged KV decode.

    PYTHONPATH=src python examples/serve_lm.py

Initialises a small LM and submits a mixed workload (heterogeneous prompt
lengths AND generation lengths) through the :class:`repro.api.Runtime` front
door. ``Runtime.serve`` builds the continuous engine — a mesh-bearing
Runtime would serve sharded with the same two lines. The
:class:`repro.api.ServeConfig` fixes the compiled surface: slot count,
per-slot KV budget, paged-cache geometry and prefill buckets (one XLA
compile per bucket — see docs/serving.md).
"""
import numpy as np

import jax

from repro.api import Runtime, ServeConfig
from repro.configs.base import ArchConfig
from repro.models import lm
from repro.serve.engine import Request


def main():
    cfg = ArchConfig(name="serve-demo", family="dense", n_layers=4, d_model=256,
                     n_heads=8, n_kv=4, d_ff=1024, vocab=1024,
                     q_chunk=64, kv_chunk=64)
    params = lm.init_params(jax.random.key(0), cfg)
    serve = ServeConfig(n_slots=4, max_len=96, page_size=16)
    eng = Runtime().serve(params, cfg, serve=serve)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=n).astype(np.int32),
                    max_new=m)
            for n, m in ((9, 12), (17, 3), (5, 12), (30, 2), (11, 8))]
    eng.run(reqs)
    for i, r in enumerate(reqs):
        print(f"req {i}: prompt_len={len(r.prompt)} stop={r.stop} "
              f"-> {r.out.tolist()}")

    t = eng.telemetry()
    print(f"served {len(reqs)} requests on {serve.n_slots} slots "
          f"({t['layout']} KV) | decode {t['decode_tok_per_s']:.0f} tok/s | "
          f"wasted decode steps {t['wasted_decode_steps']} | "
          f"compiles {t['trace_counts']} | "
          f"p50 latency {t['latency_p50_s'] * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
