"""Batched serving example: prefill + decode through the Engine.

    PYTHONPATH=src python examples/serve_lm.py

Initialises a small LM and submits a mixed batch of prompts through the
:class:`repro.api.Runtime` front door (``Runtime.serve`` builds the Engine;
a mesh-bearing Runtime would serve sharded with the same two lines).
"""
import numpy as np

import jax

from repro.api import Runtime
from repro.configs.base import ArchConfig
from repro.models import lm
from repro.serve.engine import Request


def main():
    cfg = ArchConfig(name="serve-demo", family="dense", n_layers=4, d_model=256,
                     n_heads=8, n_kv=4, d_ff=1024, vocab=1024,
                     q_chunk=64, kv_chunk=64)
    params = lm.init_params(jax.random.key(0), cfg)
    eng = Runtime().serve(params, cfg, batch=4, max_len=96)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=n).astype(np.int32),
                    max_new=12)
            for n in (9, 17, 5, 30, 11)]
    eng.run(reqs)
    for i, r in enumerate(reqs):
        print(f"req {i}: prompt_len={len(r.prompt)} -> {r.out.tolist()}")
    print(f"served {len(reqs)} requests in batches of {eng.batch}")


if __name__ == "__main__":
    main()
