"""End-to-end driver: train a ~100M-parameter LM with sketched backprop.

    PYTHONPATH=src python examples/train_lm.py --steps 300          # full run
    PYTHONPATH=src python examples/train_lm.py --steps 20 --tiny    # smoke

Uses the production stack end to end through the :class:`repro.api.Runtime`
front door: ArchConfig (a scaled llama-style dense config), synthetic bigram
LM data with host prefetch, AdamW + cosine schedule, sketch policy (ℓ1 @ 0.2
by default), async checkpointing + auto-resume, and a budget schedule
(reactive straggler buckets via ``--straggler``, a warmup-exact schedule via
``--warmup-exact N``, or the closed-loop SNR-adaptive schedule via
``--adaptive-budget SNR`` — telemetry probes included; add
``--telemetry-jsonl PATH`` for per-step records).
"""
import argparse

from repro.api import (BudgetSchedule, ExecutionConfig, Runtime, SketchConfig,
                       SketchPolicy, TelemetryConfig)
from repro.configs.base import ArchConfig
from repro.data.pipeline import prefetch
from repro.data.synthetic import LMStream
from repro.optim import adamw, cosine_warmup
from repro.train.trainer import TrainerConfig


def arch_100m(tiny: bool) -> ArchConfig:
    if tiny:
        return ArchConfig(name="lm-tiny", family="dense", n_layers=2, d_model=128,
                          n_heads=4, n_kv=2, d_ff=512, vocab=512,
                          q_chunk=64, kv_chunk=64)
    # ~100M params: 12L, d=768, ff=2048, vocab 32k
    return ArchConfig(name="lm-100m", family="dense", n_layers=12, d_model=768,
                      n_heads=12, n_kv=12, d_ff=2048, vocab=32000,
                      q_chunk=128, kv_chunk=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--method", default="l1")
    ap.add_argument("--exact", action="store_true")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--straggler", action="store_true")
    ap.add_argument("--warmup-exact", type=int, default=0,
                    help="run exact backprop for N steps, then sketched")
    ap.add_argument("--adaptive-budget", type=float, default=0.0, metavar="SNR",
                    help="closed-loop budget control: run the cheapest "
                         "pre-compiled bucket whose probe-predicted gradient "
                         "SNR stays above this target (docs/telemetry.md)")
    ap.add_argument("--telemetry-jsonl", default=None,
                    help="write per-step telemetry records to this JSONL file")
    args = ap.parse_args()

    cfg = arch_100m(args.tiny)
    policy = None if args.exact else SketchPolicy(
        base=SketchConfig(method=args.method, budget=args.budget))
    if args.straggler and policy is not None:
        schedule = BudgetSchedule.straggler((1.0, 0.5, 0.2))
    elif args.warmup_exact and policy is not None:
        schedule = BudgetSchedule.warmup_exact(args.warmup_exact)
    elif args.adaptive_budget > 0 and policy is not None:
        schedule = BudgetSchedule.adaptive(target_snr=args.adaptive_budget,
                                           budgets=(1.0, 0.5, 0.2, 0.1))
    else:
        schedule = BudgetSchedule()
    execution = ExecutionConfig()
    if args.telemetry_jsonl or (args.adaptive_budget > 0 and policy is not None):
        execution = ExecutionConfig(
            telemetry=TelemetryConfig(jsonl=args.telemetry_jsonl))
    runtime = Runtime(policy=policy, schedule=schedule, execution=execution)
    opt = adamw(cosine_warmup(3e-4, max(10, args.steps // 20), args.steps),
                weight_decay=0.1, clip=1.0)
    stream = LMStream(vocab=cfg.vocab, seed=0)
    data = prefetch(stream.batches(args.batch, args.seq), size=2)
    tcfg = TrainerConfig(steps=args.steps, log_every=max(1, args.steps // 30),
                         ckpt_dir=args.ckpt, ckpt_every=max(10, args.steps // 5))
    state, history = runtime.train(cfg, opt, data, tcfg)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss: {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"({'exact' if args.exact else f'{args.method}@{args.budget}'})")


if __name__ == "__main__":
    main()
