"""Compare every sketch method at one budget (mini paper Figs. 1b/2a/2b).

    PYTHONPATH=src python examples/sketch_comparison.py --budget 0.2
"""
import argparse
import sys

sys.path.insert(0, ".")  # allow running from repo root for benchmarks import

from benchmarks.common import make_policy, mlp_data, train_mlp_best_lr  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--epochs", type=int, default=10)
    args = ap.parse_args()

    data = mlp_data()
    methods = ["exact", "per_element", "per_column", "per_sample",
               "l1", "l2", "var", "ds", "gsv", "rcs"]
    print(f"budget p = {args.budget}")
    rows = []
    for m in methods:
        pol = make_policy(m, args.budget) if m != "exact" else None
        r = train_mlp_best_lr(pol, data=data, epochs=args.epochs)
        rows.append((m, r["test_acc"], r["lr"]))
        print(f"  {m:12s} test_acc={r['test_acc']:.4f} (lr={r['lr']})")
    best = max(rows[1:], key=lambda t: t[1])
    print(f"\nbest sketch at p={args.budget}: {best[0]} ({best[1]:.4f}); "
          f"exact reference {rows[0][1]:.4f}")


if __name__ == "__main__":
    main()
