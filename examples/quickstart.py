"""Quickstart: train the paper's MLP with unbiased sketched backprop.

    PYTHONPATH=src python examples/quickstart.py [--method l1] [--budget 0.2]

Reproduces the paper's §5 setting (SGD, clip 1.0, CE) on a synthetic
MNIST-like task and prints exact-vs-sketched accuracy side by side.

Everything goes through the one front door: a :class:`repro.api.Runtime`
bundles the sketch policy, and ``runtime.ctx(key)`` hands the model the
per-step context (``budget=None`` = exact backprop — used both for the
baseline run and for evaluation).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Runtime, SketchConfig, SketchPolicy
from repro.data.synthetic import classification
from repro.models.mlp import mlp_init, mlp_loss


def train(runtime, xtr, ytr, xte, yte, *, lr=0.2, epochs=10, batch=128, seed=0):
    params = mlp_init(jax.random.key(seed))

    @jax.jit
    def step(p, b, key):
        (loss, acc), g = jax.value_and_grad(
            lambda q: mlp_loss(q, b, runtime.ctx(key)), has_aux=True)(p)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g)))
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gn, 1e-12))
        return jax.tree.map(lambda w, gg: w - lr * scale * gg, p, g), loss

    key = jax.random.key(seed + 1)
    n = xtr.shape[0]
    for ep in range(epochs):
        perm = np.random.default_rng((seed, ep)).permutation(n)
        for i in range(n // batch):
            idx = perm[i * batch:(i + 1) * batch]
            params, loss = step(params, {"x": xtr[idx], "y": ytr[idx]},
                                jax.random.fold_in(key, ep * 1000 + i))
        # evaluate exact regardless of the training-time estimator
        acc = float(mlp_loss(params, {"x": xte, "y": yte},
                             runtime.ctx(budget=None))[1])
        print(f"  epoch {ep:2d} loss {float(loss):.4f} test_acc {acc:.4f}")
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="l1")
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--epochs", type=int, default=10)
    args = ap.parse_args()

    xtr, ytr = classification(4096, 784, 10, seed=0)
    xte, yte = classification(1024, 784, 10, seed=1)

    print("== exact backprop ==")
    train(Runtime(), xtr, ytr, xte, yte, epochs=args.epochs)

    print(f"== sketched backprop: {args.method} @ budget {args.budget} "
          f"(backward cost ≈ {args.budget:.0%} of exact) ==")
    rt = Runtime(policy=SketchPolicy(
        base=SketchConfig(method=args.method, budget=args.budget),
        exclude_roles=()))
    train(rt, xtr, ytr, xte, yte, epochs=args.epochs)


if __name__ == "__main__":
    main()
