"""Importance scores (weight proxies) for data-dependent sketches (paper §4.2).

Every score function maps the batch gradient matrix ``G`` (rows = flattened
batch/sequence samples, columns = output coordinates of the linear node, i.e.
the *practical* convention of the paper's Appendix C) to a non-negative proxy
vector ``s`` of shape ``[d_out]``. Sampling probabilities are then
``p ∝ s`` — equivalently the convex program (23) is solved with importance
weights ``w_i = s_i²`` (since its solution satisfies ``p_i ∝ sqrt(w_i)``).
"Squared" proxy variants (paper §4.2 last paragraph) use ``w_i = s_i⁴``.

Scores accumulate in fp32 regardless of input dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["column_scores", "SCORE_METHODS", "kernel_reduction_mode",
           "scores_from_kernel_reduction"]


def _f32(x):
    return x.astype(jnp.float32)


def _l1(G, W):
    # Alg. 6: s_j = ||G[:, j]||_1  (the paper's default proxy).
    return jnp.sum(jnp.abs(_f32(G)), axis=0)


def _l2(G, W):
    return jnp.sqrt(jnp.sum(jnp.square(_f32(G)), axis=0))


def _var(G, W):
    return jnp.var(_f32(G), axis=0)


def _ds(G, W):
    # Lemma 3.4 / "Diagonal Sketches": a_i = (Γ_B)_ii (JᵀJ)_ii with J = Wᵀ,
    # so (JᵀJ)_ii = ||W[i, :]||². Optimal p ∝ sqrt(a) ⇒ proxy s = sqrt(a).
    if W is None:
        raise ValueError("DS score requires the layer weight W.")
    gamma_diag = jnp.mean(jnp.square(_f32(G)), axis=0)  # (Γ_B)_ii
    w_row_sq = jnp.sum(jnp.square(_f32(W)), axis=-1)  # ||W[i,:]||², shape [d_out]
    return jnp.sqrt(gamma_diag * w_row_sq)


def _gsv(G, W):
    # "G-SV": importance from the SVD of the batch gradient matrix G.
    # We use spectrally-weighted right-singular leverage:
    #     s_i = Σ_k σ_k v_{k,i}²
    # which interpolates between ℓ2² column energy (σ_k² weighting) and plain
    # leverage (uniform weighting). See DESIGN.md §3 for the interpretation.
    Gf = _f32(G)
    n = Gf.shape[-1]
    gram = Gf.T @ Gf  # [n, n]; eigvecs = right singular vectors, eigvals = σ²
    evals, evecs = jnp.linalg.eigh(gram)
    sing = jnp.sqrt(jnp.maximum(evals, 0.0))
    return jnp.einsum("k,ik->i", sing, jnp.square(evecs))


_BASE = {
    "l1": _l1,
    "l2": _l2,
    "var": _var,
    "ds": _ds,
    "gsv": _gsv,
}

SCORE_METHODS = tuple(_BASE.keys()) + tuple(f"{k}_sq" for k in _BASE)


def column_scores(method: str, G: jax.Array, W: jax.Array | None = None) -> jax.Array:
    """Proxy scores ``s`` (shape ``[d_out]``); probabilities will be ``p ∝ s``.

    ``method`` may carry the ``_sq`` suffix for the squared proxy variant.
    """
    squared = method.endswith("_sq")
    base = method[:-3] if squared else method
    if base not in _BASE:
        raise ValueError(f"unknown score method {method!r}; choose from {SCORE_METHODS}")
    s = _BASE[base](G, W)
    return jnp.square(s) if squared else s


def kernel_reduction_mode(method: str) -> str | None:
    """The streaming kernel reduction mode underlying ``method``: ``"l1"``
    (Σ|G| per column) or ``"l2"`` (ΣG² per column), or None when the score
    cannot be computed from a single column reduction (var/ds/gsv). The
    one-pass estimators only support methods with a non-None mode — their
    fresh scores are produced by the backward kernels' in-sweep reduction
    (``kernels.ref.COL_SCORE_MODES``)."""
    base = method[:-3] if method.endswith("_sq") else method
    return base if base in ("l1", "l2") else None


def scores_from_kernel_reduction(method: str, red: jax.Array) -> jax.Array:
    """Map a raw kernel column reduction (Σ|G| for mode "l1", ΣG² for "l2")
    to :func:`column_scores` semantics for ``method``, including the ``_sq``
    variants — so carried scores are interchangeable with fresh ones."""
    base = kernel_reduction_mode(method)
    if base is None:
        raise ValueError(f"method {method!r} has no kernel column reduction")
    s = red if base == "l1" else jnp.sqrt(red)
    return jnp.square(s) if method.endswith("_sq") else s
