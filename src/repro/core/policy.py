"""Sketch placement policy — which VJP sites get which budget.

The paper applies approximations "to all linear layers except the output
classification layer" (§5) and, for BagNet, also excludes the input projection
(App. B.2). App. B.1 studies *location* (first / last / all), and suggests
straggler-mitigation by approximating only on slow nodes — we expose that as
per-step budget *buckets* the trainer can switch between (each bucket is a
separately compiled step; see ``repro/train/straggler.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

from repro.core.sketching import SketchConfig

__all__ = ["SketchPolicy", "POLICY_PRESETS"]

# Roles attached by the nn substrate to each linear site.
ROLES = (
    "attn_q", "attn_k", "attn_v", "attn_o",
    "mlp_in", "mlp_gate", "mlp_out",
    "expert_in", "expert_gate", "expert_out", "router",
    "ssm_in", "ssm_out", "ssm_small",
    "embed", "lm_head", "input_proj", "cross_q", "cross_k", "cross_v", "cross_o",
)

_DEFAULT_EXCLUDE = ("lm_head", "router", "embed", "input_proj", "ssm_small")


@dataclasses.dataclass(frozen=True)
class SketchPolicy:
    """Maps a linear site (role, layer_index, n_layers) -> Optional[SketchConfig].

    Attributes:
      base: the sketch applied to included sites (None = exact everywhere).
      exclude_roles: roles that always backprop exactly (paper default:
        classifier head + router + embeddings + input projection).
      location: "all" | "first" | "last" — paper App. B.1 location study.
      overrides: role -> SketchConfig overriding ``base``.
    """

    base: Optional[SketchConfig] = None
    exclude_roles: Sequence[str] = _DEFAULT_EXCLUDE
    location: str = "all"
    overrides: Mapping[str, SketchConfig] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.location not in ("all", "first", "last"):
            raise ValueError(f"bad location {self.location!r}")
        # freeze mapping for hashability
        object.__setattr__(self, "overrides", tuple(sorted(self.overrides.items())))

    def config_for(self, role: str, layer_index: int = 0, n_layers: int = 1) -> Optional[SketchConfig]:
        if role in self.exclude_roles:
            return None
        if self.location == "first" and layer_index != 0:
            return None
        if self.location == "last" and layer_index != n_layers - 1:
            return None
        for k, v in self.overrides:
            if k == role:
                return v
        return self.base

    def with_budget(self, budget: float) -> "SketchPolicy":
        """Same policy at a different budget (straggler buckets)."""
        if self.base is None:
            return self
        return dataclasses.replace(
            self,
            base=dataclasses.replace(self.base, budget=budget),
            overrides={k: dataclasses.replace(v, budget=budget) for k, v in self.overrides},
        )


def _mk(method, budget, backend="mask", **kw):
    return SketchPolicy(base=SketchConfig(method=method, budget=budget, backend=backend, **kw))


POLICY_PRESETS = {
    "exact": SketchPolicy(base=None),
    # paper defaults
    "l1": lambda p: _mk("l1", p),
    "ds": lambda p: _mk("ds", p),
    "gsv": lambda p: _mk("gsv", p),
    "rcs": lambda p: _mk("rcs", p),
    "per_column": lambda p: _mk("per_column", p),
    "per_sample": lambda p: _mk("per_sample", p),
    "per_element": lambda p: _mk("per_element", p),
    # beyond-paper TPU-compact production preset (128-aligned keep counts)
    "l1_compact": lambda p: _mk("l1", p, backend="compact", round_to=128),
    "ds_compact": lambda p: _mk("ds", p, backend="compact", round_to=128),
}
