"""Estimator registry: pluggable unbiased-VJP backends for sketched linears.

The paper's estimator families were hard-wired as a closed ``if/elif`` over
``SketchConfig.backend`` inside ``core/sketched_linear``. This module turns
that dispatch into a small open registry so related estimator families
(Randomized Automatic Differentiation, Oktay et al. 2021; BASIS ghost
backpropagation, Khasia 2026) can be hosted *without forking core*: a plugin
implements :class:`Estimator`, calls :func:`register_estimator`, and every
``SketchConfig(backend="<name>")`` site — through ``nn.common.dense`` up to
``repro.api.Runtime`` — routes its backward through it.

An estimator owns the *backward math* of one linear site. The surrounding
machinery (custom_vjp plumbing, residuals, CompactGrad slot cotangents,
densify-scatter) stays in ``sketched_linear`` and is shared by all entries.

Contract (unbiasedness): ``E[dX] = Ĝ·W``, ``E[dW] = Ĝᵀ·X``, ``E[db] = Σ Ĝ``
for ``E[Ĝ | G] = G`` — switching estimators never biases the gradient, only
its variance (paper §2.2), which is what makes the registry safe to open.

The three builtin backends (``mask``, ``compact``, ``pallas``) are registered
by ``core/sketched_linear`` at import time; ``repro.core`` (and therefore any
``repro.*`` import) guarantees they are present.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

__all__ = ["Estimator", "EstimatorVJP", "register_estimator", "get_estimator",
           "registered_backends", "BUILTIN_BACKENDS"]

BUILTIN_BACKENDS = ("mask", "compact", "pallas", "onepass", "stale")


@dataclasses.dataclass
class EstimatorVJP:
    """Result of one estimator backward, in one of two forms.

    Dense form (``rows is None``): ``dw`` is the full ``[n, d_in]`` weight
    gradient and ``db`` (when the site has a bias) the full ``[n]`` bias
    gradient.

    Compact form: ``rows [r, d_in]`` are the kept dW rows, ``cols [r]`` their
    int32 row indices into the dense weight, and ``db_c [r]`` the bias
    gradient restricted to the same columns. ``sketched_linear`` scatters
    these into dense cotangents — or, in compact-gradient mode
    (``supports_compact_grad``), forwards them as a ``CompactGrad`` slot
    cotangent with no scatter at all.

    ``probe`` (optional, telemetry): a ``[repro.telemetry.probes.PROBE_WIDTH]``
    f32 vector of per-site probe statistics (unbiased dW-variance / gradient
    norm estimates — see ``repro/telemetry/probes.py``). Populated only by
    :meth:`Estimator.apply_with_probe`; ``None`` means "this estimator emits
    no probe" and the site reports zeros.

    ``state`` (optional, plan carry): the refreshed per-site plan state
    (e.g. fresh column scores, ``[n]`` f32) emitted by
    :meth:`Estimator.apply_with_state` on plan-carry estimators. The site
    spine routes it out as the sslot cotangent; the train step writes it
    back into the params tree for the next step (core/plan_state.py).
    """

    dx: jax.Array  # [N, d_in] flattened-input gradient
    dw: Optional[jax.Array] = None
    db: Optional[jax.Array] = None
    rows: Optional[jax.Array] = None
    cols: Optional[jax.Array] = None
    db_c: Optional[jax.Array] = None
    probe: Optional[jax.Array] = None
    state: Optional[jax.Array] = None

    @property
    def is_compact(self) -> bool:
        return self.rows is not None


class Estimator:
    """Protocol for one registered VJP estimator (subclassing is convention,
    not requirement — duck typing with these attributes is enough).

    Attributes:
      name: registry key; referenced by ``SketchConfig.backend``.
      supports_compact_grad: the backward emits the compact
        (rows/cols/db_c) form, so the site may carry a CompactGrad slot and
        skip the densify-scatter (see core/compact_grad.py). Estimators that
        return the dense form must leave this False.
      tp_shardable: OPT-IN for the TP-local sharded sketch path
        (``core/sharded_sketch.py``): the estimator's :meth:`plan` emits a
        compact ``ColumnPlan`` (indices + scales) that is valid on a
        TP-local shard of the output gradient, and the sharded path owns the
        matmuls/collectives around it. ``tp_applicable`` consults this flag
        (and calls :meth:`validate`), so a registered estimator routes
        through the same shard_map machinery as the builtin compact/pallas
        backends. Estimators that leave this False fall back to the dense
        mask estimator on TP-sharded sites under ``tp_sketch`` (see
        ``nn.common.dense``).

    Methods (what the framework actually calls):
      validate(cfg): raise ValueError for unsupported SketchConfig
        combinations; called from ``SketchConfig.__post_init__`` for
        non-builtin backends AND from ``tp_applicable`` before the sharded
        path accepts a site — a config is rejected/accepted consistently on
        the single-device and sharded paths.
      apply(cfg, G2d, X2d, w, key, *, has_b, score_psum_axes): the estimator
        backward — returns an :class:`EstimatorVJP`. This is the hot hook:
        ``sketched_linear._bwd`` calls it for every sketched site (with
        ``score_psum_axes=None``; the TP-sharded path routes through
        :meth:`plan` instead and owns the matmuls itself).
      apply_with_probe(...): OPTIONAL telemetry hook, same signature as
        ``apply``. Called instead of ``apply`` when the site carries a probe
        slot (``ExecutionConfig.telemetry``); returns an EstimatorVJP whose
        ``probe`` field carries the per-site probe vector (see
        ``repro/telemetry/probes.py`` for the math and helpers). The default
        delegates to ``apply`` and emits no probe — a third-party estimator
        gets telemetry for free the moment it implements this hook.
      compact_rank(cfg, n): static number of compact rows ``apply`` emits for
        a site of width ``n`` (required when ``supports_compact_grad``;
        consumed by the grad-slot builder in ``core/compact_grad.py``).
      plan(cfg, G2d, w, key, *, want_compact, score_psum_axes): expose the
        sampled sketch (a ``ColumnPlan``) for tests/variance tooling — and,
        when ``tp_shardable``, the hook the TP-sharded backward calls inside
        shard_map (``want_compact=True``, ``score_psum_axes=data axes``).
        Estimators that plan inside ``apply`` and are not tp_shardable may
        leave the default (returns None).
    """

    name: str = "?"
    supports_compact_grad: bool = False
    tp_shardable: bool = False
    # Plan-carry estimators sample the step-t sketch from state carried over
    # from step t-1 (previous-step column scores) instead of a fresh score
    # pass over G — the backward's ONLY read of G is the estimator kernel
    # itself (one HBM pass). The site spine threads the state through the
    # custom_vjp as an extra "sslot" params leaf (SiteSpec.carry_rows /
    # core/plan_state.py); apply_with_state consumes it and returns the
    # refreshed state via EstimatorVJP.state.
    plan_carry: bool = False

    def validate(self, cfg) -> None:  # noqa: B027 — optional hook
        pass

    def plan(self, cfg, G2d, w, key, *, want_compact=True, score_psum_axes=None):
        return None

    def apply(self, cfg, G2d, X2d, w, key, *, has_b, score_psum_axes=None) -> EstimatorVJP:
        raise NotImplementedError

    def apply_with_probe(self, cfg, G2d, X2d, w, key, *, has_b,
                         score_psum_axes=None) -> EstimatorVJP:
        """Telemetry spelling of ``apply``: may fill ``EstimatorVJP.probe``.

        Default: no probe (``probe=None``) — telemetry degrades gracefully
        for estimators that do not implement the hook."""
        return self.apply(cfg, G2d, X2d, w, key, has_b=has_b,
                          score_psum_axes=score_psum_axes)

    def compact_rank(self, cfg, n: int) -> int:
        raise NotImplementedError(f"estimator {self.name!r} is not compact")

    def carry_size(self, cfg, n: int) -> int:
        """Static size of the per-site plan-carry state vector for a site of
        width ``n`` (required when ``plan_carry``; consumed by the sslot
        builder in core/plan_state.py)."""
        raise NotImplementedError(f"estimator {self.name!r} carries no plan")

    def apply_with_state(self, cfg, G2d, X2d, w, key, state, *, has_b,
                         want_probe: bool = False,
                         score_psum_axes=None) -> EstimatorVJP:
        """Plan-carry spelling of ``apply``: sample the sketch from the
        CARRIED ``state`` (previous-step scores; ``None`` = no carry yet —
        estimators must degrade to a uniform prior), run the one-pass
        backward, and return the EstimatorVJP with ``state`` set to the
        refreshed carry. Called instead of ``apply``/``apply_with_probe``
        when ``plan_carry`` — ``want_probe`` folds the telemetry hook in so
        a carry estimator computes at most one backward.

        Default: ignores ``state`` and delegates (no refresh emitted) — a
        non-carry estimator reached through this hook still behaves."""
        if want_probe:
            return self.apply_with_probe(cfg, G2d, X2d, w, key, has_b=has_b,
                                         score_psum_axes=score_psum_axes)
        return self.apply(cfg, G2d, X2d, w, key, has_b=has_b,
                          score_psum_axes=score_psum_axes)


_REGISTRY: Dict[str, Estimator] = {}


def register_estimator(est: Estimator, *, name: Optional[str] = None,
                       overwrite: bool = False) -> Estimator:
    """Register ``est`` under ``name`` (default ``est.name``) and return it.

    Builtin names cannot be overwritten unless ``overwrite=True`` (tests).
    """
    key = name or getattr(est, "name", None)
    if not key or not isinstance(key, str):
        raise ValueError("estimator needs a non-empty string name")
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"estimator {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[key] = est
    return est


def get_estimator(backend: str) -> Estimator:
    try:
        return _REGISTRY[backend]
    except KeyError:
        raise KeyError(
            f"unknown estimator backend {backend!r}; registered: "
            f"{sorted(_REGISTRY)} — register it first via "
            "repro.api.register_estimator") from None


def registered_backends() -> tuple:
    return tuple(sorted(_REGISTRY))
