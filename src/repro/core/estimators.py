"""Estimator registry: pluggable unbiased-VJP backends for sketched linears.

The paper's estimator families were hard-wired as a closed ``if/elif`` over
``SketchConfig.backend`` inside ``core/sketched_linear``. This module turns
that dispatch into a small open registry so related estimator families
(Randomized Automatic Differentiation, Oktay et al. 2021; BASIS ghost
backpropagation, Khasia 2026) can be hosted *without forking core*: a plugin
implements :class:`Estimator`, calls :func:`register_estimator`, and every
``SketchConfig(backend="<name>")`` site — through ``nn.common.dense`` up to
``repro.api.Runtime`` — routes its backward through it.

An estimator owns the *backward math* of one linear site. The surrounding
machinery (custom_vjp plumbing, residuals, CompactGrad slot cotangents,
densify-scatter) stays in ``sketched_linear`` and is shared by all entries.

Contract (unbiasedness): ``E[dX] = Ĝ·W``, ``E[dW] = Ĝᵀ·X``, ``E[db] = Σ Ĝ``
for ``E[Ĝ | G] = G`` — switching estimators never biases the gradient, only
its variance (paper §2.2), which is what makes the registry safe to open.

The three builtin backends (``mask``, ``compact``, ``pallas``) are registered
by ``core/sketched_linear`` at import time; ``repro.core`` (and therefore any
``repro.*`` import) guarantees they are present.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

__all__ = ["Estimator", "EstimatorVJP", "register_estimator", "get_estimator",
           "registered_backends", "BUILTIN_BACKENDS"]

BUILTIN_BACKENDS = ("mask", "compact", "pallas")


@dataclasses.dataclass
class EstimatorVJP:
    """Result of one estimator backward, in one of two forms.

    Dense form (``rows is None``): ``dw`` is the full ``[n, d_in]`` weight
    gradient and ``db`` (when the site has a bias) the full ``[n]`` bias
    gradient.

    Compact form: ``rows [r, d_in]`` are the kept dW rows, ``cols [r]`` their
    int32 row indices into the dense weight, and ``db_c [r]`` the bias
    gradient restricted to the same columns. ``sketched_linear`` scatters
    these into dense cotangents — or, in compact-gradient mode
    (``supports_compact_grad``), forwards them as a ``CompactGrad`` slot
    cotangent with no scatter at all.
    """

    dx: jax.Array  # [N, d_in] flattened-input gradient
    dw: Optional[jax.Array] = None
    db: Optional[jax.Array] = None
    rows: Optional[jax.Array] = None
    cols: Optional[jax.Array] = None
    db_c: Optional[jax.Array] = None

    @property
    def is_compact(self) -> bool:
        return self.rows is not None


class Estimator:
    """Protocol for one registered VJP estimator (subclassing is convention,
    not requirement — duck typing with these attributes is enough).

    Attributes:
      name: registry key; referenced by ``SketchConfig.backend``.
      supports_compact_grad: the backward emits the compact
        (rows/cols/db_c) form, so the site may carry a CompactGrad slot and
        skip the densify-scatter (see core/compact_grad.py). Estimators that
        return the dense form must leave this False.

    Methods (what the framework actually calls):
      validate(cfg): raise ValueError for unsupported SketchConfig
        combinations; called from ``SketchConfig.__post_init__`` for
        non-builtin backends.
      apply(cfg, G2d, X2d, w, key, *, has_b, score_psum_axes): the estimator
        backward — returns an :class:`EstimatorVJP`. This is the hot hook:
        ``sketched_linear._bwd`` calls it for every sketched site (today
        with ``score_psum_axes=None`` — the TP-sharded sketch path in
        ``core/sharded_sketch.py`` plans its batch-shared sketch outside the
        registry and does not route through ``apply``; custom estimators run
        single-replica semantics under ``tp_sketch``, see ``nn.common
        .dense``).
      compact_rank(cfg, n): static number of compact rows ``apply`` emits for
        a site of width ``n`` (required when ``supports_compact_grad``;
        consumed by the grad-slot builder in ``core/compact_grad.py``).
      plan(cfg, G2d, w, key, *, want_compact, score_psum_axes): OPTIONAL
        diagnostic hook — expose the sampled sketch (a ``ColumnPlan`` or an
        estimator-private object) for tests/variance tooling. Core never
        calls it; estimators that plan inside ``apply`` may leave the
        default (returns None).
    """

    name: str = "?"
    supports_compact_grad: bool = False

    def validate(self, cfg) -> None:  # noqa: B027 — optional hook
        pass

    def plan(self, cfg, G2d, w, key, *, want_compact=True, score_psum_axes=None):
        return None

    def apply(self, cfg, G2d, X2d, w, key, *, has_b, score_psum_axes=None) -> EstimatorVJP:
        raise NotImplementedError

    def compact_rank(self, cfg, n: int) -> int:
        raise NotImplementedError(f"estimator {self.name!r} is not compact")


_REGISTRY: Dict[str, Estimator] = {}


def register_estimator(est: Estimator, *, name: Optional[str] = None,
                       overwrite: bool = False) -> Estimator:
    """Register ``est`` under ``name`` (default ``est.name``) and return it.

    Builtin names cannot be overwritten unless ``overwrite=True`` (tests).
    """
    key = name or getattr(est, "name", None)
    if not key or not isinstance(key, str):
        raise ValueError("estimator needs a non-empty string name")
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"estimator {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[key] = est
    return est


def get_estimator(backend: str) -> Estimator:
    try:
        return _REGISTRY[backend]
    except KeyError:
        raise KeyError(
            f"unknown estimator backend {backend!r}; registered: "
            f"{sorted(_REGISTRY)} — register it first via "
            "repro.api.register_estimator") from None


def registered_backends() -> tuple:
    return tuple(sorted(_REGISTRY))
