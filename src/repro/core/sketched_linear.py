"""Linear layer with an unbiased sketched backward pass (paper App. C).

Forward (practical convention):  ``y = x @ W.T (+ b)`` with ``x: [..., d_in]``,
``W: [d_out, d_in]``. The *backward* replaces the exact VJP by the configured
unbiased estimator, resolved through the open registry in
``core/estimators.py`` (``SketchConfig.backend`` is the registry key):

* mask backend      — Alg. 3 / 4 / 5 / 6 verbatim (dense masked matmuls),
* compact backend   — gather the r kept columns once, reduced-shape matmuls
                      (TPU-native realisation of the same estimator;
                      bit-identical in expectation, and *exactly* identical
                      to mask for the same key),
* pallas backend    — compact semantics; block-granular configs run the
                      one-pass fused kernel (dX + compact dW + compact db
                      from a single HBM stream of G's kept blocks).

Third-party estimators (RAD / BASIS-style families) register additional
backends via ``repro.api.register_estimator`` — this module never needs to
change for them. Estimators own only the backward *math*; the custom_vjp
plumbing, residuals, and CompactGrad slot handling live in the one
sketched-site spine (``core/site.py``) and are shared across the local and
tensor-parallel execution plans.

The RNG key rides through the forward as a regular argument and is consumed
only in the backward (stored in residuals), so a jitted ``grad`` of a model
containing many sketched layers stays a pure function of ``(params, batch,
step_key)``.

Compact gradients: when a :class:`~repro.core.compact_grad.CompactGrad`
*slot* is passed (``grad_slot=...``, normally threaded in by ``nn.common
.dense`` from the params tree), estimators emitting the compact form return
the weight gradient through the slot's cotangent as (rows, indices) — no
densify-scatter — and a structurally zero dense cotangent for ``w``. See
core/compact_grad.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import estimators
from repro.core.compact_grad import CompactGrad, compact_rank
from repro.core.estimators import EstimatorVJP
from repro.core.scores import kernel_reduction_mode, scores_from_kernel_reduction
from repro.core.sketching import (COLUMN_METHODS, SketchConfig, column_plan,
                                  column_plan_from_scores, effective_cfg,
                                  sketch_dense)

__all__ = ["sketched_linear", "linear"]


# ---------------------------------------------------------------------------
# Builtin estimators (the registry's seed population).
# ---------------------------------------------------------------------------


class _MaskEstimator(estimators.Estimator):
    """Paper-faithful dense backend: full-size Ĝ, dense downstream matmuls."""

    name = "mask"
    supports_compact_grad = False

    def plan(self, cfg, G2d, w, key, *, want_compact=False, score_psum_axes=None):
        if cfg.method not in COLUMN_METHODS:
            return None
        return column_plan(cfg, G2d, w, key, want_compact=want_compact,
                           score_psum_axes=score_psum_axes)

    def apply(self, cfg, G2d, X2d, w, key, *, has_b, score_psum_axes=None):
        if cfg.method == "per_element":
            # Alg. 3: independent element masks on W (for dX) and X (for dW);
            # bias gradient stays exact.
            kw, kx = jax.random.split(key)
            p = cfg.budget
            mw = jax.random.bernoulli(kw, p, w.shape).astype(w.dtype)
            mx = jax.random.bernoulli(kx, p, X2d.shape).astype(X2d.dtype)
            return EstimatorVJP(dx=(G2d @ (w * mw)) / p,
                                dw=(G2d.T @ (X2d * mx)) / p,
                                db=jnp.sum(G2d, axis=0) if has_b else None)
        Ghat = sketch_dense(cfg, G2d, w, key)
        return EstimatorVJP(dx=Ghat @ w, dw=Ghat.T @ X2d,
                            db=jnp.sum(Ghat, axis=0) if has_b else None)

    def apply_with_probe(self, cfg, G2d, X2d, w, key, *, has_b,
                         score_psum_axes=None):
        """Telemetry hook: column-family methods expose the plan marginals,
        so the probe is a cheap reduction over the (already materialized)
        sketched dW — same gate, same key, bit-identical gradients to
        ``apply``. Other methods fall back probeless."""
        if cfg.method not in COLUMN_METHODS or cfg.is_noop:
            return self.apply(cfg, G2d, X2d, w, key, has_b=has_b,
                              score_psum_axes=score_psum_axes)
        from repro.telemetry.probes import probe_from_rows

        plan = column_plan(cfg, G2d, w, key, want_compact=False,
                           score_psum_axes=score_psum_axes)
        Ghat = G2d * plan.gate[None, :].astype(G2d.dtype)
        dw = Ghat.T @ X2d
        return EstimatorVJP(dx=Ghat @ w, dw=dw,
                            db=jnp.sum(Ghat, axis=0) if has_b else None,
                            probe=probe_from_rows(dw, plan.probs))


class _CompactEstimator(estimators.Estimator):
    """Exact-r compact backend: gather kept columns, reduced-shape matmuls
    (single-gather fused XLA oracle on block-granular configs)."""

    name = "compact"
    supports_compact_grad = True
    tp_shardable = True  # plan() is shard-local-valid; sharded_sketch routes it

    def validate(self, cfg) -> None:
        if cfg.method not in COLUMN_METHODS:
            raise ValueError(
                f"backend {cfg.backend!r} requires a column-family method, "
                f"got {cfg.method!r}")
        if not cfg.exact_r:
            raise ValueError(
                f"{cfg.backend}/pallas backends need exact_r=True (static shapes)")

    def plan(self, cfg, G2d, w, key, *, want_compact=True, score_psum_axes=None):
        return column_plan(cfg, G2d, w, key, want_compact=want_compact,
                           score_psum_axes=score_psum_axes)

    def compact_rank(self, cfg, n: int) -> int:
        return compact_rank(cfg, n)

    def _apply_planned(self, cfg, G2d, X2d, w, key, *, score_psum_axes=None):
        n = G2d.shape[-1]
        cfg = effective_cfg(cfg, n)
        plan = column_plan(cfg, G2d, w, key, want_compact=True,
                           score_psum_axes=score_psum_axes)
        idx, scales = plan.indices, plan.scales
        if cfg.block > 1:
            # Fused one-pass backward: dX, compact dW rows and compact db all
            # come from a single stream over G's kept column-blocks.
            dX2d, dWc, db_blk = self._fused(cfg, G2d, idx, scales, w, X2d)
            bs = cfg.block
            cols = (idx[:, None] * bs
                    + jnp.arange(bs, dtype=idx.dtype)[None, :]).reshape(-1)
            out = EstimatorVJP(dx=dX2d, rows=dWc.reshape(-1, w.shape[1]),
                               cols=cols, db_c=db_blk.reshape(-1))
        else:
            out = self._per_column(G2d, idx, scales, w, X2d)
        return out, plan

    def apply(self, cfg, G2d, X2d, w, key, *, has_b, score_psum_axes=None):
        return self._apply_planned(cfg, G2d, X2d, w, key,
                                   score_psum_axes=score_psum_axes)[0]

    def apply_with_probe(self, cfg, G2d, X2d, w, key, *, has_b,
                         score_psum_axes=None):
        """Telemetry hook: the compact rows + the plan's keep marginals at
        the kept columns are everything the probe needs — one [r]-sized
        reduction on top of the backward the estimator already did."""
        from repro.telemetry.probes import probe_from_rows

        out, plan = self._apply_planned(cfg, G2d, X2d, w, key,
                                        score_psum_axes=score_psum_axes)
        p_kept = jnp.take(plan.probs, out.cols)
        out.probe = probe_from_rows(out.rows, p_kept)
        return out

    def _fused(self, cfg, G2d, idx, scales, w, X2d):
        from repro.kernels import ref as kref

        return kref.block_gather_matmul_fused_ref(G2d, idx, scales, w, X2d,
                                                  block=cfg.block)

    def _per_column(self, G2d, idx, scales, w, X2d):
        # single gather of G shared by dX, dW and db
        Gc = jnp.take(G2d, idx, axis=1) * scales[None, :].astype(G2d.dtype)
        Wc = jnp.take(w, idx, axis=0)
        return EstimatorVJP(dx=Gc @ Wc, rows=Gc.T @ X2d, cols=idx,
                            db_c=jnp.sum(Gc, axis=0))


class _PallasEstimator(_CompactEstimator):
    """Compact semantics realised by the Pallas TPU kernels."""

    name = "pallas"

    def _fused(self, cfg, G2d, idx, scales, w, X2d):
        from repro.kernels import ops as kops

        return kops.block_gather_matmul_fused(G2d, idx, scales, w, X2d,
                                              block=cfg.block)

    def _per_column(self, G2d, idx, scales, w, X2d):
        from repro.kernels import ops as kops

        dX2d = kops.gather_cols_matmul(G2d, idx, scales, w)
        rows = kops.gather_cols_matmul_dw(G2d, idx, scales, X2d)
        db_c = (jnp.take(G2d, idx, axis=1)
                * scales[None, :].astype(G2d.dtype)).sum(0)
        return EstimatorVJP(dx=dX2d, rows=rows, cols=idx, db_c=db_c)


class _PlanCarryEstimator(_PallasEstimator):
    """Shared machinery of the one-HBM-pass estimators: the step-t sketch is
    sampled from CARRIED column scores (previous step, or a uniform prior on
    the first step) — no score pass over G — and the backward kernel's
    single sweep over G produces the gradient AND the score refresh.

    Unbiasedness does not depend on the carry being fresh: conditioned on
    the carried scores, every column keeps a strictly positive probability
    (``optimal_probabilities``'s relative floor + the all-zero guard in
    ``column_plan_from_scores``) and kept columns are rescaled by 1/p, so
    ``E[dW | carry] = GᵀX`` exactly — staleness only moves variance, which
    the telemetry probe measures online (docs/telemetry.md).
    """

    plan_carry = True
    # the carried state is not threaded through the TP shard_map path; under
    # tp_sketch these sites fall back like any non-shardable estimator
    tp_shardable = False

    def validate(self, cfg) -> None:
        super().validate(cfg)
        if kernel_reduction_mode(cfg.method) is None:
            raise ValueError(
                f"backend {cfg.backend!r} needs an l1/l2-family score method "
                f"(its fresh scores come from the backward kernel's in-sweep "
                f"column reduction), got {cfg.method!r}")

    def carry_size(self, cfg, n: int) -> int:
        return n

    def apply(self, cfg, G2d, X2d, w, key, *, has_b, score_psum_axes=None):
        return self.apply_with_state(cfg, G2d, X2d, w, key, None, has_b=has_b,
                                     score_psum_axes=score_psum_axes)

    def apply_with_probe(self, cfg, G2d, X2d, w, key, *, has_b,
                         score_psum_axes=None):
        return self.apply_with_state(cfg, G2d, X2d, w, key, None, has_b=has_b,
                                     want_probe=True,
                                     score_psum_axes=score_psum_axes)

    def apply_with_state(self, cfg, G2d, X2d, w, key, state, *, has_b,
                         want_probe=False, score_psum_axes=None):
        from repro.telemetry.probes import probe_from_rows

        n = G2d.shape[-1]
        cfg = effective_cfg(cfg, n)
        if state is None:
            state = jnp.ones((n,), jnp.float32)  # uniform prior (first step)
        plan = column_plan_from_scores(cfg, state, key, want_compact=True)
        out = self._one_pass(cfg, G2d, plan, w, X2d, state)
        if want_probe:
            out.probe = probe_from_rows(out.rows, jnp.take(plan.probs, out.cols))
        return out

    def _one_pass(self, cfg, G2d, plan, w, X2d, state) -> EstimatorVJP:
        raise NotImplementedError


class _OnePassEstimator(_PlanCarryEstimator):
    """Streaming selection: ALL of G streams through the backward kernel
    once; kept blocks (gated by the plan sampled from the carried scores)
    feed dX/compact-dW/db while EVERY column's fresh score is reduced in the
    same sweep — a full score refresh per step, one HBM pass over G."""

    name = "onepass"

    def _one_pass(self, cfg, G2d, plan, w, X2d, state):
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref

        mode = kernel_reduction_mode(cfg.method)
        idx, scales = plan.indices, plan.scales
        if cfg.block > 1:
            dX2d, dWc, db_blk, red = kops.block_stream_matmul_fused(
                G2d, idx, scales, w, X2d, block=cfg.block, score_mode=mode)
            bs = cfg.block
            cols = (idx[:, None] * bs
                    + jnp.arange(bs, dtype=idx.dtype)[None, :]).reshape(-1)
            rows, db_c = dWc.reshape(-1, w.shape[1]), db_blk.reshape(-1)
        else:
            dX2d, rows, db_c, red = kref.gather_cols_onepass_ref(
                G2d, idx, scales, w, X2d, score_mode=mode)
            cols = idx
        fresh = scores_from_kernel_reduction(cfg.method, red)
        return EstimatorVJP(dx=dX2d, rows=rows, cols=cols, db_c=db_c,
                            state=fresh)


class _StalePlanEstimator(_PlanCarryEstimator):
    """Stale-plan estimator: the kept-only fused gather backward (same G
    traffic as the ``pallas`` backend's fused kernel — dropped blocks are
    never read), with the kept columns' raw scores reduced from the tiles
    already in VMEM. The refresh is PARTIAL — unkept columns keep their
    carried score until sampled — so scores can be arbitrarily stale; the
    probability floor keeps every column visited eventually and the
    estimator unbiased (see class docstring above)."""

    name = "stale"

    def _one_pass(self, cfg, G2d, plan, w, X2d, state):
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref

        mode = kernel_reduction_mode(cfg.method)
        idx, scales = plan.indices, plan.scales
        if cfg.block > 1:
            dX2d, dWc, db_blk, kept_red = kops.block_gather_matmul_fused(
                G2d, idx, scales, w, X2d, block=cfg.block,
                with_scores=True, score_mode=mode)
            bs = cfg.block
            cols = (idx[:, None] * bs
                    + jnp.arange(bs, dtype=idx.dtype)[None, :]).reshape(-1)
            rows, db_c = dWc.reshape(-1, w.shape[1]), db_blk.reshape(-1)
            kept_red = kept_red.reshape(-1)
        else:
            dX2d, rows, db_c, kept_red = kref.gather_cols_fused_scores_ref(
                G2d, idx, scales, w, X2d, score_mode=mode)
            cols = idx
        fresh = state.at[cols].set(
            scores_from_kernel_reduction(cfg.method, kept_red))
        return EstimatorVJP(dx=dX2d, rows=rows, cols=cols, db_c=db_c,
                            state=fresh)


estimators.register_estimator(_MaskEstimator())
estimators.register_estimator(_CompactEstimator())
estimators.register_estimator(_PallasEstimator())
estimators.register_estimator(_OnePassEstimator())
estimators.register_estimator(_StalePlanEstimator())


# ---------------------------------------------------------------------------
# Spine instantiation: the shared custom_vjp plumbing lives in core/site.py.
# ---------------------------------------------------------------------------


def sketched_linear(x, w, b=None, *, key=None, cfg: Optional[SketchConfig] = None,
                    grad_slot: Optional[CompactGrad] = None,
                    probe_slot=None, plan_state=None):
    """Public entry point. ``cfg=None`` (or noop cfg / no key) = exact linear.

    This is the *local* :class:`~repro.core.site.ExecutionPlan` instantiation
    of the one sketched-site spine (``core/site.py``) — the custom_vjp
    plumbing, residuals and slot cotangents are owned there and shared with
    the TP plans.

    ``probe_slot`` (a zero ``[PROBE_WIDTH]`` f32 leaf, normally threaded in
    by ``nn.common.dense`` from the params tree) switches the backward to
    the estimator's ``apply_with_probe`` hook and routes the per-site probe
    vector out through the slot's cotangent — see repro/telemetry/probes.py.

    ``plan_state`` (an ``[n]`` f32 leaf, normally threaded in by
    ``nn.common.dense`` from the params tree — core/plan_state.py) is the
    carried plan state of plan-carry estimators ("onepass"/"stale"): the
    previous step's column scores the backward samples its sketch from. The
    refreshed scores ride out as this argument's cotangent.
    """
    from repro.core import site

    return site.sketched_site(site.local_spec(cfg), x, w, b, key,
                              grad_slot, probe_slot, plan_state)


# Alias used across the nn substrate.
linear = sketched_linear
