"""Linear layer with an unbiased sketched backward pass (paper App. C).

Forward (practical convention):  ``y = x @ W.T (+ b)`` with ``x: [..., d_in]``,
``W: [d_out, d_in]``. The *backward* replaces the exact VJP by the configured
unbiased estimator:

* mask backend      — Alg. 3 / 4 / 5 / 6 verbatim (dense masked matmuls),
* compact backend   — gather the r kept columns once, reduced-shape matmuls
                      (TPU-native realisation of the same estimator;
                      bit-identical in expectation, and *exactly* identical
                      to mask for the same key),
* pallas backend    — compact semantics; block-granular configs run the
                      one-pass fused kernel (dX + compact dW + compact db
                      from a single HBM stream of G's kept blocks).

The RNG key rides through the forward as a regular argument and is consumed
only in the backward (stored in residuals), so a jitted ``grad`` of a model
containing many sketched layers stays a pure function of ``(params, batch,
step_key)``.

Compact gradients: when a :class:`~repro.core.compact_grad.CompactGrad`
*slot* is passed (``grad_slot=...``, normally threaded in by ``nn.common
.dense`` from the params tree), the compact paths return the weight gradient
through the slot's cotangent as (rows, indices) — no densify-scatter — and a
structurally zero dense cotangent for ``w``. See core/compact_grad.py.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.compact_grad import CompactGrad
from repro.core.sketching import SketchConfig, column_plan, sketch_dense

__all__ = ["sketched_linear", "linear"]


def _flatten_leading(x):
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sketched_linear(cfg: SketchConfig, x, w, b, key, slot):
    y = jnp.einsum("...i,oi->...o", x, w)
    if b is not None:
        y = y + b
    return y


def _fwd(cfg: SketchConfig, x, w, b, key, slot):
    y = _sketched_linear(cfg, x, w, b, key, slot)
    return y, (x, w, key, b is not None, slot)


def _bwd(cfg: SketchConfig, res, g):
    x, w, key, has_b, slot = res
    G2d, lead = _flatten_leading(g)
    X2d, _ = _flatten_leading(x)
    n = G2d.shape[-1]

    if cfg.method == "per_element":
        # Alg. 3: independent element masks on W (for dX) and X (for dW);
        # bias gradient stays exact.
        kw, kx = jax.random.split(key)
        p = cfg.budget
        mw = jax.random.bernoulli(kw, p, w.shape).astype(w.dtype)
        mx = jax.random.bernoulli(kx, p, X2d.shape).astype(x.dtype)
        dX = (G2d @ (w * mw)) / p
        dW = (G2d.T @ (X2d * mx)) / p
        db = jnp.sum(G2d, axis=0) if has_b else None
        return _pack(dX.reshape(x.shape), dW.astype(w.dtype), db, has_b, slot)

    use_compact = cfg.backend in ("compact", "pallas") and not cfg.is_noop
    if use_compact:
        from repro.core.sketching import effective_cfg

        cfg = effective_cfg(cfg, n)
        plan = column_plan(cfg, G2d, w, key, want_compact=True)
        idx, scales = plan.indices, plan.scales
        if cfg.block > 1:
            # Fused one-pass backward: dX, compact dW rows and compact db all
            # come from a single stream over G's kept column-blocks (Pallas
            # kernel on the pallas backend, single-gather XLA oracle on
            # compact).
            if cfg.backend == "pallas":
                from repro.kernels import ops as kops

                dX2d, dWc, db_blk = kops.block_gather_matmul_fused(
                    G2d, idx, scales, w, X2d, block=cfg.block)
            else:
                from repro.kernels import ref as kref

                dX2d, dWc, db_blk = kref.block_gather_matmul_fused_ref(
                    G2d, idx, scales, w, X2d, block=cfg.block)
            bs = cfg.block
            cols = (idx[:, None] * bs + jnp.arange(bs, dtype=idx.dtype)[None, :]).reshape(-1)
            rows = dWc.reshape(-1, w.shape[1])
            db_c = db_blk.reshape(-1)
        elif cfg.backend == "pallas":
            from repro.kernels import ops as kops

            dX2d = kops.gather_cols_matmul(G2d, idx, scales, w)
            rows = kops.gather_cols_matmul_dw(G2d, idx, scales, X2d)
            cols = idx
            db_c = (jnp.take(G2d, idx, axis=1) * scales[None, :].astype(g.dtype)).sum(0)
        else:
            # single gather of G shared by dX, dW and db (the db gather used
            # to be repeated per output)
            Gc = jnp.take(G2d, idx, axis=1) * scales[None, :].astype(g.dtype)
            Wc = jnp.take(w, idx, axis=0)
            dX2d = Gc @ Wc
            rows = Gc.T @ X2d
            cols = idx
            db_c = jnp.sum(Gc, axis=0)
        db = None
        if has_b:
            db = jnp.zeros((n,), g.dtype).at[cols].add(db_c.astype(g.dtype))
        dX = dX2d.reshape(x.shape)
        if slot is not None:
            # compact-gradient mode: rows/indices ride the slot cotangent,
            # the dense w cotangent is structural zeros (folded by XLA)
            slot_ct = CompactGrad(rows=rows.astype(jnp.float32),
                                  idx=cols.astype(jnp.float32))
            return (dX, jnp.zeros_like(w), db if has_b else None, None, slot_ct)
        dW = jnp.zeros_like(w).at[cols].add(rows.astype(w.dtype))
        return _pack(dX, dW, db, has_b, slot)

    # Dense mask backend (paper-faithful), incl. per_sample / rcs / none.
    Ghat = sketch_dense(cfg, G2d, w, key)
    dX = Ghat @ w
    dW = Ghat.T @ X2d
    db = jnp.sum(Ghat, axis=0) if has_b else None
    return _pack(dX.reshape(x.shape), dW.astype(w.dtype), db, has_b, slot)


def _pack(dx, dw, db, has_b, slot):
    # slot primal is all-zeros, so returning it doubles as its zero cotangent
    return (dx, dw, db if has_b else None, None, slot)


_sketched_linear.defvjp(_fwd, _bwd)


def sketched_linear(x, w, b=None, *, key=None, cfg: Optional[SketchConfig] = None,
                    grad_slot: Optional[CompactGrad] = None):
    """Public entry point. ``cfg=None`` (or noop cfg / no key) = exact linear."""
    if cfg is None or cfg.is_noop or key is None:
        y = jnp.einsum("...i,oi->...o", x, w)
        return y + b if b is not None else y
    return _sketched_linear(cfg, x, w, b, key, grad_slot)


# Alias used across the nn substrate.
linear = sketched_linear
