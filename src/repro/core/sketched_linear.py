"""Linear layer with an unbiased sketched backward pass (paper App. C).

Forward (practical convention):  ``y = x @ W.T (+ b)`` with ``x: [..., d_in]``,
``W: [d_out, d_in]``. The *backward* replaces the exact VJP by the configured
unbiased estimator:

* mask backend      — Alg. 3 / 4 / 5 / 6 verbatim (dense masked matmuls),
* compact backend   — gather the r kept columns, reduced-shape matmuls,
                      scatter dW rows (TPU-native realisation of the same
                      estimator; bit-identical in expectation, and *exactly*
                      identical to mask for the same key),
* pallas backend    — compact semantics, Pallas gather-matmul kernels.

The RNG key rides through the forward as a regular argument and is consumed
only in the backward (stored in residuals), so a jitted ``grad`` of a model
containing many sketched layers stays a pure function of ``(params, batch,
step_key)``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sketching import SketchConfig, column_plan, sketch_dense

__all__ = ["sketched_linear", "linear"]


def _flatten_leading(x):
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sketched_linear(cfg: SketchConfig, x, w, b, key):
    y = jnp.einsum("...i,oi->...o", x, w)
    if b is not None:
        y = y + b
    return y


def _fwd(cfg: SketchConfig, x, w, b, key):
    y = _sketched_linear(cfg, x, w, b, key)
    return y, (x, w, key, b is not None)


def _bwd(cfg: SketchConfig, res, g):
    x, w, key, has_b = res
    G2d, lead = _flatten_leading(g)
    X2d, _ = _flatten_leading(x)
    n = G2d.shape[-1]

    if cfg.method == "per_element":
        # Alg. 3: independent element masks on W (for dX) and X (for dW);
        # bias gradient stays exact.
        kw, kx = jax.random.split(key)
        p = cfg.budget
        mw = jax.random.bernoulli(kw, p, w.shape).astype(w.dtype)
        mx = jax.random.bernoulli(kx, p, X2d.shape).astype(x.dtype)
        dX = (G2d @ (w * mw)) / p
        dW = (G2d.T @ (X2d * mx)) / p
        db = jnp.sum(G2d, axis=0) if has_b else None
        return _pack(dX.reshape(x.shape), dW.astype(w.dtype), db, g.dtype, has_b)

    use_compact = cfg.backend in ("compact", "pallas") and not cfg.is_noop
    if use_compact:
        from repro.core.sketching import effective_cfg

        cfg = effective_cfg(cfg, n)
        plan = column_plan(cfg, G2d, w, key, want_compact=True)
        idx, scales = plan.indices, plan.scales
        if cfg.block > 1:
            if cfg.backend == "pallas":
                from repro.kernels import ops as kops

                dX2d = kops.block_gather_matmul(G2d, idx, scales, w, block=cfg.block)
                dWc = kops.block_gather_matmul_dw(G2d, idx, scales, X2d, block=cfg.block)
            # expand block plan to per-column indices for the XLA paths below
            bs = cfg.block
            cols = (idx[:, None] * bs + jnp.arange(bs, dtype=idx.dtype)[None, :]).reshape(-1)
            col_scales = jnp.repeat(scales, bs)
            idx, scales = cols, col_scales
            if cfg.backend == "pallas":
                dW = jnp.zeros_like(w).at[idx].add(dWc.reshape(-1, w.shape[1]).astype(w.dtype))
                db = None
                if has_b:
                    db_c = (jnp.take(G2d, idx, axis=1) * scales[None, :].astype(g.dtype)).sum(0)
                    db = jnp.zeros((n,), g.dtype).at[idx].add(db_c)
                return _pack(dX2d.reshape(x.shape), dW, db, g.dtype, has_b)
        if cfg.backend == "pallas":
            from repro.kernels import ops as kops

            dX2d = kops.gather_cols_matmul(G2d, idx, scales, w)
            dWc = kops.gather_cols_matmul_dw(G2d, idx, scales, X2d)
        else:
            Gc = jnp.take(G2d, idx, axis=1) * scales[None, :].astype(g.dtype)
            Wc = jnp.take(w, idx, axis=0)
            dX2d = Gc @ Wc
            dWc = Gc.T @ X2d
        dW = jnp.zeros_like(w).at[idx].add(dWc.astype(w.dtype))
        db = None
        if has_b:
            db_c = (jnp.take(G2d, idx, axis=1) * scales[None, :].astype(g.dtype)).sum(0)
            db = jnp.zeros((n,), g.dtype).at[idx].add(db_c)
        return _pack(dX2d.reshape(x.shape), dW, db, g.dtype, has_b)

    # Dense mask backend (paper-faithful), incl. per_sample / rcs / none.
    Ghat = sketch_dense(cfg, G2d, w, key)
    dX = Ghat @ w
    dW = Ghat.T @ X2d
    db = jnp.sum(Ghat, axis=0) if has_b else None
    return _pack(dX.reshape(x.shape), dW.astype(w.dtype), db, g.dtype, has_b)


def _pack(dx, dw, db, gdtype, has_b):
    return (dx, dw, db if has_b else None, None)


_sketched_linear.defvjp(_fwd, _bwd)


def sketched_linear(x, w, b=None, *, key=None, cfg: Optional[SketchConfig] = None):
    """Public entry point. ``cfg=None`` (or noop cfg / no key) = exact linear."""
    if cfg is None or cfg.is_noop or key is None:
        y = jnp.einsum("...i,oi->...o", x, w)
        return y + b if b is not None else y
    return _sketched_linear(cfg, x, w, b, key)


# Alias used across the nn substrate.
linear = sketched_linear
