"""Paper core: unbiased randomized VJP sketching."""
from repro.core.compact_grad import CompactGrad
from repro.core.estimators import (Estimator, EstimatorVJP, get_estimator,
                                   register_estimator, registered_backends)
from repro.core.policy import POLICY_PRESETS, SketchPolicy
from repro.core.sketched_linear import linear, sketched_linear
from repro.core.sketching import (
    ALL_METHODS,
    COLUMN_METHODS,
    ColumnPlan,
    SketchConfig,
    column_gate,
    column_plan,
    sketch_dense,
    static_rank,
)
from repro.core import solver, scores, variance

__all__ = [
    "ALL_METHODS",
    "COLUMN_METHODS",
    "ColumnPlan",
    "CompactGrad",
    "Estimator",
    "EstimatorVJP",
    "POLICY_PRESETS",
    "get_estimator",
    "register_estimator",
    "registered_backends",
    "SketchConfig",
    "SketchPolicy",
    "column_gate",
    "column_plan",
    "linear",
    "scores",
    "sketch_dense",
    "sketched_linear",
    "solver",
    "static_rank",
    "variance",
]
