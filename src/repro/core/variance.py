"""Variance diagnostics for sketched backprop (Prop. 2.2).

Monte-Carlo estimation of the gradient-surrogate variance and of its
decomposition into the *local* term (distortion injected at node i) and the
*propagated* term (upstream variance pushed through the exact Jacobian).
Used by tests (empirical validation of Prop. 2.2) and by
``benchmarks/bench_variance.py`` (Eq. (6) accounting).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

__all__ = ["mc_gradient_variance", "chain_variance_decomposition"]


def mc_gradient_variance(grad_fn: Callable, exact_grad, keys) -> dict:
    """E||ĝ - g||² and ||E[ĝ] - g||² (bias check) over Monte-Carlo keys.

    ``grad_fn(key) -> pytree`` must return the sketched gradient; ``exact_grad``
    is the deterministic reference pytree.
    """
    flat_exact, _ = ravel_pytree(exact_grad)

    def one(key):
        g = grad_fn(key)
        flat, _ = ravel_pytree(g)
        return flat

    samples = jax.lax.map(one, keys)
    mean = jnp.mean(samples, axis=0)
    sq_err = jnp.mean(jnp.sum(jnp.square(samples - flat_exact[None, :]), axis=1))
    bias_sq = jnp.sum(jnp.square(mean - flat_exact))
    return {
        "variance": sq_err,
        "bias_sq": bias_sq,
        "exact_norm_sq": jnp.sum(jnp.square(flat_exact)),
        "n_samples": samples.shape[0],
    }


def chain_variance_decomposition(Ws, G_out, sketch_vjp, keys):
    """Empirical validation of Prop. 2.2 on a chain of linear nodes.

    Backward chain (practical row convention): the gradient entering the chain
    is ``G_out`` (exact seed, rows = samples); node k applies the VJP
    ``g_k = g_{k+1} @ W_k`` whose sketched version is
    ``sketch_vjp(k, key, W_k, g) -> ĝ`` with ``E[ĝ | g] = g @ W_k``.

    Prop. 2.2 for a chain (one successor per node) reads, at every node k:

        E||ĝ_k − g_k||² = E||Ĵ_k ĝ_{k+1} − J_k ĝ_{k+1}||²   (local)
                        + E||J_k (ĝ_{k+1} − g_{k+1})||²      (propagated)

    i.e. the cross-term cancels by conditional unbiasedness. We measure all
    three quantities by Monte-Carlo and return per-node dicts so tests can
    assert total ≈ local + propagated.
    """
    L = len(Ws)
    # exact gradients: exact[L] = G_out, exact[k] = exact[k+1] @ W_k
    exact = [None] * (L + 1)
    exact[L] = G_out
    for k in range(L - 1, -1, -1):
        exact[k] = exact[k + 1] @ Ws[k]

    # one fused device computation over all MC keys (same draws and same
    # statistics as the eager per-key loop, ~100x less dispatch overhead)
    def one(key):
        ghat = G_out
        tot, loc, pro = [], [], []
        for k in range(L - 1, -1, -1):
            kk = jax.random.fold_in(key, k)
            ghat_next = ghat  # ĝ_{k+1}
            exact_push = ghat_next @ Ws[k]  # J_k ĝ_{k+1}
            ghat = sketch_vjp(k, kk, Ws[k], ghat_next)  # ĝ_k = Ĵ_k ĝ_{k+1}
            tot.append(jnp.sum(jnp.square(ghat - exact[k])))
            loc.append(jnp.sum(jnp.square(ghat - exact_push)))
            pro.append(jnp.sum(jnp.square(exact_push - exact[k])))
        # lists run k = L-1 .. 0; flip so index == node
        return tuple(jnp.stack(v)[::-1] for v in (tot, loc, pro))

    tot, loc, pro = jax.jit(lambda ks: jax.lax.map(one, ks))(jnp.stack(list(keys)))
    to_list = lambda a: [float(v) for v in jnp.mean(a, axis=0)]
    return {"total": to_list(tot), "local": to_list(loc), "propagated": to_list(pro)}
