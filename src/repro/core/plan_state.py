"""Plan-carry state: previous-step column scores threaded through the step.

Plan-carry estimators ("onepass", "stale" — see ``core/sketched_linear``)
sample the step-t sketch from scores computed at step t-1, so the backward's
only read of ``G`` is the estimator kernel itself (ISSUE: one HBM pass over
G). The carry has to survive from one jitted step to the next, which means it
must live in ``TrainState`` — and to reach the site backward inside
``jax.grad`` it must ride the *params* tree, the same transport trick as
``core/compact_grad`` gradient slots and ``telemetry/probes`` probe slots.

Unlike those (zero slots merged per-step and stripped from the gradients),
the carry is a PERMANENT params leaf:

* :func:`with_plan_state` merges an ``"sslot"`` leaf (``[n]`` f32, ones = the
  uniform prior) into every carry-capable site at ``init_state`` time.
* ``nn.common.dense`` threads the leaf into the site spine; the backward
  defines its cotangent to be the REFRESHED scores (``EstimatorVJP.state``).
* :func:`collect_plan_state` pulls the fresh scores out of the gradient tree
  and zeroes the leaves (tree congruence for the optimizer; an sslot never
  contributes to the grad norm or the moment buffers).
* :func:`write_plan_state` overwrites the post-update params' sslot leaves
  with the fresh scores — before sentinel gating, so a tripped step keeps
  the old carry along with the old weights.

Unbiasedness does not depend on the carry's freshness: the solver floors
every keep probability strictly above zero (``optimal_probabilities``'s
relative eps floor + ``_weights_from_scores``'s all-zero guard), so
conditioned on ANY carry value ``E[dW | carry] = GᵀX`` exactly — staleness
only moves variance (measured by the telemetry probes; docs/telemetry.md).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import estimators

__all__ = ["PLAN_SLOT", "plan_carry_capable", "policy_uses_carry",
           "with_plan_state", "collect_plan_state", "write_plan_state"]

PLAN_SLOT = "sslot"


def plan_carry_capable(cfg) -> bool:
    """Does this site's estimator carry a plan? (slot-worthiness check)."""
    if cfg is None or cfg.is_noop:
        return False
    try:
        est = estimators.get_estimator(cfg.backend)
    except KeyError:
        return False
    return getattr(est, "plan_carry", False)


def policy_uses_carry(policy) -> bool:
    """True when any config the policy can hand out (base or a role
    override) is a plan-carry estimator — the cheap gate ``init_state`` uses
    before walking the params tree."""
    if policy is None:
        return False
    if plan_carry_capable(getattr(policy, "base", None)):
        return True
    return any(plan_carry_capable(cfg)
               for _, cfg in getattr(policy, "overrides", ()) or ())


def with_plan_state(params, policy, *, n_layers: int = 1, mesh=None,
                    data_axes=("data",), model_axes=("model",),
                    tp_sketch: bool = False):
    """Merge uniform-prior carry leaves into ``params`` at every site whose
    resolved :class:`~repro.core.site.SiteSpec` carries a plan.

    Mirrors ``telemetry.probes.with_probe_slots`` — emission consumes the
    same site resolution as ``nn.common.dense``'s dispatch, so a leaf
    appears exactly when the backward will consume it (``carry_rows``; TP
    plans never carry — plan-carry estimators are not tp_shardable and fall
    back to the dense mask path there). Ones, not zeros: equal scores are
    the uniform sampling prior for step 0, and the solver's probability
    floor keeps every later carry strictly positive.

    Only ``location="all"`` policies get leaves (scan-stacked models cannot
    distinguish layers statically — same restriction as the other slots).
    """
    if policy is None or policy.location != "all":
        return params
    from repro.core.site import resolve_tree_site

    def walk(node, path):
        if isinstance(node, dict):
            out = {k: walk(v, path + (k,)) for k, v in node.items()}
            spec = resolve_tree_site(path, node, policy, n_layers=n_layers,
                                     mesh=mesh, data_axes=data_axes,
                                     model_axes=model_axes,
                                     tp_sketch=tp_sketch)
            if spec is not None and spec.carry_rows:
                lead = node["w"].shape[:-2]
                out[PLAN_SLOT] = jnp.ones(lead + (spec.carry_rows,),
                                          jnp.float32)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path) for v in node)
        return node

    return walk(params, ())


def collect_plan_state(grads) -> Tuple[object, Dict[str, jax.Array]]:
    """Pull the refreshed scores out of a gradient tree.

    Returns ``(clean_grads, fresh)``: ``clean_grads`` has every ``"sslot"``
    cotangent replaced by zeros (congruent with the params tree, invisible
    to the grad norm and the optimizer moments), and ``fresh`` maps the
    ``/``-joined site path to its refreshed score vector.
    """
    fresh: Dict[str, jax.Array] = {}

    def walk(node, path):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == PLAN_SLOT:
                    fresh["/".join(map(str, path + (k,)))] = v
                    out[k] = jnp.zeros_like(v)
                else:
                    out[k] = walk(v, path + (k,))
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path + (i,)) for i, v in enumerate(node))
        return node

    clean = walk(grads, ())
    return clean, fresh


def write_plan_state(params, fresh: Dict[str, jax.Array]):
    """Overwrite the params tree's carry leaves with ``fresh`` (the map
    :func:`collect_plan_state` produced). Paths absent from ``fresh`` keep
    their current carry."""
    if not fresh:
        return params

    def walk(node, path):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                p = path + (k,)
                key = "/".join(map(str, p))
                if k == PLAN_SLOT and key in fresh:
                    out[k] = fresh[key].astype(v.dtype)
                else:
                    out[k] = walk(v, p)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path + (i,)) for i, v in enumerate(node))
        return node

    return walk(params, ())
