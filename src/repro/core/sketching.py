"""Unbiased randomized VJP sketches (paper §3–4).

The central object is :class:`SketchConfig` (static / hashable — safe to close
over in ``jax.jit``) plus pure functions that turn an output-gradient matrix
``G`` (shape ``[N, d_out]``, practical row convention of App. C) into an
unbiased surrogate ``Ĝ`` with ``E[Ĝ | G] = G``.

Two execution *backends* realise the same estimator:

* ``mask``    — paper-faithful (Alg. 3–6): full-size ``Ĝ`` with zeroed and
                rescaled columns; dense downstream matmuls.
* ``compact`` — beyond-paper TPU adaptation (DESIGN.md §3): exact-r correlated
                sampling guarantees a *static* keep count ``r``, so we gather
                the kept columns and run reduced-shape matmuls (optionally via
                Pallas kernels, backend ``pallas``).

Method families
---------------
uniform masks (§4.1):  ``per_element``, ``per_column``, ``per_sample``
data-dependent (§4.2): ``l1``, ``l2``, ``var``, ``ds``, ``gsv`` (+ ``_sq``),
spectral (Prop. 3.3):  ``rcs``
and ``none`` (exact backprop).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import solver
from repro.core.scores import SCORE_METHODS, column_scores

__all__ = [
    "SketchConfig",
    "ColumnPlan",
    "COLUMN_METHODS",
    "ALL_METHODS",
    "static_rank",
    "column_plan",
    "column_plan_from_scores",
    "column_gate",
    "apply_rcs",
    "sketch_dense",
]

COLUMN_METHODS = ("per_column",) + SCORE_METHODS
ALL_METHODS = ("none", "per_element", "per_sample", "rcs") + COLUMN_METHODS


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    """Static configuration of one sketched VJP site.

    Attributes:
      method: one of :data:`ALL_METHODS`.
      budget: fraction ``p ∈ (0, 1]`` of coordinates kept (in expectation for
        independent sampling; exactly for correlated sampling).
      exact_r: correlated exact-r Bernoulli sampling (Lemma 3.1; paper default
        after Fig. 1a) vs independent gates (Lemma 3.4).
      backend: ``mask`` | ``compact`` | ``pallas``, or any additional
        estimator registered via ``repro.api.register_estimator``
        (see core/estimators.py).
      round_to: round the static keep-count ``r`` *up* to a multiple (128 keeps
        compact matmuls MXU/lane aligned on TPU; 1 = paper-faithful count).
      block: column-block granularity. 0/1 = per-column (paper-faithful).
        >1 (e.g. 128) samples whole column *blocks*: scores are pooled per
        block and the convex program runs over blocks. Structured variant for
        TPU — a kept block is a contiguous, lane-aligned slab, so the Pallas
        backward kernels gather it straight from HBM via BlockSpec index maps
        (DESIGN.md §3). Slightly coarser variance for the same budget; the
        trade-off is benchmarked in benchmarks/bench_block_granularity.py.
      ridge: relative ridge added to Γ_B for the RCS inverse square root.
    """

    method: str = "l1"
    budget: float = 0.1
    exact_r: bool = True
    backend: str = "mask"
    round_to: int = 1
    block: int = 0
    ridge: float = 1e-5

    def __post_init__(self):
        if self.method not in ALL_METHODS:
            raise ValueError(f"unknown sketch method {self.method!r}")
        if not (0.0 < self.budget <= 1.0):
            raise ValueError(f"budget must be in (0, 1], got {self.budget}")
        if self.backend in ("mask", "compact", "pallas"):
            # builtin backends: static checks (registered in sketched_linear,
            # which may still be mid-import when presets are built)
            if self.backend in ("compact", "pallas") and self.method not in COLUMN_METHODS:
                raise ValueError(
                    f"backend {self.backend!r} requires a column-family method, got {self.method!r}")
            if self.backend in ("compact", "pallas") and not self.exact_r:
                raise ValueError("compact/pallas backends need exact_r=True (static shapes)")
        else:
            # open registry: any estimator registered via
            # repro.api.register_estimator is a valid backend
            from repro.core import estimators as _est

            try:
                est = _est.get_estimator(self.backend)
            except KeyError as e:
                raise ValueError(str(e)) from None
            est.validate(self)

    @property
    def is_noop(self) -> bool:
        return self.method == "none" or self.budget >= 1.0


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def static_rank(cfg: SketchConfig, n: int) -> int:
    """Static keep-count r for a node with n output coordinates."""
    r = max(1, int(round(cfg.budget * n)))
    r = min(n, _round_up(r, max(1, cfg.round_to)))
    return r


def static_block_rank(cfg: SketchConfig, n: int) -> int:
    """Static number of kept column-*blocks* (block-granular sketches)."""
    assert cfg.block > 1 and n % cfg.block == 0, (n, cfg.block)
    nb = n // cfg.block
    return max(1, min(nb, int(round(cfg.budget * nb))))


def effective_cfg(cfg: SketchConfig, n: int) -> SketchConfig:
    """Degrade block-granular configs gracefully on sites whose width does
    not divide the block (tiny smoke configs, odd head dims): fall back to
    per-column granularity — same estimator family, still unbiased."""
    if cfg.block > 1 and (n < cfg.block or n % cfg.block != 0):
        return dataclasses.replace(cfg, block=0)
    return cfg


@dataclasses.dataclass
class ColumnPlan:
    """A sampled column sketch: either compact (indices) or dense gate."""

    indices: Optional[jax.Array]  # [r] int32, ascending (exact-r only)
    scales: Optional[jax.Array]  # [r] f32: 1/p at kept columns
    gate: Optional[jax.Array]  # [n] f32: z_i/p_i (dense mask-and-rescale)
    probs: jax.Array  # [n] f32 marginals (diagnostics / tests)


def _proxy_scores(cfg: SketchConfig, G2d: jax.Array, W: Optional[jax.Array]) -> jax.Array:
    """Column proxy scores, routed through the Pallas reduction kernel for the
    ℓ1/ℓ2 families on the pallas backend (one streaming HBM pass over G with
    fp32 accumulation) and through the jnp scores otherwise."""
    base = cfg.method[:-3] if cfg.method.endswith("_sq") else cfg.method
    if cfg.backend == "pallas" and base in ("l1", "l2"):
        from repro.kernels import ops as kops

        if base == "l1":
            s = kops.col_l1_scores(G2d, mode="l1")
        else:
            s = jnp.sqrt(kops.col_l1_scores(G2d, mode="l2"))
        return jnp.square(s) if cfg.method.endswith("_sq") else s
    return column_scores(cfg.method, G2d, W)


def _column_probs(cfg: SketchConfig, G2d: jax.Array, W: Optional[jax.Array], r: int,
                  score_psum_axes=None) -> jax.Array:
    n = G2d.shape[-1]
    if cfg.method == "per_column":
        return jnp.full((n,), jnp.float32(r) / n)
    s = _proxy_scores(cfg, G2d, W)
    if score_psum_axes:
        # distributed batch: pool scores across data shards so every replica
        # plans the SAME sketch (required for the compressed gradient
        # collective, and matches the paper's batch-shared R)
        s = jax.lax.psum(s, score_psum_axes)
    w = jnp.square(s)  # probabilities ∝ s  ⇔  weights w = s²  (Eq. 23)
    return solver.optimal_probabilities(w, r)


def column_plan(
    cfg: SketchConfig,
    G2d: jax.Array,
    W: Optional[jax.Array],
    key: jax.Array,
    *,
    want_compact: bool,
    score_psum_axes=None,
) -> ColumnPlan:
    """Sample a column sketch for gradient matrix ``G2d`` ([N, n]).

    With ``cfg.block > 1`` the plan is block-granular: ``indices``/``scales``
    refer to column *blocks* and ``gate`` (when materialised) is expanded back
    to per-column size.
    """
    n = G2d.shape[-1]
    cfg = effective_cfg(cfg, n)
    if cfg.block > 1:
        return _block_plan(cfg, G2d, W, key, want_compact=want_compact,
                           score_psum_axes=score_psum_axes)
    r = static_rank(cfg, n)
    p = _column_probs(cfg, G2d, W, r, score_psum_axes)
    if r >= n:
        ones = jnp.ones((n,), jnp.float32)
        idx = jnp.arange(n, dtype=jnp.int32)
        return ColumnPlan(indices=idx, scales=ones, gate=ones, probs=ones)
    if cfg.exact_r:
        idx = solver.sample_exact_r(key, p, r)
        inv_p_sel = 1.0 / jnp.maximum(jnp.take(p, idx), 1e-20)
        if want_compact:
            return ColumnPlan(indices=idx, scales=inv_p_sel, gate=None, probs=p)
        gate = jnp.zeros((n,), jnp.float32).at[idx].set(inv_p_sel)
        return ColumnPlan(indices=idx, scales=inv_p_sel, gate=gate, probs=p)
    z = solver.sample_independent(key, p)
    gate = z / jnp.maximum(p, 1e-20)
    return ColumnPlan(indices=None, scales=None, gate=gate, probs=p)


def _block_plan(cfg: SketchConfig, G2d, W, key, *, want_compact: bool,
                score_psum_axes=None) -> ColumnPlan:
    """Block-granular column sketch: pool scores per block, sample blocks.

    Unbiasedness is inherited coordinate-wise: every column in a kept block is
    rescaled by 1/p_block and E[z_b/p_b] = 1.
    """
    n = G2d.shape[-1]
    bs = cfg.block
    nb = n // bs
    rb = static_block_rank(cfg, n)
    if cfg.method == "per_column":
        p = jnp.full((nb,), jnp.float32(rb) / nb)
    else:
        s = _proxy_scores(cfg, G2d, W)
        if score_psum_axes:
            s = jax.lax.psum(s, score_psum_axes)
        # pool proxy *weights* (w = s²) per block, probabilities ∝ sqrt(pool)
        w_blk = jnp.sum(jnp.square(s).reshape(nb, bs), axis=-1)
        p = solver.optimal_probabilities(w_blk, rb)
    if rb >= nb:
        ones = jnp.ones((n,), jnp.float32)
        return ColumnPlan(indices=jnp.arange(nb, dtype=jnp.int32),
                          scales=jnp.ones((nb,), jnp.float32), gate=ones, probs=ones)
    idx = solver.sample_exact_r(key, p, rb)
    inv_p_sel = 1.0 / jnp.maximum(jnp.take(p, idx), 1e-20)
    probs_cols = jnp.repeat(p, bs)
    if want_compact:
        return ColumnPlan(indices=idx, scales=inv_p_sel, gate=None, probs=probs_cols)
    gate_blk = jnp.zeros((nb,), jnp.float32).at[idx].set(inv_p_sel)
    gate = jnp.repeat(gate_blk, bs)
    return ColumnPlan(indices=idx, scales=inv_p_sel, gate=gate, probs=probs_cols)


def _weights_from_scores(scores: jax.Array) -> jax.Array:
    """Convex-program weights from precomputed proxy scores: w = s², with an
    all-zero guard (uniform) so the sampler's marginals stay well-defined for
    any carried state. ``optimal_probabilities`` then adds its own relative
    floor, keeping every p_i strictly positive — the property that makes a
    plan sampled from STALE scores still conditionally unbiased (staleness
    can only inflate variance, never zero out a coordinate's probability)."""
    w = jnp.square(scores.astype(jnp.float32))
    return jnp.where(jnp.sum(w) > 0, w, jnp.ones_like(w))


def column_plan_from_scores(cfg: SketchConfig, scores: jax.Array,
                            key: jax.Array, *,
                            want_compact: bool = True) -> ColumnPlan:
    """Sample a column sketch from PRECOMPUTED per-column proxy scores — no
    read of G. This is the planning half of the one-pass backward paths:
    the carry estimators feed it the previous step's scores (O(n) state), so
    the only G traffic left is the backward kernel's own single sweep.

    ``scores`` must follow :func:`repro.core.scores.column_scores` semantics
    for ``cfg.method`` ([n] f32, non-negative). Requires ``exact_r`` (the
    carry paths need static compact shapes).
    """
    n = scores.shape[-1]
    cfg = effective_cfg(cfg, n)
    if not cfg.exact_r:
        raise ValueError("column_plan_from_scores requires exact_r=True")
    if cfg.block > 1:
        bs = cfg.block
        nb = n // bs
        rb = static_block_rank(cfg, n)
        w_blk = jnp.sum(_weights_from_scores(scores).reshape(nb, bs), axis=-1)
        w_blk = jnp.where(jnp.sum(w_blk) > 0, w_blk, jnp.ones_like(w_blk))
        p = solver.optimal_probabilities(w_blk, rb)
        if rb >= nb:
            ones = jnp.ones((n,), jnp.float32)
            return ColumnPlan(indices=jnp.arange(nb, dtype=jnp.int32),
                              scales=jnp.ones((nb,), jnp.float32),
                              gate=ones, probs=ones)
        idx = solver.sample_exact_r(key, p, rb)
        inv_p_sel = 1.0 / jnp.maximum(jnp.take(p, idx), 1e-20)
        probs_cols = jnp.repeat(p, bs)
        if want_compact:
            return ColumnPlan(indices=idx, scales=inv_p_sel, gate=None,
                              probs=probs_cols)
        gate = jnp.repeat(
            jnp.zeros((nb,), jnp.float32).at[idx].set(inv_p_sel), bs)
        return ColumnPlan(indices=idx, scales=inv_p_sel, gate=gate,
                          probs=probs_cols)
    r = static_rank(cfg, n)
    p = solver.optimal_probabilities(_weights_from_scores(scores), r)
    if r >= n:
        ones = jnp.ones((n,), jnp.float32)
        idx = jnp.arange(n, dtype=jnp.int32)
        return ColumnPlan(indices=idx, scales=ones, gate=ones, probs=ones)
    idx = solver.sample_exact_r(key, p, r)
    inv_p_sel = 1.0 / jnp.maximum(jnp.take(p, idx), 1e-20)
    if want_compact:
        return ColumnPlan(indices=idx, scales=inv_p_sel, gate=None, probs=p)
    gate = jnp.zeros((n,), jnp.float32).at[idx].set(inv_p_sel)
    return ColumnPlan(indices=idx, scales=inv_p_sel, gate=gate, probs=p)


def column_gate(cfg: SketchConfig, G2d, W, key) -> jax.Array:
    """Dense ``[n]`` gate (z/p) for mask-backend column methods."""
    return column_plan(cfg, G2d, W, key, want_compact=False).gate


# ---------------------------------------------------------------------------
# RCS — Rank-Constrained Sketch (Prop. 3.3), factored low-rank application.
# ---------------------------------------------------------------------------


def _sym_sqrt_invsqrt(gamma: jax.Array, ridge: float):
    evals, evecs = jnp.linalg.eigh(gamma)
    floor = ridge * jnp.maximum(jnp.mean(evals), 1e-30)
    evals = jnp.maximum(evals, floor)
    s = jnp.sqrt(evals)
    half = (evecs * s) @ evecs.T
    inv_half = (evecs / s) @ evecs.T
    return half, inv_half


def apply_rcs(cfg: SketchConfig, G2d: jax.Array, W: jax.Array, key: jax.Array) -> jax.Array:
    """Ĝ = G R*ᵀ with R* from Prop. 3.3 (minimal-distortion rank-r sketch).

    Factored as Ĝ = ((G Γ^{-1/2}) U_sel ⊙ d_sel) (U_selᵀ Γ^{1/2}) —
    O(N n r + n² r) instead of materialising the n×n operator.
    """
    N, n = G2d.shape
    r = static_rank(cfg, n)
    Gf = G2d.astype(jnp.float32)
    gamma = (Gf.T @ Gf) / N
    half, inv_half = _sym_sqrt_invsqrt(gamma, cfg.ridge)
    # A = Γ^{1/2} (W Wᵀ) Γ^{1/2};   (JᵀJ = W Wᵀ in the row convention)
    Wf = W.astype(jnp.float32)
    WWt = Wf @ Wf.T
    A = half @ WWt @ half
    evals, U = jnp.linalg.eigh(A)  # ascending
    sigma_sq = jnp.maximum(evals, 0.0)
    p = solver.optimal_probabilities(sigma_sq, r)
    if r >= n:
        return G2d
    idx = solver.sample_exact_r(key, p, r)
    d_sel = 1.0 / jnp.maximum(jnp.take(p, idx), 1e-20)  # z/p on kept dirs
    U_sel = jnp.take(U, idx, axis=1)  # [n, r]
    T1 = inv_half @ U_sel  # [n, r]
    T2 = U_sel.T @ half  # [r, n]
    Ghat = ((Gf @ T1) * d_sel[None, :]) @ T2
    return Ghat.astype(G2d.dtype)


# ---------------------------------------------------------------------------
# Dense (mask-backend) sketch application — paper-faithful semantics.
# ---------------------------------------------------------------------------


def sketch_dense(cfg: SketchConfig, G2d: jax.Array, W: Optional[jax.Array], key: jax.Array) -> jax.Array:
    """Return the full-size unbiased surrogate Ĝ (E[Ĝ|G] = G).

    ``per_element`` is *not* handled here (it masks W and X, not G — Alg. 3);
    the sketched-linear backward special-cases it.
    """
    if cfg.is_noop:
        return G2d
    N, n = G2d.shape
    if cfg.method == "per_sample":
        # Alg. 4: Bernoulli gate per (flattened) sample row.
        z = jax.random.bernoulli(key, cfg.budget, (N,)).astype(G2d.dtype)
        return G2d * (z / cfg.budget)[:, None]
    if cfg.method == "rcs":
        if W is None:
            raise ValueError("RCS requires the layer weight W")
        return apply_rcs(cfg, G2d, W, key)
    gate = column_gate(cfg, G2d, W, key)
    return G2d * gate[None, :].astype(G2d.dtype)
