"""One sketched-site spine: the single ``custom_vjp`` behind every linear site.

Before this module the repo carried four separately-built ``custom_vjp``
spines — the local ``sketched_linear`` plumbing plus three shard_map builds in
``sharded_sketch`` (TP column-parallel, TP row-parallel, TP exact) — each
re-implementing residual capture, RNG-key threading, CompactGrad ``gslot``
cotangents, telemetry ``pslot`` cotangents, bias handling and
estimator-registry dispatch. ``nn.common.dense`` and the slot builders then
had to mirror the dispatch by hand ("must mirror exactly" comments).

This module collapses all of that into:

* :class:`ExecutionPlan` — *where* a site's backward runs: ``local`` (single
  program, pjit-auto sharding), ``tp_column`` / ``tp_row`` (TP-local sketch
  inside ``shard_map`` with compressed DP gradient collectives), or
  ``tp_exact`` (explicit Megatron column-parallel with an exact backward).
* :class:`SiteSpec` — the *declarative* resolution of one site: role, the
  effective :class:`SketchConfig` (after the TP-incompatibility fallback to
  the mask backend), the plan, bias presence, and the derived capabilities
  (``compact_rows`` — the gslot rank, or None when the backward stays dense —
  and ``probe_capable``). :func:`resolve_site` is the one dispatch function;
  ``nn.common.dense``, the CompactGrad slot builder and the telemetry probe
  slot builder all consume the same resolved specs, so slot emission can no
  longer drift from backward dispatch.
* :func:`sketched_site` — the single ``custom_vjp`` spine, parameterized by a
  ``SiteSpec``. It owns, once, everything the four spines duplicated:
  residuals, key threading (per-model-shard fold on the column plan), the
  estimator-registry dispatch (``apply`` / ``apply_with_probe`` locally,
  ``plan`` inside the shard_map bodies), compact-vs-dense dW emission,
  bias gradients on **every** plan (the TP streams fold db through the same
  kept-column gather), and the per-site probe — computed inside the shard_map
  backward body and ``psum``-ed over the model axis on the TP plans, so
  telemetry and adaptive budget control work under tensor parallelism.

Estimator contract on the TP plans: a ``tp_shardable`` estimator's ``plan``
hook returns a compact :class:`~repro.core.sketching.ColumnPlan` whose
``probs`` are the per-column keep marginals — that is what the in-body probe
consumes (``probe_from_rows`` math; see repro/telemetry/probes.py).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import estimators
from repro.core.compact_grad import (TP_OUT_ROLES, TP_ROW_ROLES, CompactGrad,
                                     _site_role)
from repro.core.sketching import (SketchConfig, effective_cfg,
                                  static_block_rank, static_rank)

__all__ = ["ExecutionPlan", "SiteSpec", "resolve_site", "resolve_tree_site",
           "sketched_site", "local_spec", "tp_estimator"]


# ---------------------------------------------------------------------------
# Declarative plan + spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Where one site's backward executes (static / hashable).

    kind: ``local`` | ``tp_column`` | ``tp_row`` | ``tp_exact``. The TP kinds
    run inside ``shard_map`` over ``mesh`` with activations sharded on
    ``data_axes`` and the weight's parallel dimension on ``model_axis``.
    """

    kind: str = "local"
    mesh: Optional[object] = None
    data_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None

    def __post_init__(self):
        if self.kind not in ("local", "tp_column", "tp_row", "tp_exact"):
            raise ValueError(f"unknown plan kind {self.kind!r}")
        if self.kind != "local" and (self.mesh is None or self.model_axis is None):
            raise ValueError(f"plan {self.kind!r} needs a mesh and model_axis")
        object.__setattr__(self, "data_axes", tuple(self.data_axes))

    @property
    def is_tp(self) -> bool:
        return self.kind != "local"


_LOCAL = ExecutionPlan()


@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """One resolved sketched-linear site (static / hashable).

    ``cfg`` is the *effective* config: on TP-incompatible sites under
    ``tp_sketch`` the compact-form backend is replaced by the dense mask
    backend (scatter-hostile compact rows must not be produced where the
    slot builder emits no slot — that invariant is now structural).

    ``compact_rows``: static number of compact dW rows the backward emits
    (the gslot rank), or None when the weight cotangent stays dense.
    ``probe_capable``: the backward can emit the telemetry probe vector —
    via the estimator's ``apply_with_probe`` hook on the local plan, via the
    in-body ``plan()`` marginals on the TP plans.
    ``carry_rows``: static size of the per-site plan-carry state (sslot) a
    plan-carry estimator ("onepass"/"stale") threads through the backward —
    the previous step's column scores — or None when the estimator carries
    no plan. Local plan only; the sslot builder in core/plan_state.py emits
    state leaves from this field, the same way gslot/pslot builders consume
    compact_rows/probe_capable.
    """

    role: str
    cfg: Optional[SketchConfig]
    plan: ExecutionPlan = _LOCAL
    has_bias: bool = False
    d_out: int = 0
    d_in: int = 0
    compact_rows: Optional[int] = None
    probe_capable: bool = False
    carry_rows: Optional[int] = None


@lru_cache(maxsize=None)
def local_spec(cfg: Optional[SketchConfig]) -> SiteSpec:
    """The plain single-program spec ``sketched_linear`` instantiates."""
    return SiteSpec(role="linear", cfg=cfg)


def tp_estimator(cfg):
    """The registered estimator for ``cfg`` iff it opted into the TP plans.

    Any estimator with ``tp_shardable=True`` (builtin compact/pallas, or a
    third-party entry) has its ``plan`` hook called inside the shard_map
    backward; its ``validate`` runs here too, so a config is
    rejected/accepted consistently with the single-device path. Estimators
    without the flag return None and the site resolves to a local plan.
    """
    if cfg is None or cfg.is_noop:
        return None
    try:
        est = estimators.get_estimator(cfg.backend)
    except KeyError:
        return None
    if not getattr(est, "tp_shardable", False):
        return None
    est.validate(cfg)
    return est


def _mesh_prod(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _compact_capable(backend: str) -> bool:
    try:
        return bool(estimators.get_estimator(backend).supports_compact_grad)
    except KeyError:
        return False


def _tp_column_ok(cfg, d_out, mesh, model_axes) -> bool:
    n_mp = _mesh_prod(mesh, model_axes)
    if d_out % n_mp != 0:
        return False
    n_loc = d_out // n_mp
    if cfg.block > 1:
        return n_loc % cfg.block == 0 and static_block_rank(cfg, n_loc) >= 1
    return static_rank(cfg, n_loc) >= 1


def _tp_row_ok(d_in, mesh, model_axes) -> bool:
    return d_in % _mesh_prod(mesh, model_axes) == 0


@lru_cache(maxsize=4096)
def _resolve(role, cfg, d_out, d_in, has_bias, x_ndim, mesh, data_axes,
             model_axes, tp_sketch) -> SiteSpec:
    plan = _LOCAL
    eff = cfg
    if (cfg is not None and tp_sketch and mesh is not None and x_ndim == 3
            and model_axes and tp_estimator(cfg) is not None):
        if role in TP_OUT_ROLES and _tp_column_ok(cfg, d_out, mesh, model_axes):
            plan = ExecutionPlan("tp_column", mesh, data_axes, model_axes[0])
        elif role in TP_ROW_ROLES and _tp_row_ok(d_in, mesh, model_axes):
            plan = ExecutionPlan("tp_row", mesh, data_axes, model_axes[0])
    if plan.kind == "local" and cfg is not None and tp_sketch \
            and _compact_capable(cfg.backend):
        # TP-incompatible site (e.g. kv heads < model axis, or no mesh at
        # all): fall back to the dense-mask estimator rather than the
        # scatter-hostile compact path. Applies to ANY registered
        # compact-form estimator; the slot builder sees the same spec, so no
        # gslot is emitted and the backward produces no compact rows here.
        eff = dataclasses.replace(cfg, backend="mask", block=0)

    rows = None
    carry = None
    if eff is not None and not eff.is_noop:
        try:
            est = estimators.get_estimator(eff.backend)
        except KeyError:
            est = None
        if est is not None and est.supports_compact_grad:
            if plan.kind == "tp_column":
                n_mp = _mesh_prod(mesh, model_axes)
                rows = n_mp * est.compact_rank(eff, d_out // n_mp)
            else:  # tp_row and local both emit d_out-indexed rows
                rows = est.compact_rank(eff, d_out)
        if (est is not None and getattr(est, "plan_carry", False)
                and plan.kind == "local"):
            # Plan-carry estimators thread previous-step scores through the
            # spine; the mask fallback above already rewrote eff.backend for
            # TP-incompatible sites, so carry stays local-plan only.
            carry = est.carry_size(eff, d_out)

    if plan.is_tp:
        # TP plans probe from the in-body plan marginals (ColumnPlan.probs)
        probe = True
    else:
        from repro.telemetry.probes import probe_capable

        probe = probe_capable(eff)
    return SiteSpec(role=role, cfg=eff, plan=plan, has_bias=has_bias,
                    d_out=d_out, d_in=d_in, compact_rows=rows,
                    probe_capable=probe, carry_rows=carry)


def resolve_site(role: str, cfg: Optional[SketchConfig], *, d_out: int,
                 d_in: int, has_bias: bool = False, x_ndim: int = 3,
                 mesh=None, data_axes=("data",), model_axes=("model",),
                 tp_sketch: bool = False) -> SiteSpec:
    """Resolve one linear site to its :class:`SiteSpec` (memoized).

    This is the ONE dispatch decision for sketched sites: ``nn.common.dense``
    executes whatever plan it returns, and the gslot/pslot builders emit
    slots from the same spec — replacing the old per-call
    ``x.ndim == 3 and b is None and role in TP_OUT_ROLES`` heuristics that
    the slot builders had to mirror by hand.
    """
    return _resolve(role, cfg, int(d_out), int(d_in), bool(has_bias),
                    int(x_ndim), mesh, tuple(data_axes), tuple(model_axes),
                    bool(tp_sketch))


def resolve_tree_site(path, node, policy, *, n_layers=1, mesh=None,
                      data_axes=("data",), model_axes=("model",),
                      tp_sketch=False) -> Optional[SiteSpec]:
    """Spec for one params-tree node, or None if the node is not a sketched
    site (role-matched by path: attn/cross q|k|v|o, mlp in|gate|out; the
    multi-use ``"shared"`` subtree is excluded — see with_grad_slots).

    Shared by the gslot and pslot builders and the drift-guard tests: slot
    emission consumes the *same* resolution as ``dense``'s dispatch.
    """
    role = None if "shared" in path else _site_role(path)
    if role is None or not isinstance(node, dict):
        return None
    w = node.get("w")
    if w is None or getattr(w, "ndim", 0) < 2:
        return None
    cfg = policy.config_for(role, 0, n_layers)
    if cfg is None or cfg.is_noop:
        return None
    return resolve_site(role, cfg, d_out=w.shape[-2], d_in=w.shape[-1],
                        has_bias="b" in node, x_ndim=3, mesh=mesh,
                        data_axes=data_axes, model_axes=model_axes,
                        tp_sketch=tp_sketch)


# ---------------------------------------------------------------------------
# The spine
# ---------------------------------------------------------------------------


def _flatten_leading(x):
    lead = x.shape[:-1]
    return x.reshape((-1, x.shape[-1])), lead


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _site_linear(spec: SiteSpec, x, w, b, key, slot, pslot, sslot):
    plan = spec.plan
    if plan.kind == "local":
        y = jnp.einsum("...i,oi->...o", x, w)
        return y + b if b is not None else y
    mesh, dp, mp = plan.mesh, plan.data_axes, plan.model_axis
    if plan.kind in ("tp_column", "tp_exact"):
        def body(x_l, w_l, *b_l):
            y = jnp.einsum("bsi,oi->bso", x_l, w_l)
            return y + b_l[0] if b_l else y

        args = (x, w) + (() if b is None else (b,))
        in_specs = (P(dp, None, None), P(mp, None)) \
            + (() if b is None else (P(mp),))
        return compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                                out_specs=P(dp, None, mp))(*args)

    def body(x_l, w_l, *b_l):
        y = jax.lax.psum(jnp.einsum("bsi,oi->bso", x_l, w_l), mp)
        return y + b_l[0] if b_l else y

    args = (x, w) + (() if b is None else (b,))
    in_specs = (P(dp, None, mp), P(None, mp)) + (() if b is None else (P(None),))
    return compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                            out_specs=P(dp, None, None))(*args)


def _fwd(spec, x, w, b, key, slot, pslot, sslot):
    y = _site_linear(spec, x, w, b, key, slot, pslot, sslot)
    return y, (x, w, key, b is not None, slot, pslot is not None, sslot)


def _bwd(spec, res, g):
    x, w, key, has_b, slot, want_probe, sslot = res
    kind = spec.plan.kind
    if kind == "local":
        return _local_bwd(spec.cfg, x, w, key, has_b, slot, want_probe,
                          sslot, g)
    if kind == "tp_exact":
        outs = _tp_exact_bwd(spec, x, w, has_b, slot, want_probe, g)
    else:
        outs = _tp_sketch_bwd(spec, x, w, key, has_b, slot, want_probe, g)
    # Plan-carry estimators are local-plan only (tp_shardable=False ⇒ the
    # mask fallback strips the carry before a TP plan is chosen), so on the
    # TP plans the sslot cotangent — when a carry rode along at all — is the
    # unchanged carry: echo zeros so the train step's write-back is a no-op.
    s_ct = None if sslot is None else jnp.zeros_like(sslot)
    return outs + (s_ct,)


_site_linear.defvjp(_fwd, _bwd)


def sketched_site(spec: SiteSpec, x, w, b=None, key=None, slot=None,
                  pslot=None, sslot=None):
    """Run one site through the spine. ``key=None`` / noop cfg on the local
    plan short-circuits to a plain exact linear (no custom_vjp at all —
    identical to the historical ``sketched_linear`` behavior).

    ``sslot`` (optional): the site's plan-carry state leaf (previous-step
    column scores) for plan-carry estimators. Its cotangent out of the
    custom_vjp is the REFRESHED carry, which core/plan_state.py writes back
    into the params tree after the optimizer step."""
    if spec.plan.kind == "local" and (spec.cfg is None or spec.cfg.is_noop
                                      or key is None):
        y = jnp.einsum("...i,oi->...o", x, w)
        return y + b if b is not None else y
    if spec.plan.kind in ("tp_column", "tp_row"):
        assert tp_estimator(spec.cfg) is not None, \
            "TP sketched site on a non-tp_shardable backend"
    return _site_linear(spec, x, w, b, key, slot, pslot, sslot)


# -- local plan --------------------------------------------------------------


def _local_bwd(cfg, x, w, key, has_b, slot, want_probe, sslot, g):
    G2d, _ = _flatten_leading(g)
    X2d, _ = _flatten_leading(x)
    n = G2d.shape[-1]

    est = estimators.get_estimator("mask" if cfg.is_noop else cfg.backend)
    if getattr(est, "plan_carry", False):
        # one-pass plan-carry backward: the step-t sketch is sampled from
        # the carried step-(t-1) scores (sslot; None ⇒ uniform prior), and
        # the refreshed scores come back in out.state. want_probe is folded
        # in so the carry estimator runs at most one sweep over G.
        out = est.apply_with_state(cfg, G2d, X2d, w, key, sslot, has_b=has_b,
                                   want_probe=want_probe)
    elif want_probe:
        # telemetry: the optional estimator hook may fill out.probe; the
        # probe rides the probe slot's cotangent out of jax.grad
        out = est.apply_with_probe(cfg, G2d, X2d, w, key, has_b=has_b)
    else:
        out = est.apply(cfg, G2d, X2d, w, key, has_b=has_b)
    probe_ct = None
    if want_probe:
        from repro.telemetry.probes import PROBE_WIDTH

        probe_ct = (out.probe if out.probe is not None
                    else jnp.zeros((PROBE_WIDTH,), jnp.float32))
    state_ct = None
    if sslot is not None:
        # the sslot cotangent carries the refreshed scores out of jax.grad;
        # zeros (= "carry unchanged" after the train step's write-back merge)
        # when the estimator emitted no refresh
        state_ct = (out.state.astype(sslot.dtype)
                    if out.state is not None else jnp.zeros_like(sslot))
    dX = out.dx.reshape(x.shape)
    if not out.is_compact:
        return _pack(dX, out.dw.astype(w.dtype), out.db, has_b, slot,
                     probe_ct, state_ct)

    db = None
    if has_b:
        db = jnp.zeros((n,), g.dtype).at[out.cols].add(out.db_c.astype(g.dtype))
    if slot is not None:
        # compact-gradient mode: rows/indices ride the slot cotangent,
        # the dense w cotangent is structural zeros (folded by XLA)
        slot_ct = CompactGrad(rows=out.rows.astype(jnp.float32),
                              idx=out.cols.astype(jnp.float32))
        return (dX, jnp.zeros_like(w), db if has_b else None, None, slot_ct,
                probe_ct, state_ct)
    dW = jnp.zeros_like(w).at[out.cols].add(out.rows.astype(w.dtype))
    return _pack(dX, dW, db, has_b, slot, probe_ct, state_ct)


def _pack(dx, dw, db, has_b, slot, probe_ct, state_ct=None):
    # slot primal is all-zeros, so returning it doubles as its zero cotangent
    return (dx, dw, db if has_b else None, None, slot, probe_ct, state_ct)


# -- TP sketched plans (column / row) ----------------------------------------


def _plan_via_registry(est, lcfg, G2d, w_l, key, dp):
    """One shard-local sketch plan, routed through the registered
    estimator's ``plan`` hook (tp_shardable contract: a compact
    ``ColumnPlan`` with indices + scales + keep marginals)."""
    plan = est.plan(lcfg, G2d, w_l, key, want_compact=True,
                    score_psum_axes=dp)
    if plan is None or plan.indices is None:
        raise ValueError(
            f"estimator {est.name!r} is tp_shardable but plan() returned no "
            "compact ColumnPlan — the TP-sharded backward needs indices/scales")
    return plan


def _gather_compact(lcfg, G2d, w_l, idx, scales):
    """Gather the kept G columns / W rows for the local plan.

    Block-granular plans gather whole contiguous blocks (reshape + one
    block-level take — the lane-aligned slab layout the Pallas kernels use)
    instead of expanding to per-column indices; the returned ``idx`` is the
    expanded per-column index vector for the dW scatter / CompactGrad.
    """
    if lcfg.block > 1:
        bs = lcfg.block
        nb = G2d.shape[-1] // bs
        Gc = (jnp.take(G2d.reshape(-1, nb, bs), idx, axis=1)
              * scales[None, :, None].astype(G2d.dtype)).reshape(G2d.shape[0], -1)
        Wc = jnp.take(w_l.reshape(nb, bs, -1), idx, axis=0).reshape(-1, w_l.shape[-1])
        idx = (idx[:, None] * bs + jnp.arange(bs, dtype=idx.dtype)).reshape(-1)
        return Gc, Wc, idx
    Gc = jnp.take(G2d, idx, axis=1) * scales[None, :].astype(G2d.dtype)
    Wc = jnp.take(w_l, idx, axis=0)
    return Gc, Wc, idx


def _tp_sketch_bwd(spec, x, w, key, has_b, slot, want_probe, g):
    plan = spec.plan
    column = plan.kind == "tp_column"
    mesh, dp, mp = plan.mesh, plan.data_axes, plan.model_axis
    cfg = spec.cfg
    est = tp_estimator(cfg)
    assert est is not None, "TP sketched site on a non-tp_shardable backend"
    n, din = w.shape
    scatter_axis = dp[-1] if dp else None
    n_scatter = mesh.shape[scatter_axis] if scatter_axis else 1
    psum_rest = tuple(dp[:-1])
    n_mp = mesh.shape[mp]
    n_loc = n // n_mp if column else n
    din_ok = (din if column else din // n_mp) % n_scatter == 0
    with_slot = slot is not None
    din_sp = scatter_axis if (scatter_axis and din_ok) else None

    def body(g_l, x_l, w_l, key):
        # column plan: per-shard local plan — fold the (DP-shared) key with
        # the model shard index so shards sample independent column subsets.
        # row plan: g is mp-replicated, the plan must be identical on every
        # shard (same key, scores psum'ed over dp) so dX stays ff-local.
        kk = (jax.random.fold_in(key, jax.lax.axis_index(mp)) if column
              else key)
        G2d = g_l.reshape(-1, g_l.shape[-1])
        X2d = x_l.reshape(-1, x_l.shape[-1])
        lcfg = effective_cfg(cfg, G2d.shape[-1])
        cplan = _plan_via_registry(est, lcfg, G2d, w_l, kk, dp)
        idx, scales = cplan.indices, cplan.scales
        Gc, Wc, idx = _gather_compact(lcfg, G2d, w_l, idx, scales)
        dx = (Gc @ Wc).reshape(x_l.shape)
        if column:
            dx = jax.lax.psum(dx, mp)  # the standard TP backward all-reduce
        dWc = Gc.T.astype(jnp.float32) @ X2d.astype(jnp.float32)
        if psum_rest:
            dWc = jax.lax.psum(dWc, psum_rest)
        if scatter_axis and din_ok:
            # compressed DP gradient collective: reduce-scatter the COMPACT
            # block (≈ budget × dense volume) along d_in
            dWc = jax.lax.psum_scatter(dWc, scatter_axis, scatter_dimension=1,
                                       tiled=True)
        elif scatter_axis:
            dWc = jax.lax.psum(dWc, scatter_axis)
        outs = [dx]
        if with_slot:
            if column:
                # global row indices into the full [n, din] weight; the
                # compact block never gets scattered on the backward path.
                # Rows/indices are all-gathered over mp (compact volume) so
                # the optimizer's sparse-row scatter partitions
                # collective-free.
                gidx = (jax.lax.axis_index(mp) * n_loc + idx).astype(jnp.float32)
                outs += [jax.lax.all_gather(dWc, mp, axis=0, tiled=True),
                         jax.lax.all_gather(gidx, mp, axis=0, tiled=True)]
            else:
                outs += [dWc, idx.astype(jnp.float32)]
        else:
            if scatter_axis and din_ok:
                dW_l = jnp.zeros((w_l.shape[0], dWc.shape[1]), w_l.dtype)
            else:
                dW_l = jnp.zeros_like(w_l)
            outs.append(dW_l.at[idx].add(dWc.astype(w_l.dtype)))
        if has_b:
            # bias gradient folded into the same kept-column stream: db is
            # the column sums of the (rescaled) kept G columns — the exact
            # db restricted to the sketch, still unbiased (E[Ĝ|G] = G)
            db_l = jnp.zeros((w_l.shape[0],), g_l.dtype).at[idx].add(
                jnp.sum(Gc, axis=0).astype(g_l.dtype))
            if dp:
                db_l = jax.lax.psum(db_l, dp)
            outs.append(db_l)
        if want_probe:
            # per-shard probe from the rows the backward just produced:
            # ‖row_j‖² needs the full d_in extent (psum the squared partial
            # over whatever axes shard d_in here), then the 3 probe stats
            # psum over the model axis on the column plan (each shard kept
            # its own column subset; the site probe is their sum).
            rs = jnp.einsum("rd,rd->r", dWc, dWc)
            rs_axes = (() if column else (mp,)) + (
                (scatter_axis,) if (scatter_axis and din_ok) else ())
            if rs_axes:
                rs = jax.lax.psum(rs, rs_axes)
            p = jnp.take(cplan.probs, idx).astype(jnp.float32)
            v3 = rs @ jnp.stack([p, 1.0 - p, jnp.ones_like(p)], axis=-1)
            if column:
                v3 = jax.lax.psum(v3, mp)
            outs.append(jnp.concatenate([v3, jnp.ones((1,), jnp.float32)]))
        return tuple(outs)

    specs = [P(dp, None, None) if column else P(dp, None, mp)]  # dx
    if with_slot:
        rows_sp = (P(None, din_sp) if column
                   else P(None, (mp, scatter_axis) if din_sp else mp))
        specs += [rows_sp, P(None)]
    else:
        specs.append(P(mp, din_sp) if column
                     else P(None, (mp, scatter_axis) if din_sp else mp))
    if has_b:
        specs.append(P(mp) if column else P(None))
    if want_probe:
        specs.append(P(None))
    in_specs = ((P(dp, None, mp), P(dp, None, None), P(mp, None), P())
                if column else
                (P(dp, None, None), P(dp, None, mp), P(None, mp), P()))
    res = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=tuple(specs))(g, x, w, key)

    it = iter(res)
    dx = next(it)
    if with_slot:
        rows, gidx = next(it), next(it)
        slot_ct = CompactGrad(rows=rows.astype(jnp.float32), idx=gidx)
        dw = jnp.zeros_like(w)
    else:
        dw, slot_ct = next(it), None
    db = next(it) if has_b else None
    probe_ct = next(it) if want_probe else None
    return dx, dw, db, None, slot_ct, probe_ct


# -- TP exact plan ------------------------------------------------------------


def _tp_exact_bwd(spec, x, w, has_b, slot, want_probe, g):
    """Explicit Megatron column-parallel EXACT backward (e.g. the vocabulary
    head, which the paper keeps exact): same shard_map structure as the
    sketched plans so the dW einsum never hits the pjit sharding conflict
    that replicates full fp32 weight gradients."""
    plan = spec.plan
    mesh, dp, mp = plan.mesh, plan.data_axes, plan.model_axis
    scatter_axis = dp[-1] if dp else None
    n_scatter = mesh.shape[scatter_axis] if scatter_axis else 1
    psum_rest = tuple(dp[:-1])
    din_ok = w.shape[1] % n_scatter == 0

    def body(g_l, x_l, w_l):
        G2d = g_l.reshape(-1, g_l.shape[-1])
        X2d = x_l.reshape(-1, x_l.shape[-1])
        dx = (G2d @ w_l).reshape(x_l.shape)
        dx = jax.lax.psum(dx, mp)
        dW = jax.lax.dot_general(G2d.astype(jnp.float32),
                                 X2d.astype(jnp.float32),
                                 (((0,), (0,)), ((), ())))
        if psum_rest:
            dW = jax.lax.psum(dW, psum_rest)
        if scatter_axis and din_ok:
            dW = jax.lax.psum_scatter(dW, scatter_axis, scatter_dimension=1,
                                      tiled=True)
        elif scatter_axis:
            dW = jax.lax.psum(dW, scatter_axis)
        outs = [dx, dW.astype(w_l.dtype)]
        if has_b:
            db_l = jnp.sum(G2d, axis=0)
            if dp:
                db_l = jax.lax.psum(db_l, dp)
            outs.append(db_l)
        return tuple(outs)

    out_w_spec = P(mp, scatter_axis if (scatter_axis and din_ok) else None)
    specs = [P(dp, None, None), out_w_spec] + ([P(mp)] if has_b else [])
    dx, dw, *rest = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, mp), P(dp, None, None), P(mp, None)),
        out_specs=tuple(specs))(g, x, w)
    db = rest[0] if has_b else None
    probe_ct = None
    if want_probe:
        from repro.telemetry.probes import PROBE_WIDTH

        probe_ct = jnp.zeros((PROBE_WIDTH,), jnp.float32)
    # slot primal (if any) is all-zeros: returning it is its zero cotangent
    return dx, dw, db, None, slot, probe_ct
