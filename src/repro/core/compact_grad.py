"""Compact (row-sparse) weight gradients that survive ``jax.grad``.

The compact/pallas backward produces the sketched dW as ``r`` kept rows plus
their indices, but a ``custom_vjp`` cotangent must aval-match the dense
weight, so the seed code scattered every layer's compact block into a full
``zeros_like(w)`` before the optimizer — and the optimizer then did dense
math on rows the sketch never touched. This module removes that round trip:

* :class:`CompactGrad` — a registered pytree ``(rows, idx, dense)``. ``rows``
  are the kept dW rows (f32), ``idx`` their row indices into the dense weight
  (carried as f32 — see below), ``dense`` an optional dense component with
  the full weight shape (carries the dense shape of the gradient).
* **Gradient slots** — per-site ``CompactGrad``-shaped *zero inputs* merged
  into the params tree (key ``"gslot"`` next to ``"w"``). The slots are extra
  differentiated inputs that the forward ignores; the sketched backward
  *defines* their cotangent to be the compact rows/indices. This is the only
  JAX-sanctioned way to get a non-dense gradient out of ``jax.grad``: the
  cotangent of the dense ``w`` must stay dense-shaped (it is returned as
  structural zeros and folded away by XLA), while the slot cotangent — whose
  primal is float (hence idx rides as f32) — carries the compact data.
* :func:`fold_slot_grads` — rewrites the grad tree back to the params
  structure, replacing each site's w-gradient with
  ``CompactGrad(rows, idx, dense=<w cotangent>)``.

Contract (who may densify, and where — see docs/perf.md):
  the invariant is that ``dense`` and the scattered ``rows`` have disjoint
  support (exactly one of them is nonzero per site; ``dense`` is structural
  zeros whenever the compact path ran). Consumers must preserve compactness:
  ``optim`` clips and applies sparse-row updates directly; only
  :func:`densify` may materialise the dense gradient, and the only sanctioned
  caller is diagnostics/tests. Gradient accumulation must stay dense
  (microbatches sample different index sets), so ``make_train_step`` rejects
  ``compact_grads`` with ``accum > 1``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.sketching import (SketchConfig, effective_cfg, static_block_rank,
                                  static_rank)

__all__ = ["CompactGrad", "is_compact", "densify", "compact_rank",
           "with_grad_slots", "fold_slot_grads",
           "TP_OUT_ROLES", "TP_ROW_ROLES"]

# Roles whose d_out (column-parallel) / d_in (row-parallel) is TP-sharded
# under ``ctx.tp_sketch`` — single source of truth, also used by nn.common.
TP_OUT_ROLES = frozenset({"attn_q", "attn_k", "attn_v", "mlp_in", "mlp_gate",
                          "cross_q", "cross_k", "cross_v", "ssm_in"})
TP_ROW_ROLES = frozenset({"attn_o", "mlp_out", "ssm_out", "cross_o"})


@dataclasses.dataclass
class CompactGrad:
    """Row-sparse gradient: ``dense_grad = dense + scatter_add(idx, rows)``.

    rows: ``[..., r, d_in]`` f32 kept rows (leading dims = scan stacking).
    idx:  ``[..., r]`` f32 row indices (cast to int32 at use sites; float so
          the slot primal has a float tangent space).
    dense: optional dense component with the full gradient shape; structural
          zeros when the compact backward ran (slot form uses ``None``).
    """

    rows: jax.Array
    idx: jax.Array
    dense: Optional[jax.Array] = None


jax.tree_util.register_pytree_node(
    CompactGrad,
    lambda cg: ((cg.rows, cg.idx, cg.dense), None),
    lambda _, ch: CompactGrad(rows=ch[0], idx=ch[1], dense=ch[2]),
)


def is_compact(x: Any) -> bool:
    return isinstance(x, CompactGrad)


def row_gather(a, idx):
    """a[..., n, d][..., idx, :] for 0 or 1 leading (scan-stacked) dims."""
    ii = idx.astype(jnp.int32)
    if a.ndim == 2:
        return a[ii]
    assert a.ndim == 3, a.shape
    return a[jnp.arange(a.shape[0])[:, None], ii]


def row_scatter(a, idx, rows, *, add: bool):
    """a[..., idx, :] = / += rows for 0 or 1 leading (scan-stacked) dims.

    Single source of truth for the batched row scatter — `densify` and the
    optimizer updates must agree on index handling."""
    ii = idx.astype(jnp.int32)
    if a.ndim == 2:
        ref = a.at[ii]
    else:
        assert a.ndim == 3, a.shape
        ref = a.at[jnp.arange(a.shape[0])[:, None], ii]
    return ref.add(rows.astype(a.dtype)) if add else ref.set(rows.astype(a.dtype))


def densify(cg: CompactGrad, like: Optional[jax.Array] = None) -> jax.Array:
    """Materialise the dense gradient (diagnostics/tests only — the hot path
    must keep gradients compact until the weight update)."""
    base = cg.dense
    if base is None:
        assert like is not None, "slot-form CompactGrad needs `like` for the dense shape"
        base = jnp.zeros(like.shape, jnp.result_type(cg.rows))
    return row_scatter(base, cg.idx, cg.rows, add=True)


def compact_rank(cfg: SketchConfig, n: int) -> int:
    """Static number of kept dW *rows* (columns of G) for a site of width n."""
    lcfg = effective_cfg(cfg, n)
    if lcfg.block > 1:
        return static_block_rank(lcfg, n) * lcfg.block
    return static_rank(lcfg, n)


# ---------------------------------------------------------------------------
# Gradient slots
# ---------------------------------------------------------------------------


class _MeshCtx:
    """Duck-typed stand-in for nn.common.Ctx accepted by tp_applicable."""

    def __init__(self, mesh, data_axes, model_axes, tp_sketch):
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.model_axes = tuple(model_axes)
        self.tp_sketch = tp_sketch


def _compact_capable(backend: str) -> bool:
    """Does the registered estimator for ``backend`` emit compact gradients?"""
    from repro.core.estimators import get_estimator

    try:
        return bool(get_estimator(backend).supports_compact_grad)
    except KeyError:
        return False


def _site_role(path) -> Optional[str]:
    if len(path) < 2:
        return None
    parent, leaf = path[-2], path[-1]
    if parent in ("attn", "cross") and leaf in ("q", "k", "v", "o"):
        return f"{parent}_{leaf}"
    if parent == "mlp" and leaf in ("in", "gate", "out"):
        return f"mlp_{leaf}"
    return None


def _slot_rank(role, cfg, w, has_b, shim) -> Optional[int]:
    """Mirror of nn.common.dense's backend dispatch: how many compact rows
    the site's backward will emit, or None if it stays dense."""
    from repro.core.estimators import get_estimator
    from repro.core.sharded_sketch import tp_applicable, tp_row_applicable

    est = get_estimator(cfg.backend)
    n_out = w.shape[-2]
    if shim.tp_sketch:
        if shim.mesh is None:
            # dense() forces the mask backend on every compact site when
            # tp_sketch is set without a mesh — no compact rows will be
            # emitted, so a slot here would freeze the site (its cotangent
            # stays zero)
            return None
        if role in TP_OUT_ROLES and not has_b and tp_applicable(shim, cfg, n_out):
            n_mp = 1
            for a in shim.model_axes:
                n_mp *= shim.mesh.shape[a]
            return n_mp * est.compact_rank(cfg, n_out // n_mp)
        if role in TP_ROW_ROLES and not has_b and tp_row_applicable(shim, cfg, w.shape[-1]):
            return est.compact_rank(cfg, n_out)
        return None  # dense() forces the mask backend on TP-incompatible sites
    return est.compact_rank(cfg, n_out)


def with_grad_slots(params, policy, *, mesh=None, data_axes=("data",),
                    model_axes=("model",), tp_sketch=False, n_layers=1):
    """Return a copy of ``params`` where every site whose backward will take a
    compact path gains a zero ``CompactGrad`` slot under key ``"gslot"``.

    The returned tree is what the loss should be differentiated against; the
    slots' cotangents carry the compact dW (see module docstring). Sites are
    matched by path (attn/cross q|k|v|o, mlp in|gate|out) with the layer-0
    policy config — consistent with scan-stacked models, where
    ``Ctx.cfg_for`` also uses a static layer index of 0; location-based
    policies (whose per-layer config differs from layer 0's) therefore get
    no slots and keep the dense path.

    Weights applied more than once per step never get a slot: JAX would sum
    the per-use CompactGrad cotangents LEAFWISE — adding the index vectors
    of different plans together — which is silently corrupt. That is why
    the ``"shared"`` subtree (zamba2-style shared attention, applied every
    period repetition) is excluded, and why ``compact_grads`` rejects
    ``accum > 1`` (the same aliasing across microbatches).
    """
    if policy is None or policy.location != "all":
        return params
    shim = _MeshCtx(mesh, data_axes, model_axes, tp_sketch)

    def walk(node, path):
        if isinstance(node, dict):
            out = {k: walk(v, path + (k,)) for k, v in node.items()}
            # multi-use weights (the shared-attention block is applied every
            # period repetition) must keep the dense path: summed per-use
            # slot cotangents would add index vectors of different plans
            role = None if "shared" in path else _site_role(path)
            w = node.get("w")
            if role is not None and w is not None and getattr(w, "ndim", 0) >= 2:
                cfg = policy.config_for(role, 0, n_layers)
                if (cfg is not None and not cfg.is_noop
                        and _compact_capable(cfg.backend)):
                    r = _slot_rank(role, cfg, w, "b" in node, shim)
                    if r is not None:
                        lead = w.shape[:-2]
                        out["gslot"] = CompactGrad(
                            rows=jnp.zeros(lead + (r, w.shape[-1]), jnp.float32),
                            idx=jnp.zeros(lead + (r,), jnp.float32))
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path) for v in node)
        return node

    return walk(params, ())


def fold_slot_grads(grads):
    """Rewrite the gradient of a slot-augmented params tree back to the
    original params structure: each site's ``w`` gradient becomes a
    ``CompactGrad`` whose ``dense`` field is the (structurally zero) w
    cotangent and whose rows/idx come from the slot cotangent."""

    def walk(node):
        if isinstance(node, dict):
            out = {k: walk(v) for k, v in node.items() if k != "gslot"}
            slot = node.get("gslot")
            if slot is not None:
                out["w"] = CompactGrad(rows=slot.rows, idx=slot.idx,
                                       dense=node["w"])
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(grads)
