"""Compact (row-sparse) weight gradients that survive ``jax.grad``.

The compact/pallas backward produces the sketched dW as ``r`` kept rows plus
their indices, but a ``custom_vjp`` cotangent must aval-match the dense
weight, so the seed code scattered every layer's compact block into a full
``zeros_like(w)`` before the optimizer — and the optimizer then did dense
math on rows the sketch never touched. This module removes that round trip:

* :class:`CompactGrad` — a registered pytree ``(rows, idx, dense)``. ``rows``
  are the kept dW rows (f32), ``idx`` their row indices into the dense weight
  (carried as f32 — see below), ``dense`` an optional dense component with
  the full weight shape (carries the dense shape of the gradient).
* **Gradient slots** — per-site ``CompactGrad``-shaped *zero inputs* merged
  into the params tree (key ``"gslot"`` next to ``"w"``). The slots are extra
  differentiated inputs that the forward ignores; the sketched backward
  *defines* their cotangent to be the compact rows/indices. This is the only
  JAX-sanctioned way to get a non-dense gradient out of ``jax.grad``: the
  cotangent of the dense ``w`` must stay dense-shaped (it is returned as
  structural zeros and folded away by XLA), while the slot cotangent — whose
  primal is float (hence idx rides as f32) — carries the compact data.
* :func:`fold_slot_grads` — rewrites the grad tree back to the params
  structure, replacing each site's w-gradient with
  ``CompactGrad(rows, idx, dense=<w cotangent>)``.

Contract (who may densify, and where — see docs/perf.md):
  the invariant is that ``dense`` and the scattered ``rows`` have disjoint
  support (exactly one of them is nonzero per site; ``dense`` is structural
  zeros whenever the compact path ran). Consumers must preserve compactness:
  ``optim`` clips and applies sparse-row updates directly; only
  :func:`densify` may materialise the dense gradient, and the only sanctioned
  caller is diagnostics/tests. Gradient accumulation must stay dense
  (microbatches sample different index sets), so ``make_train_step`` rejects
  ``compact_grads`` with ``accum > 1``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.sketching import (SketchConfig, effective_cfg, static_block_rank,
                                  static_rank)

__all__ = ["CompactGrad", "is_compact", "densify", "compact_rank",
           "with_grad_slots", "fold_slot_grads",
           "TP_OUT_ROLES", "TP_ROW_ROLES"]

# Roles whose d_out (column-parallel) / d_in (row-parallel) is TP-sharded
# under ``ctx.tp_sketch`` — single source of truth, also used by nn.common.
TP_OUT_ROLES = frozenset({"attn_q", "attn_k", "attn_v", "mlp_in", "mlp_gate",
                          "cross_q", "cross_k", "cross_v", "ssm_in"})
TP_ROW_ROLES = frozenset({"attn_o", "mlp_out", "ssm_out", "cross_o"})


@dataclasses.dataclass
class CompactGrad:
    """Row-sparse gradient: ``dense_grad = dense + scatter_add(idx, rows)``.

    rows: ``[..., r, d_in]`` f32 kept rows (leading dims = scan stacking).
    idx:  ``[..., r]`` f32 row indices (cast to int32 at use sites; float so
          the slot primal has a float tangent space).
    dense: optional dense component with the full gradient shape; structural
          zeros when the compact backward ran (slot form uses ``None``).
    """

    rows: jax.Array
    idx: jax.Array
    dense: Optional[jax.Array] = None


jax.tree_util.register_pytree_node(
    CompactGrad,
    lambda cg: ((cg.rows, cg.idx, cg.dense), None),
    lambda _, ch: CompactGrad(rows=ch[0], idx=ch[1], dense=ch[2]),
)


def is_compact(x: Any) -> bool:
    return isinstance(x, CompactGrad)


def row_gather(a, idx):
    """a[..., n, d][..., idx, :] for 0 or 1 leading (scan-stacked) dims."""
    ii = idx.astype(jnp.int32)
    if a.ndim == 2:
        return a[ii]
    assert a.ndim == 3, a.shape
    return a[jnp.arange(a.shape[0])[:, None], ii]


def row_scatter(a, idx, rows, *, add: bool):
    """a[..., idx, :] = / += rows for 0 or 1 leading (scan-stacked) dims.

    Single source of truth for the batched row scatter — `densify` and the
    optimizer updates must agree on index handling."""
    ii = idx.astype(jnp.int32)
    if a.ndim == 2:
        ref = a.at[ii]
    else:
        assert a.ndim == 3, a.shape
        ref = a.at[jnp.arange(a.shape[0])[:, None], ii]
    return ref.add(rows.astype(a.dtype)) if add else ref.set(rows.astype(a.dtype))


def densify(cg: CompactGrad, like: Optional[jax.Array] = None) -> jax.Array:
    """Materialise the dense gradient (diagnostics/tests only — the hot path
    must keep gradients compact until the weight update)."""
    base = cg.dense
    if base is None:
        assert like is not None, "slot-form CompactGrad needs `like` for the dense shape"
        base = jnp.zeros(like.shape, jnp.result_type(cg.rows))
    return row_scatter(base, cg.idx, cg.rows, add=True)


def compact_rank(cfg: SketchConfig, n: int) -> int:
    """Static number of kept dW *rows* (columns of G) for a site of width n."""
    lcfg = effective_cfg(cfg, n)
    if lcfg.block > 1:
        return static_block_rank(lcfg, n) * lcfg.block
    return static_rank(lcfg, n)


# ---------------------------------------------------------------------------
# Gradient slots
# ---------------------------------------------------------------------------


def _site_role(path) -> Optional[str]:
    if len(path) < 2:
        return None
    parent, leaf = path[-2], path[-1]
    if parent in ("attn", "cross") and leaf in ("q", "k", "v", "o"):
        return f"{parent}_{leaf}"
    if parent == "mlp" and leaf in ("in", "gate", "out"):
        return f"mlp_{leaf}"
    return None


def with_grad_slots(params, policy, *, mesh=None, data_axes=("data",),
                    model_axes=("model",), tp_sketch=False, n_layers=1):
    """Return a copy of ``params`` where every site whose backward will take a
    compact path gains a zero ``CompactGrad`` slot under key ``"gslot"``.

    The returned tree is what the loss should be differentiated against; the
    slots' cotangents carry the compact dW (see module docstring). Sites are
    matched by path (attn/cross q|k|v|o, mlp in|gate|out) with the layer-0
    policy config — consistent with scan-stacked models, where
    ``Ctx.cfg_for`` also uses a static layer index of 0; location-based
    policies (whose per-layer config differs from layer 0's) therefore get
    no slots and keep the dense path.

    Which sites emit slots is decided by the SAME resolved
    :class:`~repro.core.site.SiteSpec` that ``nn.common.dense`` executes
    (``core.site.resolve_tree_site``): a slot appears exactly when the
    resolved execution plan produces compact rows (``spec.compact_rows``) —
    including on the TP shard_map plans and for bias-carrying TP sites —
    so slot emission cannot drift from backward dispatch.

    Weights applied more than once per step never get a slot: JAX would sum
    the per-use CompactGrad cotangents LEAFWISE — adding the index vectors
    of different plans together — which is silently corrupt. That is why
    the ``"shared"`` subtree (zamba2-style shared attention, applied every
    period repetition) is excluded (``resolve_tree_site`` skips it), and why
    ``compact_grads`` rejects ``accum > 1`` (the same aliasing across
    microbatches).
    """
    if policy is None or policy.location != "all":
        return params
    from repro.core.site import resolve_tree_site

    def walk(node, path):
        if isinstance(node, dict):
            out = {k: walk(v, path + (k,)) for k, v in node.items()}
            spec = resolve_tree_site(path, node, policy, n_layers=n_layers,
                                     mesh=mesh, data_axes=data_axes,
                                     model_axes=model_axes,
                                     tp_sketch=tp_sketch)
            if spec is not None and spec.compact_rows is not None:
                w = node["w"]
                lead = w.shape[:-2]
                r = spec.compact_rows
                out["gslot"] = CompactGrad(
                    rows=jnp.zeros(lead + (r, w.shape[-1]), jnp.float32),
                    idx=jnp.zeros(lead + (r,), jnp.float32))
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path) for v in node)
        return node

    return walk(params, ())


def fold_slot_grads(grads):
    """Rewrite the gradient of a slot-augmented params tree back to the
    original params structure: each site's ``w`` gradient becomes a
    ``CompactGrad`` whose ``dense`` field is the (structurally zero) w
    cotangent and whose rows/idx come from the slot cotangent."""

    def walk(node):
        if isinstance(node, dict):
            out = {k: walk(v) for k, v in node.items() if k != "gslot"}
            slot = node.get("gslot")
            if slot is not None:
                out["w"] = CompactGrad(rows=slot.rows, idx=slot.idx,
                                       dense=node["w"])
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(grads)
