"""Tensor-parallel compact sketching with compressed gradient collectives.

The pjit-auto compact path breaks down under TP: gathering sketched columns of
a model-sharded G and scattering dW rows with data-dependent indices forces
XLA to replicate full fp32 buffers (measured in EXPERIMENTS.md §Perf). This
module is the TP-native realisation (DESIGN.md §3):

  * the column budget is split per model shard (r_loc = r / n_mp), planned
    *locally* inside ``shard_map`` — static shapes, no score all-gather;
    still exactly unbiased (unbiasedness is per-coordinate for any p > 0);
  * dX: local compact matmul + the SAME psum over the model axis a dense TP
    backward needs — no extra collectives;
  * dW: the compact [r_loc, d_in] block is reduce-scattered over the data
    axis BEFORE scattering into the full gradient — the DP gradient
    collective moves ≈ budget × the dense volume. This is the compressed
    all-reduce enabled by the paper's batch-shared sketch (R shared across
    the minibatch ⇒ the step key is shared across DP replicas ⇒ identical
    index sets on every data shard).

Applies to sites whose d_out is TP-sharded (attn q/k/v, mlp in/gate); other
sites keep the paper-faithful mask backend. See ``nn.common.dense``.

Registry routing: the sketch *plan* inside shard_map comes from the
registered estimator's ``plan`` hook — any estimator that sets
``tp_shardable=True`` (see ``core/estimators.py``) runs on this path with
its own sampling scheme, and its ``validate`` is consulted here exactly as
on the single-device path, so configs are accepted/rejected consistently.
The builtin compact/pallas backends are simply the first two such entries.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.core import estimators
from repro.core.compact_grad import CompactGrad
from repro.core.sketching import SketchConfig, effective_cfg

__all__ = ["tp_sketched_linear", "tp_applicable"]


def _tp_estimator(cfg):
    """The registered estimator for ``cfg`` iff it opted into the TP path.

    The sharded path is registry-routed: any estimator with
    ``tp_shardable=True`` (builtin compact/pallas, or a third-party entry)
    has its ``plan`` hook called inside shard_map; its ``validate`` runs
    here too, so a config is rejected/accepted consistently with the
    single-device path. Estimators without the flag return None and the
    site falls back per ``nn.common.dense``.
    """
    if cfg is None or cfg.is_noop:
        return None
    try:
        est = estimators.get_estimator(cfg.backend)
    except KeyError:
        return None
    if not getattr(est, "tp_shardable", False):
        return None
    est.validate(cfg)
    return est


def tp_applicable(ctx, cfg, d_out: int) -> bool:
    if ctx.mesh is None or not getattr(ctx, "tp_sketch", False) or cfg is None:
        return False
    if _tp_estimator(cfg) is None:
        return False
    n_mp = 1
    for a in ctx.model_axes:
        n_mp *= ctx.mesh.shape[a]
    if d_out % n_mp != 0:
        return False
    n_loc = d_out // n_mp
    from repro.core.sketching import static_rank, static_block_rank
    if cfg.block > 1:
        return n_loc % cfg.block == 0 and static_block_rank(cfg, n_loc) >= 1
    return static_rank(cfg, n_loc) >= 1


def _gather_compact(lcfg, G2d, w_l, idx, scales):
    """Gather the kept G columns / W rows for the local plan.

    Block-granular plans gather whole contiguous blocks (reshape + one
    block-level take — the lane-aligned slab layout the Pallas kernels use)
    instead of expanding to per-column indices; the returned ``idx`` is the
    expanded per-column index vector for the dW scatter / CompactGrad.
    """
    if lcfg.block > 1:
        bs = lcfg.block
        nb = G2d.shape[-1] // bs
        Gc = (jnp.take(G2d.reshape(-1, nb, bs), idx, axis=1)
              * scales[None, :, None].astype(G2d.dtype)).reshape(G2d.shape[0], -1)
        Wc = jnp.take(w_l.reshape(nb, bs, -1), idx, axis=0).reshape(-1, w_l.shape[-1])
        idx = (idx[:, None] * bs + jnp.arange(bs, dtype=idx.dtype)).reshape(-1)
        return Gc, Wc, idx
    Gc = jnp.take(G2d, idx, axis=1) * scales[None, :].astype(G2d.dtype)
    Wc = jnp.take(w_l, idx, axis=0)
    return Gc, Wc, idx


def tp_sketched_linear(x, w, ctx, cfg: SketchConfig, key, slot=None):
    """x: [B, S, d_in]; w: [n, d_in] with n TP-sharded. Returns [B, S, n].

    With a ``slot`` (compact-gradient mode), the backward skips the per-shard
    densify-scatter entirely: the reduce-scattered compact dW block and its
    global row indices ride the slot's cotangent (mp-replicated rows, din
    dp-sharded — so the optimizer's sparse-row scatter partitions
    collective-free), and the dense w cotangent is structural zeros.
    """
    mesh = ctx.mesh
    dp = tuple(ctx.data_axes)
    mp = ctx.model_axes[0]
    fn = _build(cfg, mesh, dp, mp, x.shape, w.shape, slot is not None)
    return fn(x, w, key, slot)


def _plan_via_registry(est, lcfg, G2d, w_l, key, dp):
    """One shard-local sketch plan, routed through the registered
    estimator's ``plan`` hook (tp_shardable contract: a compact
    ``ColumnPlan`` with indices + scales)."""
    plan = est.plan(lcfg, G2d, w_l, key, want_compact=True,
                    score_psum_axes=dp)
    if plan is None or plan.indices is None:
        raise ValueError(
            f"estimator {est.name!r} is tp_shardable but plan() returned no "
            "compact ColumnPlan — the TP-sharded backward needs indices/scales")
    return plan


def _build(cfg, mesh, dp, mp, x_shape, w_shape, with_slot: bool):
    B, S, din = x_shape
    n, _ = w_shape
    est = _tp_estimator(cfg)
    assert est is not None, "tp_sketched_linear on a non-tp_shardable backend"
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_mp = mesh.shape[mp]
    n_loc = n // n_mp
    scatter_axis = dp[-1] if dp else None
    n_scatter = mesh.shape[scatter_axis] if scatter_axis else 1
    psum_rest = tuple(a for a in dp[:-1])
    din_ok = din % n_scatter == 0

    @partial(jax.custom_vjp, nondiff_argnums=())
    def fwd_fn(x, w, key, slot):
        def body(x_l, w_l):
            return jnp.einsum("bsi,oi->bso", x_l, w_l)

        return compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(dp, None, None), P(mp, None)),
            out_specs=P(dp, None, mp))(x, w)

    def fwd(x, w, key, slot):
        return fwd_fn(x, w, key, slot), (x, w, key, slot)

    def bwd(res, g):
        x, w, key, slot = res

        def body(g_l, x_l, w_l, key):
            # per-shard local plan: fold the (DP-shared) key with the model
            # shard index so shards sample independent column subsets
            kk = jax.random.fold_in(key, jax.lax.axis_index(mp))
            G2d = g_l.reshape(-1, g_l.shape[-1])
            X2d = x_l.reshape(-1, x_l.shape[-1])
            lcfg = effective_cfg(cfg, G2d.shape[-1])
            plan = _plan_via_registry(est, lcfg, G2d, w_l, kk, dp)
            idx, scales = plan.indices, plan.scales
            Gc, Wc, idx = _gather_compact(lcfg, G2d, w_l, idx, scales)
            dx = (Gc @ Wc).reshape(x_l.shape)
            dx = jax.lax.psum(dx, mp)  # the standard TP backward all-reduce
            dWc = Gc.T.astype(jnp.float32) @ X2d.astype(jnp.float32)
            if psum_rest:
                dWc = jax.lax.psum(dWc, psum_rest)
            if scatter_axis and din_ok:
                # compressed DP gradient collective: reduce-scatter the
                # COMPACT block (≈ budget × dense volume) along d_in
                dWc = jax.lax.psum_scatter(dWc, scatter_axis, scatter_dimension=1,
                                           tiled=True)
            elif scatter_axis:
                dWc = jax.lax.psum(dWc, scatter_axis)
            if with_slot:
                # global row indices into the full [n, din] weight; the
                # compact block never gets scattered on the backward path.
                # Rows/indices are all-gathered over mp (compact volume, ≈
                # budget × a dense mp collective) so the optimizer's
                # sparse-row scatter partitions collective-free: a scatter
                # with REPLICATED updates into the (mp, dp)-sharded weight
                # lowers to a local masked scatter per shard.
                gidx = (jax.lax.axis_index(mp) * n_loc + idx).astype(jnp.float32)
                rows_all = jax.lax.all_gather(dWc, mp, axis=0, tiled=True)
                gidx_all = jax.lax.all_gather(gidx, mp, axis=0, tiled=True)
                return dx, rows_all, gidx_all
            if scatter_axis and din_ok:
                dW_l = jnp.zeros((w_l.shape[0], dWc.shape[1]), w_l.dtype)
                dW_l = dW_l.at[idx].add(dWc.astype(w_l.dtype))
            else:
                dW_l = jnp.zeros_like(w_l).at[idx].add(dWc.astype(w_l.dtype))
            return dx, dW_l

        din_spec = dp[-1] if (scatter_axis and din_ok) else None
        if with_slot:
            dx, rows, gidx = compat.shard_map(
                body, mesh=mesh,
                in_specs=(P(dp, None, mp), P(dp, None, None), P(mp, None), P()),
                out_specs=(P(dp, None, None), P(None, din_spec), P(None)))(
                    g, x, w, key)
            slot_ct = CompactGrad(rows=rows.astype(jnp.float32), idx=gidx)
            return dx, jnp.zeros_like(w), None, slot_ct
        dx, dw = compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(dp, None, mp), P(dp, None, None), P(mp, None), P()),
            out_specs=(P(dp, None, None), P(mp, din_spec)))(
                g, x, w, key)
        return dx, dw, None, None

    fwd_fn.defvjp(fwd, bwd)
    return fwd_fn


def tp_row_applicable(ctx, cfg, d_in: int) -> bool:
    """Row-parallel sites (attn_o / mlp_out / ssm_out): d_in is TP-sharded,
    d_out is the (unsharded) residual width."""
    if ctx.mesh is None or not getattr(ctx, "tp_sketch", False) or cfg is None:
        return False
    if _tp_estimator(cfg) is None:
        return False
    n_mp = 1
    for a in ctx.model_axes:
        n_mp *= ctx.mesh.shape[a]
    return d_in % n_mp == 0


def tp_row_sketched_linear(x, w, ctx, cfg: SketchConfig, key, slot=None):
    """x: [B, S, d_in] (d_in TP-sharded); w: [n, d_in]. Returns [B, S, n].

    Megatron row-parallel: forward computes local partials + psum(mp).
    Backward sketches columns of the (mp-replicated) output gradient — the
    plan is identical on every shard (same key, scores psum'ed over dp), so
    dX stays local (ff-sharded) and the compact dW block reduce-scatters
    over dp as in the column-parallel path. With a ``slot``, the compact
    block and its (replicated) row indices ride the slot cotangent instead
    of being scattered into a dense dW.
    """
    mesh = ctx.mesh
    dp = tuple(ctx.data_axes)
    mp = ctx.model_axes[0]
    fn = _build_row(cfg, mesh, dp, mp, x.shape, w.shape, slot is not None)
    return fn(x, w, key, slot)


def _build_row(cfg, mesh, dp, mp, x_shape, w_shape, with_slot: bool):
    n = w_shape[0]
    est = _tp_estimator(cfg)
    assert est is not None, "tp_row_sketched_linear on a non-tp_shardable backend"
    scatter_axis = dp[-1] if dp else None
    n_scatter = mesh.shape[scatter_axis] if scatter_axis else 1
    psum_rest = tuple(a for a in dp[:-1])
    n_mp = mesh.shape[mp]
    din_loc = w_shape[1] // n_mp
    din_ok = din_loc % n_scatter == 0

    @partial(jax.custom_vjp, nondiff_argnums=())
    def fwd_fn(x, w, key, slot):
        def body(x_l, w_l):
            y_part = jnp.einsum("bsi,oi->bso", x_l, w_l)
            return jax.lax.psum(y_part, mp)

        return compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(dp, None, mp), P(None, mp)),
            out_specs=P(dp, None, None))(x, w)

    def fwd(x, w, key, slot):
        return fwd_fn(x, w, key, slot), (x, w, key, slot)

    def bwd(res, g):
        x, w, key, slot = res

        def body(g_l, x_l, w_l, key):
            # g is mp-replicated: plan once with the shared key (NO mp fold)
            G2d = g_l.reshape(-1, g_l.shape[-1])
            X2d = x_l.reshape(-1, x_l.shape[-1])
            lcfg = effective_cfg(cfg, G2d.shape[-1])
            plan = _plan_via_registry(est, lcfg, G2d, w_l, key, dp)
            idx, scales = plan.indices, plan.scales
            Gc, Wc, idx = _gather_compact(lcfg, G2d, w_l, idx, scales)
            dx = (Gc @ Wc).reshape(x_l.shape)  # stays ff-local: no collective
            dWc = Gc.T.astype(jnp.float32) @ X2d.astype(jnp.float32)
            if psum_rest:
                dWc = jax.lax.psum(dWc, psum_rest)
            if scatter_axis and din_ok:
                dWc = jax.lax.psum_scatter(dWc, scatter_axis, scatter_dimension=1,
                                           tiled=True)
            elif scatter_axis:
                dWc = jax.lax.psum(dWc, scatter_axis)
            if with_slot:
                return dx, dWc, idx.astype(jnp.float32)
            if scatter_axis and din_ok:
                dW_l = jnp.zeros((w_l.shape[0], dWc.shape[1]), w_l.dtype)
                dW_l = dW_l.at[idx].add(dWc.astype(w_l.dtype))
            else:
                dW_l = jnp.zeros_like(w_l).at[idx].add(dWc.astype(w_l.dtype))
            return dx, dW_l

        rows_spec = P(None, (mp, scatter_axis) if (scatter_axis and din_ok) else mp)
        if with_slot:
            dx, rows, gidx = compat.shard_map(
                body, mesh=mesh,
                in_specs=(P(dp, None, None), P(dp, None, mp), P(None, mp), P()),
                out_specs=(P(dp, None, mp), rows_spec, P(None)))(
                    g, x, w, key)
            slot_ct = CompactGrad(rows=rows.astype(jnp.float32), idx=gidx)
            return dx, jnp.zeros_like(w), None, slot_ct
        dx, dw = compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, None, mp), P(None, mp), P()),
            out_specs=(P(dp, None, mp), rows_spec))(
                g, x, w, key)
        return dx, dw, None, None

    fwd_fn.defvjp(fwd, bwd)
    return fwd_fn


def tp_exact_linear(x, w, ctx, key=None):
    """Explicit Megatron column-parallel linear with EXACT backward.

    Used for sites excluded from sketching (e.g. the vocabulary head, which
    the paper keeps exact): same shard_map structure as the sketched path so
    the dW einsum never hits the pjit sharding conflict that replicates
    full fp32 weight gradients (EXPERIMENTS.md §Perf It.3).
    """
    mesh = ctx.mesh
    dp = tuple(ctx.data_axes)
    mp = ctx.model_axes[0]
    fn = _build_exact(mesh, dp, mp, w.shape)
    return fn(x, w)


def _build_exact(mesh, dp, mp, w_shape):
    scatter_axis = dp[-1] if dp else None
    n_scatter = mesh.shape[scatter_axis] if scatter_axis else 1
    psum_rest = tuple(a for a in dp[:-1])
    din_ok = w_shape[1] % n_scatter == 0

    @partial(jax.custom_vjp, nondiff_argnums=())
    def fwd_fn(x, w):
        def body(x_l, w_l):
            return jnp.einsum("bsi,oi->bso", x_l, w_l)

        return compat.shard_map(body, mesh=mesh,
                             in_specs=(P(dp, None, None), P(mp, None)),
                             out_specs=P(dp, None, mp))(x, w)

    def fwd(x, w):
        return fwd_fn(x, w), (x, w)

    def bwd(res, g):
        x, w = res

        def body(g_l, x_l, w_l):
            G2d = g_l.reshape(-1, g_l.shape[-1])
            X2d = x_l.reshape(-1, x_l.shape[-1])
            dx = (G2d @ w_l).reshape(x_l.shape)
            dx = jax.lax.psum(dx, mp)
            dW = jax.lax.dot_general(G2d.astype(jnp.float32), X2d.astype(jnp.float32),
                                     (((0,), (0,)), ((), ())))
            if psum_rest:
                dW = jax.lax.psum(dW, psum_rest)
            if scatter_axis and din_ok:
                dW = jax.lax.psum_scatter(dW, scatter_axis, scatter_dimension=1,
                                          tiled=True)
            elif scatter_axis:
                dW = jax.lax.psum(dW, scatter_axis)
            return dx, dW.astype(w_l.dtype)

        out_w_spec = P(mp, scatter_axis if (scatter_axis and din_ok) else None)
        dx, dw = compat.shard_map(body, mesh=mesh,
                               in_specs=(P(dp, None, mp), P(dp, None, None), P(mp, None)),
                               out_specs=(P(dp, None, None), out_w_spec),
                               )(g, x, w)
        return dx, dw

    fwd_fn.defvjp(fwd, bwd)
    return fwd_fn
