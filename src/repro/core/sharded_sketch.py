"""Tensor-parallel sketched linears: thin instantiations of the site spine.

The TP-native compact sketching design (DESIGN.md §3) — shard-local column
plans inside ``shard_map``, the standard TP dX all-reduce, and the compact dW
block reduce-scattered over the data axis (the compressed DP gradient
collective enabled by the paper's batch-shared sketch) — now lives in the one
sketched-site spine, ``core/site.py``, as the ``tp_column`` / ``tp_row`` /
``tp_exact`` :class:`~repro.core.site.ExecutionPlan` kinds. This module keeps
the historical entry points as spec constructors plus the applicability
predicates that :func:`~repro.core.site.resolve_site` consults.

Registry routing: the sketch *plan* inside shard_map comes from the
registered estimator's ``plan`` hook — any estimator that sets
``tp_shardable=True`` (see ``core/estimators.py``) runs on these plans with
its own sampling scheme, and its ``validate`` is consulted here exactly as on
the single-device path, so configs are accepted/rejected consistently. The
builtin compact/pallas backends are simply the first two such entries.

Bias and telemetry ride the same streams: ``db`` is folded into the
kept-column gather of every TP plan, and the per-site probe is computed
inside the shard_map backward body and ``psum``-ed over the model axis — so
compact gradients, bias sites and adaptive budget control all work under
tensor parallelism (see docs/distributed.md, docs/telemetry.md).
"""
from __future__ import annotations

from repro.core import site
from repro.core.site import tp_estimator as _tp_estimator
from repro.core.sketching import SketchConfig

__all__ = ["tp_sketched_linear", "tp_row_sketched_linear", "tp_exact_linear",
           "tp_applicable", "tp_row_applicable"]


def _plan(ctx, kind):
    return site.ExecutionPlan(kind=kind, mesh=ctx.mesh,
                              data_axes=tuple(ctx.data_axes),
                              model_axis=ctx.model_axes[0])


def tp_applicable(ctx, cfg, d_out: int) -> bool:
    """Column-parallel sites (attn q/k/v, mlp in/gate, ...): d_out is
    TP-sharded under ``ctx.tp_sketch``."""
    if ctx.mesh is None or not getattr(ctx, "tp_sketch", False) or cfg is None:
        return False
    if _tp_estimator(cfg) is None:
        return False
    return site._tp_column_ok(cfg, d_out, ctx.mesh, tuple(ctx.model_axes))


def tp_row_applicable(ctx, cfg, d_in: int) -> bool:
    """Row-parallel sites (attn_o / mlp_out / ssm_out): d_in is TP-sharded,
    d_out is the (unsharded) residual width."""
    if ctx.mesh is None or not getattr(ctx, "tp_sketch", False) or cfg is None:
        return False
    if _tp_estimator(cfg) is None:
        return False
    return site._tp_row_ok(d_in, ctx.mesh, tuple(ctx.model_axes))


def tp_sketched_linear(x, w, ctx, cfg: SketchConfig, key, slot=None, *,
                       b=None, pslot=None):
    """x: [B, S, d_in]; w: [n, d_in] with n TP-sharded. Returns [B, S, n].

    With a ``slot`` (compact-gradient mode), the backward skips the per-shard
    densify-scatter entirely: the reduce-scattered compact dW block and its
    global row indices ride the slot's cotangent. With a ``pslot``, the
    per-shard probe is psum'ed over the model axis and rides the probe-slot
    cotangent. ``b`` (sharded with the output dim) folds db into the same
    kept-column stream.
    """
    spec = site.SiteSpec(role="tp_column", cfg=cfg, plan=_plan(ctx, "tp_column"),
                         has_bias=b is not None, d_out=w.shape[0],
                         d_in=w.shape[1])
    return site.sketched_site(spec, x, w, b, key, slot, pslot)


def tp_row_sketched_linear(x, w, ctx, cfg: SketchConfig, key, slot=None, *,
                           b=None, pslot=None):
    """x: [B, S, d_in] (d_in TP-sharded); w: [n, d_in]. Returns [B, S, n].

    Megatron row-parallel: forward computes local partials + psum(mp).
    Backward sketches columns of the (mp-replicated) output gradient — the
    plan is identical on every shard, so dX stays local (ff-sharded) and the
    compact dW block reduce-scatters over dp as in the column-parallel plan.
    """
    spec = site.SiteSpec(role="tp_row", cfg=cfg, plan=_plan(ctx, "tp_row"),
                         has_bias=b is not None, d_out=w.shape[0],
                         d_in=w.shape[1])
    return site.sketched_site(spec, x, w, b, key, slot, pslot)


def tp_exact_linear(x, w, ctx, key=None, *, b=None):
    """Explicit Megatron column-parallel linear with EXACT backward.

    Used for sites excluded from sketching (e.g. the vocabulary head, which
    the paper keeps exact): same shard_map structure as the sketched plans so
    the dW einsum never hits the pjit sharding conflict that replicates full
    fp32 weight gradients (EXPERIMENTS.md §Perf It.3).
    """
    spec = site.SiteSpec(role="tp_exact", cfg=None, plan=_plan(ctx, "tp_exact"),
                         has_bias=b is not None, d_out=w.shape[0],
                         d_in=w.shape[1])
    return site.sketched_site(spec, x, w, b, key)
