"""Optimal sampling probabilities and correlated exact-r sampling.

Implements the paper's Algorithm 1 (water-filling solution of the convex program

    min_p  sum_i w_i / p_i   s.t.  sum_i p_i <= r,  p_i in (0, 1]

whose KKT solution is p_i* = min(1, t_i / sqrt(lambda)) with t_i = sqrt(w_i)),
and Algorithm 2 (systematic sampling of correlated Bernoulli variables with
fixed sum r, as required by Lemma 3.1 / Proposition 3.3).

Everything here is jittable with static ``r``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "optimal_probabilities",
    "sample_exact_r",
    "sample_independent",
    "expected_distortion",
]


def optimal_probabilities(weights: jax.Array, r: int, *, eps: float = 1e-12) -> jax.Array:
    """Water-filling solution of the paper's convex program (Eq. 23 / Alg. 1).

    Args:
      weights: non-negative importance weights ``w_i`` (shape ``[n]``). The
        optimal probabilities are ``p_i = min(1, sqrt(w_i)/sqrt(lambda))``.
      r: expected/exact budget (number of kept coordinates), ``1 <= r <= n``.
      eps: relative floor added to the weights so that every coordinate keeps
        a strictly positive probability (required for unbiasedness when a
        proxy score underestimates a coordinate that still carries signal —
        see DESIGN.md §3).

    Returns:
      ``p`` of shape ``[n]`` with ``p_i in (0, 1]`` and ``sum(p) == r`` (up to
      float error), matching the thresholding structure of the KKT conditions.
    """
    n = weights.shape[-1]
    if r >= n:
        return jnp.ones_like(weights)
    w = jnp.asarray(weights, jnp.float32)
    w = jnp.maximum(w, 0.0)
    mean_w = jnp.mean(w)
    # Relative floor: keeps p_i > 0. If all weights vanish, fall back to uniform.
    w = jnp.where(mean_w > 0, w + eps * mean_w, jnp.ones_like(w))

    t = jnp.sqrt(w)
    t_sorted = jnp.sort(t)[::-1]  # descending
    # suffix[k] = sum_{i >= k} t_sorted[i]  (0-indexed), k in [0, n-1]
    suffix = jnp.cumsum(t_sorted[::-1])[::-1]
    k = jnp.arange(n, dtype=jnp.float32)
    denom = jnp.float32(r) - k  # remaining budget if k entries saturate at 1
    valid_budget = denom > 0
    sqrt_lam_k = jnp.where(valid_budget, suffix / jnp.maximum(denom, 1.0), jnp.inf)
    # k is feasible iff the k saturated entries really exceed the water level
    # and the (k+1)-th does not:  t_(k-1) >= sqrt(lam_k) >= t_(k).
    t_prev = jnp.concatenate([jnp.array([jnp.inf], t_sorted.dtype), t_sorted[:-1]])
    feasible = valid_budget & (t_prev >= sqrt_lam_k) & (t_sorted <= sqrt_lam_k)
    # The smallest feasible k is the water-filling threshold.
    k_star = jnp.argmax(feasible)  # first True (argmax of bool)
    any_feasible = jnp.any(feasible)
    sqrt_lam = jnp.where(any_feasible, sqrt_lam_k[k_star], t_sorted[r - 1] if r >= 1 else 0.0)
    sqrt_lam = jnp.maximum(sqrt_lam, eps)
    p = jnp.minimum(1.0, t / sqrt_lam)
    # Exact renormalisation to sum(p) == r by a short fixed-point water-fill:
    # rescale the unsaturated block to absorb the remaining budget, clip, and
    # repeat (clipping can re-saturate entries; a one-shot rescale would leave
    # sum(p) < r and WARP THE SAMPLER'S MARGINALS -> bias).
    def refill(p, _):
        sat = p >= 1.0 - 1e-7
        n_sat = jnp.sum(sat)
        rest = jnp.sum(jnp.where(sat, 0.0, p))
        scale = jnp.where(rest > 0, (r - n_sat) / jnp.maximum(rest, eps), 1.0)
        return jnp.where(sat, 1.0, jnp.minimum(p * scale, 1.0)), None

    p, _ = jax.lax.scan(refill, p, None, length=8)
    return p


def sample_exact_r(key: jax.Array, p: jax.Array, r: int) -> jax.Array:
    """Correlated Bernoulli sampling with sum == r (paper Alg. 2).

    Systematic sampling: marginals are exactly ``p_i`` and exactly ``r``
    *distinct* indices are returned (requires ``p_i <= 1`` and ``sum p = r``).

    Returns indices of shape ``[r]`` (int32, ascending).
    """
    n = p.shape[-1]
    cum = jnp.cumsum(p.astype(jnp.float64) if jax.config.read("jax_enable_x64") else p.astype(jnp.float32))
    cum = cum.at[-1].set(jnp.float32(r))  # numerical safety (Alg. 2 line 3)
    u = jax.random.uniform(key, (), minval=jnp.finfo(jnp.float32).tiny, maxval=1.0)
    targets = u + jnp.arange(r, dtype=jnp.float32)
    idx = jnp.searchsorted(cum, targets, side="left")
    return jnp.clip(idx, 0, n - 1).astype(jnp.int32)


def sample_independent(key: jax.Array, p: jax.Array) -> jax.Array:
    """Independent Bernoulli gates z_i ~ B(p_i) (Lemma 3.4 setting).

    Returns a float mask of shape ``[n]`` (0/1). Expected count is sum(p).
    """
    return jax.random.bernoulli(key, p).astype(jnp.float32)


def expected_distortion(weights: jax.Array, p: jax.Array) -> jax.Array:
    """E-distortion  sum_i w_i (1/p_i - 1)  of a mask-and-rescale sketch.

    This is the objective of Eq. (23) minus its constant part (Lemma 3.4,
    Eq. 49): used by tests and by the variance diagnostics.
    """
    safe_p = jnp.maximum(p, 1e-20)
    return jnp.sum(jnp.where(weights > 0, weights * (1.0 / safe_p - 1.0), 0.0))
