"""Deterministic synthetic datasets.

* ``lm_batches`` — zipfian token stream with a planted bigram structure so a
  real LM can reduce loss well below the unigram entropy (the quickstart /
  train_lm examples and the trainer tests rely on this learnability).
* ``classification`` — MNIST/CIFAR-like class-conditional blobs used by the
  paper-figure benchmarks (MLP / ViT / BagNet comparisons): inputs are
  ``mu_class + noise`` with within-class low-rank structure, so both linear
  and deep models show a clean accuracy-vs-budget signal.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ClassStream", "LMStream", "classification"]


@dataclasses.dataclass
class LMStream:
    vocab: int
    seed: int = 0
    alpha: float = 1.1  # zipf exponent

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # planted deterministic bigram successor table on top of zipf unigrams
        self._succ = rng.integers(0, self.vocab, size=(self.vocab,), dtype=np.int32)
        w = (np.arange(1, self.vocab + 1, dtype=np.float64)) ** (-self.alpha)
        self._p = w / w.sum()

    def batches(self, batch: int, seq: int, *, start_step: int = 0, p_bigram: float = 0.8):
        """Infinite iterator of {tokens, labels} (labels = next token)."""
        step = start_step
        while True:
            rng = np.random.default_rng((self.seed, step))
            toks = np.empty((batch, seq + 1), np.int32)
            toks[:, 0] = rng.choice(self.vocab, size=batch, p=self._p)
            for t in range(seq):
                follow = rng.random(batch) < p_bigram
                rand = rng.choice(self.vocab, size=batch, p=self._p)
                toks[:, t + 1] = np.where(follow, self._succ[toks[:, t]], rand)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            step += 1


@dataclasses.dataclass
class ClassStream:
    """Infinite ``{"x", "y"}`` batch iterator over :func:`classification`
    blobs — the §5 MLP's data in the shape ``train_loop`` consumes (each
    batch is a fresh seeded draw, deterministic in ``(seed, step)``)."""

    dim: int = 784
    n_classes: int = 10
    seed: int = 0
    noise: float = 1.0

    def batches(self, batch: int, *, start_step: int = 0):
        step = start_step
        while True:
            x, y = classification(batch, self.dim, self.n_classes,
                                  seed=(self.seed * 100003 + step),
                                  noise=self.noise)
            yield {"x": x, "y": y}
            step += 1


def classification(n: int, dim, n_classes: int, *, seed: int = 0, noise: float = 1.0,
                   flatten: bool = True, mu_seed: int = 1234, mu_scale: float = 0.15):
    """Class-conditional gaussian blobs. dim: int (MLP) or (H, W, C) image.

    Class means are drawn from ``mu_seed`` (shared between train/test splits
    that differ only in ``seed``); per-coordinate separation ``mu_scale`` is
    small relative to ``noise`` so the task is non-trivial (chance ≈ 1/C,
    bayes-optimal well above — deep nets show a clean accuracy-vs-budget
    signal instead of saturating).
    """
    rng_mu = np.random.default_rng(mu_seed)
    rng = np.random.default_rng(seed)
    d = int(np.prod(dim))
    mu = rng_mu.normal(size=(n_classes, d)).astype(np.float32) * mu_scale
    y = rng.integers(0, n_classes, size=n).astype(np.int32)
    x = mu[y] + rng.normal(size=(n, d)).astype(np.float32) * noise
    if not flatten and not np.isscalar(dim):
        x = x.reshape((n,) + tuple(dim))
    return x, y
