"""Host data pipeline: background prefetch + device placement.

Multi-host note: each process feeds its own addressable shard of the global
batch (``jax.make_array_from_process_local_data``); on single-process meshes
(tests, CPU dry-run hosts) ``device_put`` against the batch sharding suffices.
"""
from __future__ import annotations

import queue
import threading

import jax

__all__ = ["prefetch", "shard_batch"]


def shard_batch(batch: dict, shardings: dict | None):
    if shardings is None:
        return batch
    return {k: jax.device_put(v, shardings[k]) if k in shardings else jax.device_put(v)
            for k, v in batch.items()}


def prefetch(it, size: int = 2, shardings: dict | None = None):
    """Background-thread prefetch with device placement overlap."""
    q: queue.Queue = queue.Queue(maxsize=size)
    _END = object()

    def worker():
        try:
            for item in it:
                q.put(shard_batch(item, shardings))
        except BaseException as e:  # forwarded: the consumer re-raises below
            q.put(e)
        else:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is _END:
            return
        if isinstance(item, BaseException):
            raise item
        yield item
