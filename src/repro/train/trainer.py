"""Training loop: metrics, periodic async checkpointing, budget schedules,
auto-resume, elastic restart.

The loop is deliberately thin — all heavy lifting is in the jitted step — but
production-shaped: it survives SIGTERM-style interruption (atomic checkpoints),
resumes from the newest checkpoint (possibly onto a different mesh), and
switches between the pre-compiled budget buckets of the runtime's
:class:`~repro.api.BudgetSchedule` per step (paper App. B.1 straggler
mitigation and §4 warmup/anneal schedules).

:func:`train_loop` is the Runtime-native loop (``Runtime.train`` delegates
here); :func:`train` is the legacy kwarg spelling kept as a thin shim that
constructs a Runtime internally and warns once.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Iterable, Optional

import jax
import numpy as np

from repro import compat
from repro.api import Runtime
from repro.obs import observability
from repro.configs.base import ArchConfig
from repro.core import SketchPolicy
from repro.optim import Optimizer
from repro.train import checkpoint as ckptlib
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import TrainState, init_state

__all__ = ["TrainerConfig", "train", "train_loop"]


@dataclasses.dataclass
class TrainerConfig:
    """Loop mechanics (steps, logging, checkpointing) — everything about the
    *model and estimator* lives on the Runtime instead.

    ``straggler_budgets`` is the legacy spelling of a reactive
    :class:`~repro.api.BudgetSchedule` and is honoured only through the
    legacy :func:`train` shim.
    """

    steps: int = 100
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    seed: int = 0
    straggler_budgets: tuple = ()  # legacy; use Runtime.schedule


def _host_metrics(metrics, *, scalars_only: bool = False) -> dict:
    """Device-get a metrics tree to plain python (floats; nested dicts — the
    per-site probe vectors — become lists, or are dropped with
    ``scalars_only`` for the cheap per-step controller fetch). One batched
    ``device_get`` per call, not one transfer per key."""
    tree = {k: v for k, v in metrics.items()
            if not (scalars_only and isinstance(v, dict))}
    fetched = jax.device_get(tree)
    out = {}
    for k, v in fetched.items():
        if isinstance(v, dict):
            out[k] = {kk: np.asarray(vv).astype(float).tolist()
                      for kk, vv in v.items()}
        else:
            out[k] = float(np.asarray(v))
    return out


def _policy_can_probe(policy, execution=None) -> bool:
    """Does any site of ``policy`` emit telemetry probes? (column-family
    method + an estimator implementing the probe hook, or — under
    ``tp_sketch`` — a TP-shardable estimator whose shard_map plans probe
    in-body; see repro/telemetry/probes.py and core/site.py)."""
    from repro.core.site import tp_estimator
    from repro.telemetry.probes import probe_capable

    if policy is None or policy.location != "all":
        return False
    tp = execution is not None and execution.tp_sketch

    def can(cfg):
        if probe_capable(cfg):
            return True
        # TP plans probe from the in-body plan marginals even when the
        # estimator has no apply_with_probe hook
        return tp and tp_estimator(cfg) is not None

    return can(policy.base) or any(can(cfg) for _, cfg in policy.overrides)


def train_loop(runtime: Runtime, cfg: ArchConfig, opt: Optimizer,
               data: Iterable, tcfg: Optional[TrainerConfig] = None, *,
               state: Optional[TrainState] = None,
               on_metrics: Optional[Callable] = None,
               faults=None, seed_salt: int = 0,
               on_event: Optional[Callable] = None):
    """Run the loop under ``runtime``; returns (final_state, history).

    One train step is compiled per distinct budget in
    ``runtime.schedule.buckets()`` — before the loop starts — and each step
    dispatches to the bucket the schedule (or, in controller mode, the
    straggler/adaptive controller) selects. Unbiasedness means bucket
    switches never bias the gradient, only its variance (paper §2.2).

    Telemetry: with ``runtime.execution.telemetry`` set, each step's metrics
    carry the probe summary and the configured sinks receive one record per
    ``telemetry.interval`` steps. An adaptive schedule
    (``BudgetSchedule.adaptive``) implies probes — they are enabled here
    automatically when the execution config has no telemetry — and its
    controller consumes the host-fetched ``probe_snr`` between steps to pick
    the next (pre-compiled) bucket: no recompiles, ever.

    Resilience (``runtime.execution.resilience`` set; docs/resilience.md):
    the compiled steps take a traced ``fault_scale`` operand, a
    :class:`~repro.resilience.GradSentinel` digests the per-step scalars —
    skipped updates surface as ``sentinel_trip``, trips force the exact
    bucket for K steps, and M consecutive trips raise
    :class:`~repro.resilience.RollbackRequired` for the supervisor.
    ``faults`` is a :class:`~repro.resilience.FaultPlan` (or a supervisor's
    :class:`~repro.resilience.FaultInjector`); ``seed_salt`` folds an extra
    term into every step key so a retried trajectory resamples its sketches;
    ``on_event`` receives every fault/trip/recovery record (the records also
    go to the telemetry sinks). A failed async checkpoint write surfaces as
    :class:`~repro.train.checkpoint.CheckpointError` here — with resilience
    enabled it is recorded and retried synchronously instead of raising.
    """
    tcfg = tcfg or TrainerConfig()
    schedule = runtime.schedule
    tel = runtime.execution.telemetry
    if schedule.is_adaptive and runtime.execution.accum != 1:
        raise ValueError(
            "adaptive BudgetSchedule requires accum == 1: the SNR probes "
            "cannot ride accumulated microbatches, so the controller would "
            "have no signal — use a fixed/warmup/reactive schedule with "
            "accumulation")
    if schedule.is_adaptive and (tel is None or not tel.probes):
        from repro.telemetry import TelemetryConfig

        # per_site=False: the controller only consumes the probe_snr scalar,
        # so the implicit config skips the per-site vectors (a user-supplied
        # TelemetryConfig keeps its own per_site choice)
        tel = (TelemetryConfig(per_site=False) if tel is None
               else dataclasses.replace(tel, probes=True))
        runtime = runtime.replace(execution=runtime.execution.replace(telemetry=tel))
    if schedule.is_adaptive and not _policy_can_probe(runtime.policy,
                                                      runtime.execution):
        warnings.warn(
            "adaptive BudgetSchedule cannot measure gradient SNR here "
            "(exact/location-restricted policy, or no probe-capable site: "
            "column-family method + an estimator with the probe hook or a "
            "TP-shardable plan) — the controller will hold its first "
            "bucket; see docs/telemetry.md", stacklevel=2)
    rcfg = runtime.execution.resilience
    if faults is not None and rcfg is None:
        raise ValueError(
            "faults= requires runtime.execution.resilience (the compiled "
            "step needs its traced fault_scale operand) — set "
            "ExecutionConfig(resilience=ResilienceConfig())")
    injector = sentinel = None
    if rcfg is not None:
        from repro.resilience.faults import DeviceLossFault, FaultInjector
        from repro.resilience.sentinel import GradSentinel, RollbackRequired

        injector = FaultInjector.wrap(faults)
        if rcfg.sentinel:
            sentinel = GradSentinel(rcfg)
    ob = observability(runtime.execution.obs)
    tracer = ob.tracer
    traced = tracer.enabled
    key = compat.prng_key(tcfg.seed)
    if state is None:
        state = init_state(jax.random.fold_in(key, 0), cfg, opt,
                           runtime.policy, execution=runtime.execution)

    ckpt = (CheckpointManager(tcfg.ckpt_dir, tcfg.ckpt_every, tracer=tracer)
            if tcfg.ckpt_dir else None)
    if ckpt is not None:
        # restore() yields host numpy leaves; commit them to device arrays
        # *before* the loop. The compiled step donates its state argument,
        # and donating an auto-converted numpy operand hands XLA a
        # conversion temporary to alias in place — the whole donation chain
        # then rides memory whose keep-alive drops with this call frame
        # (observed as the resumed run's final state.step reading recycled
        # bytes once the allocator is under churn).
        mesh = runtime.execution.mesh
        if mesh is not None:
            from repro.train import elastic
            restored = ckpt.restore_or_none(
                state, shardings=elastic.state_shardings(state, mesh))
        else:
            restored = ckpt.restore_or_none(state)
            if restored is not None:
                # an explicit target device forces owned copies; deviceless
                # device_put (like the jit-call conversion) may zero-copy
                # aligned numpy buffers, which the donating step then aliases
                dev = jax.local_devices()[0]
                restored = (compat.tree_map(
                    lambda x: jax.device_put(x, dev), restored[0]),
                    restored[1])
        if restored is not None:
            state, step0 = restored
            print(f"[trainer] resumed from step {step0}")

    # pre-built budget buckets: one compiled step per distinct budget; the
    # sentinel's escalation target (exact, i.e. None) is added when the
    # schedule alone would never compile it
    buckets = schedule.buckets()
    if sentinel is not None and None not in buckets:
        buckets = buckets + (None,)
    with tracer.span("build_buckets", n_buckets=len(buckets)):
        steps_by_budget = {b: runtime.train_step(cfg, opt, budget=b)
                           for b in buckets}
    controller = schedule.make_controller(policy=runtime.policy)
    fetch_each_step = bool(controller is not None
                           and getattr(controller, "wants_metrics", False))
    from repro.telemetry import sinks as tsinks

    sink = tsinks.build_sinks(tel)

    def emit(rec: dict):
        if sink is not None:
            sink.write(dict(rec))
        if on_event is not None:
            on_event(dict(rec))
        if ob.flight is not None:
            ob.flight.note(rec)

    def ckpt_wait_safe():
        # a pending async write may carry a CheckpointError; before raising a
        # recovery fault we drain it so the supervisor sees a settled
        # directory (with resilience on, the write error is recorded — the
        # rollback target is the newest *verified* checkpoint anyway)
        if ckpt is None:
            return
        try:
            with tracer.span("ckpt_wait"):
                ckpt.wait()
        except ckptlib.CheckpointError as e:
            emit({"event": "ckpt_io_error", "step": step, "error": str(e)})
            ob.dump_crash("ckpt_io", {"step": step, "error": str(e)})

    reg = ob.metrics
    steps_counter = reg.counter("train.steps") if reg is not None else None
    budget_gauge = reg.gauge("train.budget") if reg is not None else None
    history = []
    data_it = iter(data)
    start_step = int(jax.device_get(state.step))
    loop_span = tracer.span("train_loop", start_step=start_step,
                            steps=tcfg.steps)
    try:
      with loop_span:
        for step in range(start_step, tcfg.steps):
            batch = next(data_it)
            fscale = 1.0
            if injector is not None:
                fault = injector.take(step)
                if fault is not None:
                    emit({"event": "fault_injected", "step": step,
                          "kind": fault.kind})
                    with tracer.span("fault_injected", step=step,
                                     kind=fault.kind):
                        if fault.kind == "device_loss":
                            ckpt_wait_safe()
                            raise DeviceLossFault(step, fault.mesh_shape,
                                                  history=history, state=state)
                        if fault.kind == "slow":
                            time.sleep(fault.sleep_s)
                        elif fault.kind == "ckpt_io":
                            if ckpt is not None:
                                ckptlib.inject_fault_once()
                        elif fault.kind == "nonfinite":
                            fscale = float("nan")
                        elif fault.kind == "spike":
                            fscale = fault.scale
            step_key = jax.random.fold_in(key, step + 1)
            if seed_salt:
                # retried trajectories resample their sketches; salt 0 is
                # skipped entirely so the first attempt stays bit-identical
                # to a resilience-off run
                step_key = jax.random.fold_in(step_key, seed_salt)
            budget = controller.budget if controller else schedule.budget_at(step)
            if sentinel is not None:
                budget = sentinel.override(budget)
            fn = steps_by_budget[budget]
            if controller:
                controller.step_begin()
            if traced:
                # span attrs built only on the traced path — tracing-off
                # stays allocation-free here
                with tracer.span("train_step", step=step,
                                 budget=-1.0 if budget is None else budget):
                    if rcfg is not None:
                        state, metrics = fn(state, batch, step_key, fscale)
                    else:
                        state, metrics = fn(state, batch, step_key)
            elif rcfg is not None:
                state, metrics = fn(state, batch, step_key, fscale)
            else:
                state, metrics = fn(state, batch, step_key)
            if steps_counter is not None:
                steps_counter.inc()
            host_m = None  # full fetch (sink/log cadence only)
            host_scalars = None
            if controller or sentinel is not None:
                jax.block_until_ready(metrics["loss"])
                # per-step fetch stays scalars-only: the controller consumes
                # one scalar (probe_snr), the sentinel a handful; per-site
                # vectors are fetched on sink/log steps below
                if fetch_each_step or sentinel is not None:
                    host_scalars = _host_metrics(metrics, scalars_only=True)
            if controller:
                controller.step_end(host_scalars if fetch_each_step else None)
            if sentinel is not None:
                cause = sentinel.observe(step, host_scalars)
                if cause is not None:
                    emit(tsinks.recovery_record(
                        "sentinel_trip", step=step, cause=cause,
                        escalate_steps=rcfg.escalate_steps,
                        consecutive=sentinel.consecutive))
                if sentinel.should_rollback:
                    # raise BEFORE maybe_save: a state the sentinel cannot
                    # stabilise must never reach a checkpoint
                    ckpt_wait_safe()
                    raise RollbackRequired(step, sentinel.last_cause,
                                           history=history)
            if sink is not None and step % tel.interval == 0:
                host_m = _host_metrics(metrics)
                sink.write(dict(host_m, step=step, budget=budget))
            if budget_gauge is not None and (
                    step % tcfg.log_every == 0 or step == tcfg.steps - 1):
                budget_gauge.set(-1.0 if budget is None else budget)
                if ob.flight is not None:
                    ob.flight.snapshot(step)
            if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                m = host_m if host_m is not None else _host_metrics(metrics)
                m = dict(m, step=step, budget=budget)
                history.append(m)
                if on_metrics:
                    on_metrics(m)
                else:
                    b = "exact" if budget is None else f"{budget:.2f}"
                    print(f"[trainer] step {step:6d} loss {m['loss']:.4f} "
                          f"budget {b}")
            if ckpt is not None:
                try:
                    ckpt.maybe_save(step + 1, state)
                except ckptlib.CheckpointError as e:
                    if rcfg is None:
                        raise
                    # the failed async write is retried synchronously: one
                    # recorded hiccup, no lost checkpoint cadence
                    emit({"event": "ckpt_io_recovered", "step": step,
                          "error": str(e)})
                    ob.dump_crash("ckpt_io", {"step": step, "error": str(e)})
                    with tracer.span("ckpt_save_sync", step=step + 1):
                        ckptlib.save(ckpt.dir, step + 1, state, keep=ckpt.keep)
        if ckpt is not None:
            try:
                with tracer.span("ckpt_wait"):
                    ckpt.wait()
            except ckptlib.CheckpointError as e:
                if rcfg is None:
                    raise
                emit({"event": "ckpt_io_recovered", "step": tcfg.steps,
                      "error": str(e)})
                ob.dump_crash("ckpt_io", {"step": tcfg.steps, "error": str(e)})
                with tracer.span("ckpt_save_sync", step=tcfg.steps):
                    ckptlib.save(ckpt.dir, tcfg.steps, state, keep=ckpt.keep)
    finally:
        if sink is not None:
            sink.close()
        ob.export()
    return state, history


_warned_legacy = False


def train(cfg: ArchConfig, opt: Optimizer, data: Iterable, tcfg: TrainerConfig,
          policy: Optional[SketchPolicy] = None, *, mesh=None,
          act_sharding=None, data_axes=("data",), model_axes=("model",),
          tp_sketch: bool = False, compact_grads: bool = False,
          state: Optional[TrainState] = None,
          on_metrics: Optional[Callable] = None):
    """Legacy entry point — prefer ``repro.api.Runtime(...).train(...)``.

    Thin deprecation shim: the loose kwargs are bundled into a
    :class:`~repro.api.Runtime` (``tcfg.straggler_budgets`` becomes a
    reactive :class:`~repro.api.BudgetSchedule`) and the call is forwarded to
    :func:`train_loop`, so old calls produce bit-identical steps to the
    equivalent Runtime. Warns ``DeprecationWarning`` once per process.
    """
    global _warned_legacy
    if not _warned_legacy:
        warnings.warn(
            "repro.train.trainer.train(...) with loose kwargs is deprecated; "
            "build a repro.api.Runtime and call Runtime.train(...) "
            "(see docs/api.md for the migration table)",
            DeprecationWarning, stacklevel=2)
        _warned_legacy = True
    straggler = tuple(tcfg.straggler_budgets) if (tcfg.straggler_budgets
                                                 and policy is not None) else ()
    runtime = Runtime.from_legacy_kwargs(
        policy, mesh=mesh, act_sharding=act_sharding, data_axes=data_axes,
        model_axes=model_axes, tp_sketch=tp_sketch, compact_grads=compact_grads,
        straggler_budgets=straggler)
    return train_loop(runtime, cfg, opt, data, tcfg, state=state,
                      on_metrics=on_metrics)
