"""Training loop: metrics, periodic async checkpointing, straggler control,
auto-resume, elastic restart.

The loop is deliberately thin — all heavy lifting is in the jitted step — but
production-shaped: it survives SIGTERM-style interruption (atomic checkpoints),
resumes from the newest checkpoint (possibly onto a different mesh), and can
switch between precompiled sketch-budget buckets per step (paper App. B.1
straggler mitigation; see repro/train/straggler.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional

import jax
import numpy as np

from repro import compat
from repro.configs.base import ArchConfig
from repro.core import SketchPolicy
from repro.optim import Optimizer
from repro.train.checkpoint import CheckpointManager
from repro.train.straggler import StragglerController
from repro.train.train_step import TrainState, init_state, make_train_step

__all__ = ["TrainerConfig", "train"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    seed: int = 0
    straggler_budgets: tuple = ()  # e.g. (1.0, 0.5, 0.2) enables mitigation


def train(cfg: ArchConfig, opt: Optimizer, data: Iterable, tcfg: TrainerConfig,
          policy: Optional[SketchPolicy] = None, *, mesh=None,
          act_sharding=None, data_axes=("data",), model_axes=("model",),
          tp_sketch: bool = False, compact_grads: bool = False,
          state: Optional[TrainState] = None,
          on_metrics: Optional[Callable] = None):
    """Run the loop; returns (final_state, history list of metric dicts).

    With ``mesh`` set, the distributed kwargs (``act_sharding``, axis names,
    ``tp_sketch``) are forwarded to every compiled step so the trainer drives
    the same sharded sketched path as launch/dryrun — including the TP-local
    compact sketch with the compressed DP gradient reduce-scatter.
    ``compact_grads`` keeps sketched dW compact (rows + indices) from the
    backward through clipping into sparse-row optimizer updates (see
    docs/perf.md).
    """
    key = compat.prng_key(tcfg.seed)
    if state is None:
        state = init_state(jax.random.fold_in(key, 0), cfg, opt)

    ckpt = CheckpointManager(tcfg.ckpt_dir, tcfg.ckpt_every) if tcfg.ckpt_dir else None
    if ckpt is not None:
        restored = ckpt.restore_or_none(state)
        if restored is not None:
            state, step0 = restored
            print(f"[trainer] resumed from step {step0}")

    # straggler buckets: pre-built steps at descending sketch budgets
    controller = None
    steps_by_budget = {}
    step_kw = dict(mesh=mesh, act_sharding=act_sharding, data_axes=data_axes,
                   model_axes=model_axes, tp_sketch=tp_sketch,
                   compact_grads=compact_grads)
    if tcfg.straggler_budgets and policy is not None:
        controller = StragglerController(tcfg.straggler_budgets)
        for b in tcfg.straggler_budgets:
            pol_b = policy if b >= 1.0 else policy.with_budget(b)
            steps_by_budget[b] = jax.jit(make_train_step(cfg, opt, pol_b, **step_kw),
                                         donate_argnums=(0,))
    else:
        steps_by_budget[1.0] = jax.jit(make_train_step(cfg, opt, policy, **step_kw),
                                       donate_argnums=(0,))

    history = []
    data_it = iter(data)
    start_step = int(jax.device_get(state.step))
    for step in range(start_step, tcfg.steps):
        batch = next(data_it)
        step_key = jax.random.fold_in(key, step + 1)
        budget = controller.budget if controller else 1.0
        fn = steps_by_budget.get(budget, steps_by_budget[max(steps_by_budget)])
        if controller:
            controller.step_begin()
        state, metrics = fn(state, batch, step_key)
        if controller:
            jax.block_until_ready(metrics["loss"])
            controller.step_end()
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            m = {k: float(np.asarray(jax.device_get(v))) for k, v in metrics.items()}
            m["step"] = step
            m["budget"] = budget
            history.append(m)
            if on_metrics:
                on_metrics(m)
            else:
                print(f"[trainer] step {step:6d} loss {m['loss']:.4f} "
                      f"budget {budget:.2f}")
        if ckpt is not None:
            ckpt.maybe_save(step + 1, state)
    if ckpt is not None:
        ckpt.wait()
    return state, history
