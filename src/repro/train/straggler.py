"""Straggler mitigation via sketch-budget buckets (paper App. B.1).

The paper observes that VJP approximation can be applied *selectively at slow
compute nodes*. Under SPMD every device must run the same program, so we apply
the idea step-wise: the trainer keeps a small set of pre-compiled train steps
at different sketch budgets; a controller watches recent step times and drops
to a cheaper backward when the measured step time exceeds the target (e.g. a
slow host, a thermally-throttled chip, contention), recovering when times
normalise. Unbiasedness means switching budgets mid-run never biases the
gradient — only its variance changes (§2.2), which is exactly the trade
Eq. (6) prices.
"""
from __future__ import annotations

import time
from collections import deque

__all__ = ["StragglerController"]


class StragglerController:
    def __init__(self, budgets=(1.0, 0.5, 0.2, 0.1, 0.05), *, window: int = 8,
                 slow_factor: float = 1.3, fast_factor: float = 1.05,
                 target_step_s: float | None = None):
        """budgets must be sorted descending; index 0 = full backward."""
        self.budgets = tuple(budgets)
        self.level = 0
        self.window = window
        self.slow = slow_factor
        self.fast = fast_factor
        self.target = target_step_s
        self._times = deque(maxlen=window)
        self._t0 = None

    @property
    def budget(self) -> float:
        return self.budgets[self.level]

    def step_begin(self):
        self._t0 = time.perf_counter()

    def step_end(self):
        if self._t0 is None:
            return self.budget
        dt = time.perf_counter() - self._t0
        self._times.append(dt)
        if self.target is None and len(self._times) == self.window and self.level == 0:
            # calibrate the target from the first full window at full budget
            self.target = sorted(self._times)[self.window // 2]
        if self.target is None or len(self._times) < 3:
            return self.budget
        med = sorted(self._times)[len(self._times) // 2]
        if med > self.slow * self.target and self.level + 1 < len(self.budgets):
            self.level += 1
            self._times.clear()
        elif med < self.fast * self.target and self.level > 0:
            self.level -= 1
            self._times.clear()
        return self.budget

    def observe(self, dt: float):
        """Test hook: feed an externally measured step time."""
        self._t0 = time.perf_counter() - dt
        return self.step_end()
