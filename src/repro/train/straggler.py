"""Legacy location of the straggler controller (paper App. B.1).

The bucket machinery was absorbed into the budget-schedule front door:
:class:`repro.api.BudgetSchedule` (``BudgetSchedule.straggler(...)``) owns
the pre-compiled buckets and :class:`repro.api.StragglerController` the
reactive switching. This module re-exports the controller so existing
imports keep working.
"""
from __future__ import annotations

from repro.api.schedule import StragglerController

__all__ = ["StragglerController"]
