"""Fault-tolerant checkpointing: atomic, sharded, async, mesh-elastic.

Design (DESIGN.md §4):
  * atomic: write to ``step_<n>.tmp/`` then ``os.rename`` — a crash mid-save
    never corrupts the latest checkpoint; restore picks the newest complete dir.
  * sharded: each leaf is saved as its own ``.npy`` under a flattened path key
    with a JSON manifest (tree structure + dtypes + step). On multi-host, each
    process saves only the addressable shards of its leaves (process 0 saves
    replicated leaves); this container is single-process so leaves are whole.
  * async: ``save_async`` snapshots to host memory (device_get) and writes in
    a background thread — training continues during I/O.
  * elastic: restore takes only (tree structure, target shardings); because
    every leaf is saved as a full logical array, a checkpoint from a (16,16)
    mesh restores onto (2,16,16) or (4,8) meshes unchanged — re-sharding
    happens at ``device_put`` (tested in tests/test_checkpoint.py with fake
    device counts).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

from repro import compat

__all__ = ["save", "save_async", "restore", "latest_step", "CheckpointManager"]

_SEP = "__"


def _flatten(tree):
    flat = compat.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_part(p) for p in path)
        out[key] = leaf
    return out


def _part(p):
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    return f"x:{p}"


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3):
    """Synchronous atomic save."""
    host_tree = compat.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
    _write(ckpt_dir, step, host_tree, keep)


def save_async(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> threading.Thread:
    """Snapshot to host, write in background. Returns the writer thread."""
    host_tree = compat.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(target=_write, args=(ckpt_dir, step, host_tree, keep),
                         daemon=True)
    t.start()
    return t


def _write(ckpt_dir, step, host_tree, keep):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:012d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(host_tree)
    manifest = {"step": int(step), "keys": sorted(flat.keys()), "version": 1}
    for k, v in flat.items():
        np.save(os.path.join(tmp, k + ".npy"), v)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)


def _gc(ckpt_dir, keep):
    steps = sorted(_all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:012d}"), ignore_errors=True)


def _all_steps(ckpt_dir):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str):
    steps = _all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, *, step=None, shardings=None):
    """Restore into the structure of ``tree_like``; optionally re-shard.

    ``shardings``: a congruent tree of NamedShardings (elastic restore onto a
    *different* mesh than the one that saved) — or None for host arrays.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:012d}")
    keys = _flatten(tree_like)
    loaded = {k: np.load(os.path.join(d, k + ".npy")) for k in keys}
    treedef = compat.tree_structure(tree_like)
    ordered = [loaded[k] for k in _flatten(tree_like)]
    out = compat.tree_unflatten(treedef, ordered)
    if shardings is not None:
        out = compat.tree_map(lambda x, s: jax.device_put(x, s), out, shardings)
    return out, step


class CheckpointManager:
    """Trainer-facing manager: periodic async saves + crash-safe resume."""

    def __init__(self, ckpt_dir: str, every: int = 100, keep: int = 3):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self._pending: threading.Thread | None = None

    def maybe_save(self, step: int, tree):
        if step % self.every != 0:
            return False
        self.wait()
        self._pending = save_async(self.dir, step, tree, keep=self.keep)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_or_none(self, tree_like, shardings=None):
        if latest_step(self.dir) is None:
            return None
        return restore(self.dir, tree_like, shardings=shardings)
