"""Fault-tolerant checkpointing: atomic, sharded, async, mesh-elastic, verified.

Design (DESIGN.md §4):
  * atomic: write to ``step_<n>.tmp/`` then ``os.rename`` — a crash mid-save
    never corrupts the latest checkpoint; restore picks the newest complete dir.
  * sharded: each leaf is saved as its own ``.npy`` under a flattened path key
    with a JSON manifest (tree structure + dtypes + step). On multi-host, each
    process saves only the addressable shards of its leaves (process 0 saves
    replicated leaves); this container is single-process so leaves are whole.
  * async: ``save_async`` snapshots to host memory (device_get) and writes in
    a background thread — training continues during I/O. The writer thread
    *captures* its exception: :meth:`CheckpointManager.wait` (and hence the
    next ``maybe_save``) re-raises it as :class:`CheckpointError` instead of
    letting the failure die silently on a daemon thread.
  * elastic: restore takes only (tree structure, target shardings); because
    every leaf is saved as a full logical array, a checkpoint from a (16,16)
    mesh restores onto (2,16,16) or (4,8) meshes unchanged — re-sharding
    happens at ``device_put`` (tested in tests/test_checkpoint.py with fake
    device counts).
  * verified: the manifest (version 2) records a CRC32 per leaf, computed
    over the exact ``.npy`` bytes written. :func:`verify` re-hashes the files;
    :func:`restore` refuses a corrupt/truncated checkpoint — falling back to
    the newest *verified* step when picking automatically, raising
    :class:`CheckpointError` when the step was requested explicitly. The
    resilience drill's ``ckpt_io`` fault rides :func:`inject_fault_once`.
"""
from __future__ import annotations

import io
import json
import os
import re
import shutil
import threading
import warnings
import zlib

import jax
import numpy as np

from repro import compat

__all__ = ["CheckpointError", "save", "save_async", "restore", "latest_step",
           "latest_verified_step", "verify", "inject_fault_once",
           "CheckpointManager"]

_SEP = "__"


class CheckpointError(RuntimeError):
    """A checkpoint write failed (sync, or async surfaced on ``wait()``) or a
    requested checkpoint failed CRC verification."""


# -- fault injection hook (repro.resilience) ----------------------------------
# arm once; the next _write (sync or async) raises before touching disk —
# deterministic stand-in for a failing/filled filesystem in the tier-1 drill.

_fault_lock = threading.Lock()
_fault_armed = [False]


def inject_fault_once():
    """Arm a one-shot IO failure for the next checkpoint write."""
    with _fault_lock:
        _fault_armed[0] = True


def _take_fault() -> bool:
    with _fault_lock:
        armed = _fault_armed[0]
        _fault_armed[0] = False
        return armed


def _flatten(tree):
    flat = compat.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_part(p) for p in path)
        out[key] = leaf
    return out


def _part(p):
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    return f"x:{p}"


def _snapshot(tree):
    """Owned host copies of every leaf. ``jax.device_get`` on the CPU
    backend returns zero-copy *views* of device buffers; with donated train
    steps those buffers are reused in place by later steps, so a view held
    across an async write races the training loop (torn leaves in the
    written checkpoint, and freed-buffer reads once donation drops the
    allocation). ``np.array(..., copy=True)`` pins the snapshot."""
    return compat.tree_map(
        lambda x: np.array(jax.device_get(x), copy=True), tree)


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3):
    """Synchronous atomic save."""
    _write(ckpt_dir, step, _snapshot(tree), keep)


class _Writer(threading.Thread):
    """Async checkpoint writer. A raised exception is captured on
    ``self.error`` (not swallowed by the dying daemon thread) and re-raised
    as :class:`CheckpointError` by :meth:`CheckpointManager.wait`."""

    def __init__(self, ckpt_dir, step, host_tree, keep, tracer=None):
        super().__init__(daemon=True)
        self.error: BaseException | None = None
        self._job = (ckpt_dir, step, host_tree, keep)
        self._tracer = tracer

    def run(self):
        try:
            if self._tracer is not None and self._tracer.enabled:
                # I/O span on the writer thread (the tracer's nesting state
                # is per-thread; the ring is shared)
                with self._tracer.span("ckpt_io_write", step=self._job[1]):
                    _write(*self._job)
            else:
                _write(*self._job)
        except BaseException as e:  # captured for wait(); never swallowed
            self.error = e


def save_async(ckpt_dir: str, step: int, tree, *, keep: int = 3,
               tracer=None) -> _Writer:
    """Snapshot to host, write in background. Returns the writer thread;
    check ``.error`` after ``.join()`` (CheckpointManager does both)."""
    t = _Writer(ckpt_dir, step, _snapshot(tree), keep, tracer)
    t.start()
    return t


def _write(ckpt_dir, step, host_tree, keep):
    if _take_fault():
        raise CheckpointError(
            f"injected IO fault writing step {step} (inject_fault_once)")
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:012d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(host_tree)
    crc = {}
    for k, v in flat.items():
        # hash the exact bytes that hit disk, so verify() is a pure re-read
        buf = io.BytesIO()
        np.save(buf, v)
        data = buf.getvalue()
        crc[k] = zlib.crc32(data) & 0xFFFFFFFF
        with open(os.path.join(tmp, k + ".npy"), "wb") as f:
            f.write(data)
    manifest = {"step": int(step), "keys": sorted(flat.keys()), "version": 2,
                "crc": crc}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)


def _gc(ckpt_dir, keep):
    steps = sorted(_all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:012d}"), ignore_errors=True)


def _all_steps(ckpt_dir):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str):
    steps = _all_steps(ckpt_dir)
    return max(steps) if steps else None


def verify(ckpt_dir: str, step: int) -> bool:
    """CRC-check every leaf of ``step`` against its manifest.

    A version-1 manifest (pre-CRC) has nothing to check and verifies
    trivially; a missing/truncated/bit-flipped ``.npy`` fails.
    """
    d = os.path.join(ckpt_dir, f"step_{step:012d}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError):
        return False
    crc = manifest.get("crc")
    if crc is None:
        return True  # legacy manifest: no hashes recorded
    for k in manifest.get("keys", []):
        try:
            with open(os.path.join(d, k + ".npy"), "rb") as f:
                data = f.read()
        except OSError:
            return False
        if (zlib.crc32(data) & 0xFFFFFFFF) != crc.get(k):
            return False
    return True


def latest_verified_step(ckpt_dir: str):
    """Newest step whose every leaf passes CRC; None if no step does."""
    for s in sorted(_all_steps(ckpt_dir), reverse=True):
        if verify(ckpt_dir, s):
            return s
    return None


def restore(ckpt_dir: str, tree_like, *, step=None, shardings=None):
    """Restore into the structure of ``tree_like``; optionally re-shard.

    ``shardings``: a congruent tree of NamedShardings (elastic restore onto a
    *different* mesh than the one that saved) — or None for host arrays.

    With ``step=None`` the newest checkpoint is CRC-verified first; a corrupt
    newest falls back to the newest *verified* step (with a warning), and
    :class:`CheckpointError` is raised only when no step verifies. An
    explicit ``step`` that fails verification raises — the caller asked for
    that exact state and must not silently get another.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
        if not verify(ckpt_dir, step):
            fallback = latest_verified_step(ckpt_dir)
            if fallback is None:
                raise CheckpointError(
                    f"no verified checkpoint in {ckpt_dir} "
                    f"(newest step {step} failed CRC)")
            warnings.warn(
                f"checkpoint step {step} in {ckpt_dir} failed CRC "
                f"verification; falling back to verified step {fallback}",
                stacklevel=2)
            step = fallback
    elif not verify(ckpt_dir, step):
        raise CheckpointError(
            f"checkpoint step {step} in {ckpt_dir} failed CRC verification")
    d = os.path.join(ckpt_dir, f"step_{step:012d}")
    keys = _flatten(tree_like)
    loaded = {k: np.load(os.path.join(d, k + ".npy")) for k in keys}
    treedef = compat.tree_structure(tree_like)
    ordered = [loaded[k] for k in _flatten(tree_like)]
    out = compat.tree_unflatten(treedef, ordered)
    if shardings is not None:
        out = compat.tree_map(lambda x, s: jax.device_put(x, s), out, shardings)
    return out, step


class CheckpointManager:
    """Trainer-facing manager: periodic async saves + crash-safe resume."""

    def __init__(self, ckpt_dir: str, every: int = 100, keep: int = 3,
                 tracer=None):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self.tracer = tracer  # repro.obs tracer; async writes record I/O spans
        self._pending: _Writer | None = None

    def maybe_save(self, step: int, tree):
        if step % self.every != 0:
            return False
        self.wait()
        self._pending = save_async(self.dir, step, tree, keep=self.keep,
                                   tracer=self.tracer)
        return True

    def wait(self):
        """Join the pending write; re-raise its failure as CheckpointError."""
        t, self._pending = self._pending, None
        if t is not None:
            t.join()
            if t.error is not None:
                if isinstance(t.error, CheckpointError):
                    raise t.error
                raise CheckpointError(
                    f"async checkpoint write failed: {t.error!r}") from t.error

    def restore_or_none(self, tree_like, shardings=None):
        if latest_step(self.dir) is None:
            return None
        return restore(self.dir, tree_like, shardings=shardings)
