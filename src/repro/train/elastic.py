"""Elastic restart: re-shard a checkpoint onto a different mesh.

Parameter PartitionSpecs are *rule-derived* (launch/sharding.py) rather than
baked into checkpoints, and checkpoints store full logical arrays — so a
cluster resize (node failure shrinking DP, or scale-up) is:

    state_like  = eval_shape(init_state)
    new_mesh    = make_mesh(new_shape, axes)
    shardings   = param_shardings(state_like, new_mesh)
    state, step = restore(ckpt_dir, state_like, shardings=shardings)

``resume_on_mesh`` wraps exactly that. tests/test_checkpoint.py exercises a
(4,2) -> (2,4) -> (8,) sequence on fake devices.
"""
from __future__ import annotations

from repro.launch import sharding as shardlib
from repro.train import checkpoint as ckptlib
from repro.train.train_step import TrainState

__all__ = ["resume_on_mesh", "state_shardings"]


def state_shardings(state_like: TrainState, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    pspecs = shardlib.param_shardings(state_like.params, mesh)
    ospecs = {k: pspecs for k in state_like.opt_state}
    return TrainState(params=pspecs, opt_state=ospecs,
                      step=NamedSharding(mesh, P()))


def resume_on_mesh(ckpt_dir: str, state_like: TrainState, mesh):
    """Restore the newest checkpoint, sharded for ``mesh`` (any shape)."""
    shardings = state_shardings(state_like, mesh)
    return ckptlib.restore(ckpt_dir, state_like, shardings=shardings)
