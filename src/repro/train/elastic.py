"""Elastic restart: re-shard a checkpoint onto a different mesh.

Parameter PartitionSpecs are *rule-derived* (launch/sharding.py) rather than
baked into checkpoints, and checkpoints store full logical arrays — so a
cluster resize (node failure shrinking DP, or scale-up) is:

    state_like  = eval_shape(init_state)
    new_mesh    = make_mesh(new_shape, axes)
    shardings   = param_shardings(state_like, new_mesh)
    state, step = restore(ckpt_dir, state_like, shardings=shardings)

``resume_on_mesh`` wraps exactly that. tests/test_checkpoint.py exercises a
(4,2) -> (2,4) -> (8,) sequence on fake devices.
"""
from __future__ import annotations

from repro.launch import sharding as shardlib
from repro.train import checkpoint as ckptlib
from repro.train.train_step import TrainState

__all__ = ["resume_on_mesh", "state_shardings", "surviving_mesh"]


def surviving_mesh(old_mesh, shape, *, axes=None):
    """Mesh over the *surviving* device set after a simulated loss.

    ``shape`` is the new mesh shape (its product must not exceed the old
    mesh's device count — survivors are a prefix of the old device order, so
    a (4,2) run that loses a node resumes on (2,4)'s first 8... or fewer).
    Axis names default to the old mesh's; with no old mesh, to
    ``("data", "model")`` truncated to ``len(shape)``.
    """
    import math

    from repro import compat

    shape = tuple(int(s) for s in shape)
    n = math.prod(shape)
    if old_mesh is not None:
        devices = list(old_mesh.devices.flat)
        if axes is None:
            axes = tuple(old_mesh.axis_names)
    else:
        import jax

        devices = jax.devices()
    if n > len(devices):
        raise ValueError(f"surviving mesh {shape} needs {n} devices, only "
                         f"{len(devices)} available")
    if axes is None:
        axes = ("data", "model")[:len(shape)]
    if len(axes) != len(shape):
        axes = tuple(f"ax{i}" for i in range(len(shape))) if len(axes) < len(shape) \
            else tuple(axes)[:len(shape)]
    return compat.make_mesh(shape, tuple(axes), devices=devices[:n])


def state_shardings(state_like: TrainState, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    pspecs = shardlib.param_shardings(state_like.params, mesh)
    ospecs = {k: pspecs for k in state_like.opt_state}
    return TrainState(params=pspecs, opt_state=ospecs,
                      step=NamedSharding(mesh, P()))


def resume_on_mesh(ckpt_dir: str, state_like: TrainState, mesh):
    """Restore the newest checkpoint, sharded for ``mesh`` (any shape)."""
    shardings = state_shardings(state_like, mesh)
    return ckptlib.restore(ckpt_dir, state_like, shardings=shardings)
