"""Jitted training step factory: sketched backprop + sharded optimizer update.

``make_train_step`` closes over static config (arch, sketch policy, optimizer)
and returns a function of pure pytrees — ready for ``jax.jit`` with the param
/ batch shardings from ``repro.launch.sharding``. The same factory builds the
dry-run ``train_step`` (lowered against ShapeDtypeStructs).

Distributed-optimization knobs:
  * gradient accumulation (``accum``) — microbatch scan, gradients averaged;
    with FSDP-sharded params XLA lowers the per-microbatch gradient sums to
    reduce-scatters that overlap the next microbatch's backward.
  * compressed DP all-reduce — when the policy uses a *compact/pallas* sketch,
    the dW of sketched layers is column-sparse with an index set shared across
    DP replicas (shared step key), so the all-reduce moves ~budget × bytes.
    Under SPMD/pjit this happens structurally: the backward scatter-add of the
    compact dW is sharded over the data axis, and XLA reduce-scatters only the
    written rows' values. EXPERIMENTS.md §Perf measures the effect.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.api.execution import ExecutionConfig
from repro.configs.base import ArchConfig
from repro.core import SketchPolicy
from repro.core import compact_grad as cgrad
from repro.core import plan_state as pstate
from repro.models import lm
from repro.optim import Optimizer, global_grad_norm

__all__ = ["TrainState", "make_train_step", "init_state"]


@compat.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: dict
    opt_state: dict
    step: jax.Array


def init_state(key, cfg: ArchConfig, opt: Optimizer,
               policy: Optional[SketchPolicy] = None, *,
               execution: Optional[ExecutionConfig] = None) -> TrainState:
    """Fresh train state. ``policy``/``execution`` (optional, backwards
    compatible) let plan-carry estimators ("onepass"/"stale") merge their
    permanent per-site score leaves into the params tree — without them a
    carry policy still runs, every step just re-seeds from the uniform
    prior (see core/plan_state.py)."""
    params = lm.init_params(key, cfg)
    if pstate.policy_uses_carry(policy):
        ex = execution
        params = pstate.with_plan_state(
            params, policy, n_layers=cfg.n_layers,
            mesh=ex.mesh if ex else None,
            data_axes=ex.data_axes if ex else ("data",),
            model_axes=ex.model_axes if ex else ("model",),
            tp_sketch=ex.tp_sketch if ex else False)
    return TrainState(params=params, opt_state=opt.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ArchConfig, opt: Optimizer, policy: Optional[SketchPolicy] = None,
                    *, execution: Optional[ExecutionConfig] = None,
                    mesh=None, act_sharding=None, accum: int = 1,
                    cost_mode: bool = False, data_axes=("data",), model_axes=("model",),
                    tp_sketch: bool = False, compact_grads: bool = False):
    """Returns ``step_fn(state, batch, key) -> (state, metrics)``.

    ``execution`` is the one-object spelling (the :class:`Runtime` front door
    passes it); the loose kwargs are the legacy spelling and are ignored when
    ``execution`` is given.

    With ``execution.resilience`` set the step instead has the signature
    ``step_fn(state, batch, key, fault_scale) -> (state, metrics)``:
    ``fault_scale`` is a *traced* scalar multiplying the loss (1.0 in normal
    operation — an IEEE bitwise identity — NaN / large values under fault
    injection), and when ``resilience.sentinel`` is true the optimizer
    update is gated on an in-graph non-finite/norm-explosion flag reported
    as ``metrics["sentinel_trip"]`` (see docs/resilience.md).

    ``compact_grads=True`` threads per-site gradient slots through the params
    tree so sketched sites' dW comes out of the backward as a
    :class:`~repro.core.compact_grad.CompactGrad` (rows + indices, no
    densify-scatter) and is applied by the optimizer as a sparse-row update.
    Requires ``accum == 1`` — microbatches sample different index sets, so
    compact gradients cannot be accumulated (enforced by ExecutionConfig).

    ``execution.telemetry`` (a :class:`repro.telemetry.TelemetryConfig` with
    ``probes=True``) additionally threads per-site *probe* slots: the step's
    metrics gain the telemetry summary (``probe_gsq`` / ``probe_var`` /
    ``probe_snr`` / ``probe_align`` and, optionally, per-site vectors under
    ``probe_sites``) as a side output of the same backward — no second
    backward, no extra pass over G. Sites routed through a TP shard_map plan
    probe too: the spine computes the per-shard probe inside the backward
    body and psums it over the model axis (see docs/telemetry.md).
    """
    if execution is None:
        execution = ExecutionConfig(mesh=mesh, act_sharding=act_sharding,
                                    data_axes=tuple(data_axes),
                                    model_axes=tuple(model_axes),
                                    tp_sketch=tp_sketch,
                                    compact_grads=compact_grads, accum=accum,
                                    cost_mode=cost_mode)
    ex = execution
    accum = ex.accum
    compact_grads = ex.compact_grads
    tel = ex.telemetry
    telemetry_on = (tel is not None and tel.probes and policy is not None
                    and accum == 1)
    rcfg = ex.resilience
    carry_on = pstate.policy_uses_carry(policy)
    if ex.fused_vmem_limit is not None or ex.obs is not None:
        # bind the execution-level kernel knobs once per step build: the
        # fused-dispatch VMEM budget and the obs metrics sink its
        # dispatch/fallback decisions are recorded into (kernels/ops.py)
        from repro.kernels import ops as kops
        from repro.obs import observability

        kops.configure(vmem_limit=ex.fused_vmem_limit,
                       metrics=observability(ex.obs).metrics)

    def ctx_for(key):
        return ex.make_ctx(policy=policy, key=key)

    def loss_fn(params, batch, key, fault_scale):
        total, metrics = lm.lm_loss(params, batch, ctx_for(key), cfg, key)
        if rcfg is not None:
            # traced operand: fault injection (NaN / spike multipliers on the
            # loss, hence on every cotangent) without a recompile per fault;
            # x * 1.0 is an IEEE bitwise identity, so the clean path is
            # bit-identical to a resilience-off step
            total = total * fault_scale
        return total, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def one_micro(params, batch, key, fault_scale):
        (loss, metrics), grads = grad_fn(params, batch, key, fault_scale)
        return loss, metrics, grads

    def base_step(state: TrainState, batch, key, fault_scale):
        probe_metrics = {}
        if accum == 1:
            params_in = state.params
            if compact_grads:
                params_in = cgrad.with_grad_slots(
                    state.params, policy, mesh=ex.mesh, data_axes=ex.data_axes,
                    model_axes=ex.model_axes, tp_sketch=ex.tp_sketch,
                    n_layers=cfg.n_layers)
            if telemetry_on:
                from repro.telemetry import probes as tprobes

                params_in = tprobes.with_probe_slots(
                    params_in, policy, n_layers=cfg.n_layers, mesh=ex.mesh,
                    data_axes=ex.data_axes, model_axes=ex.model_axes,
                    tp_sketch=ex.tp_sketch)
            loss, metrics, grads = one_micro(params_in, batch, key, fault_scale)
            if telemetry_on:
                grads, probe_vecs = tprobes.collect_probes(grads)
                probe_metrics = tprobes.summarize(probe_vecs,
                                                  per_site=tel.per_site)
            if compact_grads:
                grads = cgrad.fold_slot_grads(grads)
        else:
            def micro(carry, xs):
                mb, mkey = xs
                loss, metrics, grads = one_micro(state.params, mb, mkey,
                                                 fault_scale)
                acc_loss, acc_grads = carry
                return (acc_loss + loss / accum,
                        compat.tree_map(lambda a, g: a + g / accum, acc_grads, grads)), metrics

            def to_micro(name, x):
                ax = 1 if name == "positions" else 0  # M-RoPE positions: [3, B, S]
                b = x.shape[ax] // accum
                x = jnp.moveaxis(x, ax, 0)
                x = x.reshape((accum, b) + x.shape[1:])
                return jnp.moveaxis(x, 1, ax + 1) if ax else x

            mbs = {k: to_micro(k, v) for k, v in batch.items()}
            keys = jax.random.split(key, accum)
            zeros = compat.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), metrics = jax.lax.scan(micro, (jnp.zeros(()), zeros), (mbs, keys))
            metrics = compat.tree_map(lambda m: m[-1], metrics)
        fresh_scores = {}
        if carry_on:
            # plan carry: the sslot cotangents ARE the refreshed scores —
            # pull them out (zeroing the leaves keeps the gradient tree
            # congruent for the optimizer and the grad norm; under accum the
            # scan has averaged the microbatches' scores, still a valid carry)
            grads, fresh_scores = pstate.collect_plan_state(grads)
        new_params, new_opt = opt.update(grads, state.opt_state, state.params, state.step)
        if fresh_scores:
            # write the refreshed carry over whatever the optimizer did to
            # the sslot leaves (zero grads ⇒ only decay touched them) —
            # BEFORE sentinel gating, so a tripped step keeps the old carry
            new_params = pstate.write_plan_state(new_params, fresh_scores)
        gn = _global_norm(grads)
        if rcfg is not None and rcfg.sentinel:
            from repro.resilience.sentinel import gate_update, trip_flag

            # one scalar out of quantities the step already materializes;
            # a tripped step keeps the old params AND opt state (the moment
            # buffers must not ingest a poisoned gradient) — the step
            # counter still advances so the schedule/PRNG stay on track
            ok, tripped = trip_flag(loss, gn, rcfg.max_grad_norm)
            new_params = gate_update(ok, new_params, state.params)
            new_opt = gate_update(ok, new_opt, state.opt_state)
            probe_metrics = dict(probe_metrics, sentinel_trip=tripped)
        new_state = TrainState(params=new_params, opt_state=new_opt, step=state.step + 1)
        metrics = dict(metrics, loss=loss, grad_norm=gn, **probe_metrics)
        return new_state, metrics

    if rcfg is None:
        # the historical three-argument step: bit-compatible executables,
        # unchanged golden traces
        def step_fn(state: TrainState, batch, key):
            return base_step(state, batch, key, jnp.float32(1.0))
    else:
        def step_fn(state: TrainState, batch, key, fault_scale):
            return base_step(state, batch, key, fault_scale)

    return step_fn


def _global_norm(tree):
    return global_grad_norm(tree)
