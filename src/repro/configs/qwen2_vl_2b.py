"""Qwen2-VL-2B [arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B] — backbone only.

28L, d_model 1536, 12 heads GQA kv=2, d_ff 8960, vocab 151936. M-RoPE;
dynamic-resolution vision frontend is a STUB: input_specs feed precomputed
patch/text embeddings plus 3-stream positions.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_ff=8960, vocab=151936,
    mlp_type="swiglu", rope="mrope", rope_theta=1000000.0, frontend="vision",
    dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=48, n_heads=6, n_kv=2, d_ff=96, vocab=256,
    dtype="float32", param_dtype="float32", q_chunk=16, kv_chunk=16,
)
