"""Architecture config schema + shape cells shared by all assigned archs."""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ArchConfig", "ShapeCell", "SHAPE_CELLS"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm | mlp
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    mlp_type: str = "swiglu"  # swiglu | geglu | relu_sq | gelu
    rope: str = "default"  # default | mrope | none
    rope_theta: float = 1e4
    rope_theta_global: Optional[float] = None  # gemma3 dual-theta
    window: Optional[int] = None  # sliding window width
    local_global: int = 0  # k local layers per 1 global (gemma3: 5)
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    block_kind: str = "attn"  # attn | rwkv | mamba | zamba
    ssm_state: int = 0
    ssm_head_dim: int = 64
    shared_attn_every: int = 0  # zamba: one shared attn block every k mamba layers
    # enc-dec
    enc_layers: int = 0
    # numerics
    dtype: str = "float32"
    param_dtype: str = "float32"
    # execution
    q_chunk: int = 512
    kv_chunk: int = 1024
    ssm_chunk: int = 256
    attn_impl: str = "chunked"  # chunked | einsum | pallas
    remat: str = "full"  # full | dots | none
    # frontend stub
    frontend: Optional[str] = None  # vision | audio

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (sub-quadratic / bounded-window attention)."""
        return (self.block_kind in ("rwkv", "mamba", "zamba")
                or self.window is not None or self.local_global > 0)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
