"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large].

Enc-dec backbone: 24 encoder + 24 decoder layers, d_model 1024, 16 heads,
d_ff 8192, vocab 256206. The speech/text modality frontend is a STUB
(input_specs feed precomputed frame embeddings to the encoder).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=8192,
    vocab=256206, mlp_type="gelu", rope_theta=10000.0, frontend="audio",
    dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
    vocab=256, dtype="float32", param_dtype="float32", q_chunk=16, kv_chunk=16,
)
