"""RWKV6-3B "Finch" [arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b].

32L, d_model 2560 (attention-free), channel-mix d_ff 8960, vocab 65536.
Data-dependent per-channel decay (LoRA-projected), head_dim 64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv=40, d_ff=8960, vocab=65536,
    block_kind="rwkv", ssm_head_dim=64, rope="none",
    dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256,
    ssm_head_dim=16, ssm_chunk=16, dtype="float32", param_dtype="float32",
)
