"""Yi-6B [arXiv:2403.04652; hf:01-ai/Yi-6B].

32L, d_model 4096, 32 heads GQA kv=4, d_ff 11008, vocab 64000. Llama-style.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=4, d_ff=11008, vocab=64000,
    mlp_type="swiglu", rope_theta=5000000.0,
    dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_ff=128, vocab=256,
    dtype="float32", param_dtype="float32", q_chunk=16, kv_chunk=16,
)
