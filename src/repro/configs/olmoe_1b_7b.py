"""OLMoE-1B-7B [arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924].

16L, d_model 2048, 16 heads (kv=16 — full MHA), expert d_ff 1024, vocab 50304,
64 experts top-8. SwiGLU experts, RoPE.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv=16, d_ff=1024, vocab=50304,
    n_experts=64, top_k=8, mlp_type="swiglu", rope_theta=10000.0,
    dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=32, vocab=256,
    n_experts=8, top_k=2, capacity_factor=8.0, dtype="float32", param_dtype="float32",
    q_chunk=32, kv_chunk=32, ssm_chunk=16,
)
