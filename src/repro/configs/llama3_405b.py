"""Llama-3.1-405B [arXiv:2407.21783].

126L, d_model 16384, 128 heads GQA kv=8, d_ff 53248, vocab 128256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv=8, d_ff=53248, vocab=128256,
    mlp_type="swiglu", rope_theta=500000.0,
    dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_ff=128, vocab=256,
    dtype="float32", param_dtype="float32", q_chunk=16, kv_chunk=16,
)
