"""Zamba2-7B [arXiv:2411.15242].

81 Mamba2 layers (d_model 3584, ssm_state 64) with a SHARED full-attention
transformer block applied every 6 mamba layers (32 heads, kv=32, d_ff 14336),
vocab 32000. We apply the shared block 13 times (81 = 13*6 + 3).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_ff=14336, vocab=32000,
    block_kind="zamba", ssm_state=64, shared_attn_every=6,
    mlp_type="swiglu", rope_theta=10000.0,
    dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=7, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=256,
    shared_attn_every=3, ssm_head_dim=16, ssm_chunk=16,
    dtype="float32", param_dtype="float32", q_chunk=16, kv_chunk=16,
)
