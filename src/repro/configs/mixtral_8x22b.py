"""Mixtral-8x22B [arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1].

56L, d_model 6144, 48 heads GQA kv=8, expert d_ff 16384, vocab 32768,
8 experts top-2. Sliding-window attention per the assignment spec (4096).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, mlp_type="swiglu", rope_theta=1000000.0,
    window=4096, dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_ff=64, vocab=256,
    n_experts=4, top_k=2, capacity_factor=8.0, window=32, dtype="float32", param_dtype="float32",
    q_chunk=16, kv_chunk=16,
)
