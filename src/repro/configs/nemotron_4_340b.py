"""Nemotron-4-340B [arXiv:2402.16819].

96L, d_model 18432, 96 heads GQA kv=8, d_ff 73728, vocab 256000.
Squared-ReLU MLP (no GLU).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv=8, d_ff=73728, vocab=256000,
    mlp_type="relu_sq", rope_theta=10000.0,
    dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv=2, d_ff=256, vocab=256,
    dtype="float32", param_dtype="float32", q_chunk=16, kv_chunk=16,
)
