"""Gemma3-1B [hf:google/gemma-3-1b-pt].

26L, d_model 1152, 4 heads MQA kv=1 (d_head 256), d_ff 6912, vocab 262144.
5 local (sliding 512) : 1 global pattern; dual rope theta (10k local / 1M
global); tied embeddings with sqrt(d) scaling; GeGLU.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv=1, d_head=256, d_ff=6912,
    vocab=262144, mlp_type="geglu", rope_theta=10000.0,
    rope_theta_global=1000000.0, window=512, local_global=5,
    tie_embeddings=True, embed_scale=True,
    dtype="bfloat16", param_dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    n_layers=7, d_model=48, n_heads=2, n_kv=1, d_head=24, d_ff=96, vocab=256,
    window=16, dtype="float32", param_dtype="float32", q_chunk=16, kv_chunk=16,
)
