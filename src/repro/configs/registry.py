"""Registry of assigned architectures (+ reduced smoke variants).

Every entry reproduces the exact published config assigned to this paper
(see README table). ``smoke_config(name)`` shrinks depth/width/vocab for CPU
tests while keeping the *family structure* (MoE routing, local:global pattern,
shared-attn period, enc-dec split, ...) intact.
"""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPE_CELLS, ArchConfig

ARCH_IDS = (
    "olmoe_1b_7b",
    "mixtral_8x22b",
    "qwen2_vl_2b",
    "seamless_m4t_large_v2",
    "nemotron_4_340b",
    "gemma3_1b",
    "yi_6b",
    "llama3_405b",
    "zamba2_7b",
    "rwkv6_3b",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_config(name: str) -> ArchConfig:
    name = _ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def smoke_config(name: str) -> ArchConfig:
    name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.SMOKE


def cells_for(cfg: ArchConfig):
    """Shape cells that apply to this arch (long_500k needs sub-quadratic attn)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return [SHAPE_CELLS[c] for c in cells]


def skipped_cells_for(cfg: ArchConfig):
    return [] if cfg.sub_quadratic else [SHAPE_CELLS["long_500k"]]
