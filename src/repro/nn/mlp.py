"""Feed-forward blocks: plain MLP, GLU family (SwiGLU/GeGLU), squared-ReLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.common import ACTIVATIONS, Ctx, dense, dense_init

__all__ = ["mlp_init", "mlp"]

_GLU = {"swiglu": "silu", "geglu": "gelu"}


def mlp_init(key, d_model: int, d_ff: int, mlp_type: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"in": dense_init(ks[0], d_model, d_ff, dtype),
         "out": dense_init(ks[1], d_ff, d_model, dtype, scale=d_ff ** -0.5)}
    if mlp_type in _GLU:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params, x, ctx: Ctx, mlp_type: str, role_prefix: str = "mlp"):
    h = dense(params["in"], x, ctx, f"{role_prefix}_in")
    if mlp_type in _GLU:
        g = dense(params["gate"], x, ctx, f"{role_prefix}_gate")
        h = ACTIVATIONS[_GLU[mlp_type]](g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        act = {"relu_sq": "relu_sq", "gelu": "gelu", "relu": "relu"}.get(mlp_type, "gelu")
        h = ACTIVATIONS[act](h.astype(jnp.float32)).astype(h.dtype)
    return dense(params["out"], h, ctx, f"{role_prefix}_out")
