"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (arXiv:2409.12191) splits the head dimension into three sections
rotated by (temporal, height, width) position streams; for the text-only /
stub-frontend path all three streams coincide, recovering standard RoPE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope", "apply_mrope"]


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _pin_broadcast(t: jax.Array, ctx) -> jax.Array:
    """Sharding annotation for the [B, S, 1, d/2] cos/sin position broadcast.

    Without it, SPMD has no layout for the broadcast and logs an
    `[spmd] Involuntary full rematerialization` when resharding it between
    the forward and the (remat'd) backward of production train cells —
    pinning batch over the data axes (matching the activation layout, head
    dim replicated) lets both directions reuse the same shards.
    """
    mesh = getattr(ctx, "mesh", None)
    if ctx is None or mesh is None or getattr(ctx, "act_sharding", None) is None:
        return t
    from jax.sharding import NamedSharding, PartitionSpec

    data_axes = tuple(getattr(ctx, "data_axes", ()) or ())
    n_dp = 1
    for a in data_axes:
        n_dp *= mesh.shape[a]
    bax = data_axes if (data_axes and t.shape[0] % n_dp == 0) else None
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, PartitionSpec(bax, None, None, None)))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float, ctx=None) -> jax.Array:
    """x: [B, S, H, d_head]; positions: [B, S] (int)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [d/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, d/2]
    cos = _pin_broadcast(jnp.cos(ang)[:, :, None, :], ctx)
    sin = _pin_broadcast(jnp.sin(ang)[:, :, None, :], ctx)
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections=(2, 1, 1), ctx=None) -> jax.Array:
    """M-RoPE. x: [B, S, H, d_head]; positions3: [3, B, S] (t, h, w).

    ``sections`` gives the relative split of the d/2 frequency slots across
    the three position streams (Qwen2-VL uses 16/24/24 of 64 ⇒ ratios 2:3:3;
    we parameterise and default to a t-heavy split normalised to d/2).
    """
    d = x.shape[-1]
    half = d // 2
    total = sum(sections)
    sizes = [half * s // total for s in sections]
    sizes[-1] = half - sizes[0] - sizes[1]
    freqs = rope_freqs(d, theta)  # [half]
    pos_per_slot = jnp.concatenate([
        jnp.broadcast_to(positions3[i][..., None].astype(jnp.float32),
                         positions3.shape[1:] + (sizes[i],))
        for i in range(3)
    ], axis=-1)  # [B, S, half]
    ang = pos_per_slot * freqs  # [B, S, half]
    cos = _pin_broadcast(jnp.cos(ang)[:, :, None, :], ctx)
    sin = _pin_broadcast(jnp.sin(ang)[:, :, None, :], ctx)
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)
