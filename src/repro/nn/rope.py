"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (arXiv:2409.12191) splits the head dimension into three sections
rotated by (temporal, height, width) position streams; for the text-only /
stub-frontend path all three streams coincide, recovering standard RoPE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope", "apply_mrope"]


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, d_head]; positions: [B, S] (int)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [d/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, d/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections=(2, 1, 1)) -> jax.Array:
    """M-RoPE. x: [B, S, H, d_head]; positions3: [3, B, S] (t, h, w).

    ``sections`` gives the relative split of the d/2 frequency slots across
    the three position streams (Qwen2-VL uses 16/24/24 of 64 ⇒ ratios 2:3:3;
    we parameterise and default to a t-heavy split normalised to d/2).
    """
    d = x.shape[-1]
    half = d // 2
    total = sum(sections)
    sizes = [half * s // total for s in sections]
    sizes[-1] = half - sizes[0] - sizes[1]
    freqs = rope_freqs(d, theta)  # [half]
    pos_per_slot = jnp.concatenate([
        jnp.broadcast_to(positions3[i][..., None].astype(jnp.float32),
                         positions3.shape[1:] + (sizes[i],))
        for i in range(3)
    ], axis=-1)  # [B, S, half]
    ang = pos_per_slot * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)
