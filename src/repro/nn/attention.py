"""Attention: GQA/MQA with RoPE / M-RoPE, causal, bidirectional, sliding-window.

Three execution paths:
  * chunked  — memory-bounded double-chunked online-softmax attention (the XLA
               fallback used for dry-runs and CPU; never materialises S×S).
               Sliding-window layers statically slice only ``window + Cq`` keys
               per query chunk, so locality is a *shape-level* FLOP saving.
  * einsum   — naive reference (tests, tiny shapes).
  * pallas   — Pallas flash kernel (TPU target; interpret-mode on CPU tests).
Decode (one query against a cache) uses a dedicated masked-einsum path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.common import Ctx, dense, dense_init
from repro.nn.rope import apply_mrope, apply_rope

__all__ = ["AttnCfg", "attn_init", "attention", "decode_attention", "init_kv_cache"]


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    n_heads: int
    n_kv: int
    d_head: int
    causal: bool = True
    window: Optional[int] = None  # sliding window (None = full)
    rope: str = "default"  # default | mrope | none
    theta: float = 10000.0
    q_chunk: int = 512
    kv_chunk: int = 512
    impl: str = "chunked"  # chunked | einsum | pallas
    cross: bool = False  # cross-attention (no rope on kv side, bidir)

    @property
    def groups(self) -> int:
        return self.n_heads // self.n_kv


def attn_init(key, d_model: int, cfg: AttnCfg, dtype=jnp.float32, kv_d_model: int | None = None):
    ks = jax.random.split(key, 4)
    dh, H, Kv = cfg.d_head, cfg.n_heads, cfg.n_kv
    kvd = kv_d_model or d_model
    return {
        "q": dense_init(ks[0], d_model, H * dh, dtype),
        "k": dense_init(ks[1], kvd, Kv * dh, dtype),
        "v": dense_init(ks[2], kvd, Kv * dh, dtype),
        "o": dense_init(ks[3], H * dh, d_model, dtype, scale=(H * dh) ** -0.5),
    }


def _split_heads(x, n, dh):
    return x.reshape(x.shape[:-1] + (n, dh))


def _tile(q, k, v, scale, mask):
    """One attention tile, flat-head layout (k/v pre-repeated to H heads —
    TP-shardable on H even when n_kv < model-axis size, see DESIGN.md).

    q:[B,Cq,H,dh] k/v:[B,Ck,H,dh] mask:[Cq,Ck]|[B,Cq,Ck]|None (the batched
    form carries per-row segment/packing masks — serving prefill).
    Returns (m, l, acc): running max/denom [B,H,Cq], acc [B,Cq,H,dh].
    """
    # bf16 operands feed the MXU directly; fp32 accumulation via
    # preferred_element_type (avoids materialising fp32 copies of K/V).
    s = jnp.einsum("bqhd,bchd->bhqc", q, k,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        m_b = mask[None, None] if mask.ndim == 2 else mask[:, None]
        s = jnp.where(m_b, s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqc,bchd->bqhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return m, l, acc


def _merge(m1, l1, a1, m2, l2, a2):
    m = jnp.maximum(m1, m2)
    e1 = jnp.exp(m1 - m)
    e2 = jnp.exp(m2 - m)
    l = l1 * e1 + l2 * e2
    # acc layout [B,Cq,H,dh]; coefficients are [B,H,Cq]
    c1 = jnp.swapaxes(e1, 1, 2)[..., None]
    c2 = jnp.swapaxes(e2, 1, 2)[..., None]
    return m, l, a1 * c1 + a2 * c2


def _q_chunk_full(qi, k, v, scale, causal, qpos, kpos, kv_chunk, cost_mode,
                  kv_valid_len=None, window=None, seg_qi=None, seg_k=None):
    """All-kv attention for one query chunk via online softmax over kv tiles.

    ``seg_qi`` [B, Cq] / ``seg_k`` [B, Skv] are per-row segment ids (packed
    serving prefill): queries only attend within their own segment, and
    segment id 0 marks padding keys. When given, the tile masks become
    batched [B, Cq, Ck].
    """
    B, Cq, H, dh = qi.shape
    Skv = k.shape[1]
    ck = min(kv_chunk, Skv)
    nk = Skv // ck
    assert nk * ck == Skv

    def tile_j(j):
        kj = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(kpos, j * ck, ck, axis=0)
        mask = None
        if causal:
            d = qpos[:, None] - kp[None, :]
            mask = d >= 0
            if window is not None:
                mask &= d < window
        if kv_valid_len is not None:
            vmask = (kp < kv_valid_len)[None, :]
            mask = vmask if mask is None else (mask & vmask)
        if seg_qi is not None:
            sk = jax.lax.dynamic_slice_in_dim(seg_k, j * ck, ck, axis=1)
            smask = (seg_qi[:, :, None] == sk[:, None, :]) & (sk[:, None, :] > 0)
            mask = smask if mask is None else (mask[None] & smask)
        return _tile(qi, kj, vj, scale, mask)

    if cost_mode:
        m, l, acc = tile_j(0)
        for j in range(1, nk):
            m, l, acc = _merge(m, l, acc, *tile_j(j))
        return m, l, acc

    def body(carry, j):
        m, l, acc = carry
        mj, lj, aj = tile_j(j)
        return _merge(m, l, acc, mj, lj, aj), None

    init = (jnp.full((B, H, Cq), -1e30, jnp.float32),
            jnp.zeros((B, H, Cq), jnp.float32),
            jnp.zeros((B, Cq, H, dh), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nk))
    return m, l, acc


def _q_chunk_window(qi, k_pad, v_pad, scale, window, i, q_chunk, qpos, cost_mode,
                    kv_valid_len=None, seg_qi=None, seg_k_pad=None):
    """Sliding-window attention for one query chunk.

    k_pad/v_pad are left-padded by ``window`` so the relevant keys for query
    chunk i live at padded offsets [i*Cq, i*Cq + window + Cq).
    ``seg_k_pad`` carries segment ids padded to the same layout (0 = pad).
    """
    Cq = qi.shape[1]
    span = window + Cq
    start = i * q_chunk
    kj = jax.lax.dynamic_slice_in_dim(k_pad, start, span, axis=1)
    vj = jax.lax.dynamic_slice_in_dim(v_pad, start, span, axis=1)
    kp = start - window + jnp.arange(span)  # original coordinates
    valid = kp >= 0
    if kv_valid_len is not None:
        valid &= kp < kv_valid_len
    d = qpos[:, None] - kp[None, :]
    mask = (d >= 0) & (d < window) & valid[None, :]
    if seg_qi is not None:
        sk = jax.lax.dynamic_slice_in_dim(seg_k_pad, start, span, axis=1)
        mask = (mask[None] & (seg_qi[:, :, None] == sk[:, None, :])
                & (sk[:, None, :] > 0))
    return _tile(qi, kj, vj, scale, mask)


def multi_head_attention(q, k, v, cfg: AttnCfg, *, cost_mode: bool = False,
                         q_offset=0, constrain=None, segs=None):
    """q:[B,Sq,H,dh] k,v:[B,Skv,Kv,dh] -> [B,Sq,H,dh] (fp32 accum).

    GQA k/v are repeated to H heads up front (flat-head layout): the repeat is
    free per TP shard (each shard repeats only its local groups) and keeps
    every attention tensor shardable on H even when n_kv < model-axis size.
    ``constrain`` (from Ctx.constrain_heads) re-pins [B, S, H, dh] tensors to
    (dp, None, model, None).

    ``segs`` (int32 [B, Sq], self-attention only) are packed-prefill segment
    ids: tokens attend only within their own segment and id 0 marks padding
    (docs/serving.md). The pallas flash kernel has no segment support, so a
    segs-bearing call routes through the chunked XLA path.
    """
    B, Sq, H, dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    scale = dh ** -0.5

    if cfg.impl == "pallas" and segs is None:
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=cfg.causal, window=cfg.window)
        return o.astype(q.dtype)

    if G > 1:
        # Pin the GQA k/v layout on BOTH sides of the head repeat. The repeat
        # output is head-sharded (below), so SPMD wants its operand
        # head-partial too — but the operand arrives seq-sharded from the
        # sequence-parallel projections, and with n_kv < model-axis size the
        # un-annotated transition logs an `[spmd] Involuntary full
        # rematerialization` in the forward AND the remat'd backward of
        # production train cells (same failure mode as the rope.py position
        # broadcast, see ROADMAP). constrain_heads picks (dp, None,
        # model-if-divisible, None), so the small pre-repeat tensor reshards
        # voluntarily once and both directions reuse the layout.
        if constrain is not None:
            k, v = constrain(k), constrain(v)
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    if constrain is not None:
        q, k, v = constrain(q), constrain(k), constrain(v)

    if cfg.impl == "einsum":
        s = jnp.einsum("bqhd,bchd->bhqc", q, k,
                       preferred_element_type=jnp.float32) * scale
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(k.shape[1])
        mask = None
        if cfg.causal:
            mask = qpos[:, None] >= kpos[None, :]
            if cfg.window:
                mask &= (qpos[:, None] - kpos[None, :]) < cfg.window
        if segs is not None:
            smask = (segs[:, :, None] == segs[:, None, :]) & (segs[:, None, :] > 0)
            mask = smask if mask is None else (mask[None] & smask)
        if mask is not None:
            m_b = mask[None, None] if mask.ndim == 2 else mask[:, None]
            s = jnp.where(m_b, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqc,bchd->bqhd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.astype(q.dtype)

    # chunked (pads ragged Sq / Skv internally; padded queries are sliced off,
    # padded keys masked via kv_valid_len)
    if cost_mode and not (cfg.window is not None and cfg.causal):
        # HLO cost artifacts: enlarge tiles to bound unrolled-HLO size. FLOPs
        # are identical (the full path computes every masked tile at any tile
        # size); window layers keep their production chunking — the window
        # FLOP saving is shape-level and must stay visible in the artifact.
        cfg = dataclasses.replace(cfg, q_chunk=max(cfg.q_chunk, 4096),
                                  kv_chunk=max(cfg.kv_chunk, 8192))
    Cq = min(cfg.q_chunk, Sq)
    Sq_pad = ((Sq + Cq - 1) // Cq) * Cq
    Skv = k.shape[1]
    ck = min(cfg.kv_chunk, Skv)
    Skv_pad = ((Skv + ck - 1) // ck) * ck
    qg_p = jnp.pad(q, ((0, 0), (0, Sq_pad - Sq), (0, 0), (0, 0)))
    nq = Sq_pad // Cq
    qpos_all = q_offset + jnp.arange(Sq_pad)
    kpos = jnp.arange(Skv_pad)
    kv_valid = Skv if Skv_pad != Skv else None
    use_window = cfg.window is not None and cfg.causal and Skv > (cfg.window + Cq)
    seg_q_all = seg_k_in = None
    if segs is not None:
        # 0-pad: padded queries/keys belong to no segment
        seg_q_all = jnp.pad(segs, ((0, 0), (0, Sq_pad - Sq)))
    if use_window:
        # left-pad by window; right-pad to cover padded query chunks
        right = max(0, (Sq_pad - Skv))
        k_in = jnp.pad(k, ((0, 0), (cfg.window, right), (0, 0), (0, 0)))
        v_in = jnp.pad(v, ((0, 0), (cfg.window, right), (0, 0), (0, 0)))
        if segs is not None:
            seg_k_in = jnp.pad(segs, ((0, 0), (cfg.window, right)))
    else:
        k_in = jnp.pad(k, ((0, 0), (0, Skv_pad - Skv), (0, 0), (0, 0)))
        v_in = jnp.pad(v, ((0, 0), (0, Skv_pad - Skv), (0, 0), (0, 0)))
        if segs is not None:
            seg_k_in = jnp.pad(segs, ((0, 0), (0, Skv_pad - Skv)))

    def one_chunk(i):
        qi = jax.lax.dynamic_slice_in_dim(qg_p, i * Cq, Cq, axis=1)
        qpos = jax.lax.dynamic_slice_in_dim(qpos_all, i * Cq, Cq, axis=0)
        seg_qi = None
        if segs is not None:
            seg_qi = jax.lax.dynamic_slice_in_dim(seg_q_all, i * Cq, Cq, axis=1)
        if constrain is not None:
            qi = constrain(qi)
        if use_window:
            m, l, acc = _q_chunk_window(qi, k_in, v_in, scale, cfg.window, i, Cq, qpos,
                                        cost_mode, kv_valid_len=Skv,
                                        seg_qi=seg_qi, seg_k_pad=seg_k_in)
        else:
            m, l, acc = _q_chunk_full(qi, k_in, v_in, scale, cfg.causal, qpos, kpos,
                                      cfg.kv_chunk, cost_mode, kv_valid_len=kv_valid,
                                      window=cfg.window if cfg.causal else None,
                                      seg_qi=seg_qi, seg_k=seg_k_in)
        lr = jnp.swapaxes(l, 1, 2)[..., None]  # [B,Cq,H,1]
        out = (acc / jnp.maximum(lr, 1e-30)).astype(q.dtype)
        return constrain(out) if constrain is not None else out

    chunk_fn = jax.checkpoint(one_chunk)
    if cost_mode:
        outs = [chunk_fn(i) for i in range(nq)]
        o = jnp.concatenate(outs, axis=1)
    else:
        o = jax.lax.map(chunk_fn, jnp.arange(nq))  # [nq,B,Cq,H,dh]
        o = jnp.moveaxis(o, 0, 1).reshape(B, Sq_pad, H, dh)
    o = o[:, :Sq]
    return o.reshape(B, Sq, H, dh)


def decode_attention(q, k_cache, v_cache, pos, cfg: AttnCfg):
    """q:[B,1,H,dh]; caches [B,Smax,Kv,dh]; pos: index of the new token —
    a scalar (whole batch at one timestep) or an int32 [B] vector (per-slot
    positions, the continuous-batching serving path; see docs/serving.md).

    GQA via grouped einsum on the *unrepeated* cache (repeating a 32k-entry
    cache would multiply HBM reads by G — decode is memory-bound, so the
    cache is read once per kv head). Caches may be sequence-sharded; softmax
    partials combine via XLA-inserted all-reduce (flash-decoding pattern).
    """
    B, _, H, dh = q.shape
    Kv = k_cache.shape[2]
    G = H // Kv
    qg = q.reshape(B, 1, Kv, G, dh)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) * dh ** -0.5
    idx = jnp.arange(k_cache.shape[1])
    posv = jnp.asarray(pos)
    if posv.ndim == 0:
        posv = posv[None]  # [1] broadcasts over B
    rolling = cfg.window is not None and k_cache.shape[1] <= cfg.window
    # warm ring buffer: everything valid once pos >= size; during warmup only
    # slots <= pos have been written.
    mask = idx[None, :] <= posv[:, None]
    if cfg.window is not None and not rolling:
        mask &= idx[None, :] > posv[:, None] - cfg.window
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckh->bqkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, dh).astype(q.dtype)


def init_kv_cache(batch: int, max_len: int, cfg: AttnCfg, dtype):
    size = min(max_len, cfg.window) if cfg.window is not None else max_len
    shape = (batch, size, cfg.n_kv, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention(params, x, ctx: Ctx, cfg: AttnCfg, positions, cache=None, pos=None,
              memory=None, role_prefix: str = "attn", segs=None):
    """Full attention sublayer: projections (sketched) + core + out-proj.

    * train/prefill: ``cache=None`` (or a cache dict to fill when prefilling).
    * decode: ``cache`` + ``pos`` (scalar, or int32 [B] per-slot positions)
      -> returns (out, updated_cache).
    * cross-attention: ``memory`` = encoder output (keys/values from memory).
    * packed prefill: ``segs`` = int32 [B, S] segment ids (0 = padding);
      self-attention is segment-masked (docs/serving.md).
    """
    B, S, _ = x.shape
    rq = f"{role_prefix}_q"
    q = _split_heads(dense(params["q"], x, ctx, rq), cfg.n_heads, cfg.d_head)
    kv_src = memory if memory is not None else x
    k = _split_heads(dense(params["k"], kv_src, ctx, f"{role_prefix}_k"), cfg.n_kv, cfg.d_head)
    v = _split_heads(dense(params["v"], kv_src, ctx, f"{role_prefix}_v"), cfg.n_kv, cfg.d_head)

    if cfg.rope == "default":
        q = apply_rope(q, positions, cfg.theta, ctx=ctx)
        if memory is None:
            k = apply_rope(k, positions, cfg.theta, ctx=ctx)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, cfg.theta, ctx=ctx)
        if memory is None:
            k = apply_mrope(k, positions, cfg.theta, ctx=ctx)

    if cache is not None and pos is not None:
        # decode: write new kv at pos (rolling for window caches), then
        # attend. pos is a scalar or an int32 [B] per-slot position vector
        # (continuous-batching serving) — the vector form writes each row at
        # its own timestep.
        size = cache["k"].shape[1]
        posv = jnp.asarray(pos)
        write_at = posv % size if (cfg.window is not None and size <= cfg.window) else posv
        if posv.ndim == 0:
            new_k = cache["k"].at[:, write_at].set(k[:, 0].astype(cache["k"].dtype))
            new_v = cache["v"].at[:, write_at].set(v[:, 0].astype(cache["v"].dtype))
        else:
            rows = jnp.arange(B)
            new_k = cache["k"].at[rows, write_at].set(k[:, 0].astype(cache["k"].dtype))
            new_v = cache["v"].at[rows, write_at].set(v[:, 0].astype(cache["v"].dtype))
        o = decode_attention(q, new_k, new_v, pos, cfg)
        out = dense(params["o"], o.reshape(B, S, -1), ctx, f"{role_prefix}_o")
        return out, {"k": new_k, "v": new_v}

    o = multi_head_attention(q, k, v, cfg, cost_mode=ctx.cost_mode,
                             constrain=ctx.constrain_heads,
                             segs=None if memory is not None else segs)
    out = dense(params["o"], o.reshape(B, S, -1), ctx, f"{role_prefix}_o")
    if cache is not None:
        # prefill: fill the cache with the (possibly window-truncated) tail.
        size = cache["k"].shape[1]
        ktail = k[:, -size:].astype(cache["k"].dtype)
        vtail = v[:, -size:].astype(cache["v"].dtype)
        rolling = cfg.window is not None and size <= cfg.window
        if rolling and k.shape[1] >= size:
            # ring-buffer convention: absolute position p lives at slot p % size
            shift = k.shape[1] % size
            ktail = jnp.roll(ktail, shift, axis=1)
            vtail = jnp.roll(vtail, shift, axis=1)
        cache = {"k": jax.lax.dynamic_update_slice_in_dim(cache["k"], ktail, 0, axis=1),
                 "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vtail, 0, axis=1)}
        return out, cache
    return out
