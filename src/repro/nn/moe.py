"""Mixture-of-Experts FFN: token-choice top-k, capacity-bucketed, EP-shardable.

Dispatch is sort-based (no N×E×C one-hot tensors): token replicas are ranked
within their expert via a stable argsort, bucketed into ``[E_local, C, d]``
buffers, processed by a vmapped (sketched) GLU FFN, and combined back with the
router weights.

Two execution modes share the same body:
  * local  — single device / pjit-auto sharding (tests, smoke).
  * EP     — ``shard_map`` (via repro.compat) over the mesh: activations are sharded over the
             data axes and *replicated* over ``model``; experts are sharded
             over ``model``; each model shard processes its own experts for
             the whole local batch and the outputs are ``psum``-combined over
             ``model`` (GShard-style expert parallelism without all-to-all —
             the combine all-reduce plays the role the dense TP all-reduce
             would play for a dense FFN of the same width).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro import compat
from repro.nn.common import Ctx, dense_init
from repro.core import linear

__all__ = ["MoECfg", "moe_init", "moe_ffn"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    mlp_type: str = "swiglu"
    aux_coef: float = 0.01


def moe_init(key, d_model: int, cfg: MoECfg, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    E, F = cfg.n_experts, cfg.d_ff
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "wi": jax.vmap(lambda k: dense_init(k, d_model, F, dtype)["w"])(jax.random.split(ks[1], E)),
        "wo": jax.vmap(lambda k: dense_init(k, F, d_model, dtype, scale=F ** -0.5)["w"])(jax.random.split(ks[2], E)),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["wg"] = jax.vmap(lambda k: dense_init(k, d_model, F, dtype)["w"])(jax.random.split(ks[3], E))
    return p


def _expert_ffn(wi, wg, wo, xb, ctx: Ctx, cfg: MoECfg, key):
    """One expert's FFN on its [C, d] bucket (sketched linears)."""
    kcfg_in = ctx.cfg_for("expert_in")
    kcfg_gate = ctx.cfg_for("expert_gate")
    kcfg_out = ctx.cfg_for("expert_out")
    k_in = k_gate = k_out = None
    if key is not None:
        k_in, k_gate, k_out = jax.random.split(key, 3)
    h = linear(xb, wi, key=k_in, cfg=kcfg_in)
    if wg is not None:
        g = linear(xb, wg, key=k_gate, cfg=kcfg_gate)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    return linear(h, wo, key=k_out, cfg=kcfg_out)


def _moe_local(router_w, wi, wg, wo, x2d, ctx: Ctx, cfg: MoECfg, e_offset: int,
               n_total_experts: int, capacity: int):
    """Dispatch + expert compute + combine over the experts in wi/wo.

    x2d: [N, d]; wi: [E_loc, F, d] (d_out-major like all our dense weights).
    Returns (y2d [N, d], aux_stats dict).
    """
    N, d = x2d.shape
    E_loc = wi.shape[0]
    k = cfg.top_k
    logits = (x2d.astype(jnp.float32) @ router_w.T.astype(jnp.float32))  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)  # [N, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)  # renorm (Mixtral)

    flat_ids = top_ids.reshape(-1)  # [N*k]
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(N), k)

    # rank of each replica within its expert (stable sort ⇒ FIFO capacity)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(n_total_experts))
    ranks_sorted = jnp.arange(N * k) - starts[sorted_ids]
    ranks = jnp.zeros((N * k,), jnp.int32).at[order].set(ranks_sorted.astype(jnp.int32))

    local_e = flat_ids - e_offset
    keep = (local_e >= 0) & (local_e < E_loc) & (ranks < capacity)
    slot = jnp.where(keep, local_e * capacity + ranks, E_loc * capacity)  # overflow slot

    buf = jnp.zeros((E_loc * capacity + 1, d), x2d.dtype)
    buf = buf.at[slot].add(jnp.take(x2d, flat_tok, axis=0))
    xe = buf[:-1].reshape(E_loc, capacity, d)

    ekeys = None
    if ctx.key is not None:
        ekeys = jax.random.split(jax.random.fold_in(ctx.key, 1000), E_loc)
    if wg is None:
        fn = lambda wi_e, wo_e, xb, kk: _expert_ffn(wi_e, None, wo_e, xb, ctx, cfg, kk)
        ye = jax.vmap(fn)(wi, wo, xe, ekeys) if ekeys is not None else jax.vmap(
            lambda a, b, c: fn(a, b, c, None))(wi, wo, xe)
    else:
        fn = lambda wi_e, wg_e, wo_e, xb, kk: _expert_ffn(wi_e, wg_e, wo_e, xb, ctx, cfg, kk)
        ye = jax.vmap(fn)(wi, wg, wo, xe, ekeys) if ekeys is not None else jax.vmap(
            lambda a, b, c, e: fn(a, b, c, e, None))(wi, wg, wo, xe)

    ye_flat = jnp.concatenate([ye.reshape(E_loc * capacity, d),
                               jnp.zeros((1, d), ye.dtype)], axis=0)
    rows = jnp.take(ye_flat, slot, axis=0) * jnp.where(keep, flat_w, 0.0)[:, None].astype(ye.dtype)
    y = jnp.zeros((N, d), ye.dtype).at[flat_tok].add(rows)

    # Switch-style load-balance stats (fractions over *all* experts).
    me = jnp.mean(probs, axis=0)  # [E] mean router prob
    disp = jnp.zeros((n_total_experts,), jnp.float32).at[flat_ids].add(1.0) / (N * k)
    return y, {"me": me, "disp": disp}


def moe_ffn(params, x, ctx: Ctx, cfg: MoECfg):
    """x: [B, S, d] -> (y, aux_loss scalar)."""
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    N = x2d.shape[0]
    E = cfg.n_experts
    wg = params.get("wg")

    if ctx.mesh is None:
        capacity = max(1, -(-int(N * cfg.top_k * cfg.capacity_factor) // E))
        y2d, stats = _moe_local(params["router"]["w"], params["wi"], wg, params["wo"],
                                x2d, ctx, cfg, 0, E, capacity)
        aux = E * jnp.sum(stats["me"] * stats["disp"]) * cfg.aux_coef
        return y2d.reshape(B, S, d), aux

    # shard_map parallel MoE: tokens sharded over data axes. Two expert modes:
    #   EP  (E % n_mp == 0): experts partitioned over the model axis; each
    #       shard runs full FFNs for its experts, outputs psum-combined.
    #   TPX (E % n_mp != 0, e.g. Mixtral's 8e on a 16-wide axis): every shard
    #       holds all experts but a 1/n_mp slice of the expert *hidden* dim —
    #       Megatron-style tensor parallel experts; same psum combine.
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    dp = ctx.data_axes
    mp = ctx.model_axes
    assert len(mp) == 1, "expert parallelism uses a single model axis"
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_mp = mesh.shape[mp[0]]
    ep_mode = E % n_mp == 0
    if not ep_mode:
        assert cfg.d_ff % n_mp == 0, (
            f"neither experts ({E}) nor expert d_ff ({cfg.d_ff}) divide the "
            f"model axis ({n_mp})")
    rows_divide = N % n_dp == 0
    if not rows_divide:
        dp = ()  # tiny batches (e.g. B=1 decode): replicate tokens over data
        n_dp = 1
    N_loc = N // n_dp
    capacity = max(1, -(-int(N_loc * cfg.top_k * cfg.capacity_factor) // E))
    has_gate = wg is not None
    has_key = ctx.key is not None

    def body(router_w, wi_l, wg_l, wo_l, x_loc, key):
        e_off = (jax.lax.axis_index(mp[0]) * (E // n_mp)) if ep_mode else 0
        body_ctx = dataclasses.replace(ctx, mesh=None, key=key if has_key else None)
        y_loc, stats = _moe_local(router_w, wi_l, wg_l if has_gate else None, wo_l,
                                  x_loc, body_ctx, cfg, e_off, E, capacity)
        y_loc = jax.lax.psum(y_loc, mp)
        # dispatch stats cover ALL experts on every shard (global expert ids)
        me = jax.lax.pmean(stats["me"], dp) if dp else stats["me"]
        disp = jax.lax.pmean(stats["disp"], dp) if dp else stats["disp"]
        return y_loc, me, disp

    if ep_mode:
        wi_spec = P(mp[0], None, None)
        wo_spec = P(mp[0], None, None)
        wg_spec = P(mp[0], None, None)
    else:
        wi_spec = P(None, mp[0], None)  # [E, F, d] -> shard F
        wo_spec = P(None, None, mp[0])  # [E, d, F] -> shard F
        wg_spec = P(None, mp[0], None)

    key_arg = ctx.key if has_key else compat.prng_key(0)
    f = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), wi_spec, wg_spec if has_gate else P(),
                  wo_spec, P(dp, None), P()),
        out_specs=(P(dp, None), P(), P()))
    wg_arg = wg if has_gate else jnp.zeros((), x.dtype)
    y2d, me, disp = f(params["router"]["w"], params["wi"], wg_arg, params["wo"], x2d, key_arg)
    aux = E * jnp.sum(me * disp) * cfg.aux_coef
    return y2d.reshape(B, S, d), aux
