"""Shared NN substrate: context object, norms, activations, init helpers.

The substrate is pure JAX (no flax): every module is an ``init(key, ...) ->
params`` / ``apply(params, x, ctx, ...)`` pair over plain nested dicts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import SketchPolicy, linear
from repro.core.policy import ROLES

__all__ = ["Ctx", "dense", "dense_init", "rmsnorm", "rmsnorm_init", "layernorm",
           "layernorm_init", "ACTIVATIONS", "trunc_normal"]

_ROLE_IDS = {r: i for i, r in enumerate(ROLES)}


@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through every module.

    ``key`` is the *per-layer* RNG key (already folded with the layer index);
    per-site keys are derived from it with the static role id, so two sketched
    sites in one layer never share randomness.
    """

    policy: Optional[SketchPolicy] = None
    key: Optional[jax.Array] = None
    layer_index: Any = 0  # may be a tracer inside lax.scan
    n_layers: int = 1
    mesh: Optional[Any] = None  # jax Mesh for explicit-collective paths (EP)
    model_axes: tuple = ("model",)  # mesh axis name(s) carrying TP/EP shards
    data_axes: tuple = ("data",)
    cost_mode: bool = False  # python-unrolled loops (HLO cost artifacts)
    decode: bool = False
    act_sharding: Optional[Any] = None  # NamedSharding constraint on activations
    tp_sketch: bool = False  # TP-local compact sketching (core.sharded_sketch)

    def constrain(self, x):
        if self.act_sharding is not None and x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, self.act_sharding)
        return x

    def constrain_heads(self, x):
        """Pin [B, S, H, dh] attention tensors to (dp, None, model, None)."""
        if self.act_sharding is None or self.mesh is None or x.ndim != 4:
            return x
        from jax.sharding import NamedSharding, PartitionSpec

        mesh = self.mesh
        n_mp = 1
        for a in self.model_axes:
            n_mp *= mesh.shape[a]
        n_dp = 1
        for a in self.data_axes:
            n_dp *= mesh.shape[a]
        bax = self.data_axes if x.shape[0] % n_dp == 0 else None
        hax = self.model_axes[0] if (self.model_axes and x.shape[2] % n_mp == 0) else None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(bax, None, hax, None)))

    def site_key(self, role: str) -> Optional[jax.Array]:
        if self.key is None:
            return None
        return jax.random.fold_in(self.key, _ROLE_IDS[role])

    def site_spec(self, role: str, cfg, w, *, has_bias: bool = False,
                  x_ndim: int = 3):
        """Resolve one linear site against this context's execution
        environment (memoized in core/site.py — the ONE dispatch shared with
        the gslot/pslot builders)."""
        from repro.core.site import resolve_site

        return resolve_site(role, cfg, d_out=w.shape[-2], d_in=w.shape[-1],
                            has_bias=has_bias, x_ndim=x_ndim, mesh=self.mesh,
                            data_axes=tuple(self.data_axes),
                            model_axes=tuple(self.model_axes),
                            tp_sketch=self.tp_sketch)

    def cfg_for(self, role: str):
        if self.policy is None:
            return None
        # location-based policies need a static layer index (MLP/ViT models);
        # scan-based models use location="all" where layer_index may be traced.
        li = self.layer_index if isinstance(self.layer_index, int) else 0
        return self.policy.config_for(role, li, self.n_layers)

    def for_layer(self, step_key, layer_index):
        """Child ctx for one layer of a stack (folds the RNG key)."""
        key = None if step_key is None else jax.random.fold_in(step_key, layer_index)
        return dataclasses.replace(self, key=key, layer_index=layer_index)


def trunc_normal(key, shape, scale, dtype=jnp.float32):
    """Truncated-normal init with stddev ``scale`` (fan-in handled by caller)."""
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, *, scale: float | None = None,
               bias: bool = False):
    w = trunc_normal(key, (d_out, d_in), scale if scale is not None else d_in ** -0.5, dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x, ctx: Ctx, role: str):
    """Linear site; sketched iff the policy covers ``role``.

    Thin resolver over the one sketched-site spine (``core/site.py``): the
    site is resolved once to a declarative :class:`~repro.core.site.SiteSpec`
    (local / tp_column / tp_row execution plan, TP-incompatible sites falling
    back to the dense-mask estimator) and executed by the spine. The
    CompactGrad and probe slot builders consume the *same* resolved specs, so
    a ``"gslot"`` entry in ``params`` (compact-gradient mode, see
    core/compact_grad.py) is present exactly when the backward emits compact
    rows, and a ``"pslot"`` entry (telemetry, see repro/telemetry/probes.py)
    exactly when the site can probe — including on the TP shard_map plans.
    """
    cfg = ctx.cfg_for(role)
    slot = params.get("gslot")
    pslot = params.get("pslot")
    sslot = params.get("sslot")  # plan-carry scores (core/plan_state.py)
    key = ctx.site_key(role)
    w = params["w"]
    b = params.get("b")
    if cfg is None or key is None:
        return linear(x, w, b, key=key, cfg=cfg, grad_slot=slot,
                      probe_slot=pslot, plan_state=sslot)
    spec = ctx.site_spec(role, cfg, w, has_bias=b is not None, x_ndim=x.ndim)
    if spec.plan.kind == "local":
        return linear(x, w, b, key=key, cfg=spec.cfg, grad_slot=slot,
                      probe_slot=pslot, plan_state=sslot)
    from repro.core.site import sketched_site

    return sketched_site(spec, x, w, b, key, slot, pslot, sslot)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["g"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["g"].astype(jnp.float32) + params["b"].astype(jnp.float32)).astype(x.dtype)


def _relu_sq(x):
    r = jax.nn.relu(x)
    return r * r


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "relu_sq": _relu_sq,  # Nemotron-4 squared ReLU
    "tanh": jnp.tanh,
}
