"""State-space sequence mixers: Mamba2 (SSD) and RWKV6 (Finch).

Both keep the *recurrence core* exact (the paper sketches linear VJPs; the
in/out projections — which dominate FLOPs — are sketched sites). Training uses
chunked forms whose outer chunk loop is a ``lax.scan`` (rolled) or a python
loop (``ctx.cost_mode``); decode is a single-step state update.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.common import Ctx, dense, dense_init, rmsnorm, rmsnorm_init

__all__ = ["MambaCfg", "mamba_init", "mamba_block", "mamba_decode", "mamba_state_init",
           "RWKVCfg", "rwkv_init", "rwkv_time_mix", "rwkv_channel_mix", "rwkv_state_init"]


# ---------------------------------------------------------------------------
# Mamba2 (SSD) — arXiv:2405.21060, scalar-decay-per-head chunked form.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    d_conv: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba_init(key, cfg: MambaCfg, dtype=jnp.float32):
    """Projections are split (z/x/B/C/dt) instead of fused so each one has a
    clean TP sharding (the fused layout would slice across the model axis);
    the short causal conv runs on x only (B/C un-convolved — a documented
    simplification vs. the official Mamba2, see DESIGN.md)."""
    ks = jax.random.split(key, 7)
    di, N, H = cfg.d_inner, cfg.d_state, cfg.n_heads
    return {
        "in_z": dense_init(ks[0], cfg.d_model, di, dtype),
        "in_x": dense_init(ks[1], cfg.d_model, di, dtype),
        "in_B": dense_init(ks[2], cfg.d_model, N, dtype),
        "in_C": dense_init(ks[3], cfg.d_model, N, dtype),
        "in_dt": dense_init(ks[4], cfg.d_model, H, dtype),
        "conv": (jax.random.normal(ks[5], (cfg.d_conv, di), jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out": dense_init(ks[6], di, cfg.d_model, dtype, scale=di ** -0.5),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: [B,S,C], w: [K,C]. state: [B,K-1,C] or None."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), new_state


def _ssd_chunk(state, xc, dtc, dAc, Bc, Cc):
    """One SSD chunk. state:[B,H,P,N]; xc:[B,Q,H,P]; dtc,dAc:[B,Q,H];
    Bc,Cc:[B,Q,N]. Returns (new_state, yc:[B,Q,H,P])."""
    # cumulative log decay within chunk (per head)
    la = jnp.cumsum(jnp.log(jnp.maximum(dAc, 1e-30)), axis=1)  # [B,Q,H]
    # inter-chunk: y_i += C_i · (exp(la_i) * state)
    decay_in = jnp.exp(la)  # [B,Q,H]
    y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", Cc, state, decay_in)
    # intra-chunk: y_i += Σ_{j<=i} exp(la_i - la_j) dt_j (C_i·B_j) x_j
    CB = jnp.einsum("bqn,bpn->bqp", Cc, Bc)  # [B,Q,Q] (q=query, p=key step)
    Q = xc.shape[1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    dec = jnp.exp(la[:, :, None, :] - la[:, None, :, :])  # [B,Q,Qk,H]
    dec = jnp.where(mask[None, :, :, None], dec, 0.0)
    y_intra = jnp.einsum("bqk,bqkh,bkh,bkhp->bqhp", CB, dec, dtc, xc)
    # state update: state' = exp(la_Q) state + Σ_j exp(la_Q - la_j) dt_j B_j x_jᵀ
    tot = la[:, -1]  # [B,H]
    decay_out = jnp.exp(tot[:, None, :] - la)  # [B,Q,H]
    state_new = state * jnp.exp(tot)[..., None, None]
    state_new = state_new + jnp.einsum("bqh,bqh,bqhp,bqn->bhpn", decay_out, dtc, xc, Bc)
    return state_new, y_inter + y_intra


def _ssd(x, dt, A, B, C, cfg: MambaCfg, state0, cost_mode: bool):
    """x:[B,S,H,P] dt:[B,S,H] A:[H] B,C:[B,S,N] -> (y, state)."""
    Bsz, S_in, H, P = x.shape
    Q = min(cfg.chunk, S_in)
    S = ((S_in + Q - 1) // Q) * Q
    if S != S_in:
        # pad with inert steps: dt=0 ⇒ dA=1 and zero state injection
        x = jnp.pad(x, ((0, 0), (0, S - S_in), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, S - S_in), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, S - S_in), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, S - S_in), (0, 0)))
    dA = jnp.exp(-A[None, None, :] * dt)  # [B,S,H] decay per step
    nC = S // Q

    def chunk_fn(state, i):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, i * Q, Q, axis=1)
        return _ssd_chunk(state, sl(x), sl(dt), sl(dA), sl(B), sl(C))

    if cost_mode:
        ys = []
        state = state0
        for i in range(nC):
            state, yc = jax.checkpoint(chunk_fn)(state, i)
            ys.append(yc)
        return jnp.concatenate(ys, axis=1)[:, :S_in], state

    def body(state, i):
        state, yc = chunk_fn(state, i)
        return state, yc

    state, ys = jax.lax.scan(jax.checkpoint(body), state0, jnp.arange(nC))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    return y[:, :S_in], state


def _mamba_pre(params, x, ctx: Ctx, cfg: MambaCfg, conv_state=None):
    z = dense(params["in_z"], x, ctx, "ssm_in")
    xs = dense(params["in_x"], x, ctx, "ssm_in")
    Bc = dense(params["in_B"], x, ctx, "ssm_small")
    Cc = dense(params["in_C"], x, ctx, "ssm_small")
    dt = dense(params["in_dt"], x, ctx, "ssm_small")
    xs, new_conv = _causal_conv(xs, params["conv"], conv_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    return z, xs, Bc, Cc, dt, new_conv


def mamba_block(params, x, ctx: Ctx, cfg: MambaCfg):
    """Training/prefill path. x: [B, S, d_model] -> [B, S, d_model]."""
    Bsz, S, _ = x.shape
    H, P = cfg.n_heads, cfg.head_dim
    z, xs, Bc, Cc, dt, _ = _mamba_pre(params, x, ctx, cfg)
    xh = xs.reshape(Bsz, S, H, P)
    A = jnp.exp(params["A_log"])
    state0 = jnp.zeros((Bsz, H, P, cfg.d_state), jnp.float32)
    y, _ = _ssd(xh.astype(jnp.float32), dt, A, Bc.astype(jnp.float32),
                Cc.astype(jnp.float32), cfg, state0, ctx.cost_mode)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(params["norm"], y)
    return dense(params["out"], y, ctx, "ssm_out")


def mamba_state_init(batch: int, cfg: MambaCfg, dtype):
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode(params, x, ctx: Ctx, cfg: MambaCfg, state):
    """Single-token step. x: [B, 1, d_model]; state: see mamba_state_init."""
    Bsz = x.shape[0]
    H, P, N = cfg.n_heads, cfg.head_dim, cfg.d_state
    z, xs, Bc, Cc, dt, new_conv = _mamba_pre(params, x, ctx, cfg, state["conv"])
    xh = xs.reshape(Bsz, H, P).astype(jnp.float32)
    A = jnp.exp(params["A_log"])
    dt1 = dt[:, 0]  # [B,H]
    dA = jnp.exp(-A[None, :] * dt1)  # [B,H]
    s = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt1, xh, Bc[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", s, Cc[:, 0].astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(params["norm"], y)
    return dense(params["out"], y, ctx, "ssm_out"), {"ssm": s, "conv": new_conv}


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — arXiv:2404.05892. Data-dependent per-channel decay.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RWKVCfg:
    d_model: int
    head_dim: int = 64
    d_ff: int = 0  # channel-mix hidden
    chunk: int = 64
    decay_lora: int = 64

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def rwkv_init(key, cfg: RWKVCfg, dtype=jnp.float32):
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),  # shift mixes for r,k,v,g,w
        "r": dense_init(ks[0], d, d, dtype),
        "k": dense_init(ks[1], d, d, dtype),
        "v": dense_init(ks[2], d, d, dtype),
        "g": dense_init(ks[3], d, d, dtype),
        # data-dependent decay via low-rank projection (Finch's LoRA form)
        "w1": dense_init(ks[4], d, cfg.decay_lora, jnp.float32),
        "w2": dense_init(ks[5], cfg.decay_lora, d, jnp.float32),
        "w_bias": jnp.full((d,), -6.0, jnp.float32),
        "u": (jax.random.normal(ks[6], (d,), jnp.float32) * 0.1),
        "out": dense_init(ks[7], d, d, dtype, scale=d ** -0.5),
        "cm_k": dense_init(ks[8], d, cfg.d_ff or (7 * d // 2), dtype),
        "cm_v": dense_init(ks[9], cfg.d_ff or (7 * d // 2), d, dtype, scale=d ** -0.5),
        "cm_r": dense_init(jax.random.fold_in(ks[9], 1), d, d, dtype),
        "cm_mu": 0.5 * jnp.ones((2, d), jnp.float32),
        "ln_x": rmsnorm_init(d, dtype),
    }


def _shift(x, prev=None):
    """Token shift: x_{t-1} (zeros/state at t=0). x: [B,S,d]."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv_chunk(state, r, k, v, w, u, H, P):
    """Sequential WKV over one chunk (rank-1 updates; vectorised over B,H).

    state: [B,H,P,P] (key-dim × value-dim); r,k,v,w: [B,Q,H,P]; u: [H,P].
    """
    def step(s, inp):
        rt, kt, vt, wt = inp  # each [B,H,P]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        out = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, out

    rs = jnp.moveaxis(r, 1, 0)
    ks_ = jnp.moveaxis(k, 1, 0)
    vs = jnp.moveaxis(v, 1, 0)
    ws = jnp.moveaxis(w, 1, 0)
    state, outs = jax.lax.scan(step, state, (rs, ks_, vs, ws))
    return state, jnp.moveaxis(outs, 0, 1)  # [B,Q,H,P]


def rwkv_time_mix(params, x, ctx: Ctx, cfg: RWKVCfg, state=None):
    """x: [B,S,d] -> (y, new_state). state = {"wkv": [B,H,P,P], "shift": [B,1,d]}."""
    Bsz, S, d = x.shape
    H, P = cfg.n_heads, cfg.head_dim
    prev = state["shift"] if state is not None else None
    xp = _shift(x, prev)
    mu = params["mu"]
    mix = lambda i: x + mu[i][None, None, :].astype(x.dtype) * (xp - x)
    r = dense(params["r"], mix(0), ctx, "attn_q")
    k = dense(params["k"], mix(1), ctx, "attn_k")
    v = dense(params["v"], mix(2), ctx, "attn_v")
    g = dense(params["g"], mix(3), ctx, "mlp_gate")
    # data-dependent decay w ∈ (0,1): exp(-exp(lora(x)))
    wlog = (mix(4).astype(jnp.float32) @ params["w1"]["w"].T) @ params["w2"]["w"].T
    w = jnp.exp(-jnp.exp(wlog + params["w_bias"][None, None, :]))

    shp = (Bsz, S, H, P)
    rh, kh, vh = (t.astype(jnp.float32).reshape(shp) for t in (r, k, v))
    wh = w.reshape(shp)
    u = params["u"].reshape(H, P)

    wkv0 = state["wkv"] if state is not None else jnp.zeros((Bsz, H, P, P), jnp.float32)
    Q = min(cfg.chunk, S)
    S_pad = ((S + Q - 1) // Q) * Q
    if S_pad != S:
        # inert padding: w=1 (no decay), r=k=v=0 (no state change, zero output)
        padded = ((0, 0), (0, S_pad - S), (0, 0), (0, 0))
        rh, kh, vh = (jnp.pad(t, padded) for t in (rh, kh, vh))
        wh = jnp.pad(wh, padded, constant_values=1.0)
    nC = S_pad // Q

    def chunk(s, i):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, i * Q, Q, axis=1)
        return _wkv_chunk(s, sl(rh), sl(kh), sl(vh), sl(wh), u, H, P)

    if ctx.cost_mode:
        outs, s = [], wkv0
        for i in range(nC):
            s, o = jax.checkpoint(chunk)(s, i)
            outs.append(o)
        y = jnp.concatenate(outs, axis=1)
    else:
        s, ys = jax.lax.scan(jax.checkpoint(lambda c, i: chunk(c, i)), wkv0, jnp.arange(nC))
        y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S_pad, H, P)
    y = y[:, :S].reshape(Bsz, S, d).astype(x.dtype)
    y = rmsnorm(params["ln_x"], y)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    y = dense(params["out"], y, ctx, "attn_o")
    new_state = {"wkv": s, "shift": x[:, -1:]}
    return y, new_state


def rwkv_channel_mix(params, x, ctx: Ctx, cfg: RWKVCfg, state=None):
    """RWKV channel mix (squared-ReLU MLP with token shift)."""
    prev = state if state is not None else None
    xp = _shift(x, prev)
    mu = params["cm_mu"]
    xk = x + mu[0][None, None, :].astype(x.dtype) * (xp - x)
    xr = x + mu[1][None, None, :].astype(x.dtype) * (xp - x)
    kk = dense(params["cm_k"], xk, ctx, "mlp_in")
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    rr = jax.nn.sigmoid(dense(params["cm_r"], xr, ctx, "mlp_gate").astype(jnp.float32)).astype(x.dtype)
    return rr * dense(params["cm_v"], kk, ctx, "mlp_out"), x[:, -1:]


def rwkv_state_init(batch: int, cfg: RWKVCfg, dtype):
    return {
        "wkv": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim), jnp.float32),
        "shift_tm": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((batch, 1, cfg.d_model), dtype),
    }
