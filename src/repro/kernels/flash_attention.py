"""Pallas TPU flash attention (forward) with causal / sliding-window masking.

Grid: (batch*kv_head*group, num_q_tiles, num_kv_tiles), kv innermost. Online
softmax state (m, l, fp32 acc) lives in VMEM scratch and survives across the
kv grid dimension. Causal/window tiles that are fully masked are skipped with
``pl.when`` (no MXU work issued). Q/K/V tiles are (TQ, dh)/(TK, dh) — dh is
the lane dimension (128/256 aligned for the assigned archs; 64 packs at half
lane utilisation, documented).

Training backward uses the chunked XLA path (`nn.attention`); this kernel is
the serving/prefill fast path — matching MaxText's split, where the fwd kernel
dominates inference cost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window, tq: int, tk: int, n_k: int,
            sq: int, skv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_hi = qi * tq + tq - 1 + (skv - sq)  # causal offset: right-aligned
    k_lo = kj * tk
    live = True
    if causal:
        live = k_lo <= q_hi

    @pl.when(live)
    def _():
        q = q_ref[0].astype(jnp.float32)  # [tq, dh]
        k = k_ref[0].astype(jnp.float32)  # [tk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = (skv - sq) + qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        kpos = kj * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = kpos < skv
        if causal:
            mask &= qpos >= kpos
            if window is not None:
                mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "tile_q", "tile_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    tile_q: int = 256, tile_k: int = 256, interpret: bool = False):
    """q: [B, Sq, H, dh]; k/v: [B, Skv, Kv, dh] (GQA) -> [B, Sq, H, dh]."""
    B, Sq, H, dh = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    tq = min(tile_q, Sq)
    tk = min(tile_k, Skv)
    Sqp = -(-Sq // tq) * tq
    Skp = -(-Skv // tk) * tk
    # layout: fold heads into the leading grid dim -> [B*Kv*G, S, dh]
    qh = jnp.moveaxis(q.reshape(B, Sq, Kv, G, dh), 1, 3).reshape(B * Kv * G, Sq, dh)
    kh = jnp.moveaxis(k, 1, 2).reshape(B * Kv, Skv, dh)
    kh = jnp.repeat(kh, G, axis=0)
    vh = jnp.moveaxis(v, 1, 2).reshape(B * Kv, Skv, dh)
    vh = jnp.repeat(vh, G, axis=0)
    if Sqp != Sq:
        qh = jnp.pad(qh, ((0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Skv:
        kh = jnp.pad(kh, ((0, 0), (0, Skp - Skv), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, Skp - Skv), (0, 0)))

    grid = (B * H, Sqp // tq, Skp // tk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=dh ** -0.5, causal=causal, window=window,
                          tq=tq, tk=tk, n_k=Skp // tk, sq=Sq, skv=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[pltpu.VMEM((tq, 1), jnp.float32),
                        pltpu.VMEM((tq, 1), jnp.float32),
                        pltpu.VMEM((tq, dh), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, dh), q.dtype),
        interpret=interpret,
        name="flash_attention_fwd",
    )(qh, kh, vh)
    out = out[:, :Sq].reshape(B, Kv, G, Sq, dh)
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, dh)