"""Pallas TPU kernel: fp32 column score reduction (ℓ1 / ℓ2²) over G.

The score pass reads G once ([N, n] in HBM) and emits a tiny [n] fp32 vector —
purely memory-bound, so the kernel's job is simply to stream G through VMEM in
lane-aligned tiles with fp32 accumulation. bf16 inputs must not accumulate in
bf16 — the ulp error at large N would swamp small scores and distort the
sampling probabilities. This is a TESTED property, not a comment:
``tests/test_kernels.py::test_col_scores_fp32_accumulation_property`` checks
ℓ1 and ℓ2² scores against a float64 reference at N = 10⁵ rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import COL_SCORE_MODES

__all__ = ["col_l1_scores"]


def _kernel(g_ref, o_ref, acc_ref, *, n_i: int, mode: str):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)
    v = COL_SCORE_MODES[mode](g)
    acc_ref[...] += jnp.sum(v, axis=0, keepdims=True)

    @pl.when(i == n_i - 1)
    def _():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("mode", "tile_n", "tile_c", "interpret"))
def col_l1_scores(G, *, mode: str = "l1", tile_n: int = 512, tile_c: int = 512,
                  interpret: bool = False):
    """Column scores: ℓ1 (sum |G|) or ℓ2² (sum G²). G: [N, n] -> [n] f32."""
    N, n = G.shape
    tn = min(tile_n, max(8, N))
    tc = min(tile_c, n)
    Np = -(-N // tn) * tn
    np_ = -(-n // tc) * tc
    if (Np, np_) != (N, n):
        G = jnp.pad(G, ((0, Np - N), (0, np_ - n)))
    grid = (np_ // tc, Np // tn)
    out = pl.pallas_call(
        functools.partial(_kernel, n_i=Np // tn, mode=mode),
        grid=grid,
        in_specs=[pl.BlockSpec((tn, tc), lambda j, i: (i, j))],
        out_specs=pl.BlockSpec((1, tc), lambda j, i: (0, j)),
        scratch_shapes=[pltpu.VMEM((1, tc), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        interpret=interpret,
        name="col_l1_scores",
    )(G)
    return out[0, :n]
