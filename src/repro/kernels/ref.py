"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["block_gather_matmul_ref", "block_gather_matmul_dw_ref",
           "block_gather_matmul_fused_ref", "block_gather_matmul_dw_db_ref",
           "block_gather_matmul_fallback_ref",
           "block_stream_matmul_onepass_ref", "gather_cols_onepass_ref",
           "gather_cols_fused_scores_ref",
           "gather_cols_matmul_ref", "gather_cols_matmul_dw_ref",
           "COL_SCORE_MODES", "col_scores_ref", "col_l1_scores_ref",
           "flash_attention_ref"]

# The ONE table mapping a score mode to its elementwise column reduction —
# shared by the Pallas kernels (col_scores, sketch_matmul), the XLA oracles
# below, and the ops dispatcher, so the mode sets cannot drift apart.
COL_SCORE_MODES = {"l1": jnp.abs, "l2": jnp.square}


def block_gather_matmul_ref(G, block_idx, scales, W, *, block: int):
    """dX = Σ_k scale_k · G[:, blk_k] @ W[blk_k, :].

    G: [N, n]; block_idx: [rb] (block ids); scales: [rb]; W: [n, d].
    """
    N, n = G.shape
    nb = n // block
    Gb = G.reshape(N, nb, block)
    Wb = W.reshape(nb, block, -1)
    Gc = jnp.take(Gb, block_idx, axis=1).astype(jnp.float32) * scales[None, :, None]
    Wc = jnp.take(Wb, block_idx, axis=0)  # [rb, bs, d]
    return jnp.einsum("nrb,rbd->nd", Gc, Wc.astype(jnp.float32)).astype(G.dtype)


def block_gather_matmul_dw_ref(G, block_idx, scales, X, *, block: int):
    """dWc[k] = scale_k · G[:, blk_k]ᵀ @ X  -> [rb, block, d_in]."""
    N, n = G.shape
    nb = n // block
    Gb = G.reshape(N, nb, block)
    Gc = jnp.take(Gb, block_idx, axis=1).astype(jnp.float32) * scales[None, :, None]
    return jnp.einsum("nrb,nd->rbd", Gc, X.astype(jnp.float32)).astype(G.dtype)


def block_gather_matmul_fused_ref(G, block_idx, scales, W, X, *, block: int,
                                  with_scores: bool = False,
                                  score_mode: str = "l1"):
    """Fused backward oracle: (dX, dWc, db_c) from ONE gather of G.

    The scaled compact ``Gc`` is materialised once (flat column gather — the
    layout XLA lowers with no extra copies; kept blocks are contiguous column
    runs, so this reads exactly the kept slabs) and feeds all three outputs.
    The optimization barrier stops XLA from re-fusing the gather into each
    consumer, which would read G three times — exactly the multi-pass
    backward this path exists to avoid. Shapes as in the Pallas kernel:
    dX [N, d], dWc [rb, block, d], db_c [rb, block] f32.

    ``with_scores=True`` appends the kept blocks' raw (pre-scale) column
    score reduction [rb, block] f32, computed from the already-materialised
    gather — no extra pass over G (the stale-plan partial refresh).
    """
    rb = block_idx.shape[0]
    Gc, cols, kept_s = _gather_scaled_blocks(
        G, block_idx, scales, block,
        score_mode=score_mode if with_scores else None)
    Wc = jnp.take(W, cols, axis=0).astype(jnp.float32)  # [rb*bs, d]
    dX = (Gc @ Wc).astype(G.dtype)
    dWc = jax.lax.dot_general(Gc, X.astype(jnp.float32), (((0,), (0,)), ((), ())))
    db = jnp.sum(Gc, axis=0)  # [rb*bs] f32
    out = (dX, dWc.astype(G.dtype).reshape(rb, block, -1), db.reshape(rb, block))
    if with_scores:
        return out + (kept_s.reshape(rb, block),)
    return out


def _gather_scaled_blocks(G, block_idx, scales, block: int, *,
                          score_mode=None):
    """ONE barriered gather of G's kept column-blocks, scaled, in f32.
    Returns ``(Gc, cols, kept_scores)`` — the per-column index vector is
    shared with any sibling gather (W rows) so the layouts cannot
    desynchronize; ``kept_scores`` ([rb*block] f32, or None when
    ``score_mode`` is None) is the raw pre-scale column reduction of the
    gathered slab, so a score refresh costs no extra read of G.

    The optimization barrier pins the raw gather as a materialised buffer:
    without it XLA re-fuses the gather into every consumer, turning one HBM
    pass over kept G into one pass per consumer."""
    from repro import compat

    cols = (block_idx[:, None] * block
            + jnp.arange(block, dtype=block_idx.dtype)[None, :]).reshape(-1)
    col_scales = jnp.repeat(scales, block)
    Gc0 = jnp.take(G, cols, axis=1).astype(jnp.float32)
    (Gc0,) = compat.optimization_barrier((Gc0,))
    kept_scores = None
    if score_mode is not None:
        kept_scores = jnp.sum(COL_SCORE_MODES[score_mode](Gc0), axis=0)
    Gc = Gc0 * col_scales[None, :]
    return Gc, cols, kept_scores


def _dw_db_from_gc(Gc, X, rb: int, block: int, out_dtype):
    """Compact dW with db FOLDED INTO ITS MATMUL STREAM: X is augmented with
    a trailing ones column, so ``Gcᵀ @ [X | 1]`` emits the weight gradient
    and the bias gradient from a single dot over a single read of ``Gc`` —
    the db row-reduction no longer exists as a separate consumer."""
    XA = jnp.concatenate(
        [X.astype(jnp.float32), jnp.ones((X.shape[0], 1), jnp.float32)], axis=1)
    out = jax.lax.dot_general(Gc, XA, (((0,), (0,)), ((), ())))  # [rb*bs, d+1]
    dWc = out[:, :-1].astype(out_dtype).reshape(rb, block, -1)
    db = out[:, -1].reshape(rb, block)  # f32
    return dWc, db


def block_gather_matmul_dw_db_ref(G, block_idx, scales, X, *, block: int):
    """(dWc, db_c) from ONE gather of G's kept blocks, db folded into the dW
    matmul (trailing ones column on X) — the dW/db side is literally one dot
    over one pass of kept G. Shapes: dWc [rb, block, d_in], db_c [rb, block]
    f32. See :func:`block_gather_matmul_fallback_ref` for the full fallback
    backward that shares the same gather with dX."""
    rb = block_idx.shape[0]
    Gc, _, _ = _gather_scaled_blocks(G, block_idx, scales, block)
    return _dw_db_from_gc(Gc, X, rb, block, G.dtype)


def block_gather_matmul_fallback_ref(G, block_idx, scales, W, X, *, block: int,
                                     with_scores: bool = False,
                                     score_mode: str = "l1"):
    """VMEM-overflow fallback backward: (dX, dWc, db_c) in **one pass over
    kept G**. ONE barriered gather materialises the scaled compact ``Gc``;
    the dX matmul reads ``Gc`` (not G), and the dW/db side is the single
    folded dot of :func:`block_gather_matmul_dw_db_ref`. Unlike the fused
    Pallas kernel this keeps no [r, d] accumulator resident in VMEM — XLA
    tiles the two dots freely — so it is the shape
    ``ops.block_gather_matmul_fused`` drops to when ``fused_vmem_bytes``
    overflows. Shapes as the fused oracle: dX [N, d], dWc [rb, block, d],
    db_c [rb, block] f32 (+ kept raw scores [rb, block] f32 when
    ``with_scores``)."""
    rb = block_idx.shape[0]
    Gc, cols, kept_s = _gather_scaled_blocks(
        G, block_idx, scales, block,
        score_mode=score_mode if with_scores else None)
    Wc = jnp.take(W, cols, axis=0).astype(jnp.float32)  # [rb*bs, d]
    dX = (Gc @ Wc).astype(G.dtype)
    dWc, db = _dw_db_from_gc(Gc, X, rb, block, G.dtype)
    if with_scores:
        return dX, dWc, db, kept_s.reshape(rb, block)
    return dX, dWc, db


def _onepass_perm(sel, total, r):
    """Permutation putting the ``r`` selected ids first (in selection order)
    and the rest after (ascending). ``sel``: [r] ascending unique ids."""
    keyv = jnp.full((total,), total, jnp.int32).at[sel].set(
        jnp.arange(r, dtype=jnp.int32))
    keyv = jnp.where(keyv < r, keyv,
                     r + jnp.arange(total, dtype=jnp.int32))
    return jnp.argsort(keyv)


def block_stream_matmul_onepass_ref(G, block_idx, scales, W, X, *, block: int,
                                    score_mode: str = "l1"):
    """XLA oracle for the streaming one-pass backward: (dX, dWc, db_c,
    scores) with ONE reader of G.

    A single permuted gather materialises ALL of G (kept blocks first, in
    slot order, then dropped blocks); the barrier pins it as one buffer.
    Fresh column scores for every block come from that copy (scattered back
    through the permutation), and the kept prefix — scaled — feeds the same
    dX / folded dW+db dots as the fallback oracle. The price vs the kept-only
    gather is materialising the dropped part of G too (it must be read for
    the scores anyway); vs the two-pass path the separate score read of G is
    gone. Shapes: dX [N, d], dWc [rb, block, d], db_c [rb, block] f32,
    scores [n] f32 (raw Σ|G| or ΣG² per column)."""
    from repro import compat

    N, n = G.shape
    nb = n // block
    rb = block_idx.shape[0]
    perm = _onepass_perm(block_idx, nb, rb)
    cols = (perm[:, None] * block
            + jnp.arange(block, dtype=jnp.int32)[None, :]).reshape(-1)
    Gall = jnp.take(G, cols, axis=1).astype(jnp.float32)
    (Gall,) = compat.optimization_barrier((Gall,))
    red = jnp.sum(COL_SCORE_MODES[score_mode](Gall), axis=0)  # [n] permuted
    scores = jnp.zeros((n,), jnp.float32).at[cols].set(red)
    kept = rb * block
    Gc = Gall[:, :kept] * jnp.repeat(scales, block)[None, :]
    Wc = jnp.take(W, cols[:kept], axis=0).astype(jnp.float32)
    dX = (Gc @ Wc).astype(G.dtype)
    dWc, db = _dw_db_from_gc(Gc, X, rb, block, G.dtype)
    return dX, dWc, db, scores


def gather_cols_onepass_ref(G, idx, scales, W, X, *, score_mode: str = "l1"):
    """Per-column one-pass backward oracle: (dX, dW_rows, db_rows, scores)
    with ONE reader of G — the unblocked counterpart of
    :func:`block_stream_matmul_onepass_ref`. dW_rows: [r, d_in]; db_rows:
    [r] f32; scores: [n] f32 raw per-column reduction."""
    from repro import compat

    n = G.shape[1]
    r = idx.shape[0]
    perm = _onepass_perm(idx.astype(jnp.int32), n, r)
    Gall = jnp.take(G, perm, axis=1).astype(jnp.float32)
    (Gall,) = compat.optimization_barrier((Gall,))
    red = jnp.sum(COL_SCORE_MODES[score_mode](Gall), axis=0)
    scores = jnp.zeros((n,), jnp.float32).at[perm].set(red)
    Gc = Gall[:, :r] * scales[None, :].astype(jnp.float32)
    Wc = jnp.take(W, perm[:r], axis=0).astype(jnp.float32)
    dX = (Gc @ Wc).astype(G.dtype)
    XA = jnp.concatenate(
        [X.astype(jnp.float32), jnp.ones((X.shape[0], 1), jnp.float32)], axis=1)
    out = jax.lax.dot_general(Gc, XA, (((0,), (0,)), ((), ())))  # [r, d+1]
    return dX, out[:, :-1].astype(G.dtype), out[:, -1], scores


def gather_cols_fused_scores_ref(G, idx, scales, W, X, *,
                                 score_mode: str = "l1"):
    """Per-column compact backward with a kept-column score refresh from ONE
    barriered gather of G: (dX, dW_rows, db_rows, kept_scores). The stale
    estimator's unblocked path — like the per-column compact pair but the
    gather is shared and the raw reduction rides along for free."""
    from repro import compat

    r = idx.shape[0]
    Gc0 = jnp.take(G, idx, axis=1).astype(jnp.float32)
    (Gc0,) = compat.optimization_barrier((Gc0,))
    kept_s = jnp.sum(COL_SCORE_MODES[score_mode](Gc0), axis=0)  # [r]
    Gc = Gc0 * scales[None, :].astype(jnp.float32)
    Wc = jnp.take(W, idx, axis=0).astype(jnp.float32)
    dX = (Gc @ Wc).astype(G.dtype)
    XA = jnp.concatenate(
        [X.astype(jnp.float32), jnp.ones((X.shape[0], 1), jnp.float32)], axis=1)
    out = jax.lax.dot_general(Gc, XA, (((0,), (0,)), ((), ())))  # [r, d+1]
    return dX, out[:, :-1].astype(G.dtype), out[:, -1], kept_s


def gather_cols_matmul_ref(G, idx, scales, W):
    """Per-column compact backward dX (XLA reference used by backend="compact")."""
    Gc = jnp.take(G, idx, axis=1) * scales[None, :].astype(G.dtype)
    Wc = jnp.take(W, idx, axis=0)
    return (Gc.astype(jnp.float32) @ Wc.astype(jnp.float32)).astype(G.dtype)


def gather_cols_matmul_dw_ref(G, idx, scales, X):
    Gc = jnp.take(G, idx, axis=1) * scales[None, :].astype(G.dtype)
    return (Gc.astype(jnp.float32).T @ X.astype(jnp.float32)).astype(G.dtype)


def col_scores_ref(G, *, mode: str = "l1"):
    """fp32 column score reduction over G per :data:`COL_SCORE_MODES`:
    s_j = Σ_i |G[i, j]| (``"l1"``) or Σ_i G[i, j]² (``"l2"``)."""
    return jnp.sum(COL_SCORE_MODES[mode](G.astype(jnp.float32)), axis=0)


def col_l1_scores_ref(G):
    """ℓ1 column scores in fp32: s_j = Σ_i |G[i, j]|."""
    return col_scores_ref(G, mode="l1")


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None, scale=None):
    """q: [B, Sq, H, dh]; k/v: [B, Skv, Kv, dh] (GQA) -> [B, Sq, H, dh]."""
    B, Sq, H, dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, dh).astype(jnp.float32)
    sc = scale if scale is not None else dh ** -0.5
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k.astype(jnp.float32)) * sc
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        off = k.shape[1] - Sq  # right-aligned when Skv > Sq
        mask &= (qpos + off) >= kpos
        if window is not None:
            mask &= (qpos + off - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)
