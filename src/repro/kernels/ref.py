"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["block_gather_matmul_ref", "block_gather_matmul_dw_ref",
           "block_gather_matmul_fused_ref", "block_gather_matmul_dw_db_ref",
           "gather_cols_matmul_ref", "gather_cols_matmul_dw_ref",
           "col_l1_scores_ref", "flash_attention_ref"]


def block_gather_matmul_ref(G, block_idx, scales, W, *, block: int):
    """dX = Σ_k scale_k · G[:, blk_k] @ W[blk_k, :].

    G: [N, n]; block_idx: [rb] (block ids); scales: [rb]; W: [n, d].
    """
    N, n = G.shape
    nb = n // block
    Gb = G.reshape(N, nb, block)
    Wb = W.reshape(nb, block, -1)
    Gc = jnp.take(Gb, block_idx, axis=1).astype(jnp.float32) * scales[None, :, None]
    Wc = jnp.take(Wb, block_idx, axis=0)  # [rb, bs, d]
    return jnp.einsum("nrb,rbd->nd", Gc, Wc.astype(jnp.float32)).astype(G.dtype)


def block_gather_matmul_dw_ref(G, block_idx, scales, X, *, block: int):
    """dWc[k] = scale_k · G[:, blk_k]ᵀ @ X  -> [rb, block, d_in]."""
    N, n = G.shape
    nb = n // block
    Gb = G.reshape(N, nb, block)
    Gc = jnp.take(Gb, block_idx, axis=1).astype(jnp.float32) * scales[None, :, None]
    return jnp.einsum("nrb,nd->rbd", Gc, X.astype(jnp.float32)).astype(G.dtype)


def block_gather_matmul_fused_ref(G, block_idx, scales, W, X, *, block: int):
    """Fused backward oracle: (dX, dWc, db_c) from ONE gather of G.

    The scaled compact ``Gc`` is materialised once (flat column gather — the
    layout XLA lowers with no extra copies; kept blocks are contiguous column
    runs, so this reads exactly the kept slabs) and feeds all three outputs.
    The optimization barrier stops XLA from re-fusing the gather into each
    consumer, which would read G three times — exactly the multi-pass
    backward this path exists to avoid. Shapes as in the Pallas kernel:
    dX [N, d], dWc [rb, block, d], db_c [rb, block] f32.
    """
    N, n = G.shape
    rb = block_idx.shape[0]
    cols = (block_idx[:, None] * block
            + jnp.arange(block, dtype=block_idx.dtype)[None, :]).reshape(-1)
    col_scales = jnp.repeat(scales, block)
    from repro import compat

    Gc = jnp.take(G, cols, axis=1).astype(jnp.float32) * col_scales[None, :]
    (Gc,) = compat.optimization_barrier((Gc,))
    Wc = jnp.take(W, cols, axis=0).astype(jnp.float32)  # [rb*bs, d]
    dX = (Gc @ Wc).astype(G.dtype)
    dWc = jax.lax.dot_general(Gc, X.astype(jnp.float32), (((0,), (0,)), ((), ())))
    db = jnp.sum(Gc, axis=0)  # [rb*bs] f32
    return dX, dWc.astype(G.dtype).reshape(rb, block, -1), db.reshape(rb, block)


def block_gather_matmul_dw_db_ref(G, block_idx, scales, X, *, block: int):
    """(dWc, db_c) from ONE shared gather of G's kept blocks.

    The dW-side half of :func:`block_gather_matmul_fused_ref`: the scaled
    compact ``Gc`` is materialised once behind an optimization barrier (XLA
    would otherwise re-fuse the gather into both consumers and read G twice)
    and feeds the compact weight gradient AND the compact bias gradient.
    Used by the VMEM-overflow fallback in ``ops.block_gather_matmul_fused``,
    which pairs it with the dX kernel for a 2-pass backward over kept G.
    Shapes: dWc [rb, block, d_in], db_c [rb, block] f32.
    """
    N, n = G.shape
    rb = block_idx.shape[0]
    cols = (block_idx[:, None] * block
            + jnp.arange(block, dtype=block_idx.dtype)[None, :]).reshape(-1)
    col_scales = jnp.repeat(scales, block)
    from repro import compat

    Gc = jnp.take(G, cols, axis=1).astype(jnp.float32) * col_scales[None, :]
    (Gc,) = compat.optimization_barrier((Gc,))
    dWc = jax.lax.dot_general(Gc, X.astype(jnp.float32), (((0,), (0,)), ((), ())))
    db = jnp.sum(Gc, axis=0)  # [rb*bs] f32
    return dWc.astype(G.dtype).reshape(rb, block, -1), db.reshape(rb, block)


def gather_cols_matmul_ref(G, idx, scales, W):
    """Per-column compact backward dX (XLA reference used by backend="compact")."""
    Gc = jnp.take(G, idx, axis=1) * scales[None, :].astype(G.dtype)
    Wc = jnp.take(W, idx, axis=0)
    return (Gc.astype(jnp.float32) @ Wc.astype(jnp.float32)).astype(G.dtype)


def gather_cols_matmul_dw_ref(G, idx, scales, X):
    Gc = jnp.take(G, idx, axis=1) * scales[None, :].astype(G.dtype)
    return (Gc.astype(jnp.float32).T @ X.astype(jnp.float32)).astype(G.dtype)


def col_l1_scores_ref(G):
    """ℓ1 column scores in fp32: s_j = Σ_i |G[i, j]|."""
    return jnp.sum(jnp.abs(G.astype(jnp.float32)), axis=0)


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None, scale=None):
    """q: [B, Sq, H, dh]; k/v: [B, Skv, Kv, dh] (GQA) -> [B, Sq, H, dh]."""
    B, Sq, H, dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, dh).astype(jnp.float32)
    sc = scale if scale is not None else dh ** -0.5
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k.astype(jnp.float32)) * sc
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        off = k.shape[1] - Sq  # right-aligned when Skv > Sq
        mask &= (qpos + off) >= kpos
        if window is not None:
            mask &= (qpos + off - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)
