"""Pallas TPU kernels for the block-sketched backward matmuls.

The sketch keeps ``rb`` 128-wide column *blocks* of the output-gradient matrix
G (see ``SketchConfig.block``). Because kept blocks are contiguous lane-aligned
slabs, the gather is folded into the BlockSpec index map: the kernel's DMA
engine fetches only the selected G column-blocks / W row-blocks straight from
HBM — the compacted operands are never materialised. The MXU then runs a dense
[N, rb·128] × [rb·128, d] matmul, i.e. the paper's element sparsity realised as
*shape* sparsity (DESIGN.md §3).

VMEM budget per grid step (defaults, bf16): G tile 256×128 (64 KiB) + W tile
128×256 (64 KiB) + fp32 acc 256×256 (256 KiB) ≈ 0.4 MiB — far below the
~16 MiB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["block_gather_matmul", "block_gather_matmul_dw",
           "block_gather_matmul_fused", "block_stream_matmul_fused",
           "fused_vmem_bytes", "stream_vmem_bytes"]


def _dx_kernel(idx_ref, scale_ref, g_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sc = scale_ref[k]
    g = g_ref[...].astype(jnp.float32) * sc
    acc_ref[...] += jax.lax.dot(g, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tile_n", "tile_d", "interpret"))
def block_gather_matmul(G, block_idx, scales, W, *, block: int = 128,
                        tile_n: int = 256, tile_d: int = 256, interpret: bool = False):
    """dX = Σ_k scale_k · G[:, blk_k] @ W[blk_k, :].

    G: [N, n]; block_idx: [rb] int32 (ascending block ids); scales: [rb] f32;
    W: [n, d]. Returns [N, d] in G.dtype. N, d padded internally to tiles.
    """
    N, n = G.shape
    d = W.shape[1]
    rb = block_idx.shape[0]
    tn = min(tile_n, max(8, N))
    td = min(tile_d, d)
    Np = -(-N // tn) * tn
    dp = -(-d // td) * td
    if Np != N:
        G = jnp.pad(G, ((0, Np - N), (0, 0)))
    if dp != d:
        W = jnp.pad(W, ((0, 0), (0, dp - d)))

    grid = (Np // tn, dp // td, rb)
    out = pl.pallas_call(
        functools.partial(_dx_kernel, n_k=rb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, block), lambda i, j, k, idx, sc: (i, idx[k])),
                pl.BlockSpec((block, td), lambda i, j, k, idx, sc: (idx[k], j)),
            ],
            out_specs=pl.BlockSpec((tn, td), lambda i, j, k, idx, sc: (i, j)),
            scratch_shapes=[pltpu.VMEM((tn, td), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Np, dp), G.dtype),
        interpret=interpret,
        name="block_gather_matmul_dx",
    )(block_idx, scales.astype(jnp.float32), G, W)
    return out[:N, :d]


def _dw_kernel(idx_ref, scale_ref, g_ref, x_ref, o_ref, acc_ref, *, n_i: int):
    i = pl.program_id(2)
    k = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # scale G up front (not the accumulator at the end) so the accumulation
    # order is bit-identical to the fused kernel, which shares one scaled G
    # tile between the dX and dW products.
    g = g_ref[...].astype(jnp.float32) * scale_ref[k]
    # contract over the N tile: gᵀ @ x without an explicit transpose
    acc_ref[...] += jax.lax.dot_general(
        g, x_ref[...].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == n_i - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tile_n", "tile_d", "interpret"))
def block_gather_matmul_dw(G, block_idx, scales, X, *, block: int = 128,
                           tile_n: int = 256, tile_d: int = 256, interpret: bool = False):
    """dWc[k] = scale_k · G[:, blk_k]ᵀ @ X  ->  [rb, block, d_in].

    The caller scatters the compact rows into the full dW (indices are shared
    across DP replicas, enabling the compressed all-reduce — DESIGN.md §3).
    """
    N, n = G.shape
    din = X.shape[1]
    rb = block_idx.shape[0]
    tn = min(tile_n, max(8, N))
    td = min(tile_d, din)
    Np = -(-N // tn) * tn
    dp = -(-din // td) * td
    if Np != N:
        G = jnp.pad(G, ((0, Np - N), (0, 0)))
        X = jnp.pad(X, ((0, Np - N), (0, 0)))
    if dp != din:
        X = jnp.pad(X, ((0, 0), (0, dp - din)))

    grid = (rb, dp // td, Np // tn)
    out = pl.pallas_call(
        functools.partial(_dw_kernel, n_i=Np // tn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, block), lambda k, j, i, idx, sc: (i, idx[k])),
                pl.BlockSpec((tn, td), lambda k, j, i, idx, sc: (i, j)),
            ],
            out_specs=pl.BlockSpec((1, block, td), lambda k, j, i, idx, sc: (k, 0, j)),
            scratch_shapes=[pltpu.VMEM((block, td), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((rb, block, dp), G.dtype),
        interpret=interpret,
        name="block_gather_matmul_dw",
    )(block_idx, scales.astype(jnp.float32), G, X)
    return out[:, :, :din]


# ---------------------------------------------------------------------------
# One-pass fused backward: dX, compact dW and compact db from a single
# stream of G's kept column-blocks.
# ---------------------------------------------------------------------------


def _fused_kernel(idx_ref, scale_ref, g_ref, w_ref, x_ref, *refs,
                  n_i: int, n_k: int, n_j: int, td: int,
                  with_scores: bool = False, score_mode: str = "l1"):
    if with_scores:
        o_dx, o_dw, o_db, o_s, acc_dx, acc_dw, acc_db, acc_s = refs
    else:
        o_dx, o_dw, o_db, acc_dx, acc_dw, acc_db = refs
        o_s = acc_s = None
    i, k, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    # one scaled G tile feeds both MXU products and the db reduction; the raw
    # (pre-scale) tile additionally feeds the score refresh when requested
    graw = g_ref[...].astype(jnp.float32)
    g = graw * scale_ref[k]

    @pl.when(jnp.logical_and(i == 0, jnp.logical_and(k == 0, j == 0)))
    def _():
        acc_dw[...] = jnp.zeros_like(acc_dw)
        acc_db[...] = jnp.zeros_like(acc_db)
        if with_scores:
            acc_s[...] = jnp.zeros_like(acc_s)

    @pl.when(jnp.logical_and(k == 0, j == 0))
    def _():
        acc_dx[...] = jnp.zeros_like(acc_dx)

    jsl = pl.ds(j * td, td)
    acc_dx[:, jsl] += jax.lax.dot(g, w_ref[...].astype(jnp.float32),
                                  preferred_element_type=jnp.float32)
    acc_dw[k, :, jsl] += jax.lax.dot_general(
        g, x_ref[...].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _():
        acc_db[k, :] += jnp.sum(g, axis=0)
        if with_scores:
            v = jnp.abs(graw) if score_mode == "l1" else jnp.square(graw)
            acc_s[k, :] += jnp.sum(v, axis=0)

    @pl.when(k == n_k - 1)
    def _():
        o_dx[:, jsl] = acc_dx[:, jsl].astype(o_dx.dtype)

    @pl.when(jnp.logical_and(i == n_i - 1,
                             jnp.logical_and(k == n_k - 1, j == n_j - 1)))
    def _():
        o_dw[...] = acc_dw[...].astype(o_dw.dtype)
        o_db[...] = acc_db[...]
        if with_scores:
            o_s[...] = acc_s[...]


@functools.partial(jax.jit, static_argnames=("block", "tile_n", "tile_d",
                                             "interpret", "with_scores",
                                             "score_mode"))
def block_gather_matmul_fused(G, block_idx, scales, W, X, *, block: int = 128,
                              tile_n: int = 256, tile_d: int = 256,
                              interpret: bool = False,
                              with_scores: bool = False,
                              score_mode: str = "l1"):
    """Fused one-pass backward for a block-sketched linear site.

        dX     = Σ_k scale_k · G[:, blk_k] @ W[blk_k, :]      [N, d]
        dWc[k] = scale_k · G[:, blk_k]ᵀ @ X                   [rb, block, d]
        db_c[k] = scale_k · Σ_rows G[:, blk_k]                [rb, block] f32

    G: [N, n]; block_idx: [rb] int32; scales: [rb] f32; W: [n, d]; X: [N, d].
    Each kept G column-block is DMA'd into VMEM exactly once per row tile —
    the G index map is constant over the inner d-tile sweep, so the whole
    backward makes ONE HBM pass over the kept part of G (vs one per output
    per d-tile for the unfused pair). The price is residency: the f32
    accumulators for a [tn, d] dX row panel and the full [rb·block, d]
    compact dW live in VMEM for the whole call — see ``fused_vmem_bytes``;
    the ops dispatcher falls back to the unfused pair when it doesn't fit.

    Accumulation order (ascending k for dX, ascending row tiles for dWc,
    scaled-G operands) matches ``block_gather_matmul`` /
    ``block_gather_matmul_dw`` exactly, so fused and unfused are
    bit-identical for the same plan.

    ``with_scores=True`` additionally emits the raw (pre-scale) column score
    reduction of the KEPT blocks — Σ_rows |G| (``score_mode="l1"``) or
    Σ_rows G² (``"l2"``) as a 4th output [rb, block] f32 — from the same G
    tiles already resident for the matmuls, i.e. a free partial score
    refresh for the stale-plan estimator. The first three outputs are
    bit-identical with the flag on or off.
    """
    N, n = G.shape
    d = W.shape[1]
    assert X.shape[1] == d, (X.shape, W.shape)
    rb = block_idx.shape[0]
    tn = min(tile_n, max(8, N))
    td = min(tile_d, d)
    Np = -(-N // tn) * tn
    dp = -(-d // td) * td
    if Np != N:
        G = jnp.pad(G, ((0, Np - N), (0, 0)))
        X = jnp.pad(X, ((0, Np - N), (0, 0)))
    if dp != d:
        W = jnp.pad(W, ((0, 0), (0, dp - d)))
        X = jnp.pad(X, ((0, 0), (0, dp - d)))

    n_i, n_j = Np // tn, dp // td
    grid = (n_i, rb, n_j)
    out_specs = [
        pl.BlockSpec((tn, dp), lambda i, k, j, idx, sc: (i, 0)),
        pl.BlockSpec((rb, block, dp), lambda i, k, j, idx, sc: (0, 0, 0)),
        pl.BlockSpec((rb, block), lambda i, k, j, idx, sc: (0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((Np, dp), G.dtype),
        jax.ShapeDtypeStruct((rb, block, dp), G.dtype),
        jax.ShapeDtypeStruct((rb, block), jnp.float32),
    ]
    scratch = [
        pltpu.VMEM((tn, dp), jnp.float32),
        pltpu.VMEM((rb, block, dp), jnp.float32),
        pltpu.VMEM((rb, block), jnp.float32),
    ]
    if with_scores is True:  # static flag (static_argnames), not a tracer
        out_specs.append(pl.BlockSpec((rb, block), lambda i, k, j, idx, sc: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((rb, block), jnp.float32))
        scratch.append(pltpu.VMEM((rb, block), jnp.float32))
    outs = pl.pallas_call(
        functools.partial(_fused_kernel, n_i=n_i, n_k=rb, n_j=n_j, td=td,
                          with_scores=with_scores, score_mode=score_mode),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, block), lambda i, k, j, idx, sc: (i, idx[k])),
                pl.BlockSpec((block, td), lambda i, k, j, idx, sc: (idx[k], j)),
                pl.BlockSpec((tn, td), lambda i, k, j, idx, sc: (i, j)),
            ],
            out_specs=out_specs,
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        interpret=interpret,
        name="block_gather_matmul_fused",
    )(block_idx, scales.astype(jnp.float32), G, W, X)
    dX, dWc, db = outs[0][:N, :d], outs[1][:, :, :d], outs[2]
    if with_scores is True:  # static flag (static_argnames), not a tracer
        return dX, dWc, db, outs[3]
    return dX, dWc, db


def fused_vmem_bytes(N: int, d: int, rb: int, block: int, itemsize: int,
                     tile_n: int = 256, tile_d: int = 256) -> int:
    """VMEM residency estimate for ``block_gather_matmul_fused`` (bytes).

    f32 accumulators + output buffers + double-buffered input tiles."""
    tn = min(tile_n, max(8, N))
    td = min(tile_d, d)
    dp = -(-d // td) * td
    acc = 4 * (tn * dp + rb * block * dp + rb * block)
    outs = itemsize * (tn * dp + rb * block * dp) + 4 * rb * block
    tiles = 2 * itemsize * (tn * block + block * td + tn * td)
    return acc + outs + tiles


# ---------------------------------------------------------------------------
# Streaming one-pass backward: ALL of G streams through VMEM once; kept
# blocks feed dX/compact-dW/db through per-block gates while EVERY block's
# fresh column scores are reduced in the same sweep — the separate
# col_scores pass no longer exists.
# ---------------------------------------------------------------------------


def _stream_kernel(gate_ref, slot_ref, g_ref, w_ref, x_ref,
                   o_dx, o_dw, o_db, o_s, acc_dx, acc_dw, acc_db, acc_s,
                   *, n_i: int, n_k: int, n_j: int, td: int, score_mode: str):
    i, k, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    graw = g_ref[...].astype(jnp.float32)
    sc = gate_ref[k]      # 0.0 for dropped blocks, the 1/p scale for kept
    slot = slot_ref[k]    # compact slot of block k (0 for dropped; unused)

    @pl.when(jnp.logical_and(i == 0, jnp.logical_and(k == 0, j == 0)))
    def _():
        acc_dw[...] = jnp.zeros_like(acc_dw)
        acc_db[...] = jnp.zeros_like(acc_db)
        acc_s[...] = jnp.zeros_like(acc_s)

    @pl.when(jnp.logical_and(k == 0, j == 0))
    def _():
        acc_dx[...] = jnp.zeros_like(acc_dx)

    jsl = pl.ds(j * td, td)

    # fresh scores for EVERY block, from the raw tile, once per (i, k)
    @pl.when(j == 0)
    def _():
        v = jnp.abs(graw) if score_mode == "l1" else jnp.square(graw)
        acc_s[k, :] += jnp.sum(v, axis=0)

    # gated contributions: dropped blocks skip both MXU products entirely,
    # so the accumulation sequence over kept blocks (ascending block id =
    # ascending slot) is exactly the fused kernel's — bit-identical outputs
    # for the same keep decisions.
    @pl.when(sc > 0)
    def _():
        g = graw * sc
        acc_dx[:, jsl] += jax.lax.dot(g, w_ref[...].astype(jnp.float32),
                                      preferred_element_type=jnp.float32)
        acc_dw[slot, :, jsl] += jax.lax.dot_general(
            g, x_ref[...].astype(jnp.float32), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(sc > 0, j == 0))
    def _():
        acc_db[slot, :] += jnp.sum(graw * sc, axis=0)

    @pl.when(k == n_k - 1)
    def _():
        o_dx[:, jsl] = acc_dx[:, jsl].astype(o_dx.dtype)

    @pl.when(jnp.logical_and(i == n_i - 1,
                             jnp.logical_and(k == n_k - 1, j == n_j - 1)))
    def _():
        o_dw[...] = acc_dw[...].astype(o_dw.dtype)
        o_db[...] = acc_db[...]
        o_s[...] = acc_s[...]


@functools.partial(jax.jit, static_argnames=("rb", "block", "tile_n", "tile_d",
                                             "score_mode", "interpret"))
def block_stream_matmul_fused(G, gates, slot_map, W, X, *, rb: int,
                              block: int = 128, tile_n: int = 256,
                              tile_d: int = 256, score_mode: str = "l1",
                              interpret: bool = False):
    """Streaming selection backward: ONE HBM pass over ALL of G.

    Every 128-wide column block of G streams through VMEM exactly once per
    row tile. Kept blocks (``gates[k] > 0``) are scaled by their gate and
    accumulated into dX / compact dW / compact db at compact slot
    ``slot_map[k]``; every block — kept or dropped — contributes its raw
    column score reduction (Σ|G| or ΣG² per ``score_mode``) to a fresh [n]
    score vector. The separate score/plan pass over G disappears: selection
    is evaluated online as G streams by, against gates sampled from the
    carried previous-step scores (see ``core/sketched_linear`` "onepass").

    G: [N, n]; gates: [nb] f32 (nb = n // block; 0 = dropped, else 1/p
    scale); slot_map: [nb] int32 (compact slot per kept block, ascending
    over kept blocks); W: [n, d]; X: [N, d]; rb: number of kept blocks
    (static). Returns (dX [N, d], dWc [rb, block, d], db_c [rb, block] f32,
    scores [n] f32).

    Given identical keep decisions, dX/dWc/db are bit-identical to
    ``block_gather_matmul_fused``: the kept-block accumulation order and
    operands are the same; dropped blocks only touch the score reduction.
    The extra HBM cost over the fused gather is the dropped part of G and
    the full (not kept-only) W row stream — see docs/perf.md for the
    traffic table.
    """
    N, n = G.shape
    d = W.shape[1]
    assert X.shape[1] == d, (X.shape, W.shape)
    nb = n // block
    assert nb * block == n, (n, block)
    assert gates.shape == (nb,) and slot_map.shape == (nb,), (gates.shape, nb)
    tn = min(tile_n, max(8, N))
    td = min(tile_d, d)
    Np = -(-N // tn) * tn
    dp = -(-d // td) * td
    if Np != N:
        G = jnp.pad(G, ((0, Np - N), (0, 0)))
        X = jnp.pad(X, ((0, Np - N), (0, 0)))
    if dp != d:
        W = jnp.pad(W, ((0, 0), (0, dp - d)))
        X = jnp.pad(X, ((0, 0), (0, dp - d)))

    n_i, n_j = Np // tn, dp // td
    grid = (n_i, nb, n_j)
    dX, dWc, db, s = pl.pallas_call(
        functools.partial(_stream_kernel, n_i=n_i, n_k=nb, n_j=n_j, td=td,
                          score_mode=score_mode),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, block), lambda i, k, j, gt, sl: (i, k)),
                pl.BlockSpec((block, td), lambda i, k, j, gt, sl: (k, j)),
                pl.BlockSpec((tn, td), lambda i, k, j, gt, sl: (i, j)),
            ],
            out_specs=[
                pl.BlockSpec((tn, dp), lambda i, k, j, gt, sl: (i, 0)),
                pl.BlockSpec((rb, block, dp), lambda i, k, j, gt, sl: (0, 0, 0)),
                pl.BlockSpec((rb, block), lambda i, k, j, gt, sl: (0, 0)),
                pl.BlockSpec((nb, block), lambda i, k, j, gt, sl: (0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((tn, dp), jnp.float32),
                pltpu.VMEM((rb, block, dp), jnp.float32),
                pltpu.VMEM((rb, block), jnp.float32),
                pltpu.VMEM((nb, block), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((Np, dp), G.dtype),
            jax.ShapeDtypeStruct((rb, block, dp), G.dtype),
            jax.ShapeDtypeStruct((rb, block), jnp.float32),
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
        ],
        interpret=interpret,
        name="block_stream_matmul_fused",
    )(gates.astype(jnp.float32), slot_map.astype(jnp.int32), G, W, X)
    return dX[:N, :d], dWc[:, :, :d], db, s.reshape(n)


def stream_vmem_bytes(N: int, d: int, rb: int, nb: int, block: int,
                      itemsize: int, tile_n: int = 256,
                      tile_d: int = 256) -> int:
    """VMEM residency estimate for ``block_stream_matmul_fused`` (bytes):
    the fused kernel's accumulators plus the [nb, block] score accumulator
    and its output buffer."""
    return (fused_vmem_bytes(N, d, rb, block, itemsize,
                             tile_n=tile_n, tile_d=tile_d)
            + 8 * nb * block)
