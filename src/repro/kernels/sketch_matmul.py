"""Pallas TPU kernels for the block-sketched backward matmuls.

The sketch keeps ``rb`` 128-wide column *blocks* of the output-gradient matrix
G (see ``SketchConfig.block``). Because kept blocks are contiguous lane-aligned
slabs, the gather is folded into the BlockSpec index map: the kernel's DMA
engine fetches only the selected G column-blocks / W row-blocks straight from
HBM — the compacted operands are never materialised. The MXU then runs a dense
[N, rb·128] × [rb·128, d] matmul, i.e. the paper's element sparsity realised as
*shape* sparsity (DESIGN.md §3).

VMEM budget per grid step (defaults, bf16): G tile 256×128 (64 KiB) + W tile
128×256 (64 KiB) + fp32 acc 256×256 (256 KiB) ≈ 0.4 MiB — far below the
~16 MiB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["block_gather_matmul", "block_gather_matmul_dw"]


def _dx_kernel(idx_ref, scale_ref, g_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sc = scale_ref[k]
    g = g_ref[...].astype(jnp.float32) * sc
    acc_ref[...] += jax.lax.dot(g, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tile_n", "tile_d", "interpret"))
def block_gather_matmul(G, block_idx, scales, W, *, block: int = 128,
                        tile_n: int = 256, tile_d: int = 256, interpret: bool = False):
    """dX = Σ_k scale_k · G[:, blk_k] @ W[blk_k, :].

    G: [N, n]; block_idx: [rb] int32 (ascending block ids); scales: [rb] f32;
    W: [n, d]. Returns [N, d] in G.dtype. N, d padded internally to tiles.
    """
    N, n = G.shape
    d = W.shape[1]
    rb = block_idx.shape[0]
    tn = min(tile_n, max(8, N))
    td = min(tile_d, d)
    Np = -(-N // tn) * tn
    dp = -(-d // td) * td
    if Np != N:
        G = jnp.pad(G, ((0, Np - N), (0, 0)))
    if dp != d:
        W = jnp.pad(W, ((0, 0), (0, dp - d)))

    grid = (Np // tn, dp // td, rb)
    out = pl.pallas_call(
        functools.partial(_dx_kernel, n_k=rb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, block), lambda i, j, k, idx, sc: (i, idx[k])),
                pl.BlockSpec((block, td), lambda i, j, k, idx, sc: (idx[k], j)),
            ],
            out_specs=pl.BlockSpec((tn, td), lambda i, j, k, idx, sc: (i, j)),
            scratch_shapes=[pltpu.VMEM((tn, td), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Np, dp), G.dtype),
        interpret=interpret,
        name="block_gather_matmul_dx",
    )(block_idx, scales.astype(jnp.float32), G, W)
    return out[:N, :d]


def _dw_kernel(idx_ref, scale_ref, g_ref, x_ref, o_ref, acc_ref, *, n_i: int):
    i = pl.program_id(2)
    k = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)
    # contract over the N tile: gᵀ @ x without an explicit transpose
    acc_ref[...] += jax.lax.dot_general(
        g, x_ref[...].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == n_i - 1)
    def _():
        o_ref[0] = (acc_ref[...] * scale_ref[k]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tile_n", "tile_d", "interpret"))
def block_gather_matmul_dw(G, block_idx, scales, X, *, block: int = 128,
                           tile_n: int = 256, tile_d: int = 256, interpret: bool = False):
    """dWc[k] = scale_k · G[:, blk_k]ᵀ @ X  ->  [rb, block, d_in].

    The caller scatters the compact rows into the full dW (indices are shared
    across DP replicas, enabling the compressed all-reduce — DESIGN.md §3).
    """
    N, n = G.shape
    din = X.shape[1]
    rb = block_idx.shape[0]
    tn = min(tile_n, max(8, N))
    td = min(tile_d, din)
    Np = -(-N // tn) * tn
    dp = -(-din // td) * td
    if Np != N:
        G = jnp.pad(G, ((0, Np - N), (0, 0)))
        X = jnp.pad(X, ((0, Np - N), (0, 0)))
    if dp != din:
        X = jnp.pad(X, ((0, 0), (0, dp - din)))

    grid = (rb, dp // td, Np // tn)
    out = pl.pallas_call(
        functools.partial(_dw_kernel, n_i=Np // tn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, block), lambda k, j, i, idx, sc: (i, idx[k])),
                pl.BlockSpec((tn, td), lambda k, j, i, idx, sc: (i, j)),
            ],
            out_specs=pl.BlockSpec((1, block, td), lambda k, j, i, idx, sc: (k, 0, j)),
            scratch_shapes=[pltpu.VMEM((block, td), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((rb, block, dp), G.dtype),
        interpret=interpret,
        name="block_gather_matmul_dw",
    )(block_idx, scales.astype(jnp.float32), G, X)
    return out[:, :, :din]
