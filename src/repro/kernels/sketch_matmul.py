"""Pallas TPU kernels for the block-sketched backward matmuls.

The sketch keeps ``rb`` 128-wide column *blocks* of the output-gradient matrix
G (see ``SketchConfig.block``). Because kept blocks are contiguous lane-aligned
slabs, the gather is folded into the BlockSpec index map: the kernel's DMA
engine fetches only the selected G column-blocks / W row-blocks straight from
HBM — the compacted operands are never materialised. The MXU then runs a dense
[N, rb·128] × [rb·128, d] matmul, i.e. the paper's element sparsity realised as
*shape* sparsity (DESIGN.md §3).

VMEM budget per grid step (defaults, bf16): G tile 256×128 (64 KiB) + W tile
128×256 (64 KiB) + fp32 acc 256×256 (256 KiB) ≈ 0.4 MiB — far below the
~16 MiB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["block_gather_matmul", "block_gather_matmul_dw",
           "block_gather_matmul_fused", "fused_vmem_bytes"]


def _dx_kernel(idx_ref, scale_ref, g_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sc = scale_ref[k]
    g = g_ref[...].astype(jnp.float32) * sc
    acc_ref[...] += jax.lax.dot(g, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tile_n", "tile_d", "interpret"))
def block_gather_matmul(G, block_idx, scales, W, *, block: int = 128,
                        tile_n: int = 256, tile_d: int = 256, interpret: bool = False):
    """dX = Σ_k scale_k · G[:, blk_k] @ W[blk_k, :].

    G: [N, n]; block_idx: [rb] int32 (ascending block ids); scales: [rb] f32;
    W: [n, d]. Returns [N, d] in G.dtype. N, d padded internally to tiles.
    """
    N, n = G.shape
    d = W.shape[1]
    rb = block_idx.shape[0]
    tn = min(tile_n, max(8, N))
    td = min(tile_d, d)
    Np = -(-N // tn) * tn
    dp = -(-d // td) * td
    if Np != N:
        G = jnp.pad(G, ((0, Np - N), (0, 0)))
    if dp != d:
        W = jnp.pad(W, ((0, 0), (0, dp - d)))

    grid = (Np // tn, dp // td, rb)
    out = pl.pallas_call(
        functools.partial(_dx_kernel, n_k=rb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, block), lambda i, j, k, idx, sc: (i, idx[k])),
                pl.BlockSpec((block, td), lambda i, j, k, idx, sc: (idx[k], j)),
            ],
            out_specs=pl.BlockSpec((tn, td), lambda i, j, k, idx, sc: (i, j)),
            scratch_shapes=[pltpu.VMEM((tn, td), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Np, dp), G.dtype),
        interpret=interpret,
        name="block_gather_matmul_dx",
    )(block_idx, scales.astype(jnp.float32), G, W)
    return out[:N, :d]


def _dw_kernel(idx_ref, scale_ref, g_ref, x_ref, o_ref, acc_ref, *, n_i: int):
    i = pl.program_id(2)
    k = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # scale G up front (not the accumulator at the end) so the accumulation
    # order is bit-identical to the fused kernel, which shares one scaled G
    # tile between the dX and dW products.
    g = g_ref[...].astype(jnp.float32) * scale_ref[k]
    # contract over the N tile: gᵀ @ x without an explicit transpose
    acc_ref[...] += jax.lax.dot_general(
        g, x_ref[...].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == n_i - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tile_n", "tile_d", "interpret"))
def block_gather_matmul_dw(G, block_idx, scales, X, *, block: int = 128,
                           tile_n: int = 256, tile_d: int = 256, interpret: bool = False):
    """dWc[k] = scale_k · G[:, blk_k]ᵀ @ X  ->  [rb, block, d_in].

    The caller scatters the compact rows into the full dW (indices are shared
    across DP replicas, enabling the compressed all-reduce — DESIGN.md §3).
    """
    N, n = G.shape
    din = X.shape[1]
    rb = block_idx.shape[0]
    tn = min(tile_n, max(8, N))
    td = min(tile_d, din)
    Np = -(-N // tn) * tn
    dp = -(-din // td) * td
    if Np != N:
        G = jnp.pad(G, ((0, Np - N), (0, 0)))
        X = jnp.pad(X, ((0, Np - N), (0, 0)))
    if dp != din:
        X = jnp.pad(X, ((0, 0), (0, dp - din)))

    grid = (rb, dp // td, Np // tn)
    out = pl.pallas_call(
        functools.partial(_dw_kernel, n_i=Np // tn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, block), lambda k, j, i, idx, sc: (i, idx[k])),
                pl.BlockSpec((tn, td), lambda k, j, i, idx, sc: (i, j)),
            ],
            out_specs=pl.BlockSpec((1, block, td), lambda k, j, i, idx, sc: (k, 0, j)),
            scratch_shapes=[pltpu.VMEM((block, td), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((rb, block, dp), G.dtype),
        interpret=interpret,
        name="block_gather_matmul_dw",
    )(block_idx, scales.astype(jnp.float32), G, X)
    return out[:, :, :din]


# ---------------------------------------------------------------------------
# One-pass fused backward: dX, compact dW and compact db from a single
# stream of G's kept column-blocks.
# ---------------------------------------------------------------------------


def _fused_kernel(idx_ref, scale_ref, g_ref, w_ref, x_ref,
                  o_dx, o_dw, o_db, acc_dx, acc_dw, acc_db,
                  *, n_i: int, n_k: int, n_j: int, td: int):
    i, k, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    # one scaled G tile feeds both MXU products and the db reduction
    g = g_ref[...].astype(jnp.float32) * scale_ref[k]

    @pl.when(jnp.logical_and(i == 0, jnp.logical_and(k == 0, j == 0)))
    def _():
        acc_dw[...] = jnp.zeros_like(acc_dw)
        acc_db[...] = jnp.zeros_like(acc_db)

    @pl.when(jnp.logical_and(k == 0, j == 0))
    def _():
        acc_dx[...] = jnp.zeros_like(acc_dx)

    jsl = pl.ds(j * td, td)
    acc_dx[:, jsl] += jax.lax.dot(g, w_ref[...].astype(jnp.float32),
                                  preferred_element_type=jnp.float32)
    acc_dw[k, :, jsl] += jax.lax.dot_general(
        g, x_ref[...].astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _():
        acc_db[k, :] += jnp.sum(g, axis=0)

    @pl.when(k == n_k - 1)
    def _():
        o_dx[:, jsl] = acc_dx[:, jsl].astype(o_dx.dtype)

    @pl.when(jnp.logical_and(i == n_i - 1,
                             jnp.logical_and(k == n_k - 1, j == n_j - 1)))
    def _():
        o_dw[...] = acc_dw[...].astype(o_dw.dtype)
        o_db[...] = acc_db[...]


@functools.partial(jax.jit, static_argnames=("block", "tile_n", "tile_d", "interpret"))
def block_gather_matmul_fused(G, block_idx, scales, W, X, *, block: int = 128,
                              tile_n: int = 256, tile_d: int = 256,
                              interpret: bool = False):
    """Fused one-pass backward for a block-sketched linear site.

        dX     = Σ_k scale_k · G[:, blk_k] @ W[blk_k, :]      [N, d]
        dWc[k] = scale_k · G[:, blk_k]ᵀ @ X                   [rb, block, d]
        db_c[k] = scale_k · Σ_rows G[:, blk_k]                [rb, block] f32

    G: [N, n]; block_idx: [rb] int32; scales: [rb] f32; W: [n, d]; X: [N, d].
    Each kept G column-block is DMA'd into VMEM exactly once per row tile —
    the G index map is constant over the inner d-tile sweep, so the whole
    backward makes ONE HBM pass over the kept part of G (vs one per output
    per d-tile for the unfused pair). The price is residency: the f32
    accumulators for a [tn, d] dX row panel and the full [rb·block, d]
    compact dW live in VMEM for the whole call — see ``fused_vmem_bytes``;
    the ops dispatcher falls back to the unfused pair when it doesn't fit.

    Accumulation order (ascending k for dX, ascending row tiles for dWc,
    scaled-G operands) matches ``block_gather_matmul`` /
    ``block_gather_matmul_dw`` exactly, so fused and unfused are
    bit-identical for the same plan.
    """
    N, n = G.shape
    d = W.shape[1]
    assert X.shape[1] == d, (X.shape, W.shape)
    rb = block_idx.shape[0]
    tn = min(tile_n, max(8, N))
    td = min(tile_d, d)
    Np = -(-N // tn) * tn
    dp = -(-d // td) * td
    if Np != N:
        G = jnp.pad(G, ((0, Np - N), (0, 0)))
        X = jnp.pad(X, ((0, Np - N), (0, 0)))
    if dp != d:
        W = jnp.pad(W, ((0, 0), (0, dp - d)))
        X = jnp.pad(X, ((0, 0), (0, dp - d)))

    n_i, n_j = Np // tn, dp // td
    grid = (n_i, rb, n_j)
    dX, dWc, db = pl.pallas_call(
        functools.partial(_fused_kernel, n_i=n_i, n_k=rb, n_j=n_j, td=td),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tn, block), lambda i, k, j, idx, sc: (i, idx[k])),
                pl.BlockSpec((block, td), lambda i, k, j, idx, sc: (idx[k], j)),
                pl.BlockSpec((tn, td), lambda i, k, j, idx, sc: (i, j)),
            ],
            out_specs=[
                pl.BlockSpec((tn, dp), lambda i, k, j, idx, sc: (i, 0)),
                pl.BlockSpec((rb, block, dp), lambda i, k, j, idx, sc: (0, 0, 0)),
                pl.BlockSpec((rb, block), lambda i, k, j, idx, sc: (0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((tn, dp), jnp.float32),
                pltpu.VMEM((rb, block, dp), jnp.float32),
                pltpu.VMEM((rb, block), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((Np, dp), G.dtype),
            jax.ShapeDtypeStruct((rb, block, dp), G.dtype),
            jax.ShapeDtypeStruct((rb, block), jnp.float32),
        ],
        interpret=interpret,
        name="block_gather_matmul_fused",
    )(block_idx, scales.astype(jnp.float32), G, W, X)
    return dX[:N, :d], dWc[:, :, :d], db


def fused_vmem_bytes(N: int, d: int, rb: int, block: int, itemsize: int,
                     tile_n: int = 256, tile_d: int = 256) -> int:
    """VMEM residency estimate for ``block_gather_matmul_fused`` (bytes).

    f32 accumulators + output buffers + double-buffered input tiles."""
    tn = min(tile_n, max(8, N))
    td = min(tile_d, d)
    dp = -(-d // td) * td
    acc = 4 * (tn * dp + rb * block * dp + rb * block)
    outs = itemsize * (tn * dp + rb * block * dp) + 4 * rb * block
    tiles = 2 * itemsize * (tn * block + block * td + tn * td)
    return acc + outs + tiles
