"""Jitted wrappers around the Pallas kernels with backend selection.

On TPU the real kernels run; on CPU (this container) they run in
``interpret=True`` mode — the kernel bodies execute in Python per grid step,
which validates correctness but is slow, so wrappers fall back to the jnp
oracle unless ``REPRO_FORCE_INTERPRET=1`` (tests set it or pass explicitly).

The fused kernels keep f32 accumulators resident in VMEM; when the estimate
(``fused_vmem_bytes`` / ``stream_vmem_bytes``) exceeds the VMEM limit the
dispatch drops to the one-gather XLA fallback. The limit is configurable —
``ExecutionConfig(fused_vmem_limit=...)`` or ``REPRO_FUSED_VMEM_LIMIT`` —
and every resolution + fallback decision is recorded through the bound
``repro.obs`` metrics registry (see :func:`configure`).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.col_scores import col_l1_scores as _col_l1_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.sketch_matmul import (block_gather_matmul as _bgm_pallas,
                                         block_gather_matmul_dw as _bgm_dw_pallas,
                                         block_gather_matmul_fused as _bgm_fused_pallas,
                                         block_stream_matmul_fused as _bgm_stream_pallas,
                                         fused_vmem_bytes, stream_vmem_bytes)

__all__ = ["on_tpu", "block_gather_matmul", "block_gather_matmul_dw",
           "block_gather_matmul_fused", "block_stream_matmul_fused",
           "gather_cols_matmul", "gather_cols_matmul_dw", "col_l1_scores",
           "flash_attention", "fused_vmem_limit", "configure"]

# Leave headroom below the ~16 MiB/core VMEM budget for the fused kernels'
# resident accumulators (dX row panel + full compact dW). This default can
# be overridden without code edits: configure(vmem_limit=...) — plumbed from
# ExecutionConfig.fused_vmem_limit — wins, then REPRO_FUSED_VMEM_LIMIT.
_FUSED_VMEM_LIMIT = 12 * 2 ** 20

# process-wide overrides/bindings installed by configure()
_VMEM_LIMIT_OVERRIDE = None
_METRICS = None


def configure(*, vmem_limit=None, metrics=None) -> None:
    """Install process-wide kernel-dispatch bindings.

    ``vmem_limit``: override the fused-kernel VMEM budget (bytes; None keeps
    the current override). ``metrics``: a ``repro.obs`` MetricsRegistry that
    dispatch decisions are recorded into (``kernels.fused_vmem_limit`` gauge,
    ``kernels.fused_dispatch`` / ``kernels.fused_fallback`` counters).
    Runtime wires both from its ExecutionConfig; the env var
    ``REPRO_FUSED_VMEM_LIMIT`` covers scripts that never build a Runtime."""
    global _VMEM_LIMIT_OVERRIDE, _METRICS
    if vmem_limit is not None:
        if vmem_limit <= 0:
            raise ValueError(f"vmem_limit must be > 0, got {vmem_limit}")
        _VMEM_LIMIT_OVERRIDE = int(vmem_limit)
    if metrics is not None:
        _METRICS = metrics
    if _METRICS is not None:
        _METRICS.gauge("kernels.fused_vmem_limit").set(fused_vmem_limit())


def fused_vmem_limit() -> int:
    """The effective VMEM budget for the fused backward kernels (bytes):
    configure()/ExecutionConfig override > REPRO_FUSED_VMEM_LIMIT env >
    the built-in default."""
    if _VMEM_LIMIT_OVERRIDE is not None:
        return _VMEM_LIMIT_OVERRIDE
    env = os.environ.get("REPRO_FUSED_VMEM_LIMIT")
    if env:
        try:
            v = int(env)
        except ValueError as e:
            raise ValueError(
                f"REPRO_FUSED_VMEM_LIMIT must be an int (bytes), got {env!r}"
            ) from e
        if v > 0:
            return v
    return _FUSED_VMEM_LIMIT


def _record_dispatch(kernel: str, fits: bool) -> None:
    if _METRICS is None:
        return
    _METRICS.counter(f"kernels.{kernel}.dispatch").inc()
    if not fits:
        _METRICS.counter(f"kernels.{kernel}.vmem_fallback").inc()


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas() -> bool:
    return on_tpu() or os.environ.get("REPRO_FORCE_INTERPRET") == "1"


def block_gather_matmul(G, block_idx, scales, W, *, block: int = 128):
    if _use_pallas():
        return _bgm_pallas(G, block_idx, scales, W, block=block, interpret=not on_tpu())
    return kref.block_gather_matmul_ref(G, block_idx, scales, W, block=block)


def block_gather_matmul_dw(G, block_idx, scales, X, *, block: int = 128):
    if _use_pallas():
        return _bgm_dw_pallas(G, block_idx, scales, X, block=block, interpret=not on_tpu())
    return kref.block_gather_matmul_dw_ref(G, block_idx, scales, X, block=block)


def block_gather_matmul_fused(G, block_idx, scales, W, X, *, block: int = 128,
                              with_scores: bool = False,
                              score_mode: str = "l1"):
    """One-pass fused backward (dX, compact dW, compact db); see
    ``sketch_matmul.block_gather_matmul_fused``. When the fused accumulators
    would not fit VMEM (on TPU), falls back to
    ``ref.block_gather_matmul_fallback_ref``: ONE barriered XLA gather of
    kept G feeds the dX matmul and a single dW matmul with the db
    row-reduction folded into its stream (ones column on X) — still one pass
    over kept G, just without the Pallas kernel's resident accumulators.
    Off-TPU the single-gather fused XLA oracle runs directly.

    ``with_scores=True`` appends the kept blocks' raw column score reduction
    ([rb, block] f32) on every path — the stale-plan estimator's free
    partial refresh."""
    if _use_pallas():
        rb = block_idx.shape[0]
        fits = fused_vmem_bytes(G.shape[0], W.shape[1], rb, block,
                                jnp.dtype(G.dtype).itemsize) <= fused_vmem_limit()
        _record_dispatch("fused", fits)
        if fits or not on_tpu():
            return _bgm_fused_pallas(G, block_idx, scales, W, X, block=block,
                                     interpret=not on_tpu(),
                                     with_scores=with_scores,
                                     score_mode=score_mode)
        return kref.block_gather_matmul_fallback_ref(
            G, block_idx, scales, W, X, block=block,
            with_scores=with_scores, score_mode=score_mode)
    return kref.block_gather_matmul_fused_ref(
        G, block_idx, scales, W, X, block=block,
        with_scores=with_scores, score_mode=score_mode)


def block_stream_matmul_fused(G, block_idx, scales, W, X, *, block: int = 128,
                              score_mode: str = "l1"):
    """Streaming one-pass backward over ALL of G: (dX, compact dW, compact
    db, fresh scores [n]) — score/selection/matmuls in one sweep; see
    ``sketch_matmul.block_stream_matmul_fused``. The plan (kept block ids +
    1/p scales, sampled OUTSIDE from carried scores — no G read) arrives as
    ``block_idx``/``scales`` and is expanded to per-block gates here. When
    the streaming accumulators would not fit VMEM (on TPU), or off-TPU,
    falls back to ``ref.block_stream_matmul_onepass_ref``: ONE barriered
    permuted gather of ALL of G (kept blocks first) feeds the same outputs
    with a single G reader."""
    rb = block_idx.shape[0]
    nb = G.shape[1] // block
    if _use_pallas():
        fits = stream_vmem_bytes(G.shape[0], W.shape[1], rb, nb, block,
                                 jnp.dtype(G.dtype).itemsize) <= fused_vmem_limit()
        _record_dispatch("stream", fits)
        if fits or not on_tpu():
            gates = jnp.zeros((nb,), jnp.float32).at[block_idx].set(
                scales.astype(jnp.float32))
            slot_map = jnp.zeros((nb,), jnp.int32).at[block_idx].set(
                jnp.arange(rb, dtype=jnp.int32))
            return _bgm_stream_pallas(G, gates, slot_map, W, X, rb=rb,
                                      block=block, score_mode=score_mode,
                                      interpret=not on_tpu())
    return kref.block_stream_matmul_onepass_ref(G, block_idx, scales, W, X,
                                                block=block,
                                                score_mode=score_mode)


def gather_cols_matmul(G, idx, scales, W):
    """Per-column compact dX. Arbitrary (unblocked) column gathers do not map
    onto BlockSpec index maps, so this stays an XLA gather + matmul; the
    Pallas fast path is the block-granular variant (SketchConfig.block=128)."""
    return kref.gather_cols_matmul_ref(G, idx, scales, W)


def gather_cols_matmul_dw(G, idx, scales, X):
    return kref.gather_cols_matmul_dw_ref(G, idx, scales, X)


def col_l1_scores(G, *, mode: str = "l1"):
    if mode not in kref.COL_SCORE_MODES:
        raise ValueError(f"unknown score mode {mode!r}; "
                         f"expected one of {sorted(kref.COL_SCORE_MODES)}")
    if _use_pallas():
        return _col_l1_pallas(G, mode=mode, interpret=not on_tpu())
    return kref.col_scores_ref(G, mode=mode)


def flash_attention(q, k, v, *, causal: bool = True, window=None):
    if _use_pallas():
        return _flash_pallas(q, k, v, causal=causal, window=window, interpret=not on_tpu())
    return kref.flash_attention_ref(q, k, v, causal=causal, window=window)
