"""Jitted wrappers around the Pallas kernels with backend selection.

On TPU the real kernels run; on CPU (this container) they run in
``interpret=True`` mode — the kernel bodies execute in Python per grid step,
which validates correctness but is slow, so wrappers fall back to the jnp
oracle unless ``REPRO_FORCE_INTERPRET=1`` (tests set it or pass explicitly).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.col_scores import col_l1_scores as _col_l1_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.sketch_matmul import (block_gather_matmul as _bgm_pallas,
                                         block_gather_matmul_dw as _bgm_dw_pallas,
                                         block_gather_matmul_fused as _bgm_fused_pallas,
                                         fused_vmem_bytes)

__all__ = ["on_tpu", "block_gather_matmul", "block_gather_matmul_dw",
           "block_gather_matmul_fused",
           "gather_cols_matmul", "gather_cols_matmul_dw", "col_l1_scores",
           "flash_attention"]

# Leave headroom below the ~16 MiB/core VMEM budget for the fused kernel's
# resident accumulators (dX row panel + full compact dW).
_FUSED_VMEM_LIMIT = 12 * 2 ** 20


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas() -> bool:
    return on_tpu() or os.environ.get("REPRO_FORCE_INTERPRET") == "1"


def block_gather_matmul(G, block_idx, scales, W, *, block: int = 128):
    if _use_pallas():
        return _bgm_pallas(G, block_idx, scales, W, block=block, interpret=not on_tpu())
    return kref.block_gather_matmul_ref(G, block_idx, scales, W, block=block)


def block_gather_matmul_dw(G, block_idx, scales, X, *, block: int = 128):
    if _use_pallas():
        return _bgm_dw_pallas(G, block_idx, scales, X, block=block, interpret=not on_tpu())
    return kref.block_gather_matmul_dw_ref(G, block_idx, scales, X, block=block)


def block_gather_matmul_fused(G, block_idx, scales, W, X, *, block: int = 128):
    """One-pass fused backward (dX, compact dW, compact db); see
    ``sketch_matmul.block_gather_matmul_fused``. When the fused accumulators
    would not fit VMEM (on TPU), falls back to
    ``ref.block_gather_matmul_fallback_ref``: ONE barriered XLA gather of
    kept G feeds the dX matmul and a single dW matmul with the db
    row-reduction folded into its stream (ones column on X) — still one pass
    over kept G, just without the Pallas kernel's resident accumulators.
    Off-TPU the single-gather fused XLA oracle runs directly."""
    if _use_pallas():
        rb = block_idx.shape[0]
        fits = fused_vmem_bytes(G.shape[0], W.shape[1], rb, block,
                                jnp.dtype(G.dtype).itemsize) <= _FUSED_VMEM_LIMIT
        if fits or not on_tpu():
            return _bgm_fused_pallas(G, block_idx, scales, W, X, block=block,
                                     interpret=not on_tpu())
        return kref.block_gather_matmul_fallback_ref(G, block_idx, scales, W, X,
                                                     block=block)
    return kref.block_gather_matmul_fused_ref(G, block_idx, scales, W, X, block=block)


def gather_cols_matmul(G, idx, scales, W):
    """Per-column compact dX. Arbitrary (unblocked) column gathers do not map
    onto BlockSpec index maps, so this stays an XLA gather + matmul; the
    Pallas fast path is the block-granular variant (SketchConfig.block=128)."""
    return kref.gather_cols_matmul_ref(G, idx, scales, W)


def gather_cols_matmul_dw(G, idx, scales, X):
    return kref.gather_cols_matmul_dw_ref(G, idx, scales, X)


def col_l1_scores(G, *, mode: str = "l1"):
    if _use_pallas():
        return _col_l1_pallas(G, mode=mode, interpret=not on_tpu())
    if mode == "l1":
        return kref.col_l1_scores_ref(G)
    return jnp.sum(jnp.square(G.astype(jnp.float32)), axis=0)


def flash_attention(q, k, v, *, causal: bool = True, window=None):
    if _use_pallas():
        return _flash_pallas(q, k, v, causal=causal, window=window, interpret=not on_tpu())
    return kref.flash_attention_ref(q, k, v, causal=causal, window=window)
