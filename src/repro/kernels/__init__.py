"""Pallas TPU kernels (block-sketched backward matmuls, column scores, flash
attention) + jnp oracles. See EXAMPLE.md for the kernel/ops/ref convention."""
from repro.kernels import ops, ref  # noqa: F401
