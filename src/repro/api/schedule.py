"""Budget schedules: which sketch budget runs at which point in training.

The paper trades gradient variance against backward cost (§4's
epochs-vs-cost curves) and App. B.1 shows the knob can move *during* a run:
warm up exact then anneal to a sketched backward, or drop the budget
reactively when a straggler slows the step. Unbiasedness (§2.2) is what makes
all of this safe — switching budgets mid-run never biases the gradient, only
its variance.

:class:`BudgetSchedule` makes those schedules first-class. It is
piecewise-constant in the step index and realised as *pre-compiled buckets*:
every distinct budget value in the schedule gets one compiled train step up
front (``Runtime.train`` builds them before the loop), and the loop just
switches between executables — no mid-run recompiles. This subsumes the old
``train/straggler.py`` bucket machinery: reactive (straggler) mode is a
schedule whose bucket choice comes from measured step times instead of the
step index, via the same :class:`StragglerController` that module now
re-exports.

Controller-driven modes share one :class:`Controller` protocol: the trainer
calls ``step_begin()`` before and ``step_end(metrics)`` after each step and
reads ``.budget`` for the next bucket. Two implementations ship:
:class:`StragglerController` (reactive — measured step times, paper
App. B.1) and :class:`~repro.telemetry.controller.AdaptiveBudgetController`
(closed-loop — probe-measured gradient SNR, ``BudgetSchedule.adaptive``; see
docs/telemetry.md).

Budget values:
  * ``None``  — exact backprop (no sketching at all);
  * ``1.0``   — the policy as configured (its own per-site budgets);
  * ``0<b<1`` — the policy with every site's budget overridden to ``b``
    (``SketchPolicy.with_budget``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence, Tuple

from repro.obs import clock

__all__ = ["BudgetSchedule", "Controller", "StragglerController"]

Budget = Optional[float]  # None = exact; 1.0 = policy as configured


def _check_budget(b: Budget):
    if b is not None and not (0.0 < b <= 1.0):
        raise ValueError(f"budget must be None (exact) or in (0, 1], got {b}")


def _dedupe_points(points) -> Tuple[Tuple[int, Budget], ...]:
    """Collapse points landing on the same step (later budget wins) so
    degenerate constructor inputs yield a valid ascending schedule."""
    by_step = {}
    for s, b in points:
        by_step[int(s)] = b
    return tuple(sorted(by_step.items()))


@dataclasses.dataclass(frozen=True)
class BudgetSchedule:
    """Piecewise-constant budget-vs-step schedule, or a controller-driven
    (reactive / adaptive) bucket set.

    Attributes:
      points: ``((step, budget), ...)`` with strictly ascending non-negative
        steps; the budget before the first point is ``1.0`` (policy as
        configured). Empty = constant ``1.0``.
      reactive: descending budget buckets for straggler mitigation (paper
        App. B.1); index 0 is the full backward. Non-empty ``reactive``
        switches the schedule to reactive mode: the budget for each step
        comes from a :class:`StragglerController` watching measured step
        times.
      adaptive_budgets: budget buckets for the closed-loop SNR controller
        (``BudgetSchedule.adaptive``), ordered highest-fidelity first /
        cheapest last; requires ``target_snr``. The per-step bucket comes
        from an :class:`~repro.telemetry.controller
        .AdaptiveBudgetController` consuming the telemetry probe summary.
      target_snr: gradient-SNR floor for adaptive mode (see
        docs/telemetry.md for the exact statistic).
      window / slow_factor / fast_factor / target_step_s: controller tuning
        (``window`` is shared by both controller modes).

      ``points`` / ``reactive`` / ``adaptive_budgets`` are mutually
      exclusive.
    """

    points: Tuple[Tuple[int, Budget], ...] = ()
    reactive: Tuple[Budget, ...] = ()
    adaptive_budgets: Tuple[Budget, ...] = ()
    target_snr: Optional[float] = None
    window: int = 8
    slow_factor: float = 1.3
    fast_factor: float = 1.05
    target_step_s: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "points",
                           tuple((int(s), b) for s, b in self.points))
        object.__setattr__(self, "reactive", tuple(self.reactive))
        object.__setattr__(self, "adaptive_budgets",
                           tuple(self.adaptive_budgets))
        modes = [bool(self.points), bool(self.reactive),
                 bool(self.adaptive_budgets)]
        if sum(modes) > 1:
            raise ValueError("points, reactive and adaptive_budgets are "
                             "mutually exclusive")
        last = -1
        for s, b in self.points:
            if s <= last:
                raise ValueError(f"schedule steps must ascend, got {self.points}")
            last = s
            _check_budget(b)
        for b in self.reactive:
            _check_budget(b)
        prev = None
        for b in self.adaptive_budgets:
            _check_budget(b)
            eff = float("inf") if b is None else b
            if prev is not None and eff >= prev:
                raise ValueError("adaptive buckets must strictly descend "
                                 "(highest fidelity first, cheapest last), "
                                 f"got {self.adaptive_budgets}")
            prev = eff
        if self.adaptive_budgets and not (self.target_snr or 0) > 0:
            raise ValueError("adaptive schedule needs target_snr > 0")
        if self.target_snr is not None and not self.adaptive_budgets:
            raise ValueError("target_snr only applies to adaptive schedules")

    # -- constructors -------------------------------------------------------

    @classmethod
    def constant(cls, budget: Budget = 1.0) -> "BudgetSchedule":
        """One budget for the whole run (the default is the policy itself)."""
        _check_budget(budget)
        return cls(points=((0, budget),))

    @classmethod
    def warmup_exact(cls, exact_steps: int, budget: Budget = 1.0) -> "BudgetSchedule":
        """Paper App. B.1: exact backward for ``exact_steps``, then sketched
        (``exact_steps=0`` degrades to a constant schedule)."""
        return cls(points=_dedupe_points(((0, None), (int(exact_steps), budget))))

    @classmethod
    def piecewise(cls, *points: Tuple[int, Budget]) -> "BudgetSchedule":
        return cls(points=tuple(points))

    @classmethod
    def anneal(cls, steps: int, *, start: float = 1.0, end: float = 0.1,
               n_buckets: int = 4) -> "BudgetSchedule":
        """Geometric budget anneal ``start -> end`` over ``steps`` steps in
        ``n_buckets`` piecewise-constant stages (each stage = one compiled
        bucket; short runs collapse colliding stages, keeping the later
        budget)."""
        if n_buckets < 2:
            raise ValueError("anneal needs n_buckets >= 2")
        pts = []
        for i in range(n_buckets):
            frac = i / (n_buckets - 1)
            b = float(start * (end / start) ** frac)
            pts.append((int(round(steps * i / n_buckets)), min(1.0, b)))
        return cls(points=_dedupe_points(pts))

    @classmethod
    def straggler(cls, budgets: Sequence[Budget] = (1.0, 0.5, 0.2, 0.1, 0.05),
                  *, window: int = 8, slow_factor: float = 1.3,
                  fast_factor: float = 1.05,
                  target_step_s: Optional[float] = None) -> "BudgetSchedule":
        """Reactive straggler mitigation over pre-compiled budget buckets."""
        return cls(reactive=tuple(budgets), window=window,
                   slow_factor=slow_factor, fast_factor=fast_factor,
                   target_step_s=target_step_s)

    @classmethod
    def adaptive(cls, target_snr: float,
                 budgets: Sequence[Budget] = (1.0, 0.5, 0.2, 0.1),
                 *, window: int = 4) -> "BudgetSchedule":
        """Closed-loop schedule: each step runs the cheapest pre-compiled
        bucket whose probe-predicted gradient SNR meets ``target_snr``
        (docs/telemetry.md). ``budgets`` must descend (highest fidelity
        first); the controller re-evaluates every ``window`` steps and moves
        one bucket at a time. Requires telemetry probes — ``Runtime.train``
        enables them automatically for adaptive schedules."""
        return cls(adaptive_budgets=tuple(budgets),
                   target_snr=float(target_snr), window=window)

    # -- queries ------------------------------------------------------------

    @property
    def is_reactive(self) -> bool:
        return bool(self.reactive)

    @property
    def is_adaptive(self) -> bool:
        return bool(self.adaptive_budgets)

    def buckets(self) -> Tuple[Budget, ...]:
        """Distinct budget values to pre-compile, in first-use order
        (including the implicit ``1.0`` that runs before a late first
        point)."""
        if self.reactive:
            return tuple(dict.fromkeys(self.reactive))
        if self.adaptive_budgets:
            return tuple(dict.fromkeys(self.adaptive_budgets))
        if not self.points:
            return (1.0,)
        lead = () if self.points[0][0] == 0 else (1.0,)
        return tuple(dict.fromkeys(lead + tuple(b for _, b in self.points)))

    def budget_at(self, step: int) -> Budget:
        """Budget for ``step`` (non-controller schedules)."""
        if self.reactive or self.adaptive_budgets:
            raise ValueError("controller-driven schedule: use make_controller()")
        b: Budget = 1.0
        for s, pb in self.points:
            if step >= s:
                b = pb
            else:
                break
        return b

    def make_controller(self, policy=None) -> Optional["Controller"]:
        """The per-step bucket controller, or None for step-indexed
        schedules. ``policy`` (a SketchPolicy) lets adaptive mode map the
        ``1.0`` bucket onto the policy's own base budget for its SNR
        scaling law."""
        if self.reactive:
            return StragglerController(self.reactive, window=self.window,
                                       slow_factor=self.slow_factor,
                                       fast_factor=self.fast_factor,
                                       target_step_s=self.target_step_s)
        if self.adaptive_budgets:
            from repro.telemetry.controller import AdaptiveBudgetController

            base = getattr(getattr(policy, "base", None), "budget", None)
            # Mapping the 1.0 bucket onto the policy's own base budget can
            # break the descending-fidelity contract (e.g. a policy at 0.2
            # with buckets (1.0, 0.5, 0.2, 0.1) -> effective (0.2, 0.5,
            # 0.2, 0.1)). Re-sort by effective fidelity (stable, so the
            # earlier-listed bucket wins a tie) and dedupe, so every bucket
            # the user listed stays reachable — including ones ABOVE the
            # policy's configured budget — and "later = cheaper" holds.
            pairs = []
            for b in self.adaptive_budgets:
                eff = (base if (b is not None and b >= 1.0 and base is not None)
                       else b)
                pairs.append((float("inf") if eff is None else eff, b, eff))
            pairs.sort(key=lambda p: -p[0])
            budgets, effective = [], []
            for feff, b, eff in pairs:
                if effective and feff == (float("inf") if effective[-1] is None
                                          else effective[-1]):
                    continue  # duplicate fidelity: keep the first
                budgets.append(b)
                effective.append(eff)
            return AdaptiveBudgetController(tuple(budgets), self.target_snr,
                                            effective=tuple(effective),
                                            window=self.window)
        return None


class Controller:
    """Protocol for per-step budget-bucket controllers.

    The trainer calls ``step_begin()`` before launching a step, reads
    ``.budget`` to pick the pre-compiled bucket, and calls
    ``step_end(metrics)`` after the step completes — ``metrics`` is the
    host-fetched step metrics dict when ``wants_metrics`` is True, else
    None. ``budget`` must always be one of the schedule's ``buckets()``:
    controllers select among pre-compiled executables, they never cause a
    recompile.
    """

    wants_metrics = False  # True -> trainer device_gets metrics every step

    @property
    def budget(self):
        raise NotImplementedError

    def step_begin(self):  # noqa: B027 — optional hook
        pass

    def step_end(self, metrics=None):
        return self.budget


class StragglerController(Controller):
    """Reactive sketch-budget bucket switching (paper App. B.1).

    The paper observes that VJP approximation can be applied *selectively at
    slow compute nodes*. Under SPMD every device must run the same program, so
    the idea is applied step-wise: the trainer keeps a small set of
    pre-compiled train steps at different sketch budgets (the
    :class:`BudgetSchedule` buckets); this controller watches recent step
    times and drops to a cheaper backward when the measured step time exceeds
    the target (a slow host, a thermally-throttled chip, contention),
    recovering when times normalise.
    """

    def __init__(self, budgets=(1.0, 0.5, 0.2, 0.1, 0.05), *, window: int = 8,
                 slow_factor: float = 1.3, fast_factor: float = 1.05,
                 target_step_s: float | None = None):
        """budgets must be sorted descending; index 0 = full backward."""
        self.budgets = tuple(budgets)
        self.level = 0
        self.window = window
        self.slow = slow_factor
        self.fast = fast_factor
        self.target = target_step_s
        self._times = deque(maxlen=window)
        self._t0 = None

    @property
    def budget(self) -> float:
        return self.budgets[self.level]

    def step_begin(self):
        self._t0 = clock.now()

    def step_end(self, metrics=None):
        if self._t0 is None:
            return self.budget
        dt = clock.now() - self._t0
        self._times.append(dt)
        if self.target is None and len(self._times) == self.window and self.level == 0:
            # calibrate the target from the first full window at full budget
            self.target = sorted(self._times)[self.window // 2]
        if self.target is None or len(self._times) < 3:
            return self.budget
        med = sorted(self._times)[len(self._times) // 2]
        if med > self.slow * self.target and self.level + 1 < len(self.budgets):
            self.level += 1
            self._times.clear()
        elif med < self.fast * self.target and self.level > 0:
            self.level -= 1
            self._times.clear()
        return self.budget

    def observe(self, dt: float):
        """Test hook: feed an externally measured step time."""
        self._t0 = clock.now() - dt
        return self.step_end()
