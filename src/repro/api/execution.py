"""Execution configuration: where and how compiled steps run.

Everything that used to ride as seven loose kwargs on ``train()`` /
``make_train_step`` (mesh, activation sharding, axis names, TP-local
sketching, compact gradients, gradient accumulation) lives in one frozen,
hashable object. ``ExecutionConfig`` is the *only* sanctioned factory for
``nn.common.Ctx`` outside the nn substrate itself — ``tests/test_compat.py``
greps for stray ``Ctx(...)`` construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

__all__ = ["ExecutionConfig"]


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """Static execution environment of one Runtime (hashable; safe to key
    jit caches on).

    Attributes:
      mesh: ``jax.sharding.Mesh`` for distributed runs (None = single device).
      act_sharding: NamedSharding constraint pinned on [B, S, d] activations.
      data_axes / model_axes: mesh axis names carrying DP and TP/EP shards.
      tp_sketch: TP-local compact sketching with compressed DP gradient
        collectives — sites resolve onto the tp_column/tp_row execution
        plans of the one sketched-site spine (core/site.py; see
        :meth:`site_spec`).
      compact_grads: keep sketched dW compact (rows + indices) from the
        backward through clipping into sparse-row optimizer updates
        (core/compact_grad.py; requires ``accum == 1``).
      accum: gradient-accumulation microbatch count.
      cost_mode: python-unrolled loops for HLO cost artifacts (dry-run).
      telemetry: a :class:`repro.telemetry.TelemetryConfig` enabling the
        in-graph probes (per-site VJP-variance estimates emitted as a side
        output of the train step) and naming optional sinks; ``None`` (the
        default) disables telemetry entirely. See docs/telemetry.md.
      resilience: a :class:`repro.resilience.ResilienceConfig` enabling the
        fault-handling plumbing: the compiled step takes a traced
        ``fault_scale`` operand (fault injection without recompiles) and,
        with ``sentinel=True``, gates the optimizer update on an in-graph
        non-finite/norm-explosion flag — bit-identical training when the
        sentinel never trips. ``None`` (the default) compiles the plain
        three-argument step. See docs/resilience.md.
      obs: a :class:`repro.obs.ObsConfig` enabling execution observability:
        wall-clock spans on the train/serve/recovery hot paths, the unified
        metrics registry, compile/memory ledgers on steps built through
        ``Runtime.train_step``, and the flight recorder's crash bundles.
        Purely host-side — the compiled computation is untouched, so
        training stays bit-identical with obs on or off. ``None`` (the
        default) disables it entirely (null tracer, zero allocation on the
        step path). See docs/observability.md.
      fused_vmem_limit: VMEM budget (bytes) for the fused/streaming Pallas
        backward kernels' resident accumulators — above it the dispatch
        drops to the one-gather XLA fallback (``repro.kernels.ops``).
        ``None`` (the default) defers to the ``REPRO_FUSED_VMEM_LIMIT`` env
        var, then the built-in ~12 MiB headroom default. Steps built from
        this config bind the value (and the obs metrics registry, which
        records every dispatch/fallback decision) via
        ``kernels.ops.configure``. See docs/perf.md.
    """

    mesh: Optional[Any] = None
    act_sharding: Optional[Any] = None
    data_axes: Tuple[str, ...] = ("data",)
    model_axes: Tuple[str, ...] = ("model",)
    tp_sketch: bool = False
    compact_grads: bool = False
    accum: int = 1
    cost_mode: bool = False
    telemetry: Optional[Any] = None  # repro.telemetry.TelemetryConfig
    resilience: Optional[Any] = None  # repro.resilience.ResilienceConfig
    obs: Optional[Any] = None  # repro.obs.ObsConfig
    fused_vmem_limit: Optional[int] = None  # bytes; kernels.ops.configure

    def __post_init__(self):
        object.__setattr__(self, "data_axes", tuple(self.data_axes))
        object.__setattr__(self, "model_axes", tuple(self.model_axes))
        if self.accum < 1:
            raise ValueError(f"accum must be >= 1, got {self.accum}")
        if self.compact_grads and self.accum != 1:
            raise ValueError("compact_grads requires accum == 1 (compact index "
                             "sets differ per microbatch; accumulate densely)")
        if (self.telemetry is not None and self.telemetry.probes
                and self.accum != 1):
            raise ValueError("telemetry probes require accum == 1 (probe slot "
                             "cotangents would silently average across "
                             "microbatch plans); use TelemetryConfig("
                             "probes=False) with accumulation")
        if self.resilience is not None and not hasattr(self.resilience,
                                                       "sentinel"):
            raise ValueError("resilience must be a repro.resilience."
                             f"ResilienceConfig, got {self.resilience!r}")
        if self.obs is not None and not hasattr(self.obs, "trace_capacity"):
            raise ValueError("obs must be a repro.obs.ObsConfig, got "
                             f"{self.obs!r}")
        if self.fused_vmem_limit is not None:
            if (not isinstance(self.fused_vmem_limit, int)
                    or self.fused_vmem_limit <= 0):
                raise ValueError("fused_vmem_limit must be a positive int "
                                 f"(bytes), got {self.fused_vmem_limit!r}")

    def site_spec(self, role: str, cfg, *, d_out: int, d_in: int,
                  has_bias: bool = False, x_ndim: int = 3):
        """Resolve one sketched-linear site against this execution
        environment to its declarative :class:`~repro.core.site.SiteSpec`
        (local / tp_column / tp_row plan, slot ranks, probe capability).
        This is the same memoized resolution ``nn.common.dense`` and the
        gslot/pslot builders consume — the one dispatch decision per site.
        """
        from repro.core.site import resolve_site

        return resolve_site(role, cfg, d_out=d_out, d_in=d_in,
                            has_bias=has_bias, x_ndim=x_ndim, mesh=self.mesh,
                            data_axes=self.data_axes,
                            model_axes=self.model_axes,
                            tp_sketch=self.tp_sketch)

    def make_ctx(self, *, policy=None, key=None, decode: bool = False,
                 cost_mode: Optional[bool] = None, layer_index: int = 0,
                 n_layers: int = 1):
        """Build the per-call :class:`~repro.nn.common.Ctx` this config
        describes (the one front door to Ctx outside ``repro/nn``)."""
        from repro.nn.common import Ctx

        return Ctx(policy=policy, key=key, layer_index=layer_index,
                   n_layers=n_layers, mesh=self.mesh,
                   model_axes=self.model_axes, data_axes=self.data_axes,
                   cost_mode=self.cost_mode if cost_mode is None else cost_mode,
                   decode=decode, act_sharding=self.act_sharding,
                   tp_sketch=self.tp_sketch)

    def replace(self, **kw) -> "ExecutionConfig":
        return dataclasses.replace(self, **kw)
