"""repro.api — the public front door.

One import gives the whole paper-reproduction surface:

  * :class:`Runtime` — bundles policy + execution + budget schedule; builds
    cached train steps, the training loop, and the serving engine.
  * :class:`ExecutionConfig` — mesh / sharding / TP-sketch / compact-grad /
    accumulation knobs, one hashable object.
  * :class:`BudgetSchedule` — budget-vs-step as pre-compiled buckets
    (warmup-exact, anneal, reactive straggler mitigation, and the
    closed-loop SNR-adaptive mode backed by telemetry probes).
  * :class:`TelemetryConfig` — in-graph probes + sinks switchboard
    (``ExecutionConfig.telemetry``; see docs/telemetry.md).
  * :class:`ServeConfig` — the continuous-batching serving surface
    (``Runtime.serve``): slot count, KV budget, paged-cache geometry,
    prefill buckets/packing, stop tokens (see docs/serving.md).
  * :class:`ResilienceConfig` / :class:`FaultPlan` / :class:`GradSentinel` /
    :class:`Supervisor` — the fault-handling layer (``ExecutionConfig.
    resilience``): in-graph gradient sentinel with exact-budget escalation,
    seeded fault injection, and checkpoint-rollback / elastic-remesh
    recovery (see docs/resilience.md).
  * :class:`ObsConfig` / :class:`Observability` — host-side execution
    observability (``ExecutionConfig.obs``): spans (Chrome-trace export),
    the unified metrics registry, compile/memory ledgers, and the flight
    recorder's crash bundles; ``Runtime.observability()`` is the accessor
    (see docs/observability.md).
  * :func:`register_estimator` — plug in new unbiased-VJP estimator families
    (RAD / BASIS-style) without touching core.
  * :class:`SiteSpec` / :class:`ExecutionPlan` / :func:`resolve_site` — the
    declarative per-site dispatch of the one sketched-site spine
    (``core/site.py``): which execution plan (local / tp_column / tp_row /
    tp_exact) a site's backward takes, whether it emits compact gradient
    rows, and whether it can probe.
  * :class:`SketchPolicy` / :class:`SketchConfig` — the paper's estimator
    placement and per-site configuration (re-exported from core).

Typical use::

    from repro import api

    rt = api.Runtime(policy=api.SketchPolicy(base=api.SketchConfig(
             method="l1", budget=0.2)))
    state, history = rt.train(cfg, opt, data, tcfg)

``tests/test_api_surface.py`` snapshots this module's exports — extending the
surface means updating the checked-in snapshot, so accidental breaks fail
loudly.
"""
from repro.api.execution import ExecutionConfig
from repro.api.runtime import Runtime
from repro.api.schedule import BudgetSchedule, Controller, StragglerController
from repro.core import SketchConfig, SketchPolicy
from repro.core.estimators import (Estimator, EstimatorVJP, get_estimator,
                                   register_estimator, registered_backends)
from repro.core.site import ExecutionPlan, SiteSpec, resolve_site
from repro.obs import Observability, ObsConfig
from repro.resilience import (FaultPlan, FaultSpec, GradSentinel,
                              ResilienceConfig, Supervisor)
from repro.serve.config import ServeConfig
from repro.telemetry import TelemetryConfig
from repro.telemetry.controller import AdaptiveBudgetController

__all__ = [
    "AdaptiveBudgetController",
    "BudgetSchedule",
    "Controller",
    "Estimator",
    "EstimatorVJP",
    "ExecutionConfig",
    "ExecutionPlan",
    "FaultPlan",
    "FaultSpec",
    "GradSentinel",
    "Observability",
    "ObsConfig",
    "ResilienceConfig",
    "Runtime",
    "ServeConfig",
    "SiteSpec",
    "SketchConfig",
    "SketchPolicy",
    "StragglerController",
    "Supervisor",
    "TelemetryConfig",
    "get_estimator",
    "register_estimator",
    "registered_backends",
    "resolve_site",
]
