"""The Runtime: one front door for sketched training, serving and dry-runs.

A :class:`Runtime` bundles the paper's three orthogonal knobs into one
frozen, hashable object:

  * **what** to estimate — :class:`~repro.core.policy.SketchPolicy`
    (which VJP sites get which unbiased estimator, resolved through the
    open estimator registry);
  * **where/how** to run — :class:`~repro.api.execution.ExecutionConfig`
    (mesh, shardings, TP-local sketching, compact gradients, accumulation);
  * **when** at which budget — :class:`~repro.api.schedule.BudgetSchedule`
    (piecewise-constant budget-vs-step, realised as pre-compiled buckets;
    reactive straggler mode).

Because the Runtime is hashable, compiled train steps are cached on it:
asking the same Runtime for the same (arch, optimizer, budget) step twice
returns the *same* jitted callable — one XLA compile per schedule bucket,
never one per call site. ``examples/``, ``benchmarks/``, ``launch/dryrun``
and ``serve/`` all consume this object; the legacy kwarg spellings on
``repro.train.trainer.train`` construct one internally (with a one-time
DeprecationWarning).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

from repro.api.execution import ExecutionConfig
from repro.api.schedule import BudgetSchedule
from repro.core import SketchPolicy

__all__ = ["Runtime"]

# Compiled-step cache: (runtime, cfg, opt, budget, donate, jitted) -> step fn.
# Module-level (not per-instance) so equal Runtimes share executables; the
# paired list records build keys for the recompile-count tests. LRU-bounded:
# Optimizer instances hash by the identity of their closures, so sweeps that
# rebuild optimizers would otherwise pin every compiled executable forever.
_STEP_CACHE: Dict[Tuple, Callable] = {}
_STEP_CACHE_MAX = 64
_STEP_BUILDS: list = []


def _cache_get(key):
    fn = _STEP_CACHE.pop(key, None)
    if fn is not None:
        _STEP_CACHE[key] = fn  # re-insert = move to LRU tail
    return fn


def _cache_put(key, fn):
    while len(_STEP_CACHE) >= _STEP_CACHE_MAX:
        _STEP_CACHE.pop(next(iter(_STEP_CACHE)))
    _STEP_CACHE[key] = fn
    _STEP_BUILDS.append(key)


def _cache_clear():  # test hook
    _STEP_CACHE.clear()
    del _STEP_BUILDS[:]


def _ledger_key(runtime, cfg, budget, donate) -> str:
    """Human-readable spelling of one step-cache key for the compile ledger
    (same identity granularity as _STEP_CACHE: runtime hash disambiguates
    equal arch/budget under different policies/meshes)."""
    name = getattr(cfg, "name", type(cfg).__name__)
    return (f"train_step/{name}/budget={budget}/donate={donate}"
            f"/rt={hash(runtime) & 0xffffffff:08x}")


def _with_ledger(jfn, ob, lkey: str, want_memory: bool):
    """Wrap a jitted step so its first call runs AOT lower+compile, timing
    the trace and compile phases separately and recording
    ``memory_analysis()`` into the shared ledgers; later calls dispatch to
    the compiled executable directly.

    Falls back to the plain jitted callable — permanently — if AOT is
    unavailable or a later call arrives with different arg shapes (the
    compiled object is monomorphic; ``jax.jit`` re-specializes instead).
    Host-side only: the computation, donation and outputs are unchanged.
    """
    from repro.obs import clock, ledgers

    state = {"compiled": None, "first": True}

    def step(*args, **kw):
        compiled = state["compiled"]
        if compiled is not None:
            try:
                return compiled(*args, **kw)
            except (TypeError, ValueError):
                # shape-polymorphic caller — hand back to jit's own cache
                state["compiled"] = None
                return jfn(*args, **kw)
        if not state["first"]:
            return jfn(*args, **kw)
        state["first"] = False
        t0 = clock.now()
        try:
            lowered = jfn.lower(*args, **kw)
            t1 = clock.now()
            compiled = lowered.compile()
            t2 = clock.now()
        except Exception:
            # AOT path unavailable on this release/call — time the first
            # call as one opaque trace+compile+run figure instead
            t0 = clock.now()
            out = jfn(*args, **kw)
            _ledger_compile(ob, lkey, first_call_s=clock.now() - t0)
            return out
        mem = None
        if want_memory:
            try:
                mem = ledgers.memory_summary(compiled.memory_analysis())
            except Exception:
                mem = None
        _ledger_compile(ob, lkey, trace_s=t1 - t0, compile_s=t2 - t1,
                        memory=mem)
        if ob is not None and ob.tracer.enabled:
            parent = ob.tracer.current_id()
            ob.tracer.add_span("jit_trace", t0, t1, parent=parent, key=lkey)
            ob.tracer.add_span("xla_compile", t1, t2, parent=parent, key=lkey)
        state["compiled"] = compiled
        return compiled(*args, **kw)

    return step


def _ledger_compile(ob, lkey: str, *, trace_s=None, compile_s=None,
                    first_call_s=None, memory=None):
    from repro.obs import ledgers

    kw = dict(trace_s=trace_s, compile_s=compile_s, first_call_s=first_call_s)
    if ob is not None and ob.compile_ledger is not None:
        ob.compile_ledger.record_compile(lkey, **kw)
    if ob is not None and ob.memory_ledger is not None and memory is not None:
        ob.memory_ledger.record(lkey, memory)
        ob.memory_ledger.sample(lkey)
    if ledgers.global_active():
        ledgers.GLOBAL_COMPILE_LEDGER.record_compile(lkey, **kw)


def _ledger_hit(ob, lkey: str):
    from repro.obs import ledgers

    if ob is not None and ob.compile_ledger is not None:
        ob.compile_ledger.record_hit(lkey)
    if ledgers.global_active():
        ledgers.GLOBAL_COMPILE_LEDGER.record_hit(lkey)


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Unified sketched-backprop runtime (hashable; compare by value).

    ``Runtime()`` is a valid single-device exact-backprop runtime; every
    field upgrades one axis independently.
    """

    policy: Optional[SketchPolicy] = None
    execution: ExecutionConfig = dataclasses.field(default_factory=ExecutionConfig)
    schedule: BudgetSchedule = dataclasses.field(default_factory=BudgetSchedule)

    def replace(self, **kw) -> "Runtime":
        return dataclasses.replace(self, **kw)

    # -- policy / context ---------------------------------------------------

    def policy_at(self, budget: Optional[float] = 1.0) -> Optional[SketchPolicy]:
        """The effective policy at one schedule budget (see BudgetSchedule:
        None = exact, 1.0 = as configured, else per-site override)."""
        if budget is None or self.policy is None:
            return None
        if budget >= 1.0:
            return self.policy
        return self.policy.with_budget(budget)

    def ctx(self, key=None, *, budget: Optional[float] = 1.0,
            decode: bool = False, layer_index: int = 0, n_layers: int = 1):
        """A :class:`~repro.nn.common.Ctx` for hand-driven model calls
        (`examples/quickstart.py` pattern: custom loss, own loop)."""
        return self.execution.make_ctx(policy=self.policy_at(budget), key=key,
                                       decode=decode, layer_index=layer_index,
                                       n_layers=n_layers)

    # -- observability ------------------------------------------------------

    def observability(self):
        """The shared :class:`repro.obs.Observability` for this runtime's
        ``execution.obs`` config: tracer, metrics registries, compile/memory
        ledgers (``.report()`` gives the JSON-ready rollup — compile
        hit/miss, per-step memory, merged metrics). The disabled singleton
        when ``obs`` is None."""
        from repro.obs import observability

        return observability(self.execution.obs)

    # -- training -----------------------------------------------------------

    def train_step(self, cfg, opt, *, budget: Optional[float] = 1.0,
                   donate: bool = True, jitted: bool = True) -> Callable:
        """``step_fn(state, batch, key) -> (state, metrics)`` for this runtime.

        Jitted results are cached on (runtime, cfg, opt, budget, donate):
        the same Runtime yields the same executable — one compile per
        schedule bucket. ``jitted=False`` returns the raw step function for
        callers that jit with their own in_shardings (dry-run, benchmarks).
        """
        if self.policy is None:
            # every budget is the same exact step — collapse the cache key
            # so a multi-bucket schedule with no policy compiles once
            budget = 1.0
        from repro.obs import ledgers, observability

        ob = observability(self.execution.obs)
        ledger_on = jitted and (ob.compile_ledger is not None
                                or ob.memory_ledger is not None)
        global_on = jitted and ledgers.global_active()
        lkey = (_ledger_key(self, cfg, budget, donate)
                if (ledger_on or global_on) else None)
        key = (self, cfg, opt, budget, donate, jitted)
        fn = _cache_get(key)
        if fn is not None:
            if lkey is not None:
                _ledger_hit(ob if ledger_on else None, lkey)
            return fn
        import jax

        from repro.train.train_step import make_train_step

        fn = make_train_step(cfg, opt, self.policy_at(budget),
                             execution=self.execution)
        if jitted:
            fn = jax.jit(fn, donate_argnums=(0,) if donate else ())
            if lkey is not None:
                fn = _with_ledger(fn, ob if ledger_on else None, lkey,
                                  ob.memory_ledger is not None)
        _cache_put(key, fn)
        return fn

    def train(self, cfg, opt, data: Iterable, tcfg=None, *, state=None,
              on_metrics: Optional[Callable] = None):
        """Run the training loop; returns ``(final_state, history)``.

        ``tcfg`` is a :class:`repro.train.trainer.TrainerConfig` (steps,
        logging, checkpointing); the sketch policy, execution environment and
        budget schedule all come from this Runtime.
        """
        from repro.train import trainer

        return trainer.train_loop(self, cfg, opt, data, tcfg, state=state,
                                  on_metrics=on_metrics)

    def init_state(self, key, cfg, opt):
        from repro.train.train_step import init_state

        # policy/execution let plan-carry estimators ("onepass"/"stale")
        # seed their permanent per-site score leaves (core/plan_state.py)
        return init_state(key, cfg, opt, self.policy,
                          execution=self.execution)

    # -- serving ------------------------------------------------------------

    def prefill_step(self, cfg, max_len: int) -> Callable:
        """``prefill_fn(params, batch) -> (logits, caches)`` (unjitted)."""
        from repro.serve.serve_step import make_prefill

        return make_prefill(cfg, max_len, execution=self.execution)

    def decode_step(self, cfg) -> Callable:
        """``decode_fn(params, caches, tokens, pos) -> (logits, caches)``
        (unjitted)."""
        from repro.serve.serve_step import make_decode_step

        return make_decode_step(cfg, execution=self.execution)

    def serve(self, params, cfg, *, serve=None, batch: int = 4,
              max_len: int = 256):
        """A continuous-batching :class:`~repro.serve.engine.Engine` whose
        prefill/decode steps run under this runtime's execution config.

        ``serve`` is a :class:`~repro.serve.config.ServeConfig` (slot count,
        KV budget, paged-cache geometry, prefill buckets/packing, stop
        tokens); the ``batch``/``max_len`` kwargs are the legacy spelling and
        build one. See docs/serving.md.
        """
        from repro.serve.engine import Engine

        return Engine(params, cfg, serve=serve, batch=batch, max_len=max_len,
                      runtime=self)

    # -- migration ----------------------------------------------------------

    @classmethod
    def from_legacy_kwargs(cls, policy=None, *, mesh=None, act_sharding=None,
                           data_axes=("data",), model_axes=("model",),
                           tp_sketch: bool = False, compact_grads: bool = False,
                           accum: int = 1, cost_mode: bool = False,
                           straggler_budgets: Tuple[float, ...] = (),
                           schedule: Optional[BudgetSchedule] = None) -> "Runtime":
        """Adapter for the pre-Runtime kwarg spelling (see docs/api.md for
        the migration table). ``straggler_budgets`` maps onto a reactive
        :class:`BudgetSchedule` exactly like the old trainer buckets."""
        if schedule is None:
            schedule = (BudgetSchedule.straggler(tuple(straggler_budgets))
                        if straggler_budgets else BudgetSchedule())
        return cls(policy=policy,
                   execution=ExecutionConfig(
                       mesh=mesh, act_sharding=act_sharding,
                       data_axes=tuple(data_axes), model_axes=tuple(model_axes),
                       tp_sketch=tp_sketch, compact_grads=compact_grads,
                       accum=accum, cost_mode=cost_mode),
                   schedule=schedule)
