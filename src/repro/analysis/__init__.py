"""Static analysis for the sketched-backprop repo: AST lint + sketch coverage.

Two layers, one subsystem (ISSUE 6):

* :mod:`repro.analysis.lint` — an AST lint engine (``python -m
  repro.analysis.lint src/``) whose rules replace the regex greps that used
  to live in ``tests/test_compat.py``: import-resolving detection of
  version-gated JAX symbols outside ``compat.py``, second ``custom_vjp``
  spines outside ``core/site.py``, direct ``Ctx(...)`` construction outside
  ``api``/``nn`` — plus JAX-specific hygiene rules (PRNG-key reuse,
  host-sync inside jitted step functions, Python ``if`` on traced values).
* :mod:`repro.analysis.coverage` — a jaxpr sketch-coverage analyzer that
  traces a Runtime train cell's backward, attributes every ``dot_general``
  to the sketched-site spine (``core/site.py``) or flags it as an escaped
  dense matmul, and gates the result against the checked-in
  ``baseline.json`` waiver set so new escapes fail while the known MoE/SSM
  gap stays documented and machine-readable.
* :mod:`repro.analysis.invariants` — the cross-cutting compiled-program
  invariants (zero involuntary remats, G-reader passes <= 2, donation) that
  used to live as per-test helpers.

The lint layer is import-light (stdlib ``ast`` only — safe for <10 s CI
gates); the coverage layer imports JAX lazily inside its functions.
"""
# Lazy exports (PEP 562): `python -m repro.analysis.lint` must not trigger
# an eager sibling import of the submodule runpy is about to execute, and
# importing the package stays as light as its lightest member.
_EXPORTS = {
    "Finding": "findings", "LintResult": "findings",
    "format_findings": "findings",
    "run_lint": "lint",
    "Rule": "rules", "DEFAULT_RULES": "rules", "rule_ids": "rules",
    "BaselineResult": "coverage", "CoverageReport": "coverage",
    "SiteCoverage": "coverage", "analyze_loss": "coverage",
    "analyze_runtime": "coverage", "check_baseline": "coverage",
    "load_baseline": "coverage", "role_hint": "coverage",
    "donated_input_bytes": "invariants", "g_reader_passes": "invariants",
    "g_reader_ceiling": "invariants", "G_READER_CEILINGS": "invariants",
    "involuntary_remat_count": "invariants",
}


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        mod = importlib.import_module(f"repro.analysis.{_EXPORTS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))


__all__ = [
    "Finding",
    "LintResult",
    "Rule",
    "DEFAULT_RULES",
    "rule_ids",
    "format_findings",
    "run_lint",
    "CoverageReport",
    "SiteCoverage",
    "BaselineResult",
    "analyze_loss",
    "analyze_runtime",
    "role_hint",
    "load_baseline",
    "check_baseline",
    "g_reader_passes",
    "g_reader_ceiling",
    "G_READER_CEILINGS",
    "involuntary_remat_count",
    "donated_input_bytes",
]
