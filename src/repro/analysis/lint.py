"""Lint runner + CLI: ``python -m repro.analysis.lint src/``.

Pure stdlib (``ast`` + ``tokenize``): linting the whole ``src/`` tree takes
well under a second, so CI runs it as a fail-fast tier-1 gate before any
tracing test. Exit status is nonzero iff un-waived findings exist.
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from typing import Iterable, List, Optional, Sequence

from repro.analysis.findings import (Finding, LintResult, apply_waivers,
                                     collect_waivers, format_findings)
from repro.analysis.rules import DEFAULT_RULES, Rule

__all__ = ["run_lint", "lint_file", "iter_py_files", "main"]


def package_relpath(path: str) -> str:
    """Path relative to the ``repro`` package root, posix separators.

    Rule allowlists (``compat.py``, ``core/site.py``, ``nn/*``) are written
    against the package layout, not the invocation directory, so
    ``src/repro/compat.py``, ``./repro/compat.py`` and a bare fixture file
    all normalize consistently. Files outside a ``repro`` directory (test
    fixtures) keep their basename — never accidentally allowlisted.
    """
    parts = os.path.normpath(path).replace(os.sep, "/").split("/")
    if "repro" in parts:
        i = len(parts) - 1 - parts[::-1].index("repro")
        rel = "/".join(parts[i + 1:])
        if rel:
            return rel
    return parts[-1]


def iter_py_files(paths: Iterable[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        else:
            for dirpath, dirnames, files in os.walk(p):
                dirnames[:] = [d for d in dirnames if d != "__pycache__"]
                out.extend(os.path.join(dirpath, f)
                           for f in sorted(files) if f.endswith(".py"))
    return sorted(out)


def lint_file(path: str, rules: Sequence[Rule] = DEFAULT_RULES) -> LintResult:
    from repro.analysis.rules import FileContext

    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return LintResult(findings=[Finding(
            path, e.lineno or 0, "parse-error", f"syntax error: {e.msg}")])
    ctx = FileContext(path=path, relpath=package_relpath(path),
                      source=source, tree=tree)
    findings: List[Finding] = []
    for rule in rules:
        findings.extend(rule.check(ctx))
    return apply_waivers(findings, collect_waivers(source))


def run_lint(paths: Iterable[str],
             rules: Optional[Sequence[Rule]] = None,
             select: Optional[Sequence[str]] = None) -> LintResult:
    """Lint ``paths`` (files or directories, recursively).

    ``select`` keeps only the named rule ids. Returns a
    :class:`LintResult`; ``result.findings`` are the violations that stand,
    ``result.waived`` the ones suppressed by ``# lint: waive=`` comments.
    """
    chosen: Sequence[Rule] = DEFAULT_RULES if rules is None else rules
    if select is not None:
        want = set(select)
        unknown = want - {r.id for r in chosen}
        if unknown:
            raise ValueError(f"unknown rule ids: {sorted(unknown)}")
        chosen = [r for r in chosen if r.id in want]
    result = LintResult()
    for path in iter_py_files(paths):
        result.extend(lint_file(path, chosen))
    return result


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST lint for the sketched-backprop repo")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories (default: src)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--show-waived", action="store_true",
                    help="also print findings suppressed by inline waivers")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in DEFAULT_RULES:
            print(f"{r.id}: {r.description}")
        return 0

    select = args.select.split(",") if args.select else None
    result = run_lint(args.paths or ["src"], select=select)
    if result.findings:
        print(format_findings(result.findings))
    if args.show_waived and result.waived:
        print(format_findings(result.waived, header="-- waived --"))
    n, w = len(result.findings), len(result.waived)
    print(f"lint: {n} finding(s), {w} waived")
    return 1 if result.findings else 0


if __name__ == "__main__":
    sys.exit(main())
