"""Jaxpr sketch-coverage: prove every parameter matmul is on the spine.

The paper's savings only accrue at sites that actually route through the one
sketched-site ``custom_vjp`` spine (``core/site.py``). This analyzer traces
a train cell's backward with ``jax.make_jaxpr(jax.grad(loss))`` (abstract —
no FLOP is spent, no state is touched), then answers, per weight leaf:
*which matmuls produce this gradient, and do they run through the spine?*

Mechanics (validated against every registered arch family):

* **Flattened provenance graph** — ``pjit`` / ``remat2`` /
  ``custom_vjp_call_jaxpr`` sub-jaxprs are inlined into one global var
  graph (loop primitives stay opaque; under ``cost_mode`` ctx the chunk
  scans are python-unrolled so almost nothing hides in a loop body).
* **Equation provenance** — ``compat.user_frames`` yields user-code
  (file, line) frames per equation. JAX's transpose rules inherit the
  forward equation's source info, so a site's forward, dX and dW matmuls
  all share one provenance key — grouping by it collects a site's full
  FLOP footprint from any one attributed equation.
* **Gradient attribution** — from each parameter's grad output var, walk
  producers backward through *gradient-transparent* ops (add_any,
  transpose, reshape, pad, convert, psum, ...) until hitting opaque
  "terminal" equations. A terminal ``dot_general``/``scatter-add`` whose
  provenance lies in ``repro/core`` is spine evidence (compact dW is a
  scatter of sketched rows into zeros — still the spine); a terminal
  ``dot_general`` elsewhere is an **escaped dense matmul**, named by its
  file:line. ``mul``/``select_n``/``reduce_sum`` are deliberately opaque:
  keeping them transparent would let the embedding cotangent cone swallow
  the whole graph.

Per-site categories:

* ``resolved`` — ``core.site.resolve_tree_site`` yields a SiteSpec (the
  slot builders, telemetry and TP planning all see this site).
* ``exact`` — on the spine but deliberately exact: the role is
  policy-excluded (lm_head, router-class small sites, ssm_small) or the
  multi-use ``shared`` subtree.
* ``unresolved`` — executes through the spine at runtime (role hints via
  ``Ctx.cfg_for``) but is invisible to path-based spec resolution: no
  gslots, no probes, no TP plan. This is exactly the ROADMAP MoE/SSM gap.
* ``escaped`` — at least one gradient-producing dense matmul bypasses the
  spine entirely (MoE router, RWKV decay-LoRA ``w1``/``w2``).
* ``no_matmul`` — gradient produced without any matmul (embeddings,
  norms, convs, gates).

``escaped``/``unresolved`` sites must be waived by ``baseline.json`` or
:func:`check_baseline` fails, naming the site and its file:line — the gate
starts green on the known gap and *ratchets*.
"""
from __future__ import annotations

import dataclasses
import json
import os
from fnmatch import fnmatch
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["SiteCoverage", "CoverageReport", "BaselineResult", "analyze_loss",
           "analyze_runtime", "role_hint", "load_baseline", "check_baseline",
           "BASELINE_PATH"]

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baseline.json")

# Gradient accumulation / layout ops the backward walk sees through. NOT
# mul/select_n/reduce_sum/gather: those would let the walk escape the
# gradient cone (the embedding cotangent reaches the whole graph via adds
# and masks) and mis-attribute activation matmuls to parameters.
_TRANSPARENT = frozenset({
    "add_any", "add", "transpose", "reshape", "convert_element_type",
    "broadcast_in_dim", "squeeze", "expand_dims", "slice", "pad",
    "concatenate", "rev", "copy", "psum", "sharding_constraint",
    "reduce_precision", "optimization_barrier",
})

# Straight-line higher-order primitives inlined into the flat graph.
_INLINE = frozenset({"pjit", "remat2", "custom_vjp_call_jaxpr",
                     "custom_jvp_call", "custom_vjp_call", "closed_call",
                     "checkpoint"})

# Anything under repro/core is the spine's own machinery (site.py fwd/bwd,
# sketched_linear residuals, estimator plans, compact scatter emission).
_SPINE_DIR = os.sep + os.path.join("repro", "core") + os.sep


# ---------------------------------------------------------------------------
# Role hints: the analyzer's *extended* path->role map
# ---------------------------------------------------------------------------

# Read-only superset of core.compact_grad._site_role. The runtime map must
# NOT learn these entries (a gslot emitted for a site whose `linear` call
# never consumes it silently zeroes that gradient); the analyzer only needs
# them to say which policy role a path *would* carry.
_PARENT_ROLES = {
    "moe": {"wi": "expert_in", "wg": "expert_gate", "wo": "expert_out",
            "router": "router"},
    "mamba": {"in_z": "ssm_in", "in_x": "ssm_in", "out": "ssm_out",
              "in_B": "ssm_small", "in_C": "ssm_small", "in_dt": "ssm_small"},
    "rwkv": {"r": "attn_q", "k": "attn_k", "v": "attn_v", "g": "mlp_gate",
             "out": "attn_o", "cm_k": "mlp_in", "cm_v": "mlp_out",
             "cm_r": "mlp_gate", "w1": "ssm_small", "w2": "ssm_small"},
}


def role_hint(path: Tuple) -> Optional[str]:
    """Policy role a params-tree path would carry at runtime (via explicit
    ``Ctx.cfg_for`` role arguments), including the paths that
    ``core.compact_grad._site_role`` is deliberately blind to."""
    from repro.core.compact_grad import _site_role

    role = _site_role(path)
    if role is not None:
        return role
    if not path:
        return None
    if path[-1] == "embed":
        return "embed"
    if len(path) >= 2 and path[-2] == "lm_head":
        return "lm_head"
    if len(path) >= 2:
        parent, leaf = path[-2], path[-1]
        if leaf == "w" and len(path) >= 3:
            parent, leaf = path[-3], path[-2]
        sub = _PARENT_ROLES.get(parent)
        if sub:
            return sub.get(leaf)
    return None


# ---------------------------------------------------------------------------
# Jaxpr graph
# ---------------------------------------------------------------------------


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else [v]
        for s in vs:
            if hasattr(s, "jaxpr"):          # ClosedJaxpr
                yield s.jaxpr
            elif hasattr(s, "eqns"):         # raw Jaxpr
                yield s


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval.shape, eqn.invars[1].aval.shape
    batch = 1.0
    for d in lb:
        batch *= lhs[d]
    contract = 1.0
    for d in lc:
        contract *= lhs[d]
    lfree = rfree = 1.0
    for i, d in enumerate(lhs):
        if i not in lc and i not in lb:
            lfree *= d
    for i, d in enumerate(rhs):
        if i not in rc and i not in rb:
            rfree *= d
    return 2.0 * batch * lfree * rfree * contract


def _modelled_site_flops(shape, n_tokens: float) -> float:
    """Dense-equivalent fwd+dX+dW FLOPs of one weight site: 6·T·d_out·d_in
    (× stacked leading dims for vmapped expert weights). Spine sites all
    share one provenance key (the single custom_vjp call line), so their
    per-site cost comes from this static model instead of provenance
    grouping; the telemetry site_cost_table uses the same convention."""
    lead = 1.0
    for d in shape[:-2]:
        lead *= d
    return 6.0 * lead * n_tokens * shape[-2] * shape[-1]


def _prov_key(eqn) -> str:
    from repro import compat  # lazy: keep the lint CLI jax-free

    frames = compat.user_frames(eqn.source_info)
    if not frames:
        return "?"
    f, line = frames[0]
    return f"{f}:{line}"


def _is_spine(eqn) -> bool:
    from repro import compat

    for f, _ in compat.user_frames(eqn.source_info):
        if _SPINE_DIR in f.replace("/", os.sep):
            return True
    return False


class _Graph:
    """Flattened producer graph over a closed jaxpr (see module docstring)."""

    def __init__(self, closed_jaxpr):
        import jax

        self._literal = jax.core.Literal
        self.eqns: List[Tuple[object, dict]] = []   # (eqn, invar-substitution)
        self.alias: Dict[object, object] = {}       # outer var -> inner var
        self.dots: List[Tuple[object, float]] = []  # every dot, x trip count
        self._flatten(closed_jaxpr.jaxpr, {}, 1.0)
        self.producer: Dict[object, Tuple[object, dict]] = {}
        for eqn, amap in self.eqns:
            for ov in eqn.outvars:
                self.producer[ov] = (eqn, amap)

    def _flatten(self, jaxpr, amap, mult) -> None:
        for eqn in jaxpr.eqns:
            prim = str(eqn.primitive)
            if prim == "dot_general":
                self.dots.append((eqn, mult))
            if prim in _INLINE:
                inner = next(iter(_sub_jaxprs(eqn)), None)
                if inner is not None and len(inner.invars) == len(eqn.invars):
                    outer = [iv if isinstance(iv, self._literal)
                             else amap.get(iv, iv) for iv in eqn.invars]
                    inner_map = dict(zip(inner.invars, outer))
                    self._flatten(inner, inner_map, mult)
                    for ov, iov in zip(eqn.outvars, inner.outvars):
                        self.alias[ov] = (iov if isinstance(iov, self._literal)
                                          else inner_map.get(iov, iov))
                    continue
            self.eqns.append((eqn, amap))
            # opaque sub-jaxprs (loops, failed inlines): still surface their
            # dots for the FLOP totals, scaled by the scan trip count
            trips = mult * float(eqn.params.get("length", 1)) \
                if prim == "scan" else mult
            for sub in _sub_jaxprs(eqn):
                self._collect_dots(sub, trips)

    def _collect_dots(self, jaxpr, mult) -> None:
        for eqn in jaxpr.eqns:
            prim = str(eqn.primitive)
            if prim == "dot_general":
                self.dots.append((eqn, mult))
            trips = mult * float(eqn.params.get("length", 1)) \
                if prim == "scan" else mult
            for sub in _sub_jaxprs(eqn):
                self._collect_dots(sub, trips)

    def resolve(self, v):
        seen = set()
        while v in self.alias and id(v) not in seen:
            seen.add(id(v))
            v = self.alias[v]
        return v

    def terminals(self, outvar) -> List[object]:
        """Opaque equations producing ``outvar`` through transparent ops."""
        seen, terms = set(), []
        frontier = [self.resolve(outvar)]
        while frontier:
            v = frontier.pop()
            if isinstance(v, self._literal) or id(v) in seen:
                continue
            seen.add(id(v))
            got = self.producer.get(v)
            if got is None:
                continue
            eqn, amap = got
            if str(eqn.primitive) in _TRANSPARENT:
                for iv in eqn.invars:
                    if not isinstance(iv, self._literal):
                        frontier.append(self.resolve(amap.get(iv, iv)))
            else:
                terms.append(eqn)
        return terms


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SiteCoverage:
    """Coverage verdict for one parameter leaf."""

    param: str                       # "segments/0/0/moe/router/w"
    role: Optional[str]              # policy role hint (extended map)
    category: str                    # resolved|exact|unresolved|escaped|no_matmul
    provenance: List[str]            # file:line keys of gradient terminals
    flops: float                     # modelled dot FLOPs sharing that provenance
    detail: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CoverageReport:
    sites: List[SiteCoverage]
    total_dot_flops: float
    escaped_flops: float
    unresolved_flops: float

    @property
    def escaped_flop_frac(self) -> float:
        """Traced escaped-dot FLOPs over all traced dot FLOPs."""
        return self.escaped_flops / self.total_dot_flops \
            if self.total_dot_flops else 0.0

    @property
    def unresolved_flop_frac(self) -> float:
        """Modelled dense-equivalent FLOPs of unresolved sites over traced
        dot FLOPs. Indicative, not a proportion: at aggressive budgets the
        traced denominator is already sketch-reduced, so this can exceed 1
        when most sites are unresolved."""
        return self.unresolved_flops / self.total_dot_flops \
            if self.total_dot_flops else 0.0

    def by_category(self) -> Dict[str, List[SiteCoverage]]:
        out: Dict[str, List[SiteCoverage]] = {}
        for s in self.sites:
            out.setdefault(s.category, []).append(s)
        return out

    def escapes(self) -> List[SiteCoverage]:
        return [s for s in self.sites if s.category in ("escaped", "unresolved")]

    def escaped_frac_vs_hlo(self, hlo_flops: float) -> Optional[float]:
        """Escaped modelled FLOPs over an HLO-measured total (the
        ``launch.hlo_analysis.cost_summary`` join)."""
        return self.escaped_flops / hlo_flops if hlo_flops else None

    def summary(self) -> dict:
        cats = {k: len(v) for k, v in self.by_category().items()}
        return {
            "n_sites": len(self.sites),
            "categories": cats,
            "total_dot_flops": self.total_dot_flops,
            "escaped_flops": self.escaped_flops,
            "escaped_flop_frac": self.escaped_flop_frac,
            "unresolved_flop_frac": self.unresolved_flop_frac,
            "escapes": [{"param": s.param, "provenance": s.provenance,
                         "category": s.category, "flops": s.flops}
                        for s in self.escapes()],
        }


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(out)


def _tree_node(tree, path):
    """Parent dict of the leaf at ``path`` (for resolve_tree_site)."""
    node = tree
    for k in path[:-1]:
        key = getattr(k, "key", getattr(k, "idx", k))
        try:
            node = node[key]
        except (KeyError, IndexError, TypeError):
            return None
    return node


def analyze_loss(loss_fn, params, *args, policy=None, n_layers=1,
                 n_tokens: float = 1.0, resolve_kwargs=None) -> CoverageReport:
    """Coverage of ``grad(loss_fn)(params, *args)``'s backward graph.

    ``loss_fn(params, *args) -> scalar``; ``params``/``args`` may be
    concrete arrays or ``ShapeDtypeStruct``s (tracing is abstract either
    way — nothing executes, nothing is mutated). ``policy`` drives
    ``resolve_tree_site``; pass the same one the Runtime trains with.
    ``n_tokens`` scales the static per-site cost model for on-spine sites
    (escaped sites are costed from the traced dots themselves).
    """
    import jax

    from repro import compat
    from repro.core.site import resolve_tree_site

    jaxpr = jax.make_jaxpr(jax.grad(loss_fn))(params, *args)
    graph = _Graph(jaxpr)

    flops_by_prov: Dict[str, float] = {}
    for eqn, mult in graph.dots:
        flops_by_prov[_prov_key(eqn)] = flops_by_prov.get(_prov_key(eqn), 0.0) \
            + _dot_flops(eqn) * mult
    total = sum(flops_by_prov.values())

    leaves_with_path = compat.tree_flatten_with_path(params)[0]
    outvars = jaxpr.jaxpr.outvars
    rk = dict(resolve_kwargs or {})
    rk.setdefault("n_layers", n_layers)

    sites: List[SiteCoverage] = []
    escaped_keys = set()
    unresolved = 0.0
    for (path, leaf), ov in zip(leaves_with_path, outvars):
        if getattr(leaf, "ndim", 0) < 2:
            continue
        pstr = _path_str(path)
        terms = graph.terminals(ov)
        raw_path = tuple(getattr(k, "key", getattr(k, "idx", k)) for k in path)
        role = role_hint(raw_path)

        off_dots = [e for e in terms
                    if str(e.primitive) == "dot_general" and not _is_spine(e)]
        spine_evidence = [e for e in terms if _is_spine(e)]
        has_dot = off_dots or any(str(e.primitive) == "dot_general"
                                  for e in terms)

        if off_dots:
            category = "escaped"
            prov = sorted({_prov_key(e) for e in off_dots})
            flops = sum(flops_by_prov.get(p, 0.0) for p in prov)
            escaped_keys.update(prov)
            detail = "gradient produced by a dense matmul off the spine"
        elif spine_evidence:
            prov = sorted({_prov_key(e) for e in spine_evidence})
            flops = _modelled_site_flops(leaf.shape, n_tokens)
            spec = None
            if policy is not None and "shared" not in raw_path:
                node = _tree_node(params, path)
                if isinstance(node, dict):
                    spec = resolve_tree_site(raw_path[:-1] if
                                             raw_path[-1] == "w" else raw_path,
                                             node, policy, **rk)
            if spec is not None:
                category, detail = "resolved", f"plan={spec.plan.kind}"
            elif "shared" in raw_path:
                category = "exact"
                detail = "multi-use shared subtree — deliberately slot-free"
            elif role is not None and (policy is None or
                                       policy.config_for(role, 0,
                                                         rk["n_layers"]) is None):
                category, detail = "exact", f"role {role!r} is policy-excluded"
            else:
                category = "unresolved"
                unresolved += flops
                detail = ("on the spine at runtime (role hint) but invisible "
                          "to path-based spec resolution — no gslots/probes/"
                          "TP plan")
        elif has_dot:
            # dot inside an opaque loop body etc. — treat as escaped
            category = "escaped"
            prov = sorted({_prov_key(e) for e in terms
                           if str(e.primitive) == "dot_general"})
            flops = sum(flops_by_prov.get(p, 0.0) for p in prov)
            escaped_keys.update(prov)
            detail = "matmul terminal outside the spine"
        else:
            category, prov, flops = "no_matmul", [], 0.0
            detail = "gradient carries no matmul"
        sites.append(SiteCoverage(param=pstr, role=role, category=category,
                                  provenance=prov, flops=flops, detail=detail))

    # escaped total dedupes shared provenance (two params produced by one
    # fused off-spine site — RWKV's w1/w2 decay-LoRA line — count once)
    escaped = sum(flops_by_prov.get(k, 0.0) for k in escaped_keys)
    return CoverageReport(sites=sites, total_dot_flops=total,
                          escaped_flops=escaped, unresolved_flops=unresolved)


def analyze_runtime(runtime, cfg, *, batch_size: int = 2, seq_len: int = 16,
                    resolve_kwargs=None) -> CoverageReport:
    """Coverage of one Runtime train cell's backward (abstract trace).

    Builds the same ``lm_loss`` the train step differentiates, under a
    ``cost_mode`` ctx (python-unrolled chunk loops — nothing hides inside
    scan bodies), over ``ShapeDtypeStruct`` params: read-only by
    construction.
    """
    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.models import lm

    ex = dc.replace(runtime.execution, cost_mode=True)
    rt = runtime.replace(execution=ex)
    ctx = rt.ctx(key=compat.prng_key(0))
    pshapes = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                             compat.prng_key(0))
    batch = {"tokens": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
             "labels": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32)}
    if getattr(cfg, "is_encdec", False):
        batch["src_embeds"] = jax.ShapeDtypeStruct(
            (batch_size, seq_len, cfg.d_model), jnp.float32)
    kstruct = jax.ShapeDtypeStruct((), compat.key_dtype())

    def loss(p, b, k):
        return lm.lm_loss(p, b, dc.replace(ctx), cfg, k)[0]

    return analyze_loss(loss, pshapes, batch, kstruct, policy=rt.policy,
                        n_layers=cfg.n_layers,
                        n_tokens=float(batch_size * seq_len),
                        resolve_kwargs=resolve_kwargs)


# ---------------------------------------------------------------------------
# Baseline gate
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BaselineResult:
    ok: bool
    unwaived: List[SiteCoverage]
    used: List[str]      # waiver ids that matched at least one site
    unused: List[str]    # waiver ids that matched nothing (stale)

    def message(self) -> str:
        if self.ok:
            return (f"coverage gate: ok ({len(self.used)} baseline waiver(s) "
                    "in use)")
        lines = ["coverage gate: un-waived escapes — every parameter matmul "
                 "must route through core/site.py or be waived in "
                 "src/repro/analysis/baseline.json:"]
        for s in self.unwaived:
            lines.append(f"  {s.param} [{s.category}] at "
                         f"{', '.join(s.provenance) or '?'} — {s.detail}")
        return "\n".join(lines)


def load_baseline(path: Optional[str] = None) -> dict:
    with open(path or BASELINE_PATH) as f:
        return json.load(f)


def _waiver_matches(w: dict, site: SiteCoverage) -> bool:
    if w.get("category") and w["category"] != site.category:
        return False
    if not fnmatch(site.param, w.get("param", "*")):
        return False
    prov_pat = w.get("provenance")
    if prov_pat:
        files = [p.rsplit(":", 1)[0] for p in site.provenance]
        if not any(fnmatch(f, prov_pat) or fnmatch(os.path.basename(f),
                                                   prov_pat) or prov_pat in f
                   for f in files):
            return False
    return True


def check_baseline(report: CoverageReport,
                   baseline: Optional[dict] = None) -> BaselineResult:
    """Gate: every escaped/unresolved site must match a baseline waiver."""
    baseline = baseline if baseline is not None else load_baseline()
    waivers = baseline.get("waivers", [])
    used = set()
    unwaived = []
    for site in report.escapes():
        hit = False
        for w in waivers:
            if _waiver_matches(w, site):
                used.add(w["id"])
                hit = True
        if not hit:
            unwaived.append(site)
    unused = [w["id"] for w in waivers if w["id"] not in used]
    return BaselineResult(ok=not unwaived, unwaived=unwaived,
                          used=sorted(used), unused=unused)
