"""Cross-cutting compiled-program invariants, in one place.

Three checks that used to live as per-test/per-bench helpers:

* :func:`g_reader_passes` — HLO G-reader accounting (lifted from
  ``benchmarks/bench_backward_fusion.py``, which now imports it from here):
  the compact backward must stream the gradient matrix G from HBM at most
  twice (score pass + fused dX/dW/db pass).
* :func:`involuntary_remat_count` — compile a function while capturing the
  process-level stderr (GSPMD logs ``[spmd] Involuntary full
  rematerialization`` from C++, invisible to ``contextlib.redirect_stderr``)
  and count the warnings. Production train cells must report zero.
* :func:`donated_input_bytes` — bytes of donated (aliased) inputs in a
  compiled executable; a train step compiled with ``donate_argnums`` must
  alias its state or it silently doubles peak memory.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Optional, Tuple

__all__ = ["g_reader_passes", "g_reader_ceiling", "G_READER_CEILINGS",
           "involuntary_remat_count", "donated_input_bytes", "REMAT_WARNING"]

REMAT_WARNING = "Involuntary full rematerialization"

# Per-estimator ceilings on the HLO G-reader count (see g_reader_passes).
# The legacy compact/pallas backward reads G at most twice (a score pass
# plus the fused dX/dW/db pass); the plan-carry estimators sample from the
# previous step's carried scores, so their ONLY read of G is the backward
# kernel itself — exactly one pass. Asserted per-estimator in
# tests/test_benchmarks_smoke.py and recorded by the dryrun coverage record
# and benchmarks/bench_backward_fusion.py (BENCH_summary.json gates the
# one-pass paths at a --check ceiling of 1).
G_READER_CEILINGS = {
    "mask": 2,       # score pass + masked-G matmuls (dense, no gather)
    "compact": 2,    # score pass + one-gather fused backward
    "pallas": 2,     # score pass + fused kernel sweep
    "onepass": 1,    # streaming selection: score/plan inside the one sweep
    "stale": 1,      # carried plan: kept-only fused sweep w/ score refresh
}


def g_reader_ceiling(backend: str) -> int:
    """The G-reader ceiling for an estimator backend (unknown/third-party
    backends get the legacy two-pass ceiling)."""
    return G_READER_CEILINGS.get(backend, 2)


def g_reader_passes(hlo_text: str, N: int, n: int) -> int:
    """Number of instructions that read THE ``f32[N,n]`` G entry parameter
    in the optimized HLO. Each reader is at most one HBM pass over G
    (gathers of kept columns read less), so the count upper-bounds the true
    pass count."""
    shape = re.escape(f"f32[{N},{n}]")
    # only the ENTRY computation: nested fusion/call bodies re-declare their
    # operands as parameters and would double count
    entry = hlo_text.split("\nENTRY ", 1)[-1]
    entry = entry.split("\n}", 1)[0]
    g_syms = set()
    for m in re.finditer(rf"(%\S+)\s*=\s*{shape}\S*\s+parameter\(", entry):
        g_syms.add(m.group(1))
    readers = 0
    for line in entry.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?(%\S+)\s*=\s*\S+\s+(\S+)\((.*)", line)
        if not m:
            continue
        sym, op, operands = m.groups()
        if op in ("parameter", "copy", "bitcast", "get-tuple-element", "tuple"):
            continue
        if any(g + "," in operands or g + ")" in operands or g + " " in operands
               for g in g_syms):
            readers += 1
    return readers


def involuntary_remat_count(compile_fn) -> Tuple[int, object]:
    """Run ``compile_fn()`` (typically ``lambda: jax.jit(f).lower(*a).compile()``)
    with the OS-level stderr captured; return (warning count, result).

    XLA's SPMD partitioner emits the warning from C++ directly to fd 2, so
    Python-level redirection misses it — the capture swaps the fd itself.
    """
    import sys

    sys.stderr.flush()
    saved_fd = os.dup(2)
    with tempfile.TemporaryFile(mode="w+b") as tmp:
        os.dup2(tmp.fileno(), 2)
        try:
            result = compile_fn()
        finally:
            sys.stderr.flush()
            os.dup2(saved_fd, 2)
            os.close(saved_fd)
        tmp.seek(0)
        text = tmp.read().decode("utf-8", errors="replace")
    return text.count(REMAT_WARNING), result


def donated_input_bytes(compiled) -> Optional[float]:
    """Aliased (donated) input bytes of a compiled executable, or None when
    the runtime exposes no memory analysis."""
    try:
        ma = compiled.memory_analysis()
        if isinstance(ma, list):
            ma = ma[0]
        return float(ma.alias_size_in_bytes)
    except Exception:
        return None
