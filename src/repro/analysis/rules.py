"""AST lint rules (stdlib ``ast`` only — no JAX import at lint time).

Every rule sees a parsed module plus a :class:`FileContext` that owns the
import-alias table, so detection is *name-resolving*: ``from
jax.experimental import shard_map as sm`` trips the version-gate rule at the
import and at every ``sm(...)`` use — patterns the old ``test_compat.py``
regexes missed — while prose mentions in docstrings/comments no longer
false-positive (strings are not names).

Rule ids are stable kebab-case strings; waive one occurrence with an inline
``# lint: waive=<rule-id>`` comment (see findings.py). Per-rule ``allow``
patterns are fnmatch'ed against the file's path relative to the ``repro``
package root (so ``compat.py`` means ``src/repro/compat.py`` wherever the
tree is checked out).
"""
from __future__ import annotations

import ast
import dataclasses
from fnmatch import fnmatch
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Tuple

from repro.analysis.findings import Finding

__all__ = ["Rule", "FileContext", "DEFAULT_RULES", "rule_ids"]


# ---------------------------------------------------------------------------
# Import resolution
# ---------------------------------------------------------------------------


def _import_table(tree: ast.Module) -> Tuple[Dict[str, str], List[Tuple[int, str]]]:
    """(local name -> dotted path, [(line, imported dotted path)]).

    The second list replays every from-import as a "virtual use" so rules
    can flag the import line itself (`from jax import custom_vjp` is already
    the violation, whether or not the name is ever called).
    """
    table: Dict[str, str] = {}
    imported: List[Tuple[int, str]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                table[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
                imported.append((node.lineno, a.name))
        elif isinstance(node, ast.ImportFrom):
            base = ("." * node.level) + (node.module or "")
            for a in node.names:
                if a.name == "*":
                    continue
                full = f"{base}.{a.name}" if base else a.name
                table[a.asname or a.name] = full
                imported.append((node.lineno, full))
    return table, imported


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class FileContext:
    """Everything a rule needs about one parsed file."""

    path: str        # display path (as passed to the linter)
    relpath: str     # path relative to the repro package root (allow match)
    source: str
    tree: ast.Module
    imports: Dict[str, str] = dataclasses.field(default_factory=dict)
    imported_names: List[Tuple[int, str]] = dataclasses.field(default_factory=list)
    _jitted: Optional[List[ast.AST]] = None

    def __post_init__(self):
        self.imports, self.imported_names = _import_table(self.tree)

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Alias-expanded dotted path of a Name/Attribute chain."""
        d = _dotted(node)
        if d is None:
            return None
        head, _, rest = d.partition(".")
        head = self.imports.get(head, head)
        return f"{head}.{rest}" if rest else head

    def jitted_functions(self) -> List[ast.AST]:
        """Function defs whose body runs under ``jax.jit`` tracing: defs
        decorated with ``*.jit`` (directly or via ``partial(jit, ...)``),
        defs passed to a ``jit(...)`` call, and every def nested inside one
        of those."""
        if self._jitted is not None:
            return self._jitted

        def is_jit(expr) -> bool:
            r = self.resolve(expr)
            return r is not None and (r == "jit" or r.endswith(".jit")
                                      or r.endswith(".pjit"))

        roots: List[ast.AST] = []
        defs: Dict[str, ast.AST] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, node)
                for dec in node.decorator_list:
                    if is_jit(dec) or (isinstance(dec, ast.Call)
                                       and (is_jit(dec.func)
                                            or any(is_jit(a) for a in dec.args))):
                        roots.append(node)
                        break
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) and is_jit(node.func) and node.args:
                target = node.args[0]
                if isinstance(target, ast.Name) and target.id in defs:
                    roots.append(defs[target.id])
        out: List[ast.AST] = []
        seen = set()
        for root in roots:
            for node in ast.walk(root):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and id(node) not in seen:
                    seen.add(id(node))
                    out.append(node)
        self._jitted = out
        return out


class Rule(Protocol):
    """One pluggable lint rule."""

    id: str
    description: str
    allow: Tuple[str, ...]

    def check(self, ctx: FileContext) -> List[Finding]: ...


def _allowed(rule, ctx: FileContext) -> bool:
    return any(fnmatch(ctx.relpath, pat) for pat in rule.allow)


# ---------------------------------------------------------------------------
# Rule 1: version-gated JAX surfaces outside compat.py
# ---------------------------------------------------------------------------


def _jax_rooted(path: str) -> bool:
    return path == "jax" or path.startswith("jax.")


def _version_gated(path: str) -> Optional[str]:
    """Why a resolved jax-rooted dotted path is version-gated, or None."""
    if not _jax_rooted(path):
        return None
    if path.split(".")[-1] == "AxisType":
        return "jax.sharding.AxisType is absent on part of the supported range"
    if path == "jax.shard_map" or ".experimental.shard_map" in path \
            or path.endswith(".shard_map"):
        return "shard_map moved modules across the supported range"
    if path == "jax.make_mesh":
        return "jax.make_mesh is absent on part of the supported range"
    if path == "jax.lax.optimization_barrier":
        return ("optimization_barrier ships without a vmap batching rule on "
                "some releases")
    return None


_GATED_KWARGS = ("axis_types", "check_vma", "check_rep")


@dataclasses.dataclass(frozen=True)
class JaxVersionGatedRule:
    id: str = "jax-version-gated"
    description: str = ("version-gated JAX symbol used outside repro/compat.py "
                        "(AxisType, shard_map, make_mesh, optimization_barrier, "
                        "axis_types=/check_vma=/check_rep=)")
    allow: Tuple[str, ...] = ("compat.py",)

    def check(self, ctx: FileContext) -> List[Finding]:
        if _allowed(self, ctx):
            return []
        out = set()

        def add(line, what, why):
            out.add(Finding(ctx.path, line, self.id,
                            f"{what} — {why}; route through repro.compat"))

        for line, dotted in ctx.imported_names:
            why = _version_gated(dotted)
            if why:
                add(line, f"import of {dotted}", why)
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Attribute, ast.Name)):
                r = ctx.resolve(node)
                if r:
                    why = _version_gated(r)
                    if why:
                        add(node.lineno, r, why)
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg in _GATED_KWARGS:
                        add(node.lineno, f"keyword {kw.arg}=",
                            "gated mesh/shard_map kwarg")
        return sorted(out)


# ---------------------------------------------------------------------------
# Rule 2: custom_vjp outside the one spine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CustomVjpRule:
    id: str = "custom-vjp-outside-site"
    description: str = ("jax.custom_vjp outside core/site.py — a second "
                        "sketched-site spine in the making")
    # THE spine; and the pipeline-parallel stage-boundary vjp (not a
    # sketched site). A kernel/decode path that genuinely needs its own vjp
    # must extend this tuple explicitly, with a comment.
    allow: Tuple[str, ...] = ("core/site.py", "launch/pipeline.py")

    def check(self, ctx: FileContext) -> List[Finding]:
        if _allowed(self, ctx):
            return []
        out = set()

        def add(line, what):
            out.add(Finding(
                ctx.path, line, self.id,
                f"{what}: route the site through the one spine "
                "(SiteSpec/ExecutionPlan in core/site.py) or extend the "
                "allowlist explicitly"))

        for line, dotted in ctx.imported_names:
            if _jax_rooted(dotted) and dotted.split(".")[-1] == "custom_vjp":
                add(line, f"import of {dotted}")
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Attribute, ast.Name)):
                r = ctx.resolve(node)
                if r and _jax_rooted(r) and r.split(".")[-1] == "custom_vjp":
                    add(node.lineno, r)
        return sorted(out)


# ---------------------------------------------------------------------------
# Rule 3: Ctx construction outside api/ + nn/
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CtxConstructionRule:
    id: str = "ctx-outside-api-nn"
    description: str = ("direct Ctx(...) construction outside repro/api + "
                        "repro/nn")
    allow: Tuple[str, ...] = ("nn/*", "api/*")

    def check(self, ctx: FileContext) -> List[Finding]:
        if _allowed(self, ctx):
            return []
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            name = func.attr if isinstance(func, ast.Attribute) else (
                func.id if isinstance(func, ast.Name) else None)
            if name == "Ctx":
                out.append(Finding(
                    ctx.path, node.lineno, self.id,
                    "direct Ctx(...) construction (route through "
                    "ExecutionConfig.make_ctx / Runtime.ctx)"))
        return sorted(out)


# ---------------------------------------------------------------------------
# Rule 4: PRNG key reuse
# ---------------------------------------------------------------------------

# jax.random ops that *derive* new keys rather than consuming entropy;
# everything else under jax.random consumes its key argument.
_KEY_DERIVING = frozenset({"split", "fold_in", "key", "PRNGKey", "key_data",
                           "wrap_key_data", "clone", "key_impl"})


@dataclasses.dataclass(frozen=True)
class PrngKeyReuseRule:
    id: str = "prng-key-reuse"
    description: str = ("the same PRNG key consumed by two jax.random ops "
                        "without an intervening split/fold_in")
    allow: Tuple[str, ...] = ()

    def check(self, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        for fn in ast.walk(ctx.tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_function(fn, ctx, out)
        return sorted(set(out))

    def _consumed_key(self, call: ast.Call, ctx: FileContext) -> Optional[str]:
        r = ctx.resolve(call.func)
        if r is None or not r.startswith("jax.random."):
            return None
        if r.split(".")[-1] in _KEY_DERIVING:
            return None
        if call.args and isinstance(call.args[0], ast.Name):
            return call.args[0].id
        for kw in call.keywords:
            if kw.arg == "key" and isinstance(kw.value, ast.Name):
                return kw.value.id
        return None

    def _scan_function(self, fn, ctx: FileContext, out: List[Finding]) -> None:
        def bound_names(target) -> List[str]:
            return [n.id for n in ast.walk(target) if isinstance(n, ast.Name)]

        def scan_expr(node, consumed: Dict[str, int]) -> None:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    name = self._consumed_key(sub, ctx)
                    if name is None:
                        continue
                    if name in consumed:
                        out.append(Finding(
                            ctx.path, sub.lineno, self.id,
                            f"key '{name}' already consumed at line "
                            f"{consumed[name]} — split or fold_in first"))
                    else:
                        consumed[name] = sub.lineno

        def scan_block(stmts, consumed: Dict[str, int]) -> None:
            for st in stmts:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                    continue  # separate scope, scanned on its own
                if isinstance(st, ast.If):
                    scan_expr(st.test, consumed)
                    # exclusive branches don't see each other's consumption;
                    # afterwards either may have happened (union)
                    a, b = dict(consumed), dict(consumed)
                    scan_block(st.body, a)
                    scan_block(st.orelse, b)
                    consumed.update(a)
                    consumed.update(b)
                    continue
                if isinstance(st, (ast.For, ast.AsyncFor)):
                    scan_expr(st.iter, consumed)
                    for n in bound_names(st.target):
                        consumed.pop(n, None)
                    scan_block(st.body, consumed)
                    scan_block(st.orelse, consumed)
                    continue
                if isinstance(st, ast.While):
                    scan_expr(st.test, consumed)
                    scan_block(st.body, consumed)
                    scan_block(st.orelse, consumed)
                    continue
                if isinstance(st, (ast.With, ast.AsyncWith)):
                    for item in st.items:
                        scan_expr(item.context_expr, consumed)
                    scan_block(st.body, consumed)
                    continue
                if isinstance(st, ast.Try):
                    scan_block(st.body, consumed)
                    for h in st.handlers:
                        scan_block(h.body, consumed)
                    scan_block(st.orelse, consumed)
                    scan_block(st.finalbody, consumed)
                    continue
                scan_expr(st, consumed)
                if isinstance(st, ast.Assign):
                    for t in st.targets:
                        for n in bound_names(t):
                            consumed.pop(n, None)
                elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
                    for n in bound_names(st.target):
                        consumed.pop(n, None)

        scan_block(fn.body, {})


# ---------------------------------------------------------------------------
# Shared static-expression analysis (rules 5 and 6)
# ---------------------------------------------------------------------------

# metadata reads that are static under tracing (never force a host sync)
_STATIC_ATTRS = frozenset({"ndim", "shape", "dtype", "size", "sharding",
                           "aval", "itemsize", "nbytes"})
_STATIC_CALLS = frozenset({"isinstance", "len", "getattr", "hasattr",
                           "callable", "type", "issubclass"})


def _dynamic_value_use(node: ast.AST, names: frozenset) -> bool:
    """True if the expression reads the traced *value* of one of ``names``
    (rather than static metadata like ``x.ndim`` / ``x.shape[0]``)."""
    if isinstance(node, ast.Name):
        return node.id in names
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        return _dynamic_value_use(node.value, names)
    if isinstance(node, ast.Subscript):
        return _dynamic_value_use(node.value, names)
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False
        return any(_dynamic_value_use(c, names)
                   for c in [node.left] + node.comparators)
    if isinstance(node, ast.Call):
        fname = node.func.attr if isinstance(node.func, ast.Attribute) else (
            node.func.id if isinstance(node.func, ast.Name) else None)
        if fname in _STATIC_CALLS:
            return False
        if isinstance(node.func, ast.Attribute) \
                and _dynamic_value_use(node.func.value, names):
            return True  # method call on a traced receiver, e.g. x.sum()
        return any(_dynamic_value_use(a, names) for a in node.args)
    if isinstance(node, (ast.BoolOp, ast.BinOp, ast.UnaryOp, ast.IfExp)):
        return any(_dynamic_value_use(c, names) for c in ast.iter_child_nodes(node))
    return False


def _param_names(fn) -> frozenset:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return frozenset(names)


# ---------------------------------------------------------------------------
# Rule 5: host sync inside jitted step functions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HostSyncInJitRule:
    id: str = "host-sync-in-jit"
    description: str = ("float()/.item()/np.asarray on traced values inside "
                        "a jitted function (host sync / trace error)")
    allow: Tuple[str, ...] = ()

    def check(self, ctx: FileContext) -> List[Finding]:
        out = set()
        for fn in ctx.jitted_functions():
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                func = node.func
                if isinstance(func, ast.Attribute) \
                        and func.attr in ("item", "tolist"):
                    out.add(Finding(
                        ctx.path, node.lineno, self.id,
                        f".{func.attr}() inside a jitted function forces a "
                        "host sync"))
                    continue
                r = ctx.resolve(func)
                if r in ("float", "int") and node.args \
                        and not isinstance(node.args[0], ast.Constant) \
                        and _dynamic_value_use(node.args[0], frozenset(
                            n.id for n in ast.walk(node.args[0])
                            if isinstance(n, ast.Name))):
                    out.add(Finding(
                        ctx.path, node.lineno, self.id,
                        f"{r}() on a traced value inside a jitted function "
                        "forces a host sync"))
                elif r is not None and (r.startswith("numpy.")
                                        and r.split(".")[-1] in
                                        ("asarray", "array")):
                    out.add(Finding(
                        ctx.path, node.lineno, self.id,
                        f"{r}() inside a jitted function materializes the "
                        "traced value on host"))
        return sorted(out)


# ---------------------------------------------------------------------------
# Rule 6: Python branches on traced values
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TracerBranchRule:
    id: str = "tracer-branch"
    description: str = ("Python if/while on a traced value inside a jitted "
                        "function (TracerBoolConversionError; use lax.cond/"
                        "jnp.where)")
    allow: Tuple[str, ...] = ()

    def check(self, ctx: FileContext) -> List[Finding]:
        out = set()
        for fn in ctx.jitted_functions():
            params = _param_names(fn)
            for node in ast.walk(fn):
                if isinstance(node, (ast.If, ast.While)) \
                        and _dynamic_value_use(node.test, params):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    out.add(Finding(
                        ctx.path, node.lineno, self.id,
                        f"Python `{kind}` on the traced value of a function "
                        "argument (static checks like .ndim/.shape/`is None` "
                        "are fine; data-dependent control flow needs "
                        "lax.cond / jnp.where)"))
        return sorted(out)


# ---------------------------------------------------------------------------
# Rule 7: swallowed exceptions
# ---------------------------------------------------------------------------

_BROAD_EXC = frozenset({"Exception", "BaseException"})


def _broad_handler(handler: ast.ExceptHandler, ctx: FileContext) -> bool:
    """Bare ``except:``, or a handler naming Exception/BaseException
    (directly or inside a tuple). Narrow handlers (``except TypeError``)
    are the caller saying exactly what it expects — never flagged."""
    t = handler.type
    if t is None:
        return True
    elts = t.elts if isinstance(t, ast.Tuple) else [t]
    for e in elts:
        r = ctx.resolve(e)
        if r is not None and r.split(".")[-1] in _BROAD_EXC:
            return True
    return False


def _handles_or_records(handler: ast.ExceptHandler) -> bool:
    """Does the handler body *do* anything with the failure? Re-raising,
    returning/yielding a fallback, assigning (recording) or calling
    (logging, forwarding through a queue) all count; ``pass``/docstrings/
    ``continue``/``break`` alone do not."""
    for node in ast.walk(handler):
        if isinstance(node, (ast.Raise, ast.Return, ast.Yield, ast.YieldFrom,
                             ast.Call, ast.Assign, ast.AugAssign,
                             ast.AnnAssign, ast.Delete)):
            return True
    return False


@dataclasses.dataclass(frozen=True)
class SwallowedExceptionRule:
    id: str = "swallowed-exception"
    description: str = ("broad except (bare / Exception / BaseException) that "
                        "neither re-raises nor records — failures vanish "
                        "silently")
    allow: Tuple[str, ...] = ()

    def check(self, ctx: FileContext) -> List[Finding]:
        if _allowed(self, ctx):
            return []
        out = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ExceptHandler) \
                    and _broad_handler(node, ctx) \
                    and not _handles_or_records(node):
                out.append(Finding(
                    ctx.path, node.lineno, self.id,
                    "broad exception handler swallows the failure — "
                    "re-raise, narrow the type, or record it (log / store / "
                    "forward), with a `# lint: waive=swallowed-exception` "
                    "comment only for a justified sink"))
        return sorted(out)


# ---------------------------------------------------------------------------
# Rule 8: threading.Thread targets that lose their exceptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ThreadUncapturedTargetRule:
    id: str = "thread-uncaptured-target"
    description: str = ("threading.Thread(target=...) whose target cannot "
                        "surface an exception — a failing worker dies "
                        "silently on the daemon thread")
    allow: Tuple[str, ...] = ()

    def check(self, ctx: FileContext) -> List[Finding]:
        if _allowed(self, ctx):
            return []
        defs = {node.name: node for node in ast.walk(ctx.tree)
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))}
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            r = ctx.resolve(node.func)
            if r is None or r.split(".")[-1] != "Thread" \
                    or not (r == "Thread" or r.startswith("threading.")):
                continue
            target = None
            for kw in node.keywords:
                if kw.arg == "target":
                    target = kw.value
            if target is None:
                continue  # subclass style (run() overridden) — its job
            captured = False
            if isinstance(target, ast.Name) and target.id in defs:
                captured = any(isinstance(n, ast.ExceptHandler)
                               for n in ast.walk(defs[target.id]))
            if not captured:
                out.append(Finding(
                    ctx.path, node.lineno, self.id,
                    "Thread target has no exception capture — wrap the "
                    "worker body in try/except and store or forward the "
                    "failure (re-raised on join/wait), or subclass Thread "
                    "with an error-capturing run()"))
        return sorted(out)


# ---------------------------------------------------------------------------
# Rule 9: wall-clock reads outside repro/obs
# ---------------------------------------------------------------------------

# the sanctioned clock lives in repro/obs/clock.py; every timing read in the
# package goes through it so spans / metrics / ad-hoc timers share one
# timebase. _ns/monotonic variants are the same violation in disguise.
_WALL_CLOCK = frozenset({"time.perf_counter", "time.perf_counter_ns",
                         "time.time", "time.time_ns",
                         "time.monotonic", "time.monotonic_ns"})


@dataclasses.dataclass(frozen=True)
class WallClockOutsideObsRule:
    id: str = "wall-clock-outside-obs"
    description: str = ("time.perf_counter/time.time read outside repro/obs — "
                        "use repro.obs.clock.now()/wall() so every timer "
                        "shares the span/metrics timebase")
    allow: Tuple[str, ...] = ("obs/*",)

    def check(self, ctx: FileContext) -> List[Finding]:
        if _allowed(self, ctx):
            return []
        out = set()

        def add(line, what):
            out.add(Finding(
                ctx.path, line, self.id,
                f"{what} — use repro.obs.clock.now() (perf_counter) or "
                "repro.obs.clock.wall() (time.time) instead"))

        for line, dotted in ctx.imported_names:
            if dotted in _WALL_CLOCK:
                add(line, f"import of {dotted}")
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Attribute, ast.Name)):
                r = ctx.resolve(node)
                if r in _WALL_CLOCK:
                    add(node.lineno, r)
        return sorted(out)


DEFAULT_RULES: Tuple[Rule, ...] = (
    JaxVersionGatedRule(),
    CustomVjpRule(),
    CtxConstructionRule(),
    PrngKeyReuseRule(),
    HostSyncInJitRule(),
    TracerBranchRule(),
    SwallowedExceptionRule(),
    ThreadUncapturedTargetRule(),
    WallClockOutsideObsRule(),
)


def rule_ids() -> List[str]:
    return [r.id for r in DEFAULT_RULES]
