"""Lint findings, inline waivers, and result formatting.

A finding is (path, line, rule id, message). Waivers are explicit inline
comments on the offending line::

    y = risky_thing()  # lint: waive=rule-id

Waived findings are not dropped — they move to ``LintResult.waived`` so
callers can assert "clean with zero waivers" (the migrated ``test_compat``
rules do) or merely "clean modulo reviewed waivers" (the CLI default).
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from typing import Dict, List, Set, Tuple

__all__ = ["Finding", "LintResult", "collect_waivers", "format_findings"]

_WAIVE_RE = re.compile(r"#\s*lint:\s*waive=([\w?*-]+(?:\s*,\s*[\w?*-]+)*)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One structured lint finding (sortable: path, line, rule)."""

    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class LintResult:
    """Findings that stand plus findings suppressed by inline waivers."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    waived: List[Finding] = dataclasses.field(default_factory=list)

    def extend(self, other: "LintResult") -> None:
        self.findings.extend(other.findings)
        self.waived.extend(other.waived)

    def select(self, rules) -> "LintResult":
        rules = set(rules)
        return LintResult(
            findings=[f for f in self.findings if f.rule in rules],
            waived=[f for f in self.waived if f.rule in rules])

    @property
    def ok(self) -> bool:
        return not self.findings


def collect_waivers(source: str) -> Dict[int, Set[str]]:
    """line -> set of waived rule ids, from ``# lint: waive=...`` comments.

    Tokenize-based so a waiver only counts inside a real comment — the
    string ``"# lint: waive=x"`` in a docstring or literal does nothing.
    Unparsable files yield no waivers (the lint runner reports the syntax
    error separately).
    """
    out: Dict[int, Set[str]] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _WAIVE_RE.search(tok.string)
            if m:
                ids = {r.strip() for r in m.group(1).split(",")}
                out.setdefault(tok.start[0], set()).update(ids)
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass
    return out


def apply_waivers(findings: List[Finding], waivers: Dict[int, Set[str]]) -> LintResult:
    res = LintResult()
    for f in sorted(findings):
        if f.rule in waivers.get(f.line, ()):
            res.waived.append(f)
        else:
            res.findings.append(f)
    return res


def format_findings(findings, header: str = "") -> str:
    lines = [header] if header else []
    lines += [str(f) for f in findings]
    return "\n".join(lines)
