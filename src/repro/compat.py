"""Version-portability layer for JAX API drift.

Supported range: jax 0.4.26+ through the 0.7 line (see docs/distributed.md).
All drift handling is feature-detected, never version-compared.

Surfaces that genuinely break somewhere inside that range are centralized
here, and no other module may reference them directly — enforced
symbol-by-symbol by
tests/test_compat.py::test_no_version_gated_jax_symbols_outside_compat:

  * mesh construction — ``jax.make_mesh`` grew an ``axis_types`` kwarg
    (``jax.sharding.AxisType``) in newer releases; older releases predate
    ``jax.make_mesh`` entirely and build ``Mesh(mesh_utils.create_device_mesh)``
  * ``shard_map`` — moved from ``jax.experimental.shard_map`` to ``jax.shard_map``,
    and its replication-check kwarg was renamed ``check_rep`` → ``check_vma``
  * ``jax.tree_util.register_dataclass`` — absent on older releases, and its
    early versions require explicit field lists (bare decorator came later)

The pytree (``jax.tree.*``) and typed-PRNG-key (``jax.random.key``) helpers
below are *stable within the supported range*; they exist for uniform use by
the distributed stack and as best-effort cover below the 0.4.26 floor (where
``jax.tree`` / typed keys are missing), not as enforced gates — modules
outside the distributed stack may call ``jax.tree.*`` directly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import inspect
import os
import warnings
from typing import Any, Optional

import jax

__all__ = [
    "make_mesh",
    "shard_map",
    "ensure_host_devices",
    "enable_compilation_cache",
    "optimization_barrier",
    "prng_key",
    "key_dtype",
    "tree_map",
    "tree_leaves",
    "tree_flatten",
    "tree_unflatten",
    "tree_structure",
    "tree_map_with_path",
    "tree_flatten_with_path",
    "register_dataclass",
    "user_frames",
    "named_scope",
    "trace_annotation",
]


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------


def _axis_types_kw(n_axes: int) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, axes, *, devices=None):
    """Build a ``jax.sharding.Mesh`` on any supported JAX.

    Newer JAX distinguishes Auto/Explicit mesh axes; we always request Auto
    (the pjit-style GSPMD behaviour the whole repo assumes). Older JAX has no
    axis types — plain meshes behave identically.
    """
    shape = tuple(shape)
    axes = tuple(axes)
    if hasattr(jax, "make_mesh"):
        try:
            return jax.make_mesh(shape, axes, devices=devices,
                                 **_axis_types_kw(len(axes)))
        except TypeError:
            return jax.make_mesh(shape, axes, devices=devices)
    from jax.experimental import mesh_utils

    devs = mesh_utils.create_device_mesh(shape, devices=devices)
    return jax.sharding.Mesh(devs, axes)


def ensure_host_devices(n: int) -> None:
    """Request ``n`` fake host-platform XLA devices.

    Must run before the JAX backend initializes (i.e. before any computation
    or device query). A no-op when a device count is already forced — callers
    that layer (conftest forces 8 for the suite; dryrun asks for 512) get the
    outermost request, and should check ``jax.device_count()`` for what they
    actually received.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}").strip()


def enable_compilation_cache(cache_dir: Optional[str] = None, *,
                             min_compile_time_secs: Optional[float] = None) -> bool:
    """Turn on JAX's persistent compilation cache, where this release has it.

    Feature-detected (``jax.config.update`` raises on unknown options —
    absence degrades to a no-op returning False, never a version compare).
    An explicitly configured cache (``JAX_COMPILATION_CACHE_DIR`` env or a
    prior call) is left alone.

    ``min_compile_time_secs=None`` keeps JAX's own threshold (~1 s), which
    caches exactly the expensive compiles worth persisting. Do NOT lower it
    to cache everything: serializing the long tail of sub-second executables
    costs more wall-clock than it saves.

    jax 0.4.37 (jaxlib 0.4.36) CPU is blacklisted outright: an executable
    *reloaded* from the persistent cache loses its input-output aliasing
    metadata, so donated state chains free buffers that are still alive —
    recycled bytes in donated outputs at best, ``malloc_consolidate():
    invalid chunk size`` at worst. Reproduce by running the resilience
    drill twice against a warm cache. This is a version blacklist rather
    than the usual feature detection because the breakage is silent memory
    corruption — there is nothing to probe without tripping it.
    """
    if jax.default_backend() == "cpu" and jax.__version__ == "0.4.37":
        return False
    if cache_dir is None:
        if os.environ.get("JAX_COMPILATION_CACHE_DIR"):
            return True  # explicitly configured — respect it
        import tempfile
        cache_dir = os.path.join(tempfile.gettempdir(), "repro-jax-cache")
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
    except (AttributeError, ValueError, TypeError):
        return False  # release predates the persistent cache
    if min_compile_time_secs is not None:
        try:
            jax.config.update("jax_persistent_cache_min_compile_time_secs",
                              min_compile_time_secs)
        except (AttributeError, ValueError, TypeError):
            pass  # threshold is tuning, not a requirement
    return True


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
    params = inspect.signature(fn).parameters
    if "check_vma" in params:
        flag = "check_vma"
    elif "check_rep" in params:
        flag = "check_rep"
    else:
        flag = None
    return fn, flag


_SHARD_MAP, _SM_CHECK_FLAG = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Portable ``shard_map``.

    ``check`` maps onto ``check_vma`` (new) / ``check_rep`` (old). The repo
    default is False: our bodies mix psum/psum_scatter over axis subsets in
    ways the replication checker rejects on several releases.
    """
    kw = {_SM_CHECK_FLAG: check} if _SM_CHECK_FLAG else {}
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# optimization_barrier
# ---------------------------------------------------------------------------

_OPT_BARRIER_PATCHED = False


def _ensure_barrier_batchable() -> None:
    """Backfill the vmap rule for ``optimization_barrier``.

    The primitive exists throughout the supported range, but releases in it
    (e.g. 0.4.37) ship it without a batching rule, so any ``vmap``/``lax.map
    (batch_size=...)`` over code using a barrier raises NotImplementedError
    (fixed upstream later). The rule is the identity passthrough. Failure to
    patch degrades gracefully — the barrier only guards against fusion
    duplication, not correctness."""
    global _OPT_BARRIER_PATCHED
    if _OPT_BARRIER_PATCHED:
        return
    _OPT_BARRIER_PATCHED = True
    try:
        from jax._src.lax import lax as _lax_internal
        from jax.interpreters import batching

        prim = _lax_internal.optimization_barrier_p
        if prim not in batching.primitive_batchers:
            def _rule(args, dims):
                return prim.bind(*args), dims

            batching.primitive_batchers[prim] = _rule
    except Exception as e:  # pragma: no cover - private path moved
        # degraded, not broken: the barrier still works outside vmap — but
        # say so instead of failing silently on the next vmap'd barrier
        warnings.warn(
            f"could not backfill the optimization_barrier batching rule "
            f"({type(e).__name__}: {e}); vmap over barrier-guarded code may "
            f"raise NotImplementedError on this JAX release", stacklevel=2)


def optimization_barrier(values):
    """``jax.lax.optimization_barrier`` usable under vmap on every supported
    release (see ``_ensure_barrier_batchable``)."""
    _ensure_barrier_batchable()
    return jax.lax.optimization_barrier(values)


# ---------------------------------------------------------------------------
# PRNG keys
# ---------------------------------------------------------------------------


def prng_key(seed: int) -> jax.Array:
    """Typed PRNG key where available, legacy uint32 key otherwise."""
    if hasattr(jax.random, "key"):
        return jax.random.key(seed)
    return jax.random.PRNGKey(seed)


def key_dtype():
    """dtype of a step key — for ShapeDtypeStructs fed to ``jit.lower``."""
    return prng_key(0).dtype


def user_frames(source_info):
    """User-code (file_name, start_line) frames of one jaxpr equation.

    ``eqn.source_info`` provenance lives in ``jax._src.source_info_util``,
    which is internal and has moved across releases — every consumer (the
    sketch-coverage analyzer) goes through here so absence degrades to "no
    provenance" instead of an ImportError.
    """
    try:
        from jax._src import source_info_util as siu
        return [(f.file_name, f.start_line)
                for f in siu.user_frames(source_info)]
    except Exception:
        return []


# ---------------------------------------------------------------------------
# profiler / naming annotations (consumed by repro.obs.tracing)
# ---------------------------------------------------------------------------


def named_scope(name: str):
    """``jax.named_scope`` context manager, or a null context where absent.

    Purely a tracing-time op-naming aid (shows up in HLO / jaxpr dumps);
    absence degrades to nothing.
    """
    fn = getattr(jax, "named_scope", None)
    return fn(name) if fn is not None else contextlib.nullcontext()


def _resolve_trace_annotation():
    try:
        return getattr(jax.profiler, "TraceAnnotation", None)
    except AttributeError:  # pragma: no cover - profiler module absent
        return None


_TRACE_ANNOTATION = _resolve_trace_annotation()


def trace_annotation(name: str):
    """``jax.profiler.TraceAnnotation`` context manager when this release
    has one, else a null context — host-side spans opened through it appear
    on the TraceMe timeline of a real ``jax.profiler`` capture (negligible
    cost outside an active profiling session)."""
    if _TRACE_ANNOTATION is None:  # pragma: no cover - whole range has it
        return contextlib.nullcontext()
    return _TRACE_ANNOTATION(name)


# ---------------------------------------------------------------------------
# pytree ops
# ---------------------------------------------------------------------------

if hasattr(jax, "tree"):
    tree_map = jax.tree.map
    tree_leaves = jax.tree.leaves
    tree_flatten = jax.tree.flatten
    tree_unflatten = jax.tree.unflatten
    tree_structure = jax.tree.structure
else:  # pre-jax.tree releases
    tree_map = jax.tree_util.tree_map
    tree_leaves = jax.tree_util.tree_leaves
    tree_flatten = jax.tree_util.tree_flatten
    tree_unflatten = jax.tree_util.tree_unflatten
    tree_structure = jax.tree_util.tree_structure

tree_map_with_path = jax.tree_util.tree_map_with_path
tree_flatten_with_path = jax.tree_util.tree_flatten_with_path


def register_dataclass(cls):
    """``jax.tree_util.register_dataclass`` with a manual fallback.

    Early releases of ``register_dataclass`` require explicit
    ``data_fields``/``meta_fields`` (bare-decorator field inference came
    later), so a bare call can raise TypeError even where the symbol exists —
    both absence and that signature fall through to manual registration.
    """
    if hasattr(jax.tree_util, "register_dataclass"):
        try:
            return jax.tree_util.register_dataclass(cls)
        except TypeError:
            pass

    fields = [f.name for f in dataclasses.fields(cls)]

    def flatten(obj):
        return tuple(getattr(obj, f) for f in fields), None

    def unflatten(_, children):
        return cls(**dict(zip(fields, children)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls
