"""Spans & tracing: nestable wall-clock spans with Chrome-trace export.

The span model (docs/observability.md):

* a **span** is a named interval ``[t0, t1)`` on the shared monotonic
  clock (:mod:`repro.obs.clock`), with an integer ``sid``, an optional
  ``parent`` sid, the opening thread's id, and free-form scalar ``attrs``;
* ``with tracer.span("decode_step", live=n):`` opens a child of the
  innermost open span on the current thread (per-thread stacks — the
  checkpoint writer thread records I/O spans concurrently);
* :meth:`Tracer.add_span` records a span from *explicit* timestamps after
  the fact — how the serving engine turns each finished request's existing
  stamps (submit/admit/first/done) into a queued→prefill→decode lifecycle
  without touching the hot loop;
* completed spans land in a bounded ring (oldest dropped), exportable as
  Chrome-trace/Perfetto JSON (:meth:`to_chrome`) or JSONL through the
  telemetry sink machinery (:meth:`export_jsonl`).

When ``annotate=True`` each context-manager span also opens a
``jax.profiler.TraceAnnotation`` (via :mod:`repro.compat`), so spans appear
on the host timeline of a real profiler capture.

Zero-cost-when-off: callers hold a module-singleton :data:`NULL_TRACER`
whose ``span()`` returns one shared no-op context manager — no allocation,
no clock read — and hot loops additionally guard on ``tracer.enabled`` so
the off path doesn't even build the attrs dict.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
from collections import deque
from typing import Dict, List, Optional

from repro import compat
from repro.obs import clock

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER"]


class Span:
    """One completed (or open) interval. ``t1 < 0`` marks still-open spans
    in flight-recorder dumps taken mid-crash."""

    __slots__ = ("sid", "parent", "name", "t0", "t1", "tid", "attrs")

    def __init__(self, sid: int, parent: Optional[int], name: str,
                 t0: float, t1: float, tid: int, attrs: Optional[dict]):
        self.sid = sid
        self.parent = parent
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.tid = tid
        self.attrs = attrs

    @property
    def duration_s(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def to_record(self) -> dict:
        rec = {"sid": self.sid, "parent": self.parent, "name": self.name,
               "t0": self.t0, "t1": self.t1, "dur_s": self.duration_s,
               "tid": self.tid}
        if self.attrs:
            rec.update(self.attrs)
        return rec


class _SpanCtx:
    """Context manager for one live span (a tiny class, not a generator —
    the hot loops open one per decode step)."""

    __slots__ = ("_tracer", "_span", "_jax")

    def __init__(self, tracer: "Tracer", name: str, attrs: Optional[dict]):
        self._tracer = tracer
        self._span = Span(next(tracer._ids), None, name, 0.0, -1.0,
                          threading.get_ident(), attrs)
        self._jax = None

    def __enter__(self) -> Span:
        tr = self._tracer
        stack = tr._stack()
        if stack:
            self._span.parent = stack[-1].sid
        stack.append(self._span)
        if tr._annotate:
            self._jax = compat.trace_annotation(self._span.name)
            self._jax.__enter__()
        self._span.t0 = clock.now()
        return self._span

    def __exit__(self, exc_type, exc, tb):
        self._span.t1 = clock.now()
        if self._jax is not None:
            self._jax.__exit__(exc_type, exc, tb)
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] is self._span:
            stack.pop()
        elif self._span in stack:  # pragma: no cover - unbalanced exit
            stack.remove(self._span)
        if exc_type is not None and self._span.attrs is not None:
            self._span.attrs.setdefault("error", exc_type.__name__)
        elif exc_type is not None:
            self._span.attrs = {"error": exc_type.__name__}
        tr._buf.append(self._span)
        return False


class Tracer:
    """Bounded ring of completed spans + per-thread open-span stacks.

    Thread-safe by construction: span ids come from an atomic counter, the
    ring is a ``deque(maxlen=...)``, and nesting state is ``threading.local``
    — the trainer's main loop and the checkpoint writer thread trace
    concurrently without locks.
    """

    enabled = True

    def __init__(self, capacity: int = 4096, *, annotate: bool = False):
        self._buf: deque = deque(maxlen=int(capacity))
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self._annotate = bool(annotate)
        self.origin = clock.now()

    # -- recording ----------------------------------------------------------

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def span(self, name: str, **attrs) -> _SpanCtx:
        """Open a nested span: ``with tracer.span("train_step", step=i):``."""
        return _SpanCtx(self, name, attrs or None)

    def add_span(self, name: str, t0: float, t1: float, *,
                 parent: Optional[int] = None, tid: int = 0,
                 **attrs) -> int:
        """Record a span from explicit ``clock.now()`` stamps (post-hoc —
        per-request lifecycles reconstructed at finish time). Returns the
        span id, so callers can join it onto other records (the serve ring)
        and parent further sub-spans under it."""
        sid = next(self._ids)
        self._buf.append(Span(sid, parent, name, t0, t1, tid, attrs or None))
        return sid

    def current_id(self) -> Optional[int]:
        """sid of the innermost open span on this thread (None outside)."""
        stack = getattr(self._tls, "stack", None)
        return stack[-1].sid if stack else None

    def clear(self) -> None:
        self._buf.clear()

    # -- reading / export ---------------------------------------------------

    def spans(self, name: Optional[str] = None) -> List[Span]:
        out = list(self._buf)
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def records(self) -> List[dict]:
        return [s.to_record() for s in self.spans()]

    def to_chrome(self) -> dict:
        """Chrome-trace JSON object (the format Perfetto / chrome://tracing
        load): complete-events (``ph: "X"``), microsecond timestamps
        relative to the tracer origin, span id/parent under ``args``."""
        events = []
        for s in self.spans():
            t1 = s.t1 if s.t1 >= s.t0 else s.t0  # still-open: zero width
            args: Dict[str, object] = {"span_id": s.sid}
            if s.parent is not None:
                args["parent_id"] = s.parent
            if s.attrs:
                args.update(s.attrs)
            events.append({
                "name": s.name, "ph": "X", "pid": 1, "tid": s.tid,
                "ts": (s.t0 - self.origin) * 1e6,
                "dur": (t1 - s.t0) * 1e6,
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> str:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=str)
        return path

    def export_jsonl(self, path: str) -> str:
        """One JSON object per completed span, through the telemetry
        :class:`~repro.telemetry.sinks.JsonlSink` (the repo's one JSONL
        writer)."""
        from repro.telemetry.sinks import JsonlSink

        sink = JsonlSink(path)
        try:
            for rec in self.records():
                sink.write(rec)
        finally:
            sink.close()
        return path


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_CTX = _NullCtx()


class NullTracer:
    """Tracing disabled: one shared no-op context, no clock reads, no
    allocation. ``bool(NULL_TRACER)`` is False so hot paths can guard with
    ``if tracer:``."""

    enabled = False
    origin = 0.0

    def __bool__(self) -> bool:
        return False

    def span(self, name: str, **attrs) -> _NullCtx:
        return _NULL_CTX

    def add_span(self, name: str, t0: float, t1: float, *, parent=None,
                 tid: int = 0, **attrs) -> None:
        return None

    def current_id(self) -> None:
        return None

    def clear(self) -> None:
        pass

    def spans(self, name: Optional[str] = None) -> list:
        return []

    def records(self) -> list:
        return []

    def to_chrome(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_TRACER = NullTracer()
