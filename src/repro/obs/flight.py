"""Flight recorder: bounded recent history, dumped as a crash bundle.

The recorder holds references to the run's tracer and metrics registry plus
two small rings of its own — periodic metrics snapshots and notable events
(fault injections, recovery records). On a crash-worthy condition
(``RollbackRequired``, ``CheckpointError``, device loss — the resilience
``Supervisor`` is the main caller, the trainer dumps on checkpoint-IO
faults) :meth:`dump` writes a **crash bundle**: one JSON directory under the
configured ``crash_dir``.

Bundle layout (docs/observability.md)::

    <crash_dir>/crash_<seq>_<reason>/
        meta.json     # reason, wall time, counts, extra context
        spans.json    # recent spans, Chrome-trace form (Perfetto-loadable)
        metrics.json  # latest registry snapshot + the snapshot ring
        events.json   # noted events (faults, recoveries), oldest first

Bundle names are deterministic (a per-recorder sequence number, no
timestamps in paths) so fault-injection drills assert exact paths.
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import List, Optional

from repro.obs import clock

__all__ = ["FlightRecorder"]


def _write_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=str)


class FlightRecorder:
    def __init__(self, tracer, registry, *, capacity: int = 256):
        self.tracer = tracer
        self.registry = registry
        self._snaps: deque = deque(maxlen=64)
        self._events: deque = deque(maxlen=int(capacity))
        self.dumps: List[str] = []

    # -- feeding ------------------------------------------------------------

    def note(self, record: dict) -> None:
        """Remember one notable event (fault injected, rollback, checkpoint
        retry) — shape-compatible with ``telemetry.sinks.recovery_record``."""
        self._events.append(dict(record))

    def snapshot(self, step: Optional[int] = None) -> None:
        """Snapshot the metrics registry (cheap: one flat dict copy)."""
        if self.registry is None:
            return
        snap = {"step": step, "at": clock.now()}
        snap.update(self.registry.snapshot())
        self._snaps.append(snap)

    # -- dumping ------------------------------------------------------------

    def dump(self, crash_dir: str, reason: str, extra: Optional[dict] = None) -> str:
        """Write one crash bundle; returns its directory path."""
        seq = len(self.dumps)
        safe = "".join(c if c.isalnum() or c in "-_" else "_" for c in reason)
        path = os.path.join(crash_dir, f"crash_{seq:03d}_{safe}")
        os.makedirs(path, exist_ok=True)
        spans = self.tracer.to_chrome() if self.tracer is not None else None
        _write_json(os.path.join(path, "meta.json"), {
            "reason": reason,
            "wall_time": clock.wall(),
            "n_spans": len(spans["traceEvents"]) if spans else 0,
            "n_metric_snapshots": len(self._snaps),
            "n_events": len(self._events),
            "extra": extra or {},
        })
        if spans is not None:
            _write_json(os.path.join(path, "spans.json"), spans)
        _write_json(os.path.join(path, "metrics.json"), {
            "latest": self.registry.snapshot() if self.registry else {},
            "snapshots": list(self._snaps),
        })
        _write_json(os.path.join(path, "events.json"), list(self._events))
        self.dumps.append(path)
        return path
