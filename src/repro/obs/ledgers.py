"""Compile & memory ledgers: where compile time and HBM actually go.

**Compile ledger** — one entry per executable built through
``Runtime.train_step`` (and per ``launch/dryrun`` lower+compile), keyed by
the same human-readable description of the Runtime step-cache key
(``(runtime, arch, opt, budget, donate)``), recording trace/compile wall
seconds and subsequent cache **hits** — the machine-readable answer to "how
many distinct executables did this run/suite build, and what did each cost"
(the tier-1 warm-run wall-time floor; conftest can dump the process-global
ledger via ``REPRO_COMPILE_LEDGER``).

**Memory ledger** — per-compiled-step ``compiled.memory_analysis()``
(argument/output/temp/alias, peak bytes per device — same fields the dry-run
records) plus live ``device.memory_stats()`` samples where real hardware
provides them (feature-detected; host CPU devices return nothing).

Both are plain-python and bounded-cost: entries are appended only at compile
time (rare) or on explicit ``sample()`` calls, never in the step hot loop.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, List, Optional

from repro.obs import clock

__all__ = ["CompileLedger", "MemoryLedger", "memory_summary",
           "device_memory_stats", "GLOBAL_COMPILE_LEDGER", "global_active",
           "GLOBAL_ENV"]

GLOBAL_ENV = "REPRO_COMPILE_LEDGER"


def memory_summary(ma, hbm_bytes: Optional[int] = None) -> dict:
    """``memory_analysis()`` result → the repo's standard GB-per-device dict
    (the exact field set ``launch/dryrun`` has always recorded; ``fits_hbm``
    only when an HBM size is given)."""
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    out = {
        "argument_GB_per_dev": ma.argument_size_in_bytes / 1e9,
        "output_GB_per_dev": ma.output_size_in_bytes / 1e9,
        "temp_GB_per_dev": ma.temp_size_in_bytes / 1e9,
        "alias_GB_per_dev": ma.alias_size_in_bytes / 1e9,
        "peak_GB_per_dev": peak / 1e9,
    }
    if hbm_bytes is not None:
        out["fits_hbm"] = peak < hbm_bytes
    return out


def device_memory_stats() -> List[dict]:
    """Live per-device allocator stats where the backend offers them.

    Real TPU/GPU devices expose ``memory_stats()`` (bytes in use, peak,
    limit); host-CPU fakes either lack the method or return ``None`` — those
    devices are simply omitted, so on the test mesh this is ``[]``.
    """
    import jax

    out = []
    for d in jax.devices():
        fn = getattr(d, "memory_stats", None)
        if fn is None:
            continue
        try:
            stats = fn()
        except (RuntimeError, NotImplementedError):
            stats = None
        if stats:
            out.append({"device": str(d), **{k: v for k, v in stats.items()}})
    return out


class CompileLedger:
    """Append-only record of executable builds and step-cache hits."""

    def __init__(self):
        self._lock = threading.Lock()
        self.entries: List[dict] = []
        self._hits: Dict[str, int] = {}

    def record_compile(self, key: str, *, trace_s: Optional[float] = None,
                       compile_s: Optional[float] = None,
                       first_call_s: Optional[float] = None,
                       **extra) -> dict:
        entry = {"key": key, "event": "compile", "at": clock.now(),
                 "trace_s": trace_s, "compile_s": compile_s,
                 "first_call_s": first_call_s}
        entry.update(extra)
        with self._lock:
            self.entries.append(entry)
        return entry

    def record_hit(self, key: str) -> None:
        with self._lock:
            self._hits[key] = self._hits.get(key, 0) + 1

    def summary(self) -> dict:
        with self._lock:
            entries = list(self.entries)
            hits = dict(self._hits)
        compile_s = sum(e["compile_s"] or 0.0 for e in entries)
        first_s = sum(e["first_call_s"] or 0.0 for e in entries)
        return {"compiles": len(entries), "hits": sum(hits.values()),
                "distinct_keys": len({e["key"] for e in entries} | set(hits)),
                "total_compile_s": compile_s,
                "total_first_call_s": first_s}

    def to_json(self) -> dict:
        summary = self.summary()  # takes the lock itself — don't hold it here
        with self._lock:
            return {"summary": summary,
                    "hits_by_key": dict(self._hits),
                    "entries": [dict(e) for e in self.entries]}

    def write(self, path: str) -> str:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, default=str)
        return path


class MemoryLedger:
    """Per-executable memory analyses + on-demand live device samples."""

    def __init__(self):
        self._lock = threading.Lock()
        self.by_key: Dict[str, dict] = {}
        self.samples: List[dict] = []

    def record(self, key: str, ma_or_summary: Any) -> dict:
        summ = (ma_or_summary if isinstance(ma_or_summary, dict)
                else memory_summary(ma_or_summary))
        with self._lock:
            self.by_key[key] = summ
        return summ

    def sample(self, label: str = "") -> List[dict]:
        stats = device_memory_stats()
        if stats:
            with self._lock:
                self.samples.append({"label": label, "at": clock.now(),
                                     "devices": stats})
        return stats

    def to_json(self) -> dict:
        with self._lock:
            return {"by_key": {k: dict(v) for k, v in self.by_key.items()},
                    "live_samples": [dict(s) for s in self.samples]}


# Process-global compile ledger: opt-in via the REPRO_COMPILE_LEDGER env var
# (conftest dumps it to results/compile_ledger.json after the tier-1 suite).
GLOBAL_COMPILE_LEDGER = CompileLedger()


def global_active() -> bool:
    return bool(os.environ.get(GLOBAL_ENV))
