"""repro.obs — execution observability: spans, metrics, ledgers, flight data.

The *numerical* half of the paper's trade-off is instrumented by
``repro.telemetry`` (per-site variance probes); this package instruments the
*execution* half — where wall-clock, compile time and HBM actually go —
across training, serving and recovery:

* :mod:`repro.obs.tracing` — nestable wall-clock spans
  (``with tracer.span("decode_step", ...)``), Chrome-trace/Perfetto + JSONL
  export, per-request lifecycle reconstruction;
* :mod:`repro.obs.metrics` — one Counter/Gauge/Histogram registry behind
  the old ad-hoc counter dicts (``serve``/``resilience``), JSONL snapshots
  and Prometheus text exposition;
* :mod:`repro.obs.ledgers` — compile ledger (per-executable trace/compile
  time + step-cache hits) and memory ledger (``memory_analysis()`` + live
  ``device.memory_stats()`` where hardware has them);
* :mod:`repro.obs.flight` — bounded recent-history ring dumped as a crash
  bundle by the resilience Supervisor;
* :mod:`repro.obs.clock` — the one sanctioned wall-clock source
  (lint-enforced: ``time.perf_counter``/``time.time`` are forbidden in
  ``src/`` outside this package).

:class:`ObsConfig` below is the static, hashable switchboard riding on
:class:`repro.api.ExecutionConfig` (``ExecutionConfig.obs`` — the same
pattern as ``TelemetryConfig``). Because the config is hashable and
equal-by-value, :func:`observability` returns one shared mutable
:class:`Observability` per distinct config — the same keyed-state pattern as
the Runtime step cache — so a Runtime, its trainer, its serving engine and
its Supervisor all feed one tracer/registry/ledger set. ``None`` (the
default) yields the :data:`NULL_OBS` singleton: null tracer, no registries,
zero cost on hot paths. See docs/observability.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.obs import clock  # noqa: F401  (re-export: the sanctioned clock)
from repro.obs.flight import FlightRecorder
from repro.obs.ledgers import (CompileLedger, MemoryLedger,
                               GLOBAL_COMPILE_LEDGER, global_active)
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import NULL_TRACER, Tracer

__all__ = ["ObsConfig", "Observability", "observability", "NULL_OBS"]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Static observability switchboard (frozen/hashable — safe on
    ExecutionConfig and therefore inside jit-cache keys).

    Attributes:
      trace: record wall-clock spans on the instrumented paths (trainer
        step/compile/checkpoint-wait, serve request lifecycle, recovery).
      metrics: route counters/gauges through the unified registry (the old
        dict spellings keep working either way — off just means each
        component gets a private registry nothing ever exports).
      compile_ledger / memory_ledger: record per-executable compile wall
        time + cache hits / ``memory_analysis()`` for steps built through
        ``Runtime.train_step`` (first call per executable runs AOT
        lower+compile so the phases can be timed separately).
      flight: keep the bounded recent-history ring and allow crash bundles.
      annotate: additionally open ``jax.profiler.TraceAnnotation`` per span
        (shows up in real profiler captures; off by default).
      trace_capacity / flight_capacity: ring sizes (completed spans /
        noted events).
      chrome_trace / trace_jsonl: optional export paths written by
        ``Observability.export()`` (the trainer and serving engine call it
        at loop end).
      crash_dir: directory for flight-recorder crash bundles; ``None``
        disables dumping (the ring still fills).
    """

    trace: bool = True
    metrics: bool = True
    compile_ledger: bool = True
    memory_ledger: bool = True
    flight: bool = True
    annotate: bool = False
    trace_capacity: int = 4096
    flight_capacity: int = 256
    chrome_trace: Optional[str] = None
    trace_jsonl: Optional[str] = None
    crash_dir: Optional[str] = None

    def __post_init__(self):
        if self.trace_capacity < 1:
            raise ValueError(f"trace_capacity must be >= 1, got "
                             f"{self.trace_capacity}")
        if self.flight_capacity < 1:
            raise ValueError(f"flight_capacity must be >= 1, got "
                             f"{self.flight_capacity}")


class Observability:
    """The mutable observability state for one :class:`ObsConfig`.

    Shared by every component constructed from an equal config (see
    :func:`observability`); ``NULL_OBS`` is the disabled singleton.
    """

    def __init__(self, cfg: Optional[ObsConfig]):
        self.cfg = cfg
        self.enabled = cfg is not None
        trace_on = self.enabled and cfg.trace
        self.tracer = (Tracer(cfg.trace_capacity, annotate=cfg.annotate)
                       if trace_on else NULL_TRACER)
        self.metrics = MetricsRegistry() if (self.enabled and cfg.metrics) else None
        self.compile_ledger = (CompileLedger()
                               if self.enabled and cfg.compile_ledger else None)
        self.memory_ledger = (MemoryLedger()
                              if self.enabled and cfg.memory_ledger else None)
        self.flight = (FlightRecorder(self.tracer if trace_on else None,
                                      self.metrics,
                                      capacity=cfg.flight_capacity)
                       if self.enabled and cfg.flight else None)
        # components: (name, registry) pairs adopted from multi-instance
        # subsystems (each serving engine owns its counters but registers
        # here so report()/prometheus() see them)
        self.components: List[Tuple[str, MetricsRegistry]] = []

    # -- component registries ----------------------------------------------

    def adopt(self, name: str, registry: MetricsRegistry) -> None:
        if self.enabled:
            self.components.append((name, registry))

    def _registries(self) -> List[Tuple[str, MetricsRegistry]]:
        regs: List[Tuple[str, MetricsRegistry]] = []
        if self.metrics is not None:
            regs.append(("", self.metrics))
        regs.extend(self.components)
        return regs

    def metrics_snapshot(self) -> dict:
        """Merged flat snapshot across the root registry and every adopted
        component registry (later duplicates get ``#<n>`` suffixes)."""
        out: Dict[str, object] = {}
        for _, reg in self._registries():
            for k, v in reg.snapshot().items():
                key, n = k, 1
                while key in out:
                    key = f"{k}#{n}"
                    n += 1
                out[key] = v
        return out

    def prometheus(self) -> str:
        return "".join(reg.to_prometheus() for _, reg in self._registries())

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        """One JSON-ready dict: compile hit/miss, per-step memory, metrics.
        (``Runtime.observability().report()`` is the documented read path.)"""
        if not self.enabled:
            return {"enabled": False}
        out: Dict[str, object] = {"enabled": True}
        if self.compile_ledger is not None:
            out["compile"] = self.compile_ledger.to_json()
        if self.memory_ledger is not None:
            out["memory"] = self.memory_ledger.to_json()
        out["metrics"] = self.metrics_snapshot()
        out["n_spans"] = len(self.tracer.spans())
        return out

    def export(self) -> List[str]:
        """Write the configured trace exports; returns the paths written."""
        paths = []
        if self.enabled and self.tracer.enabled:
            if self.cfg.chrome_trace:
                paths.append(self.tracer.export_chrome(self.cfg.chrome_trace))
            if self.cfg.trace_jsonl:
                paths.append(self.tracer.export_jsonl(self.cfg.trace_jsonl))
        return paths

    def dump_crash(self, reason: str, extra: Optional[dict] = None) -> Optional[str]:
        """Flight-recorder crash bundle (None when flight recording or
        ``crash_dir`` is off — callers need no guards)."""
        if self.flight is None or not self.cfg.crash_dir:
            return None
        return self.flight.dump(self.cfg.crash_dir, reason, extra)


NULL_OBS = Observability(None)

# One shared Observability per distinct ObsConfig — same keyed-state idiom
# as the Runtime step cache (module-level so equal configs share state).
_OBS: Dict[ObsConfig, Observability] = {}


def observability(cfg: Optional[ObsConfig]) -> Observability:
    """The shared :class:`Observability` for ``cfg`` (``NULL_OBS`` for None)."""
    if cfg is None:
        return NULL_OBS
    ob = _OBS.get(cfg)
    if ob is None:
        ob = _OBS[cfg] = Observability(cfg)
    return ob


def _reset() -> None:  # test hook
    _OBS.clear()
