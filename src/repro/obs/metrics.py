"""Unified metrics registry: Counter / Gauge / Histogram.

One :class:`MetricsRegistry` replaces the ad-hoc counter dicts that used to
live in ``serve/engine.py`` (``wasted_decode_steps`` & co),
``serve/legacy.py`` (``dead_slot_steps``) and the resilience supervisor
(``recoveries``). The old spellings keep working through
:class:`CounterView` — a MutableMapping over a name prefix, so
``engine.counters["decode_steps"] += 1`` still reads like a dict while the
values live in the registry and reach every exporter.

Naming convention (see docs/observability.md): dotted lowercase paths,
``<component>.<name>`` (``serve.decode_steps``, ``resilience.recoveries``,
``train.steps``). Exposition: :meth:`MetricsRegistry.snapshot` (flat dict →
JSONL via the telemetry sinks) and :meth:`MetricsRegistry.to_prometheus`
(text format; dots become underscores).
"""
from __future__ import annotations

import bisect
from typing import Dict, Iterable, List, Optional, Tuple

try:  # MutableMapping moved to collections.abc (removed from collections in 3.10)
    from collections.abc import MutableMapping
except ImportError:  # pragma: no cover
    from collections import MutableMapping  # type: ignore

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "CounterView",
           "DEFAULT_BUCKETS"]

# Exponential latency-ish buckets (seconds): 1 µs .. ~67 s, doubling.
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(1e-6 * 2 ** i for i in range(27))


class Counter:
    """Monotonically *intended* counter (floats allowed: the serve views
    accumulate seconds into ``prefill_s``/``decode_s``). ``set`` exists for
    the dict-compatible views; prefer ``inc``."""

    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set(self, v: float) -> None:
        self.value = float(v)


class Gauge:
    """Point-in-time value (queue depth, live slots, budget)."""

    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Fixed-bucket histogram (bounded memory — no sample retention).

    ``buckets`` are upper bounds (``le``); an implicit +inf bucket catches
    the tail. ``observe`` is O(log n) via bisection on the static bounds.
    """

    __slots__ = ("name", "buckets", "counts", "count", "total", "min", "max")
    kind = "histogram"

    def __init__(self, name: str, buckets: Optional[Iterable[float]] = None):
        self.name = name
        self.buckets = tuple(sorted(buckets)) if buckets else DEFAULT_BUCKETS
        self.counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.total += v
        self.min = v if self.min is None or v < self.min else self.min
        self.max = v if self.max is None or v > self.max else self.max

    def summary(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "mean": (self.total / self.count) if self.count else None,
                "min": self.min, "max": self.max}


class MetricsRegistry:
    """Name → metric map with idempotent constructors (asking twice for the
    same name returns the same instance; a kind mismatch is a bug)."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, factory, *args):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = factory(name, *args)
        elif not isinstance(m, factory):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {factory.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, buckets=None) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(name, buckets)
        elif not isinstance(m, Histogram):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not Histogram")
        return m

    def view(self, prefix: str, keys: Iterable[str]) -> "CounterView":
        """Dict-shaped view over ``{prefix}.{key}`` counters — the migration
        shim for the old ad-hoc counter dicts."""
        return CounterView(self, prefix, keys)

    def snapshot(self) -> dict:
        """Flat scalar dict (histograms expand to ``name.count`` etc.) —
        sink-ready: feed it to a telemetry ``JsonlSink`` as one record."""
        out: Dict[str, object] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                for k, v in m.summary().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = m.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (metric names: dots → underscores)."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            pname = name.replace(".", "_").replace("-", "_")
            if isinstance(m, Histogram):
                lines.append(f"# TYPE {pname} histogram")
                acc = 0
                for le, c in zip(m.buckets, m.counts):
                    acc += c
                    lines.append(f'{pname}_bucket{{le="{le:g}"}} {acc}')
                lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{pname}_sum {m.total:g}")
                lines.append(f"{pname}_count {m.count}")
            else:
                lines.append(f"# TYPE {pname} {m.kind}")
                lines.append(f"{pname} {m.value:g}")
        return "\n".join(lines) + ("\n" if lines else "")


class CounterView(MutableMapping):
    """MutableMapping facade over registry counters under one prefix.

    Preserves the ad-hoc-dict ergonomics the serve/resilience code (and its
    tests) rely on — ``c["tokens_out"] += n``, ``dict(c)``, ``c.update`` —
    while the values live as ``{prefix}.{key}`` counters in the registry.
    New keys may be added by assignment (mirrors dict behaviour).
    """

    __slots__ = ("_reg", "_prefix", "_keys")

    def __init__(self, registry: MetricsRegistry, prefix: str,
                 keys: Iterable[str] = ()):
        self._reg = registry
        self._prefix = prefix
        self._keys = []
        for k in keys:
            self[k] = 0.0

    def _name(self, key: str) -> str:
        return f"{self._prefix}.{key}"

    def __getitem__(self, key: str) -> float:
        if key not in self._keys:
            raise KeyError(key)
        v = self._reg.counter(self._name(key)).value
        return int(v) if float(v).is_integer() else v

    def __setitem__(self, key: str, value: float) -> None:
        if key not in self._keys:
            self._keys.append(key)
        self._reg.counter(self._name(key)).set(value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("CounterView keys cannot be deleted — registry "
                        "metrics persist for exporters")

    def __iter__(self):
        return iter(list(self._keys))

    def __len__(self) -> int:
        return len(self._keys)

    def __repr__(self) -> str:
        return f"CounterView({dict(self)!r})"
