"""The one sanctioned wall-clock source.

Every host-side timing in ``src/`` routes through this module — the
``wall-clock-outside-obs`` lint rule (``repro.analysis``) forbids
``time.perf_counter`` / ``time.time`` anywhere else under ``src/repro`` so
that spans, metrics and ledgers all share one monotonic timebase and no
module quietly grows its own ad-hoc timing again.

``now()`` is the monotonic timestamp used by spans, the serving engine's
request stamps, the straggler controller and the ledgers; ``wall()`` is
epoch time, only for labeling artifacts (crash-bundle metadata).
"""
from __future__ import annotations

import time

__all__ = ["now", "wall"]

now = time.perf_counter
wall = time.time
