"""Run-to-completion serving baseline (the pre-continuous-batching engine).

Requests are served in fixed batches: one prefill per batch (right-padded to
the batch's longest prompt, segment-masked so pads never leak into
attention), then **every** slot decodes ``max(max_new)`` steps — a slot that
finished early keeps burning decode work until the stragglers catch up, and
a shorter final batch decodes padding lanes. Neither loss is hidden:
``wasted_decode_steps`` counts finished-slot steps and ``dead_slot_steps``
counts padding-lane steps, which is exactly the gap the continuous engine
(`repro.serve.engine`) closes; ``benchmarks/bench_serve.py`` measures both
sides on the same workload. There is no queue, no eviction, no per-slot stop
(eos is ignored), and prefill retraces per distinct padded prompt length
(see ``trace_counts``).

Greedy outputs are byte-identical to the continuous engine and to sequential
single-request decoding — test-enforced in tests/test_serve.py.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.runtime import Runtime
from repro.configs.base import ArchConfig
from repro.obs import clock, observability
from repro.obs.metrics import MetricsRegistry
from repro.serve.scheduler import Request
from repro.serve.serve_step import greedy_sample
from repro.telemetry.sinks import RingSink

__all__ = ["Request", "RunToCompletionEngine"]


class RunToCompletionEngine:
    def __init__(self, params, cfg: ArchConfig, *, batch: int = 4,
                 max_len: int = 256, runtime: Optional[Runtime] = None):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.runtime = runtime if runtime is not None else Runtime()
        self.trace_counts: dict = {}
        pref_raw = self.runtime.prefill_step(cfg, max_len)
        dec_raw = self.runtime.decode_step(cfg)

        def pf(params, batch_d, last_idx):
            self._count(f"prefill[{batch_d['tokens'].shape[1]}]")
            logits, caches = pref_raw(params, batch_d)
            lg = jnp.take_along_axis(logits, last_idx[:, None, None], axis=1)
            return greedy_sample(lg)[:, 0], caches

        def dc(params, caches, toks, pos):
            self._count("decode")
            logits, new = dec_raw(params, caches, toks, pos)
            return greedy_sample(logits)[:, 0], new

        self._prefill = jax.jit(pf)
        self._decode = jax.jit(dc)
        self.obs = observability(self.runtime.execution.obs)
        self.metrics = MetricsRegistry()
        if self.obs.metrics is not None:
            self.obs.adopt("serve_legacy", self.metrics)
        self.counters = self.metrics.view(
            "serve_legacy",
            ("batches", "prefill_calls", "prefill_tokens", "decode_steps",
             "tokens_out", "truncated_tokens", "dead_slot_steps",
             "wasted_decode_steps", "prefill_s", "decode_s"))
        self.ring = RingSink(capacity=256)

    def _count(self, key: str):
        self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests in fixed-size run-to-completion batches.

        Admission checks up front (before any device work): an empty prompt
        is rejected, as is a ``max_new`` that cannot fit the engine's
        ``max_len`` KV budget even with the whole prompt truncated away.
        Over-long prompts are *left*-truncated to ``max_len - max_new`` —
        the most recent context survives — and the dropped token count is
        recorded (``counters["truncated_tokens"]`` + the per-batch ring).
        """
        for i, r in enumerate(requests):
            if len(r.prompt) == 0:
                raise ValueError(f"request {i}: empty prompt")
            if r.max_new <= 0:
                raise ValueError(f"request {i}: max_new must be >= 1, "
                                 f"got {r.max_new}")
            if r.max_new >= self.max_len:
                raise ValueError(
                    f"request {i}: max_new={r.max_new} leaves no room for "
                    f"any prompt token within max_len={self.max_len}")
        for i in range(0, len(requests), self.batch):
            self._run_batch(requests[i:i + self.batch])
        return requests

    def _run_batch(self, reqs: List[Request]):
        B, N = len(reqs), self.batch
        prompts, truncated = [], 0
        for r in reqs:
            p = np.asarray(r.prompt, np.int32)
            keep = self.max_len - r.max_new
            if len(p) > keep:
                truncated += len(p) - keep
                p = p[-keep:]  # keep the most recent context
            prompts.append(p)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((N, plen), np.int32)
        segs = np.zeros((N, plen), np.int32)
        lens = np.zeros(N, np.int32)
        for j, p in enumerate(prompts):
            toks[j, :len(p)] = p  # right-pad; pads are segment-masked out
            segs[j, :len(p)] = 1
            lens[j] = len(p)
        last_idx = np.maximum(lens - 1, 0)
        t0 = clock.now()
        first, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks), "segments": jnp.asarray(segs)},
            jnp.asarray(last_idx))
        first_np = np.asarray(first)
        t_prefill = clock.now() - t0
        outs = [[int(first_np[j])] for j in range(B)]
        max_new = max(r.max_new for r in reqs)
        cur = first[:, None]
        pos = jnp.asarray(lens)  # per-slot positions (heterogeneous prompts)
        wasted = dead = 0
        t0 = clock.now()
        for t in range(1, max_new):
            # every slot decodes every step — that is the run-to-completion
            # deal. One [N] host transfer per step (dead-slot discipline).
            nxt, caches = self._decode(self.params, caches, cur, pos)
            step_tok = np.asarray(nxt)
            for j in range(B):
                outs[j].append(int(step_tok[j]))
            wasted += sum(1 for r in reqs if t >= r.max_new)
            dead += N - B
            cur = nxt[:, None]
            pos = pos + 1
        jax.block_until_ready(cur)
        t_decode = clock.now() - t0
        for j, r in enumerate(reqs):
            r.out = np.asarray(outs[j][:r.max_new], np.int32)
            r.stop = "length"
        tokens_out = sum(r.max_new for r in reqs)
        c = self.counters
        c["batches"] += 1
        c["prefill_calls"] += 1
        c["prefill_tokens"] += N * plen
        c["decode_steps"] += max_new - 1
        c["tokens_out"] += tokens_out
        c["truncated_tokens"] += truncated
        c["dead_slot_steps"] += dead
        c["wasted_decode_steps"] += wasted + dead
        c["prefill_s"] += t_prefill
        c["decode_s"] += t_decode
        self.ring.write({"batch": B, "prompt_len": plen,
                         "decode_steps": max_new - 1, "tokens_out": tokens_out,
                         "truncated_tokens": truncated, "dead_slots": N - B,
                         "wasted_decode_steps": wasted + dead,
                         "prefill_s": t_prefill, "decode_s": t_decode})
        return reqs

    def telemetry(self) -> dict:
        """Decode-path counter summary (cumulative since construction)."""
        c = dict(self.counters)
        c["decode_tok_per_s"] = (c["tokens_out"] / c["decode_s"]
                                 if c["decode_s"] > 0 else 0.0)
        c["prefill_tok_per_s"] = (c["prefill_tokens"] / c["prefill_s"]
                                  if c["prefill_s"] > 0 else 0.0)
        c["trace_counts"] = dict(self.trace_counts)
        return c
