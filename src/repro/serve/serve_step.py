"""Serving steps: prefill and single-token decode (the dry-run ``serve_step``).

decode cells lower ``serve_step`` — one new token against a KV/SSM cache of
``seq_len`` — NOT ``train_step`` (task spec). The cache sharding comes from
``repro.launch.sharding.cache_specs`` (sequence over model, batch over data).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.nn.common import Ctx

__all__ = ["make_decode_step", "make_prefill", "greedy_sample"]


def greedy_sample(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def make_decode_step(cfg: ArchConfig, *, mesh=None, act_sharding=None,
                     data_axes=("data",), model_axes=("model",), cost_mode=False):
    """Returns ``decode_fn(params, caches, tokens[B,1], pos) -> (logits, caches)``."""

    def decode_fn(params, caches, tokens, pos):
        ctx = Ctx(policy=None, mesh=mesh, act_sharding=act_sharding, decode=True,
                  data_axes=data_axes, model_axes=model_axes, cost_mode=cost_mode)
        logits, new_caches = lm.decode_step(params, caches, tokens, pos, ctx, cfg)
        return logits, new_caches

    return decode_fn


def make_prefill(cfg: ArchConfig, max_len: int, *, mesh=None, act_sharding=None,
                 data_axes=("data",), model_axes=("model",), cost_mode=False):
    def prefill_fn(params, batch):
        ctx = Ctx(policy=None, mesh=mesh, act_sharding=act_sharding,
                  data_axes=data_axes, model_axes=model_axes, cost_mode=cost_mode)
        return lm.prefill(params, batch, ctx, cfg, max_len)

    return prefill_fn
