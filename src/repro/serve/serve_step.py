"""Serving steps: prefill and single-token decode (the dry-run ``serve_step``).

decode cells lower ``serve_step`` — one new token against a KV/SSM cache of
``seq_len`` — NOT ``train_step`` (task spec). The cache sharding comes from
``repro.launch.sharding.cache_specs`` (sequence over model, batch over data).

Both factories take an :class:`~repro.api.ExecutionConfig` (the Runtime front
door passes it via ``Runtime.prefill_step`` / ``Runtime.decode_step``); the
loose kwargs are the legacy spelling.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.api.execution import ExecutionConfig
from repro.configs.base import ArchConfig
from repro.models import lm

__all__ = ["make_decode_step", "make_prefill", "greedy_sample"]


def greedy_sample(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _execution(execution, mesh, act_sharding, data_axes, model_axes, cost_mode):
    if execution is not None:
        return execution
    return ExecutionConfig(mesh=mesh, act_sharding=act_sharding,
                           data_axes=tuple(data_axes),
                           model_axes=tuple(model_axes), cost_mode=cost_mode)


def make_decode_step(cfg: ArchConfig, *, execution: Optional[ExecutionConfig] = None,
                     mesh=None, act_sharding=None,
                     data_axes=("data",), model_axes=("model",), cost_mode=False):
    """Returns ``decode_fn(params, caches, tokens[B,1], pos) -> (logits, caches)``."""
    ex = _execution(execution, mesh, act_sharding, data_axes, model_axes, cost_mode)

    def decode_fn(params, caches, tokens, pos):
        ctx = ex.make_ctx(decode=True)
        logits, new_caches = lm.decode_step(params, caches, tokens, pos, ctx, cfg)
        return logits, new_caches

    return decode_fn


def make_prefill(cfg: ArchConfig, max_len: int, *,
                 execution: Optional[ExecutionConfig] = None,
                 mesh=None, act_sharding=None,
                 data_axes=("data",), model_axes=("model",), cost_mode=False):
    ex = _execution(execution, mesh, act_sharding, data_axes, model_axes, cost_mode)

    def prefill_fn(params, batch):
        ctx = ex.make_ctx()
        return lm.prefill(params, batch, ctx, cfg, max_len)

    return prefill_fn
