"""ServeConfig: the serving counterpart of :class:`~repro.api.ExecutionConfig`.

One frozen, hashable object holds every engine knob — slot count, KV budget,
paged-cache geometry, prefill bucketing/packing, stop tokens — so
``Runtime.serve(params, cfg, serve=ServeConfig(...))`` fully determines the
engine's compiled surface (see docs/serving.md for the compile-bucket
contract).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ServeConfig"]


def _pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching engine configuration (hashable, compare by value).

    * ``n_slots`` — decode batch width: the number of concurrently decoding
      requests. Finished slots are refilled from the queue between steps.
    * ``max_len`` — per-slot KV budget (prompt + generated tokens).
    * ``page_size`` — KV-cache page length in tokens. ``None`` = contiguous
      slot-major caches. Paged mode additionally requires the arch's cache
      tree to be pure full-length attention KV (no SSM/ring-buffer state) —
      the engine falls back to contiguous otherwise and records the choice
      in ``Engine.telemetry()["layout"]``.
    * ``n_pages`` — physical page-pool size (``None`` = enough for every
      slot at ``max_len`` plus the reserved trash page 0). Smaller pools
      make admission wait for evictions to free pages.
    * ``pack_prefill`` — pack several queued prompts into one prefill call
      (page-aligned segments + segment-masked attention). Paged mode only.
    * ``prefill_buckets`` — prompt-length buckets (one XLA compile each).
      Empty = powers of two from ``max(8, page_size)`` up to ``max_len``.
    * ``eos`` — engine-default stop token (per-request ``Request.eos`` wins).
    """

    n_slots: int = 4
    max_len: int = 256
    page_size: Optional[int] = 16
    n_pages: Optional[int] = None
    pack_prefill: bool = True
    prefill_buckets: Tuple[int, ...] = ()
    eos: Optional[int] = None
    ring_capacity: int = 256

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {self.max_len}")
        if self.page_size is not None:
            if self.page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {self.page_size}")
            if self.max_len % self.page_size != 0:
                raise ValueError(
                    f"max_len={self.max_len} must be a multiple of "
                    f"page_size={self.page_size} (whole pages per slot)")
        for b in self.prefill_buckets:
            if not (0 < b <= self.max_len):
                raise ValueError(f"prefill bucket {b} outside (0, max_len]")

    # -- derived geometry ---------------------------------------------------

    @property
    def pages_per_slot(self) -> int:
        assert self.page_size is not None
        return self.max_len // self.page_size

    @property
    def pool_pages(self) -> int:
        """Physical pages incl. the reserved trash page 0."""
        assert self.page_size is not None
        if self.n_pages is not None:
            return self.n_pages
        return self.n_slots * self.pages_per_slot + 1

    def buckets(self) -> Tuple[int, ...]:
        """Ascending prefill buckets (compile shapes), ending at max_len."""
        if self.prefill_buckets:
            bs = sorted(set(self.prefill_buckets))
            if bs[-1] != self.max_len:
                bs.append(self.max_len)
            return tuple(bs)
        lo = max(8, self.page_size or 1)
        bs, b = [], _pow2_ceil(lo)
        while b < self.max_len:
            bs.append(b)
            b *= 2
        bs.append(self.max_len)
        return tuple(bs)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n (n must be <= max_len)."""
        for b in self.buckets():
            if n <= b:
                return b
        raise ValueError(f"length {n} exceeds max_len={self.max_len}")

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)
