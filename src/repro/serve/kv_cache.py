"""Paged KV cache: fixed-size pages, a per-slot page map, and a trash page.

The decode cache tree (``lm.init_cache``) is slot-major: every leaf carries a
batch dim of ``n_slots``. Paged mode replaces each full-length attention K/V
leaf ``[n_rep, B, max_len, kv, hd]`` with a physical page *pool*
``[n_rep, n_pages, page_size, kv, hd]`` plus one shared int32 page map
``[n_slots, pages_per_slot]`` of physical page ids. Page 0 is reserved as the
**trash page**: freed slots point every map entry at it, so their decode
writes land harmlessly in storage nothing ever reads back un-masked.

The three ops below are pure functions over the cache pytree; the engine
composes them inside its jitted steps (gather -> ``lm.decode_step`` ->
scatter of the one written column), so a decode step stays a single XLA
program regardless of layout. Layout selection is shape-driven
(:func:`plan_layout`): paging requires every cache leaf to be full-length
attention K/V — sliding-window ring buffers and SSM/RWKV recurrent states
are slot-major by construction (their decode updates are in-place row
writes, not appends), so such trees fall back to the contiguous layout.

See docs/serving.md for the page-map walkthrough and insert rules.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ArchConfig
from repro.models import lm
from repro.serve.config import ServeConfig

__all__ = ["CacheLayout", "plan_layout", "init_pools", "gather_slots",
           "scatter_token", "insert_prompt_pages", "insert_prompt_rows"]


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """Resolved cache layout for one (arch, ServeConfig) pair.

    * ``paged`` — pool + page-map storage (requires ``pack_ok``).
    * ``pack_ok`` — every leaf is full-length attention K/V, so several
      prompts may share one segment-masked prefill row and be inserted
      page-wise.
    * ``pad_ok`` — no recurrent state leaves: prompts may be right-padded to
      a compile bucket (pad keys are segment-masked out of attention, and
      ring/KV garbage beyond the prompt is hidden by the ``idx <= pos``
      decode mask until overwritten). SSM/RWKV states integrate padding
      tokens irreversibly, so ``pad_ok=False`` trees prefill at exact prompt
      length (one compile per distinct length — recorded in telemetry).
    """

    paged: bool
    pack_ok: bool
    pad_ok: bool
    leaf_kinds: tuple  # ("kv_full" | "kv_ring" | "state" | "cross", ...)


def _leaf_kind(path_s: str, shape, max_len: int) -> str:
    if "cross" in path_s:
        return "cross"
    if path_s.endswith("/k") or path_s.endswith("/v"):
        return "kv_full" if shape[-3] == max_len else "kv_ring"
    return "state"


def plan_layout(cfg: ArchConfig, serve: ServeConfig) -> CacheLayout:
    """Classify the arch's cache tree and pick paged vs contiguous."""
    shapes = jax.eval_shape(lambda: lm.init_cache(cfg, 1, serve.max_len))
    kinds = []
    compat.tree_map_with_path(
        lambda path, leaf: kinds.append(
            _leaf_kind(_path_str(path), leaf.shape, serve.max_len)), shapes)
    kinds = tuple(kinds)
    pack_ok = bool(kinds) and all(k == "kv_full" for k in kinds)
    pad_ok = bool(kinds) and all(k in ("kv_full", "kv_ring") for k in kinds)
    paged = serve.page_size is not None and pack_ok
    return CacheLayout(paged=paged, pack_ok=pack_ok, pad_ok=pad_ok,
                       leaf_kinds=kinds)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/" + "/".join(parts)


def init_pools(cfg: ArchConfig, serve: ServeConfig):
    """Zero page pools mirroring the cache tree: each full-length K/V leaf
    ``[n_rep, 1, max_len, kv, hd]`` becomes ``[n_rep, n_pages, P, kv, hd]``."""
    shapes = jax.eval_shape(lambda: lm.init_cache(cfg, 1, serve.max_len))
    P = serve.page_size

    def pool(leaf):
        n_rep = leaf.shape[0]
        return jnp.zeros((n_rep, serve.pool_pages, P) + leaf.shape[3:],
                         leaf.dtype)

    return jax.tree.map(pool, shapes)


def gather_slots(pools, page_map, serve: ServeConfig):
    """Materialise the contiguous slot-major view ``lm.decode_step`` expects:
    ``pool[:, page_map[b]]`` concatenated along the sequence dim per slot."""
    P, pp = serve.page_size, serve.pages_per_slot
    B = page_map.shape[0]
    flat_idx = page_map.reshape(-1)

    def gather(pool):
        flat = jnp.take(pool, flat_idx, axis=1)  # [n_rep, B*pp, P, ...]
        x = flat.reshape((pool.shape[0], B, pp * P) + pool.shape[3:])
        return x[:, :, :serve.max_len]

    return jax.tree.map(gather, pools)


def scatter_token(pools, new_caches, page_map, pos, serve: ServeConfig):
    """Write back the one K/V column decode appended at ``pos`` (int32 [B],
    per-slot). Freed slots map to the trash page, absorbing their writes."""
    P = serve.page_size
    B = page_map.shape[0]
    page = pos // P
    off = pos % P
    phys = jnp.take_along_axis(page_map, page[:, None], axis=1)[:, 0]  # [B]
    rows = jnp.arange(B)

    def scatter(pool, new):
        col = new[:, rows, pos]  # [n_rep, B, kv, hd]
        return pool.at[:, phys, off].set(col.astype(pool.dtype))

    return jax.tree.map(scatter, pools, new_caches)


def insert_prompt_pages(pools, pref_caches, phys_pages, src_page0,
                        serve: ServeConfig):
    """Copy one prefilled segment into its slot's pages.

    ``pref_caches`` is a prefill cache tree (batch dim 1, seq dim max_len)
    holding a packed row; the segment's tokens live at page-aligned offsets
    ``[src_page0 * P, ...)``. ``phys_pages`` (int32 [pages_per_slot]) names
    the destination: the slot's physical pages for the prompt span, padded
    with trash page 0 — pages beyond the prompt (other segments' data, or
    pads) are routed to the trash page, keeping the copy shape static so
    one insert compiles for every bucket.
    """
    P, pp = serve.page_size, serve.pages_per_slot
    src_idx = jnp.clip(src_page0 + jnp.arange(pp), 0, serve.max_len // P - 1)

    def insert(pool, pref):
        src = pref[:, 0].reshape(
            (pref.shape[0], serve.max_len // P, P) + pref.shape[3:])
        pages = jnp.take(src, src_idx, axis=1)  # [n_rep, pp, P, ...]
        return pool.at[:, phys_pages].set(pages.astype(pool.dtype))

    return jax.tree.map(insert, pools, pref_caches)


def insert_prompt_rows(dec_caches, pref_caches, slot):
    """Contiguous-layout insert: copy every prefill-cache leaf's single row
    into slot ``slot`` (traced scalar — one compile covers all slots and
    buckets). Full-row copies are layout-exact for K/V, ring buffers and
    recurrent state alike because prefill builds its caches at the engine's
    own ``max_len``."""

    def insert(dec, pref):
        return dec.at[:, slot].set(pref[:, 0].astype(dec.dtype))

    return jax.tree.map(insert, dec_caches, pref_caches)
