"""Continuous-batching serving engine: queue -> slots -> paged KV decode.

The engine drives three layers, all behind the :class:`~repro.api.Runtime`
front door (``Runtime.serve`` constructs one; a mesh-bearing Runtime serves
sharded through the identical code path):

  * :class:`repro.serve.scheduler.Scheduler` — FIFO request queue, slot
    table, and the physical-page allocator. Finished slots are evicted and
    refilled from the queue **between decode steps**, so decode never idles
    a slot while work is queued.
  * :mod:`repro.serve.kv_cache` — paged KV storage (fixed-size pages, a
    per-slot page map, trash page 0 for freed slots) or the contiguous
    slot-major fallback for cache trees with ring-buffer / recurrent leaves.
  * bucketed, segment-masked **packed prefill** — queued prompts are packed
    page-aligned into one row, rounded up to a power-of-two bucket, so
    heterogeneous prompt lengths compile once per bucket instead of
    retracing (``trace_counts`` records every compile, keyed by shape).

Every decode step is one jitted XLA call (gather pages -> ``decode_step`` ->
scatter the new column) followed by ONE batched host transfer of the [B]
sampled tokens — per-slot stop tracking (eos / ``max_new``) happens on the
host against that single array, preserving the dead-slot discipline from the
resilience PR. Per-request latency stamps (queue, TTFT, total) land on a
bounded :class:`~repro.telemetry.sinks.RingSink`; ``Engine.telemetry()``
summarizes counters, trace counts and latency percentiles. See
docs/serving.md for the full contract.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.runtime import Runtime
from repro.configs.base import ArchConfig
from repro.obs import clock, observability
from repro.obs.metrics import MetricsRegistry
from repro.serve import kv_cache
from repro.serve.config import ServeConfig
from repro.serve.scheduler import Request, Scheduler, Slot
from repro.serve.serve_step import greedy_sample
from repro.telemetry.sinks import RingSink, percentiles

__all__ = ["Request", "Engine"]

_COUNTER_KEYS = ("batches", "prefill_calls", "prefill_tokens", "decode_steps",
                 "tokens_out", "decode_tokens", "requests_done",
                 "truncated_tokens", "wasted_decode_steps")


class Engine:
    """Continuous-batching engine over ``Runtime.prefill_step``/``decode_step``.

    ``serve`` (a :class:`~repro.serve.config.ServeConfig`) fixes the compiled
    surface; the legacy ``batch``/``max_len`` kwargs build one (paged when
    ``max_len`` permits). Byte-identical greedy outputs vs the
    run-to-completion baseline (`repro.serve.legacy`) are test-enforced.
    """

    def __init__(self, params, cfg: ArchConfig, *, serve: Optional[ServeConfig] = None,
                 batch: int = 4, max_len: int = 256,
                 runtime: Optional[Runtime] = None):
        if cfg.is_encdec:
            raise ValueError("the serving engine targets decoder-only archs")
        if serve is None:
            serve = ServeConfig(n_slots=batch, max_len=max_len,
                                page_size=16 if max_len % 16 == 0 else None)
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.batch = serve.n_slots
        self.max_len = serve.max_len
        self.runtime = runtime if runtime is not None else Runtime()
        self.layout = kv_cache.plan_layout(cfg, serve)
        self.scheduler = Scheduler(serve, paged=self.layout.paged)
        # metrics: each engine owns a registry (instances never collide) and
        # registers it with the shared Observability for export/reporting;
        # `counters` keeps the historical dict spelling as a view
        self.obs = observability(self.runtime.execution.obs)
        self._tracer = self.obs.tracer
        self._traced = self._tracer.enabled
        self.metrics = MetricsRegistry()
        if self.obs.metrics is not None:
            self.obs.adopt("serve", self.metrics)
        self.counters = self.metrics.view(
            "serve", _COUNTER_KEYS + ("prefill_s", "decode_s"))
        self.ring = RingSink(capacity=serve.ring_capacity)
        self.trace_counts: dict = {}

        self._pref_raw = self.runtime.prefill_step(cfg, serve.max_len)
        self._dec_raw = self.runtime.decode_step(cfg)
        self._prefills: dict = {}  # bucket -> jitted prefill
        self._decode = self._build_decode()
        self._insert = self._build_insert()
        if self.layout.paged:
            self._state = kv_cache.init_pools(cfg, serve)
        else:
            from repro.models import lm
            self._state = lm.init_cache(cfg, serve.n_slots, serve.max_len)
        self._cur = np.zeros(serve.n_slots, np.int32)
        self._pos = np.zeros(serve.n_slots, np.int32)

    # -- compiled steps (each python body runs once per XLA trace) ----------

    def _count(self, key: str):
        self.trace_counts[key] = self.trace_counts.get(key, 0) + 1

    def _build_decode(self):
        serve, dec = self.serve, self._dec_raw
        if self.layout.paged:
            def step(params, pools, page_map, toks, pos):
                self._count("decode")
                posc = jnp.minimum(pos, serve.max_len - 1)
                contig = kv_cache.gather_slots(pools, page_map, serve)
                logits, new = dec(params, contig, toks, posc)
                pools = kv_cache.scatter_token(pools, new, page_map, posc, serve)
                return greedy_sample(logits)[:, 0], pools
        else:
            def step(params, caches, toks, pos):
                self._count("decode")
                posc = jnp.minimum(pos, serve.max_len - 1)
                logits, new = dec(params, caches, toks, posc)
                return greedy_sample(logits)[:, 0], new
        return jax.jit(step)

    def _build_insert(self):
        serve = self.serve
        if self.layout.paged:
            def ins(pools, pref, phys_pages, src_page0):
                self._count("insert")
                return kv_cache.insert_prompt_pages(pools, pref, phys_pages,
                                                    src_page0, serve)
        else:
            def ins(caches, pref, slot):
                self._count("insert")
                return kv_cache.insert_prompt_rows(caches, pref, slot)
        return jax.jit(ins)

    def _bucket_prefill(self, bucket: int):
        fn = self._prefills.get(bucket)
        if fn is not None:
            return fn
        raw, n_slots = self._pref_raw, self.serve.n_slots

        def pf(params, batch, last_idx):
            self._count(f"prefill[{bucket}]")
            logits, caches = raw(params, batch)
            idx = jnp.clip(last_idx, 0, logits.shape[1] - 1)
            lg = jnp.take_along_axis(logits, idx[None, :, None], axis=1)
            return greedy_sample(lg)[0], caches  # first tokens [n_slots]

        fn = jax.jit(pf)
        self._prefills[bucket] = fn
        return fn

    # -- serving loop -------------------------------------------------------

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve requests to completion (continuous batching: admission,
        per-slot stop, eviction and refill all interleave with decode).

        Admission checks run up front, before any device work: empty prompts
        and unservable ``max_new`` raise; over-long prompts are
        *left*-truncated to ``max_len - max_new`` (the most recent context
        survives) with the dropped count recorded.
        """
        requests = list(requests)
        with self._tracer.span("serve.run", n_requests=len(requests)):
            truncated = self.scheduler.submit(requests, clock.now())
            self.counters["truncated_tokens"] += truncated
            sched = self.scheduler
            while sched.pending() or sched.live_slots():
                self._refill()
                if sched.live_slots():
                    self._decode_one_step()
        return requests

    def _refill(self):
        sched, serve = self.scheduler, self.serve
        pack = self.layout.paged and serve.pack_prefill
        align = serve.page_size if pack else 1
        while sched.free_slots() and sched.pending():
            wave = sched.take_wave(pack=pack, align=align)
            if not wave:
                break  # head-of-line blocked on pages until an eviction
            self._prefill_wave(wave, pack, align)

    def _prefill_wave(self, wave: List[Request], pack: bool, align: int):
        serve, c = self.serve, self.counters
        t0 = clock.now()
        offs, off = [], 0
        for r in wave:
            offs.append(off)
            off += -(-len(r.prompt) // align) * align
        if self.layout.pad_ok:
            bucket = serve.bucket_for(off)
        else:
            # recurrent state integrates pad tokens irreversibly: prefill at
            # exact length (one compile per distinct length, see trace_counts)
            bucket = len(wave[0].prompt)
        toks = np.zeros((1, bucket), np.int32)
        segs = np.zeros((1, bucket), np.int32)
        poss = np.zeros((1, bucket), np.int32)
        last = np.zeros(serve.n_slots, np.int32)
        for i, r in enumerate(wave):
            o, n = offs[i], len(r.prompt)
            toks[0, o:o + n] = r.prompt
            segs[0, o:o + n] = i + 1
            poss[0, o:o + n] = np.arange(n)
            last[i] = o + n - 1
        positions = (np.broadcast_to(poss[None], (3, 1, bucket))
                     if self.cfg.rope == "mrope" else poss)
        batch = {"tokens": jnp.asarray(toks), "segments": jnp.asarray(segs),
                 "positions": jnp.asarray(positions)}
        first, pref = self._bucket_prefill(bucket)(
            self.params, batch, jnp.asarray(last))
        first_np = np.asarray(first)  # one [n_slots] host transfer
        now = clock.now()
        c["batches"] += 1
        c["prefill_calls"] += 1
        c["prefill_tokens"] += bucket
        for i, r in enumerate(wave):
            tok = int(first_np[i])
            slot = self.scheduler.place(r, tok, now)
            if self.layout.paged:
                g = -(-len(r.prompt) // serve.page_size)
                phys = np.where(np.arange(serve.pages_per_slot) < g,
                                self.scheduler.page_map[slot.idx], 0)
                self._state = self._insert(
                    self._state, pref, jnp.asarray(phys, dtype=jnp.int32),
                    jnp.asarray(offs[i] // serve.page_size, jnp.int32))
            else:
                self._state = self._insert(self._state, pref,
                                           jnp.asarray(slot.idx, jnp.int32))
            self._cur[slot.idx] = tok
            self._pos[slot.idx] = slot.pos
            c["tokens_out"] += 1
            self._maybe_finish(slot, tok, now)
        end = clock.now()
        c["prefill_s"] += end - t0
        if self._traced:
            self._tracer.add_span("prefill_wave", t0, end, bucket=int(bucket),
                                  n=len(wave))

    def _decode_one_step(self):
        sched, c = self.scheduler, self.counters
        live = sched.live_slots()
        t0 = clock.now()
        c["decode_steps"] += 1
        c["wasted_decode_steps"] += self.serve.n_slots - len(live)
        toks = jnp.asarray(self._cur[:, None])
        pos = jnp.asarray(self._pos)
        if self.layout.paged:
            nxt, self._state = self._decode(self.params, self._state,
                                            jnp.asarray(sched.page_map),
                                            toks, pos)
        else:
            nxt, self._state = self._decode(self.params, self._state, toks, pos)
        nxt_np = np.asarray(nxt)  # the ONE batched host sync for this step
        now = clock.now()
        for s in live:
            t = int(nxt_np[s.idx])
            s.outs.append(t)
            s.pos += 1
            self._cur[s.idx] = t
            self._pos[s.idx] = s.pos
            c["tokens_out"] += 1
            c["decode_tokens"] += 1
            self._maybe_finish(s, t, now)
        c["decode_s"] += now - t0
        if self._traced:
            self._tracer.add_span("decode_step", t0, now, live=len(live))

    def _maybe_finish(self, slot: Slot, tok: int, now: float):
        r = slot.req
        eos = r.eos if r.eos is not None else self.serve.eos
        if len(slot.outs) >= r.max_new:
            self._finish(slot, "length", now)
        elif eos is not None and tok == eos:
            self._finish(slot, "eos", now)  # eos token stays in the output

    def _finish(self, slot: Slot, reason: str, now: float):
        n_new = len(slot.outs)
        req = self.scheduler.finish(slot, reason, now)
        self.counters["requests_done"] += 1
        span_id = None
        if self._traced:
            # the request's full lifecycle, reconstructed post-hoc from the
            # scheduler's existing stamps: queued -> prefill (admit..first
            # token, includes the KV insert) -> decode; `span_id` on the
            # ring record joins latency rows to the trace
            tr = self._tracer
            span_id = tr.add_span("request", req.t_submit, req.t_done,
                                  stop=reason, prompt_len=int(len(req.prompt)),
                                  new_tokens=n_new)
            tr.add_span("queued", req.t_submit, req.t_admit, parent=span_id)
            tr.add_span("prefill", req.t_admit, req.t_first, parent=span_id)
            tr.add_span("decode", req.t_first, req.t_done, parent=span_id)
        self.ring.write({
            "prompt_len": int(len(req.prompt)), "new_tokens": n_new,
            "stop": reason, "truncated_tokens": req.truncated,
            "queue_s": req.t_admit - req.t_submit,
            "ttft_s": req.t_first - req.t_submit,
            "latency_s": req.t_done - req.t_submit,
            "span_id": span_id,
        })
        self._cur[slot.idx] = 0
        self._pos[slot.idx] = 0

    # -- telemetry ----------------------------------------------------------

    def telemetry(self) -> dict:
        """Counters + throughput + latency percentiles + compile counts."""
        c = dict(self.counters)
        c["decode_tok_per_s"] = (c["decode_tokens"] / c["decode_s"]
                                 if c["decode_s"] > 0 else 0.0)
        c["prefill_tok_per_s"] = (c["prefill_tokens"] / c["prefill_s"]
                                  if c["prefill_s"] > 0 else 0.0)
        c["layout"] = "paged" if self.layout.paged else "contiguous"
        c["trace_counts"] = dict(self.trace_counts)
        lat = percentiles(self.ring.records, "latency_s", (50, 99))
        c["latency_p50_s"], c["latency_p99_s"] = lat[50], lat[99]
        ttft = percentiles(self.ring.records, "ttft_s", (50, 99))
        c["ttft_p50_s"], c["ttft_p99_s"] = ttft[50], ttft[99]
        return c
