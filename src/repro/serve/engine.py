"""Batched serving engine: prefill + decode with a simple admission queue.

A deliberately compact continuous-batching-lite engine: requests are padded
into fixed prefill buckets, decoded as one batch with per-slot stop tracking,
and finished slots are refilled from the queue between decode bursts. The
jitted prefill/decode steps come from the :class:`~repro.api.Runtime` front
door (``Runtime.serve`` constructs an Engine) — the same factories the
dry-run lowers, so the engine exercises the production code paths end-to-end
(examples/serve_lm.py). Pass a mesh-bearing Runtime to serve sharded.

Telemetry: the engine keeps decode-path counters (prefill/decode calls,
tokens, wall time) plus a bounded ring of per-batch records
(:class:`repro.telemetry.sinks.RingSink`); ``Engine.telemetry()`` summarizes
them (tokens/s etc.) for dashboards and tests. See docs/telemetry.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.runtime import Runtime
from repro.configs.base import ArchConfig
from repro.serve.serve_step import greedy_sample
from repro.telemetry.sinks import RingSink

__all__ = ["Request", "Engine"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # int32 [len]
    max_new: int = 16
    out: Optional[np.ndarray] = None


class Engine:
    def __init__(self, params, cfg: ArchConfig, *, batch: int = 4,
                 max_len: int = 256, runtime: Optional[Runtime] = None):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.runtime = runtime if runtime is not None else Runtime()
        self._prefill = jax.jit(self.runtime.prefill_step(cfg, max_len))
        self._decode = jax.jit(self.runtime.decode_step(cfg))
        self.counters = {"batches": 0, "prefill_calls": 0, "prefill_tokens": 0,
                         "decode_steps": 0, "tokens_out": 0,
                         "prefill_s": 0.0, "decode_s": 0.0}
        self.ring = RingSink(capacity=256)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests in fixed-size batches."""
        for i in range(0, len(requests), self.batch):
            self._run_batch(requests[i:i + self.batch])
        return requests

    def _run_batch(self, reqs: List[Request]):
        B = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, plen), np.int32)
        for j, r in enumerate(reqs):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        toks = jnp.asarray(toks)
        if B < self.batch:
            toks = jnp.pad(toks, ((0, self.batch - B), (0, 0)))
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, {"tokens": toks})
        cur = greedy_sample(logits[:, -1:])
        jax.block_until_ready(cur)
        t_prefill = time.perf_counter() - t0
        outs = [[] for _ in range(self.batch)]
        max_new = max(r.max_new for r in reqs)
        pos = plen
        t0 = time.perf_counter()
        for _ in range(max_new):
            for j in range(self.batch):
                outs[j].append(int(cur[j, 0]))
            logits, caches = self._decode(self.params, caches, cur, pos)
            cur = greedy_sample(logits)
            pos += 1
        jax.block_until_ready(cur)
        t_decode = time.perf_counter() - t0
        for j, r in enumerate(reqs):
            r.out = np.asarray(outs[j][:r.max_new], np.int32)
        tokens_out = sum(min(r.max_new, max_new) for r in reqs)
        c = self.counters
        c["batches"] += 1
        c["prefill_calls"] += 1
        c["prefill_tokens"] += B * plen
        c["decode_steps"] += max_new
        c["tokens_out"] += tokens_out
        c["prefill_s"] += t_prefill
        c["decode_s"] += t_decode
        self.ring.write({"batch": B, "prompt_len": plen, "decode_steps": max_new,
                         "tokens_out": tokens_out, "prefill_s": t_prefill,
                         "decode_s": t_decode})
        return reqs

    def telemetry(self) -> dict:
        """Decode-path counter summary (cumulative since construction)."""
        c = dict(self.counters)
        c["decode_tok_per_s"] = (c["tokens_out"] / c["decode_s"]
                                 if c["decode_s"] > 0 else 0.0)
        c["prefill_tok_per_s"] = (c["prefill_tokens"] / c["prefill_s"]
                                  if c["prefill_s"] > 0 else 0.0)
        return c
