"""Batched serving engine: prefill + decode with a simple admission queue.

A deliberately compact continuous-batching-lite engine: requests are padded
into fixed prefill buckets, decoded as one batch with per-slot stop tracking,
and finished slots are refilled from the queue between decode bursts. The
jitted prefill/decode steps come from the :class:`~repro.api.Runtime` front
door (``Runtime.serve`` constructs an Engine) — the same factories the
dry-run lowers, so the engine exercises the production code paths end-to-end
(examples/serve_lm.py). Pass a mesh-bearing Runtime to serve sharded.

Telemetry: the engine keeps decode-path counters (prefill/decode calls,
tokens, wall time) plus a bounded ring of per-batch records
(:class:`repro.telemetry.sinks.RingSink`); ``Engine.telemetry()`` summarizes
them (tokens/s etc.) for dashboards and tests. See docs/telemetry.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.runtime import Runtime
from repro.configs.base import ArchConfig
from repro.serve.serve_step import greedy_sample
from repro.telemetry.sinks import RingSink

__all__ = ["Request", "Engine"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray  # int32 [len]
    max_new: int = 16
    out: Optional[np.ndarray] = None


class Engine:
    def __init__(self, params, cfg: ArchConfig, *, batch: int = 4,
                 max_len: int = 256, runtime: Optional[Runtime] = None):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.runtime = runtime if runtime is not None else Runtime()
        self._prefill = jax.jit(self.runtime.prefill_step(cfg, max_len))
        self._decode = jax.jit(self.runtime.decode_step(cfg))
        self.counters = {"batches": 0, "prefill_calls": 0, "prefill_tokens": 0,
                         "decode_steps": 0, "tokens_out": 0,
                         "truncated_tokens": 0, "dead_slot_steps": 0,
                         "prefill_s": 0.0, "decode_s": 0.0}
        self.ring = RingSink(capacity=256)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests in fixed-size batches.

        Admission checks up front (before any device work): an empty prompt
        is rejected, as is a ``max_new`` that cannot fit the engine's
        ``max_len`` KV budget even with the whole prompt truncated away.
        Over-long prompts are *left*-truncated to ``max_len - max_new`` —
        the most recent context survives — and the dropped token count is
        recorded (``counters["truncated_tokens"]`` + the per-batch ring).
        """
        for i, r in enumerate(requests):
            if len(r.prompt) == 0:
                raise ValueError(f"request {i}: empty prompt")
            if r.max_new <= 0:
                raise ValueError(f"request {i}: max_new must be >= 1, "
                                 f"got {r.max_new}")
            if r.max_new >= self.max_len:
                raise ValueError(
                    f"request {i}: max_new={r.max_new} leaves no room for "
                    f"any prompt token within max_len={self.max_len}")
        for i in range(0, len(requests), self.batch):
            self._run_batch(requests[i:i + self.batch])
        return requests

    def _run_batch(self, reqs: List[Request]):
        B = len(reqs)
        prompts, truncated = [], 0
        for r in reqs:
            p = np.asarray(r.prompt, np.int32)
            keep = self.max_len - r.max_new
            if len(p) > keep:
                truncated += len(p) - keep
                p = p[-keep:]  # keep the most recent context
            prompts.append(p)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for j, p in enumerate(prompts):
            toks[j, plen - len(p):] = p  # left-pad
        toks = jnp.asarray(toks)
        if B < self.batch:
            toks = jnp.pad(toks, ((0, self.batch - B), (0, 0)))
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, {"tokens": toks})
        cur = greedy_sample(logits[:, -1:])
        jax.block_until_ready(cur)
        t_prefill = time.perf_counter() - t0
        outs = [[] for _ in range(B)]
        max_new = max(r.max_new for r in reqs)
        pos = plen
        t0 = time.perf_counter()
        for _ in range(max_new):
            # one B-element host transfer per step — padded dead slots (and
            # their per-slot int() syncs) never reach the host
            step_tok = np.asarray(cur[:B, 0])
            for j in range(B):
                outs[j].append(int(step_tok[j]))
            logits, caches = self._decode(self.params, caches, cur, pos)
            cur = greedy_sample(logits)
            pos += 1
        jax.block_until_ready(cur)
        t_decode = time.perf_counter() - t0
        for j, r in enumerate(reqs):
            r.out = np.asarray(outs[j][:r.max_new], np.int32)
        tokens_out = sum(min(r.max_new, max_new) for r in reqs)
        c = self.counters
        c["batches"] += 1
        c["prefill_calls"] += 1
        c["prefill_tokens"] += B * plen
        c["decode_steps"] += max_new
        c["tokens_out"] += tokens_out
        c["truncated_tokens"] += truncated
        c["dead_slot_steps"] += (self.batch - B) * max_new
        c["prefill_s"] += t_prefill
        c["decode_s"] += t_decode
        self.ring.write({"batch": B, "prompt_len": plen, "decode_steps": max_new,
                         "tokens_out": tokens_out, "truncated_tokens": truncated,
                         "dead_slots": self.batch - B, "prefill_s": t_prefill,
                         "decode_s": t_decode})
        return reqs

    def telemetry(self) -> dict:
        """Decode-path counter summary (cumulative since construction)."""
        c = dict(self.counters)
        c["decode_tok_per_s"] = (c["tokens_out"] / c["decode_s"]
                                 if c["decode_s"] > 0 else 0.0)
        c["prefill_tok_per_s"] = (c["prefill_tokens"] / c["prefill_s"]
                                  if c["prefill_s"] > 0 else 0.0)
        return c
