"""Request queue + slot table + page allocator for the continuous engine.

The scheduler owns everything host-side: the FIFO admission queue, per-slot
state (which request, decode position, emitted tokens), and — in paged mode —
the physical page free list and the slot page map. It never touches the
device; the engine asks it *what* to run next and tells it *what* happened.

Admission is strict FIFO (no reordering): the head request is admitted as
soon as a slot is free and its worst-case page reservation
``ceil((prompt_len + max_new) / page_size)`` fits the free list. Reserving
the worst case up front means decode can never deadlock waiting for a page —
a slot that started always finishes. See docs/serving.md for the state
machine.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import numpy as np

from repro.serve.config import ServeConfig

__all__ = ["Request", "Slot", "Scheduler"]


@dataclasses.dataclass
class Request:
    """One generation request. ``out`` is filled on completion; the stamps
    (seconds, ``time.perf_counter`` clock) feed the per-request latency
    records on the engine's ring."""

    prompt: np.ndarray  # int32 [len]
    max_new: int = 16
    eos: Optional[int] = None  # per-request stop token (None = engine default)
    out: Optional[np.ndarray] = None
    stop: Optional[str] = None  # "eos" | "length"
    truncated: int = 0
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None


class Slot:
    """Decode-batch lane state. ``req is None`` marks a free lane (its decode
    work is wasted — counted by the engine)."""

    __slots__ = ("idx", "req", "pos", "outs", "pages")

    def __init__(self, idx: int):
        self.idx = idx
        self.req: Optional[Request] = None
        self.pos = 0          # next KV write position (= prompt_len + emitted - 1)
        self.outs: List[int] = []
        self.pages: Optional[np.ndarray] = None  # physical pages (paged mode)


class Scheduler:
    def __init__(self, serve: ServeConfig, *, paged: bool):
        self.serve = serve
        self.paged = paged
        self.queue: deque = deque()
        self.slots = [Slot(i) for i in range(serve.n_slots)]
        if paged:
            self.free_pages: List[int] = list(range(1, serve.pool_pages))
            self.page_map = np.zeros((serve.n_slots, serve.pages_per_slot),
                                     np.int32)
        else:
            self.free_pages = []
            self.page_map = None

    # -- admission ----------------------------------------------------------

    def submit(self, requests: List[Request], now: float) -> int:
        """Validate, left-truncate over-long prompts, enqueue. Returns the
        total truncated-token count. Raises before any request is enqueued
        (all-or-nothing, and always before any device work)."""
        serve = self.serve
        for i, r in enumerate(requests):
            if len(r.prompt) == 0:
                raise ValueError(f"request {i}: empty prompt")
            if r.max_new <= 0:
                raise ValueError(f"request {i}: max_new must be >= 1, "
                                 f"got {r.max_new}")
            if r.max_new >= serve.max_len:
                raise ValueError(
                    f"request {i}: max_new={r.max_new} leaves no room for "
                    f"any prompt token within max_len={serve.max_len}")
            if self.paged:
                worst = self._pages_needed(
                    min(len(r.prompt), serve.max_len - r.max_new), r.max_new)
                if worst > serve.pool_pages - 1:
                    raise ValueError(
                        f"request {i}: needs {worst} pages but the pool has "
                        f"{serve.pool_pages - 1} (raise n_pages)")
        truncated = 0
        for r in requests:
            p = np.asarray(r.prompt, np.int32)
            keep = serve.max_len - r.max_new
            if len(p) > keep:
                r.truncated = len(p) - keep
                truncated += r.truncated
                p = p[-keep:]  # keep the most recent context
            r.prompt = p
            r.t_submit = now
            self.queue.append(r)
        return truncated

    def _pages_needed(self, plen: int, max_new: int) -> int:
        P = self.serve.page_size
        return -(-(plen + max_new) // P)

    # -- wave selection -----------------------------------------------------

    def free_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.req is None]

    def live_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.req is not None]

    def pending(self) -> int:
        return len(self.queue)

    def take_wave(self, *, pack: bool, align: int) -> List[Request]:
        """Pop the FIFO head requests runnable right now.

        ``pack=True``: take as many consecutive requests as fit one packed
        prefill row of ``max_len`` tokens (each prompt rounded up to
        ``align``), bounded by free slots and the page free list.
        ``pack=False``: at most one request per wave. FIFO is strict — a
        head request that does not fit blocks the queue until evictions
        free its resources (worst-case reservation makes that inevitable).
        """
        wave: List[Request] = []
        used_tokens = 0
        pages_left = len(self.free_pages)
        n_free = len(self.free_slots())
        while self.queue and len(wave) < n_free:
            r = self.queue[0]
            plen = len(r.prompt)
            aligned = -(-plen // align) * align
            if wave and (not pack or used_tokens + aligned > self.serve.max_len):
                break
            if self.paged:
                need = self._pages_needed(plen, r.max_new)
                if need > pages_left:
                    break
                pages_left -= need
            wave.append(self.queue.popleft())
            used_tokens += aligned
        return wave

    # -- slot lifecycle -----------------------------------------------------

    def place(self, req: Request, first_tok: int, now: float) -> Slot:
        """Bind an admitted request to a free slot (allocating its full page
        reservation in paged mode) and record the prefill-produced first
        token."""
        slot = self.free_slots()[0]
        slot.req = req
        slot.outs = [first_tok]
        slot.pos = len(req.prompt)
        if self.paged:
            need = self._pages_needed(len(req.prompt), req.max_new)
            pages = np.asarray([self.free_pages.pop() for _ in range(need)],
                               np.int32)
            slot.pages = pages
            row = np.zeros(self.serve.pages_per_slot, np.int32)
            row[:need] = pages
            self.page_map[slot.idx] = row
        req.t_admit = now
        req.t_first = now
        return slot

    def finish(self, slot: Slot, reason: str, now: float) -> Request:
        """Evict: release pages back to the free list, point the slot's page
        map at the trash page, finalize the request."""
        req = slot.req
        req.out = np.asarray(slot.outs, np.int32)
        req.stop = reason
        req.t_done = now
        if self.paged:
            self.free_pages.extend(int(p) for p in slot.pages)
            self.page_map[slot.idx] = 0
            slot.pages = None
        slot.req = None
        slot.outs = []
        slot.pos = 0
        return req
