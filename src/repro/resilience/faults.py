"""Seeded, declarative fault injection for the training loop.

A :class:`FaultPlan` maps step indices to :class:`FaultSpec` s; the trainer
consumes it through a :class:`FaultInjector`, which marks each fault as
fired exactly once — so a retried trajectory (after a rollback restores an
earlier step) does not re-trip the same injected fault forever.

Fault kinds (docs/resilience.md has the taxonomy and what each drills):

  * ``nonfinite``   — the step's traced ``fault_scale`` operand becomes NaN,
    poisoning the loss and every cotangent (the non-finite-gradient class).
  * ``spike``       — ``fault_scale = scale`` (large, finite): a loss spike
    with exploding-but-finite gradients.
  * ``slow``        — host-side sleep before the step (straggler class; the
    reactive Controller is the mitigation, not the sentinel).
  * ``ckpt_io``     — the next async checkpoint write raises ``IOError`` in
    the writer thread (surfaces as CheckpointError on the next wait).
  * ``device_loss`` — raise :class:`DeviceLossFault` before the step; the
    supervisor re-shards onto the surviving ``mesh_shape`` via
    ``elastic.resume_on_mesh``.

Both the declarative spelling (``FaultPlan(faults=(...,))``) and a seeded
random generator (:meth:`FaultPlan.random`) are deterministic: the same
plan yields the same drill on every run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["FaultSpec", "FaultPlan", "FaultInjector", "DeviceLossFault",
           "KINDS"]

KINDS = ("nonfinite", "spike", "slow", "ckpt_io", "device_loss")

#: fault kinds that perturb the step numerically via ``fault_scale``
SOFT_KINDS = ("nonfinite", "spike")


class DeviceLossFault(RuntimeError):
    """Simulated loss of devices mid-run (a mesh-shrink trigger).

    Carries everything the supervisor needs to recover: the step it fired
    at, the surviving mesh shape, the history accumulated so far, and the
    (structurally intact) last state as a restore template.
    """

    def __init__(self, step: int, mesh_shape: Tuple[int, ...], *,
                 history=None, state=None):
        super().__init__(f"device loss at step {step} "
                         f"(surviving mesh shape {mesh_shape})")
        self.step = int(step)
        self.mesh_shape = tuple(int(s) for s in mesh_shape)
        self.history = list(history or [])
        self.state = state


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injected fault: what happens at ``step``."""

    step: int
    kind: str
    scale: float = 1e4          # spike: fault_scale multiplier on the loss
    sleep_s: float = 0.05       # slow: host-side stall duration
    mesh_shape: Tuple[int, ...] = ()  # device_loss: surviving mesh shape

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.kind == "spike" and not (np.isfinite(self.scale)
                                         and self.scale > 1.0):
            raise ValueError(f"spike scale must be finite and > 1, "
                             f"got {self.scale}")
        if self.kind == "device_loss" and not self.mesh_shape:
            raise ValueError("device_loss fault needs the surviving "
                             "mesh_shape")
        object.__setattr__(self, "mesh_shape",
                           tuple(int(s) for s in self.mesh_shape))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative step -> fault mapping (at most one fault per step)."""

    faults: Tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        specs = tuple(sorted(self.faults, key=lambda f: f.step))
        steps = [f.step for f in specs]
        if len(set(steps)) != len(steps):
            dupes = sorted({s for s in steps if steps.count(s) > 1})
            raise ValueError(f"multiple faults on step(s) {dupes}; "
                             "one fault per step")
        object.__setattr__(self, "faults", specs)

    def at(self, step: int) -> Optional[FaultSpec]:
        for f in self.faults:
            if f.step == step:
                return f
        return None

    @property
    def kinds(self) -> Tuple[str, ...]:
        return tuple(dict.fromkeys(f.kind for f in self.faults))

    @classmethod
    def random(cls, seed: int, steps: int, *, kinds: Tuple[str, ...] = SOFT_KINDS,
               n: int = 3, min_step: int = 1) -> "FaultPlan":
        """``n`` faults at seeded-random distinct steps in
        ``[min_step, steps)``, kinds cycling through ``kinds``."""
        if steps - min_step < n:
            raise ValueError(f"cannot place {n} faults in "
                             f"[{min_step}, {steps})")
        rng = np.random.default_rng(seed)
        where = rng.choice(np.arange(min_step, steps), size=n, replace=False)
        return cls(faults=tuple(
            FaultSpec(step=int(s), kind=kinds[i % len(kinds)])
            for i, s in enumerate(sorted(where))))

    @classmethod
    def drill(cls, *, ckpt_every: int = 5, mesh_shape: Tuple[int, ...] = ()
              ) -> "FaultPlan":
        """The canned acceptance drill: one fault of every soft/IO kind (plus
        ``device_loss`` when a surviving ``mesh_shape`` is given), laid out
        so each recovery path fires — a lone non-finite step (escalation), a
        loss spike, an injected checkpoint-write failure on a save step, and
        a non-finite burst long enough to force a rollback."""
        k = int(ckpt_every)
        faults = [
            FaultSpec(step=2 * k - 1, kind="ckpt_io"),      # arms save(2k)
            FaultSpec(step=2 * k + 1, kind="nonfinite"),    # 1 trip -> escalate
            FaultSpec(step=3 * k + 1, kind="spike"),
            # M=3 consecutive trips -> RollbackRequired -> restore
            FaultSpec(step=4 * k + 0, kind="nonfinite"),
            FaultSpec(step=4 * k + 1, kind="nonfinite"),
            FaultSpec(step=4 * k + 2, kind="nonfinite"),
        ]
        if mesh_shape:
            faults.append(FaultSpec(step=6 * k, kind="device_loss",
                                    mesh_shape=tuple(mesh_shape)))
        return cls(faults=tuple(faults))


class FaultInjector:
    """Stateful, fire-once view of a :class:`FaultPlan`.

    The supervisor owns one injector across retry attempts: after a
    rollback replays steps the plan already faulted, ``take`` returns None
    for the spent entries and the retried trajectory runs clean.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._armed: Dict[int, FaultSpec] = {f.step: f for f in plan.faults}
        self.fired: list = []

    @classmethod
    def wrap(cls, faults) -> Optional["FaultInjector"]:
        if faults is None or isinstance(faults, cls):
            return faults
        return cls(faults)

    def take(self, step: int) -> Optional[FaultSpec]:
        f = self._armed.pop(step, None)
        if f is not None:
            self.fired.append(f)
        return f

    @property
    def pending(self) -> int:
        return len(self._armed)
