"""repro.resilience — fault injection, gradient sentinels, recovery.

Randomized unbiased VJPs trade per-step cost for gradient noise (paper §3),
so a production run must *detect* divergence — non-finite grads, loss
spikes, probe-SNR collapse — and *degrade gracefully* instead of silently
corrupting a long training job. Three pieces (docs/resilience.md):

  * :class:`ResilienceConfig` — frozen/hashable switchboard riding
    ``ExecutionConfig.resilience`` (the one front door). With it set, the
    compiled train step takes a traced ``fault_scale`` operand and gates the
    optimizer update on an in-graph finiteness/norm flag; training is
    bit-identical when the sentinel never trips.
  * :mod:`~repro.resilience.faults` — a seeded, declarative
    :class:`FaultPlan` (step -> fault) injecting realistic failures
    (non-finite cotangents, loss spikes, slow steps, checkpoint-write IO
    errors, simulated device loss) so every recovery path is
    deterministically testable on the fake-device mesh.
  * :class:`~repro.resilience.sentinel.GradSentinel` (host side) and
    :class:`~repro.resilience.supervisor.Supervisor` — escalate the budget
    to exact for K steps on a trip (the paper-native fallback: when the
    estimator is the suspect, buy variance down before buying a rollback),
    and roll back to the last *verified* checkpoint / re-shard onto the
    surviving mesh on hard faults.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.resilience.faults import (DeviceLossFault, FaultInjector,
                                     FaultPlan, FaultSpec)
from repro.resilience.sentinel import GradSentinel, RollbackRequired

__all__ = [
    "DeviceLossFault",
    "FaultInjector",
    "FaultPlan",
    "FaultSpec",
    "GradSentinel",
    "ResilienceConfig",
    "RollbackRequired",
    "Supervisor",
]


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Fault detection + recovery policy (hashable; rides
    ``ExecutionConfig.resilience``).

    Attributes:
      sentinel: compile the in-graph gate — the step emits a one-scalar
        ``sentinel_trip`` flag from quantities it already materializes
        (loss + global grad norm) and skips the optimizer update when the
        flag trips. ``jnp.where(ok, new, old)`` returns ``new`` bitwise
        when ``ok`` — an untripped run is bit-identical to sentinel-off.
      max_grad_norm: global-grad-norm explosion threshold for the in-graph
        gate (non-finite loss/grads always trip).
      spike_factor: host-side loss-spike EMA — trip when the fetched loss
        exceeds ``spike_factor x EMA(loss)`` after ``warmup_steps`` clean
        steps (faulty losses never update the EMA).
      ema_decay: EMA decay for the loss tracker.
      warmup_steps: clean steps before spike detection arms.
      escalate_steps: K — steps to force the *exact* (budget=None) bucket
        after a trip, via the same pre-compiled-bucket switching the
        Controller protocol uses (no recompiles).
      rollback_after: M — consecutive trips before the sentinel gives up on
        escalation and raises :class:`RollbackRequired` (0 disables).
      max_recoveries: supervisor retry budget across rollbacks + device
        losses before the original fault is re-raised.
      min_snr: optional probe-SNR floor (requires telemetry probes); a
        fetched ``probe_snr`` below it counts as a trip.
    """

    sentinel: bool = True
    max_grad_norm: float = 1e3
    spike_factor: float = 8.0
    ema_decay: float = 0.9
    warmup_steps: int = 5
    escalate_steps: int = 4
    rollback_after: int = 3
    max_recoveries: int = 8
    min_snr: Optional[float] = None

    def __post_init__(self):
        if self.max_grad_norm <= 0:
            raise ValueError(f"max_grad_norm must be > 0, got {self.max_grad_norm}")
        if self.spike_factor <= 1.0:
            raise ValueError(f"spike_factor must be > 1, got {self.spike_factor}")
        if not (0.0 < self.ema_decay < 1.0):
            raise ValueError(f"ema_decay must be in (0, 1), got {self.ema_decay}")
        for name in ("warmup_steps", "escalate_steps", "rollback_after",
                     "max_recoveries"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")

    def replace(self, **kw) -> "ResilienceConfig":
        return dataclasses.replace(self, **kw)


def __getattr__(name):
    # Supervisor imports the trainer (which imports repro.api); loading it
    # lazily keeps `repro.api -> repro.resilience` import-cycle free.
    if name == "Supervisor":
        from repro.resilience.supervisor import Supervisor

        return Supervisor
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
