"""Retry/rollback orchestrator: the outermost loop of a resilient run.

:class:`Supervisor` wraps ``trainer.train_loop`` and owns the recovery
ladder the sentinel cannot climb alone (docs/resilience.md):

  * :class:`~repro.resilience.sentinel.RollbackRequired` (M consecutive
    trips — escalation to the exact bucket did not help) — restore the
    newest *verified* checkpoint (CRC-checked; ``train_loop`` auto-resumes)
    and retry with a per-attempt PRNG salt, so the retried trajectory
    *resamples* every sketch: a rare bad index draw cannot recur.
  * :class:`~repro.resilience.faults.DeviceLossFault` (hard fault) — build
    the surviving mesh (``elastic.surviving_mesh``), re-shard the newest
    checkpoint onto it (``elastic.resume_on_mesh``), rebind the runtime's
    execution config to the new mesh, and continue.

Every recovery is recorded — cause, steps lost, wall-time cost — through
the runtime's telemetry sinks and kept on ``Supervisor.events``;
``benchmarks/bench_resilience.py`` distills them into wasted-work fraction
and steps-to-recover.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional

from repro.obs import clock, observability
from repro.obs.metrics import MetricsRegistry
from repro.resilience.faults import DeviceLossFault, FaultInjector
from repro.resilience.sentinel import RollbackRequired

__all__ = ["Supervisor"]


class Supervisor:
    """Run ``train_loop`` to completion across rollbacks and device loss.

    ``runtime.execution.resilience`` must be set (a default
    :class:`~repro.resilience.ResilienceConfig` is installed if absent —
    the supervisor is pointless without the sentinel/fault plumbing).
    Rollback recovery requires ``tcfg.ckpt_dir``; without one, a rollback
    restarts from scratch (recorded as such).
    """

    def __init__(self, runtime, cfg, opt, tcfg, *, fault_plan=None):
        from repro.resilience import ResilienceConfig

        if runtime.execution.resilience is None:
            runtime = runtime.replace(
                execution=runtime.execution.replace(
                    resilience=ResilienceConfig()))
        self.runtime = runtime
        self.cfg = cfg
        self.opt = opt
        self.tcfg = tcfg
        self.injector = FaultInjector.wrap(fault_plan)
        self.events: list = []
        # recovery counters live in the unified registry; `recoveries` stays
        # readable/assignable as a plain int through the property below
        self._ob = observability(runtime.execution.obs)
        self.metrics = MetricsRegistry()
        if self._ob.metrics is not None:
            self._ob.adopt("resilience", self.metrics)
        self._recoveries = self.metrics.counter("resilience.recoveries")
        self._event_count = self.metrics.counter("resilience.events")

    @property
    def recoveries(self) -> int:
        return int(self._recoveries.value)

    @recoveries.setter
    def recoveries(self, v: int) -> None:
        self._recoveries.set(v)

    # -- event plumbing ------------------------------------------------------

    def _record(self, rec: dict, sink=None):
        self.events.append(dict(rec))
        self._event_count.inc()
        if self._ob.flight is not None:
            self._ob.flight.note(rec)
        if sink is not None:
            sink.write(dict(rec))

    # -- recovery actions ----------------------------------------------------

    def _remesh(self, mesh_shape):
        """Rebind the runtime onto the surviving mesh (same axis names)."""
        from repro.train import elastic

        ex = self.runtime.execution
        new_mesh = elastic.surviving_mesh(ex.mesh, mesh_shape)
        act = ex.act_sharding
        if act is not None and hasattr(act, "spec"):
            from jax.sharding import NamedSharding

            act = NamedSharding(new_mesh, act.spec)
        self.runtime = self.runtime.replace(
            execution=ex.replace(mesh=new_mesh, act_sharding=act))
        return new_mesh

    # -- the loop ------------------------------------------------------------

    def run(self, data: Iterable, *, state=None,
            on_metrics: Optional[Callable] = None):
        """Returns ``(final_state, history)`` — history stitched across
        attempts; recovery events on ``self.events`` and the sinks."""
        from repro.telemetry import sinks as tsinks
        from repro.train import checkpoint as ckptlib
        from repro.train import elastic, trainer

        rcfg = self.runtime.execution.resilience
        sink = tsinks.build_sinks(self.runtime.execution.telemetry)
        tracer = self._ob.tracer
        history: list = []
        attempt = 0
        try:
            while True:
                try:
                    state, hist = trainer.train_loop(
                        self.runtime, self.cfg, self.opt, data, self.tcfg,
                        state=state, faults=self.injector,
                        seed_salt=attempt, on_event=self.events.append,
                        on_metrics=on_metrics)
                    history.extend(hist)
                    return state, history
                except RollbackRequired as e:
                    history.extend(e.history)
                    self._ob.dump_crash("rollback", {
                        "step": e.step, "cause": e.cause, "attempt": attempt})
                    self._bump(e, rcfg)
                    attempt += 1
                    with tracer.span("recovery.rollback", step=e.step,
                                     cause=e.cause):
                        t0 = clock.now()
                        resume = (
                            ckptlib.latest_verified_step(self.tcfg.ckpt_dir)
                            if self.tcfg.ckpt_dir else None)
                        state = None  # train_loop auto-restores (verified) or re-inits
                        self._record(tsinks.recovery_record(
                            "rollback", step=e.step, cause=e.cause,
                            resume_step=int(resume or 0),
                            steps_lost=e.step + 1 - int(resume or 0),
                            wall_s=clock.now() - t0), sink)
                except DeviceLossFault as e:
                    history.extend(e.history)
                    self._ob.dump_crash("device_loss", {
                        "step": e.step, "mesh_shape": list(e.mesh_shape),
                        "attempt": attempt})
                    self._bump(e, rcfg)
                    attempt += 1
                    if not self.tcfg.ckpt_dir:
                        raise
                    with tracer.span("recovery.device_loss", step=e.step):
                        t0 = clock.now()
                        old = self.runtime.execution.mesh
                        old_shape = (tuple(old.devices.shape)
                                     if old is not None else ())
                        new_mesh = self._remesh(e.mesh_shape)
                        state, resume = elastic.resume_on_mesh(
                            self.tcfg.ckpt_dir, e.state, new_mesh)
                        self._record(tsinks.recovery_record(
                            "device_loss_reshard", step=e.step,
                            cause="device_loss",
                            resume_step=int(resume),
                            steps_lost=e.step - int(resume),
                            old_mesh=list(old_shape),
                            new_mesh=list(e.mesh_shape),
                            wall_s=clock.now() - t0), sink)
                except ckptlib.CheckpointError as e:
                    # unrecoverable inside train_loop (retry ladder exhausted)
                    self._ob.dump_crash("checkpoint_error", {"error": str(e)})
                    raise
        finally:
            if sink is not None:
                sink.close()

    def _bump(self, exc, rcfg):
        self.recoveries += 1
        if self.recoveries > rcfg.max_recoveries:
            raise RuntimeError(
                f"supervisor exceeded max_recoveries={rcfg.max_recoveries}"
            ) from exc
