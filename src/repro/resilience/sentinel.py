"""Gradient sentinel: in-graph trip flag + host-side escalation logic.

Two halves, split by where the information lives:

  * **In graph** (:func:`gate_update`, compiled into the train step when
    ``ResilienceConfig.sentinel`` is set): from quantities the step already
    materializes — the loss and the global grad norm — compute one boolean
    ``ok = isfinite(loss) & isfinite(gn) & (gn <= max_grad_norm)`` and gate
    the optimizer update with ``jnp.where(ok, new, old)``. ``where`` with a
    true predicate returns its first operand bitwise, so an untripped run
    is bit-identical to a sentinel-off run; a tripped step keeps the old
    params/opt state (the step counter still advances) and reports
    ``sentinel_trip = 1``.
  * **Host side** (:class:`GradSentinel`, consulted by the trainer between
    steps): reads the fetched scalars, adds a loss-spike EMA and an
    optional probe-SNR floor, and decides *escalate vs rollback*. On a trip
    it forces the exact (budget=None) pre-compiled bucket for K steps —
    the paper-native fallback: unbiasedness means swapping buckets never
    biases the gradient, so when the estimator is the suspect the cheapest
    remedy is buying its variance down. After M *consecutive* trips the
    estimator is exonerated (the exact bucket tripped too) and the
    sentinel raises :class:`RollbackRequired` for the supervisor.
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

from repro import compat

__all__ = ["GradSentinel", "RollbackRequired", "gate_update"]


class RollbackRequired(RuntimeError):
    """Escalation exhausted: restore the last verified checkpoint.

    Raised by the trainer when the sentinel sees ``rollback_after``
    consecutive trips; carries the trip step, the last cause, and the
    history accumulated so far for the supervisor to stitch.
    """

    def __init__(self, step: int, cause: str, *, history=None):
        super().__init__(f"sentinel requires rollback at step {step} "
                         f"({cause})")
        self.step = int(step)
        self.cause = cause
        self.history = list(history or [])


def gate_update(ok, new_tree, old_tree):
    """``jnp.where(ok, new, old)`` leafwise — bitwise ``new`` when ``ok``."""
    return compat.tree_map(lambda n, o: jnp.where(ok, n, o),
                           new_tree, old_tree)


def trip_flag(loss, grad_norm, max_grad_norm: float):
    """The in-graph sentinel scalar: 0.0 when the update is safe, 1.0 when
    it must be skipped (non-finite loss/grads or norm explosion)."""
    ok = (jnp.isfinite(loss) & jnp.isfinite(grad_norm)
          & (grad_norm <= max_grad_norm))
    return ok, 1.0 - ok.astype(jnp.float32)


class GradSentinel:
    """Host-side trip accounting: spike EMA, budget escalation, rollback.

    Mirrors the :class:`repro.api.Controller` step cadence (the trainer
    feeds it the same scalars-only fetched metrics) but composes *with* a
    schedule controller instead of replacing it: :meth:`override` rewrites
    the controller/schedule-chosen budget to ``None`` (exact) while an
    escalation window is open.
    """

    wants_metrics = True  # the trainer must fetch scalars every step

    def __init__(self, rcfg):
        self.rcfg = rcfg
        self.consecutive = 0
        self.escalate_left = 0
        self.trips: list = []
        self._ema: Optional[float] = None
        self._clean_steps = 0

    # -- budget composition --------------------------------------------------

    def override(self, budget):
        """The budget actually run this step: exact while escalating."""
        return None if self.escalate_left > 0 else budget

    # -- per-step observation ------------------------------------------------

    def observe(self, step: int, metrics: dict) -> Optional[str]:
        """Digest one step's fetched scalars; returns the trip cause (or
        None for a clean step). Faulty losses never update the spike EMA."""
        r = self.rcfg
        loss = metrics.get("loss")
        cause = None
        if metrics.get("sentinel_trip", 0.0) > 0.5:
            cause = "nonfinite_or_norm"
        elif loss is not None and not math.isfinite(loss):
            cause = "nonfinite_loss"
        elif (loss is not None and self._ema is not None
                and self._clean_steps >= r.warmup_steps
                and loss > r.spike_factor * self._ema + 1e-6):
            cause = "loss_spike"
        else:
            snr = metrics.get("probe_snr")
            if (r.min_snr is not None and snr is not None
                    and math.isfinite(snr) and snr < r.min_snr):
                cause = "snr_collapse"

        if cause is not None:
            self.consecutive += 1
            self.escalate_left = r.escalate_steps
            self.trips.append({"step": int(step), "cause": cause})
        else:
            self.consecutive = 0
            if self.escalate_left > 0:
                self.escalate_left -= 1
            if loss is not None and math.isfinite(loss):
                d = 1.0 - r.ema_decay
                self._ema = (loss if self._ema is None
                             else (1.0 - d) * self._ema + d * loss)
                self._clean_steps += 1
        return cause

    @property
    def should_rollback(self) -> bool:
        return (self.rcfg.rollback_after > 0
                and self.consecutive >= self.rcfg.rollback_after)

    @property
    def last_cause(self) -> str:
        return self.trips[-1]["cause"] if self.trips else "unknown"
