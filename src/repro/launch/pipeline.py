"""Pipeline-parallel stage boundaries with sketched backward compression.

The paper's motivation (i): in pipeline parallelism, inter-stage activations
(forward) and activation *gradients* (backward) dominate cross-device traffic;
compressing them while preserving unbiasedness cuts bandwidth without biasing
SGD. This module provides the JAX-native primitive:

    x = stage_boundary(x, key=k, cfg=SketchConfig(...))   # between stages

Forward: identity (activations cross exactly — the technique targets the
*gradient* signal; Assumption 2.1 only requires unbiasedness of backward
operators). Backward: the cotangent crossing back over the boundary is
replaced by its unbiased column sketch Ĝ = G·R with E[R]=I — on a real
inter-pod link the compact (indices, values) pair is what moves:
``budget × bytes + r indices`` instead of the dense gradient.

With the ``pod`` mesh axis mapped to pipeline stages, the boundary composes
with `jax.lax.ppermute` for the stage-to-stage transfer; the GPipe-style
microbatch schedule lives in the trainer's gradient-accumulation loop (each
microbatch is a pipeline bubble slot). tests/test_pipeline.py validates
unbiasedness and the compression accounting.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sketching import SketchConfig, column_plan, effective_cfg

__all__ = ["stage_boundary", "boundary_wire_bytes"]


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _boundary(cfg: SketchConfig, x, key):
    return x


def _fwd(cfg, x, key):
    return x, key


def _bwd(cfg, key, g):
    G2d = g.reshape(-1, g.shape[-1])
    lcfg = effective_cfg(cfg, G2d.shape[-1])
    plan = column_plan(lcfg, G2d, None, key, want_compact=False)
    ghat = G2d * plan.gate[None, :].astype(g.dtype)
    # On hardware, only plan.indices + the kept columns cross the link; the
    # dense reconstruction here is the receiving stage's scatter.
    return ghat.reshape(g.shape), None


_boundary.defvjp(_fwd, _bwd)


def stage_boundary(x, *, key=None, cfg: SketchConfig | None = None):
    """Insert between pipeline stages. Identity fwd; sketched cotangent bwd."""
    if cfg is None or cfg.is_noop or key is None:
        return x
    if cfg.method not in ("l1", "l2", "var", "per_column", "ds"):
        raise ValueError("stage boundaries support column-family sketches")
    return _boundary(cfg, x, key)


def boundary_wire_bytes(cfg: SketchConfig, shape, dtype=jnp.bfloat16) -> dict:
    """Backward wire accounting for one boundary crossing (per microbatch)."""
    n = shape[-1]
    rows = 1
    for d in shape[:-1]:
        rows *= d
    lcfg = effective_cfg(cfg, n)
    from repro.core.sketching import static_block_rank, static_rank

    if lcfg.block > 1:
        r = static_block_rank(lcfg, n) * lcfg.block
    else:
        r = static_rank(lcfg, n)
    itemsize = jnp.dtype(dtype).itemsize
    dense = rows * n * itemsize
    compact = rows * r * itemsize + r * 4  # values + int32 indices
    return {"dense_bytes": dense, "compact_bytes": compact,
            "ratio": compact / dense}
