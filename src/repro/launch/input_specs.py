"""ShapeDtypeStruct stand-ins for every (arch × shape-cell) input.

Weak-type-correct, shardable, zero allocation — the dry-run lowers against
these. Modality frontends are stubs per the assignment: `vision` feeds
precomputed patch/text embeddings + M-RoPE position streams; `audio` feeds
frame embeddings to the encoder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ArchConfig, ShapeCell
from repro.models import lm

__all__ = ["train_inputs", "decode_inputs", "params_struct", "cache_struct"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_inputs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """Batch structs for train/prefill cells ({tokens|embeds, labels, ...})."""
    B, S = cell.global_batch, cell.seq_len
    batch = {"labels": _sds((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        batch["positions"] = _sds((3, B, S), jnp.int32)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    if cfg.is_encdec:
        batch["src_embeds"] = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def decode_inputs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """One-token decode structs: new token + KV/SSM caches at seq_len."""
    B, S = cell.global_batch, cell.seq_len
    tok = (_sds((B, 1, cfg.d_model), jnp.dtype(cfg.dtype))
           if cfg.frontend == "vision" else _sds((B, 1), jnp.int32))
    return {"tokens": tok, "pos": _sds((), jnp.int32),
            "caches": cache_struct(cfg, B, S)}


def params_struct(cfg: ArchConfig):
    return jax.eval_shape(lambda k: lm.init_params(k, cfg), compat.prng_key(0))


def cache_struct(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, max_len,
                              enc_len=max_len if cfg.is_encdec else 0))
