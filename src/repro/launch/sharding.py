"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Strategy (DESIGN.md §4):
  * batch over (pod, data); TP over model (heads / d_ff / vocab); EP: experts
    over model; FSDP: the non-TP dim of every large weight is sharded over
    (pod, data) — ZeRO-3-style, optimizer state inherits the same specs.
  * rules match parameter *paths*; a rule's spec covers the TRAILING dims of
    the leaf and is left-padded with None (covers scan-stacked [L, ...] leaves).
  * dims that do not divide evenly by their mesh axis fall back to None (XLA
    requires divisibility for Auto axes); the fallback is logged by dryrun.
"""
from __future__ import annotations

import re
from typing import Optional

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat

from repro.configs.base import ArchConfig, ShapeCell
from repro.launch.mesh import dp_axes, mp_axes

__all__ = ["param_specs", "param_shardings", "batch_specs", "cache_specs",
           "paged_cache_specs", "logical_rules"]

# (path regex, spec for trailing dims). "dp"/"mp" are placeholders resolved
# against the mesh axis names.
_RULES = [
    # embed: vocab replicated, d over model — a vocab-sharded table would turn
    # the token-gather backward into an unpartitionable scatter (XLA would
    # replicate a full fp32 dEmbed per device). lm_head is pure matmul, so it
    # keeps the vocab-parallel layout.
    (r"(^|/)embed$", (None, "mp")),
    (r"/lm_head/w$", ("mp", "dp")),
    (r"/(attn|cross)/(q|k|v)/w$", ("mp", "dp")),
    (r"/(attn|cross)/o/w$", ("dp", "mp")),
    (r"/mlp/(in|gate)/w$", ("mp", "dp")),
    (r"/mlp/out/w$", ("dp", "mp")),
    (r"/moe/router/w$", (None, None)),
    (r"/moe/(wi|wg)$", ("mp", None, "dp")),
    (r"/moe/wo$", ("mp", "dp", None)),
    (r"/mamba/(in_x|in_z)/w$", ("mp", "dp")),
    (r"/mamba/(in_B|in_C|in_dt)/w$", (None, "dp")),
    (r"/mamba/out/w$", ("dp", "mp")),
    (r"/mamba/conv$", (None, "mp")),
    (r"/rwkv/(r|k|v|g|cm_k|cm_r)/w$", ("mp", "dp")),
    (r"/rwkv/(out|cm_v)/w$", ("dp", "mp")),
    (r"/rwkv/(w1|w2)/w$", (None, None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/" + "/".join(parts)


def _resolve(tag, mesh):
    if tag == "dp":
        return dp_axes(mesh)
    if tag == "mp":
        ax = mp_axes(mesh)
        return ax[0] if len(ax) == 1 else ax
    return tag


def _axis_size(mesh, tag) -> int:
    ax = _resolve(tag, mesh)
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax])) if ax else 1
    return mesh.shape[ax]


_MOE_TPX = {  # fallback when n_experts doesn't divide the model axis:
    # shard the expert hidden dim instead (tensor-parallel experts, cf. moe.py)
    r"/moe/(wi|wg)$": (None, "mp", "dp"),
    r"/moe/wo$": (None, "dp", "mp"),
}


def _spec_for(path_s: str, shape, mesh) -> P:
    for pat, trailing in _RULES:
        if re.search(pat, path_s):
            # MoE: if experts don't divide the model axis, use the TPX layout
            for tpat, ttrail in _MOE_TPX.items():
                if re.search(tpat, path_s):
                    e_dim = shape[-3]
                    if e_dim % _axis_size(mesh, "mp") != 0:
                        trailing = ttrail
                    break
            spec = [None] * (len(shape) - len(trailing)) + list(trailing)
            resolved = []
            for dim, tag in zip(shape, spec):
                if tag is None:
                    resolved.append(None)
                    continue
                size = _axis_size(mesh, tag)
                resolved.append(_resolve(tag, mesh) if dim % size == 0 else None)
            return P(*resolved)
    return P()  # small leaves (norms, scalars, biases, mu/u/...) replicate


def param_specs(params_shape, mesh):
    """PartitionSpecs for a params pytree (works on ShapeDtypeStructs too)."""
    return compat.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_str(path), leaf.shape, mesh), params_shape)


def param_shardings(params_shape, mesh):
    return compat.tree_map(lambda s: NamedSharding(mesh, s), param_specs(params_shape, mesh))


def batch_specs(cfg: ArchConfig, cell: ShapeCell, mesh) -> dict:
    """PartitionSpecs for the input batch of a shape cell."""
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    bspec = dp if cell.global_batch % n_dp == 0 else None
    row = P(bspec, None)
    specs = {"labels": row}
    if cfg.frontend == "vision":
        specs["embeds"] = P(bspec, None, None)
        specs["positions"] = P(None, bspec, None)
    else:
        specs["tokens"] = row
    if cfg.is_encdec:
        specs["src_embeds"] = P(bspec, None, None)
    return specs


def cache_specs(cfg: ArchConfig, cache_shape, mesh, global_batch: int):
    """Decode-cache PartitionSpecs.

    KV caches [L, B, S, kv, hd]: batch over dp when divisible; the *sequence*
    dim over model (flash-decoding style — partial softmax stats are combined
    by XLA-inserted all-reduces). SSM/conv/shift states: batch over dp only.
    """
    dp = dp_axes(mesh)
    mp = mp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    bax = dp if global_batch % n_dp == 0 else None
    mp1 = mp[0] if mp else None

    def spec_for(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        if s.endswith("/k") or s.endswith("/v"):
            # [L, B, S, kv, hd] (stacked) or [B, S, kv, hd] (cross, stacked->5)
            seq_dim_size = shape[-3]
            seq_ok = mp1 is not None and seq_dim_size % mesh.shape[mp1] == 0
            lead = [None] * (len(shape) - 4)
            return P(*lead, bax, mp1 if seq_ok else None, None, None)
        if s.endswith("/ssm"):  # [L, B, H, P, N]
            lead = [None] * (len(shape) - 4)
            return P(*lead, bax, None, None, None)
        if s.endswith("/wkv"):  # [L, B, H, P, P]
            lead = [None] * (len(shape) - 4)
            return P(*lead, bax, None, None, None)
        if s.endswith("/conv") or "shift" in s:  # [L, B, K-1, C] / [L, B, 1, d]
            lead = [None] * (len(shape) - 3)
            return P(*lead, bax, None, None)
        return P()

    return compat.tree_map_with_path(spec_for, cache_shape)


def paged_cache_specs(pool_shape, mesh, n_pages: int):
    """PartitionSpecs for a paged KV pool tree (``repro.serve.kv_cache``).

    Pool leaves ``[L, n_pages, page_size, kv, hd]`` shard the *page* dim over
    dp when divisible — pages are the batch-like unit of paged serving (a
    slot's pages are scattered across the pool, so page-gather/scatter cross
    shards via XLA-inserted collectives, same trade the contiguous layout
    makes for batch). The token dim inside a page is too short to split over
    model (flash-decoding seq sharding needs whole-sequence runs), so pages
    keep their interior replicated; the page map is host-owned and always
    replicated.
    """
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    pax = dp if n_pages % n_dp == 0 else None

    def spec_for(path, leaf):
        shape = leaf.shape
        if len(shape) >= 4 and shape[-4] == n_pages:
            lead = [None] * (len(shape) - 4)
            return P(*lead, pax, None, None, None)
        return P()

    return compat.tree_map_with_path(spec_for, pool_shape)


def logical_rules(mesh) -> dict:
    """Activation constraint specs used by train/serve steps."""
    dp = dp_axes(mesh)
    mp = mp_axes(mesh)
    mp1 = mp[0] if mp else None
    return {
        "activations": P(dp, None, None),
        "logits": P(dp, None, mp1),
    }
