"""HLO artifact analysis: collective bytes, cost extraction, roofline terms.

Methodology (EXPERIMENTS.md §Roofline):
  * ``compiled.cost_analysis()`` supplies HLO FLOPs / bytes of the PER-DEVICE
    partitioned program — but XLA counts while-loop bodies ONCE, so scan-based
    production programs undercount by ~n_layers. The dry-run therefore lowers
    *cost artifacts*: python-unrolled (``cost_mode``) slices at small layer
    counts, and reconstructs full-depth cost by solving the linear model
    cost(L) = intercept + n_full_periods(L)·per_period + rem_layers(L)·per_layer.
  * collective bytes are not in cost_analysis: we parse the post-SPMD HLO text
    and sum wire-cost-weighted operand sizes of every collective op
    (ring model: all-reduce 2(n-1)/n·size, all-gather/reduce-scatter/all-to-all
    (n-1)/n·size (size = full logical buffer), collective-permute 1·size).
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

__all__ = ["HW", "collective_bytes", "cost_summary", "roofline_terms",
           "fit_depth_model", "predict_depth_model"]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e (per chip)."""
    peak_flops_bf16: float = 197e12
    hbm_bw: float = 819e9
    ici_link_bw: float = 50e9  # per link per direction
    ici_links: int = 2  # links usable per collective ring (bidirectional)
    hbm_bytes: float = 16e9

    @property
    def ici_bw(self) -> float:
        return self.ici_link_bw * self.ici_links


_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Wire bytes per collective kind (per device), ring cost model."""
    out = {k: 0.0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_type, kind = m.group(1), m.group(2)
        size = _shape_bytes(result_type)
        n = max(2, _group_size(line))
        if kind == "all-reduce":
            wire = 2.0 * size * (n - 1) / n
        elif kind == "all-gather":
            wire = size * (n - 1) / n  # size = gathered output
        elif kind == "reduce-scatter":
            wire = size * (n - 1)  # size = scattered output; input = n·size
        elif kind == "all-to-all":
            wire = size * (n - 1) / n
        else:  # collective-permute
            wire = float(size)
        out[kind] += wire
        counts[kind] += 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["counts"] = counts
    return out


def cost_summary(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def fit_depth_model(points):
    """points: [(n_full, rem, cost_dict)] -> coefficient dict per metric.

    Linear model: cost = I + n_full·PPC + rem·M (least squares; exact when the
    design matrix has full column rank).
    """
    keys = set()
    for _, _, c in points:
        keys |= set(k for k, v in c.items() if isinstance(v, (int, float)))
    A = np.array([[1.0, nf, rem] for nf, rem, _ in points])
    coefs = {}
    for k in keys:
        y = np.array([c.get(k, 0.0) for _, _, c in points])
        sol, *_ = np.linalg.lstsq(A, y, rcond=None)
        coefs[k] = sol  # [I, PPC, M]
    return coefs


def predict_depth_model(coefs, n_full: int, rem: int) -> dict:
    return {k: float(max(0.0, c[0] + c[1] * n_full + c[2] * rem))
            for k, c in coefs.items()}


def roofline_terms(flops: float, bytes_hbm: float, coll_bytes: float,
                   chips: int, hw: HW = HW(), *, per_device: bool = True) -> dict:
    """Three roofline terms in seconds. Inputs are per-device unless noted."""
    if not per_device:
        flops, bytes_hbm, coll_bytes = (x / chips for x in (flops, bytes_hbm, coll_bytes))
    t_c = flops / hw.peak_flops_bf16
    t_m = bytes_hbm / hw.hbm_bw
    t_x = coll_bytes / hw.ici_bw
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom, "bound_s": max(t_c, t_m, t_x)}
