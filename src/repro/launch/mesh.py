"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device initialisation.

Mesh layout (TPU v5e pods):
  single-pod:  (16, 16)        axes ("data", "model")   = 256 chips
  multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") = 512 chips

``pod`` composes with ``data`` for data parallelism by default; the pipeline
launcher (repro/launch/pipeline.py) can remap it to pipeline stages.

All mesh construction goes through ``repro.compat`` — the only module allowed
to touch version-gated JAX mesh APIs.
"""
from __future__ import annotations

from repro import compat

__all__ = ["make_production_mesh", "make_mesh", "dp_axes", "mp_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small fake-device meshes)."""
    return compat.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """Axes that carry data parallelism (pod folds into DP by default)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a == "model")
