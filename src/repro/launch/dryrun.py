import os

from repro import compat

# 512 fake host devices for full production meshes. ensure_host_devices never
# clobbers an existing forced count (the test suite forces 8 via conftest), so
# importing this module inside pytest no longer silently re-sizes the backend.
compat.ensure_host_devices(512)

"""Multi-pod dry-run: lower + compile every (arch × shape-cell × mesh).

For each cell this produces TWO artifacts (DESIGN.md / hlo_analysis docstring):
  * memory artifact — full-depth production program (scan-rolled):
    ``memory_analysis()`` proves the cell fits; its HLO carries the production
    collective schedule.
  * cost artifacts — python-unrolled slices at small depths; FLOPs / bytes /
    collective wire-bytes are reconstructed at full depth via the linear depth
    model (XLA counts while bodies once, so rolled programs undercount).

Results accumulate in ``results/dryrun/<arch>.<cell>.<mesh>.json`` which
EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.py consume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --cell train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--skip-cost]
"""
import argparse
import json
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api import ExecutionConfig, Runtime, SketchConfig, SketchPolicy
from repro.configs.base import SHAPE_CELLS, ArchConfig, ShapeCell
from repro.configs.registry import ARCH_IDS, cells_for, get_config
from repro.launch import input_specs as ispec
from repro.launch import sharding as shard
from repro.launch.hlo_analysis import (HW, collective_bytes, cost_summary,
                                       fit_depth_model, predict_depth_model,
                                       roofline_terms)
from repro.launch.mesh import dp_axes, make_production_mesh, mp_axes
from repro.obs import clock
from repro.obs.ledgers import memory_summary
from repro.optim import adamw, cosine_warmup

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# default sketch policy for train cells: the paper's ℓ1 default at p=0.1 in
# the TPU-compact realisation. Baseline (exact / mask) variants are produced
# by --policy {exact, mask, compact}. Each entry is (policy, tp_sketch);
# "compact_sharded" adds the TP-local compact sketch + compressed DP gradient
# reduce-scatter. run_cell folds these into a Runtime per cell.
_BLOCK_L1 = SketchPolicy(base=SketchConfig(method="l1", budget=0.1,
                                           backend="compact", block=128))
_POLICIES = {
    "exact": (None, False),
    "mask": (SketchPolicy(base=SketchConfig(method="l1", budget=0.1,
                                            backend="mask")), False),
    "compact": (_BLOCK_L1, False),
    "compact_sharded": (_BLOCK_L1, True),
}


def _adjust_for_depth(cfg: ArchConfig, L: int) -> ArchConfig:
    kw = {"n_layers": L}
    if cfg.is_encdec:
        kw["enc_layers"] = L
    return cfg.replace(**kw)


def _depth_points(cfg: ArchConfig):
    """Cost-artifact depths: (L, n_full, rem) for the depth model."""
    if cfg.block_kind == "zamba":
        p = cfg.shared_attn_every
        return [(1, 0, 1), (p, 1, 0), (2 * p, 2, 0)]
    if cfg.local_global > 0:
        p = cfg.local_global + 1
        return [(1, 0, 1), (p, 1, 0), (2 * p, 2, 0)]
    return [(1, 1, 0), (2, 2, 0)]


def _depth_target(cfg: ArchConfig):
    if cfg.block_kind == "zamba":
        p = cfg.shared_attn_every
        return cfg.n_layers // p, cfg.n_layers % p
    if cfg.local_global > 0:
        p = cfg.local_global + 1
        return cfg.n_layers // p, cfg.n_layers % p
    return cfg.n_layers, 0


def _mesh_axes(mesh):
    return dp_axes(mesh), mp_axes(mesh)


def _act_sharding(mesh, batch_div, seq_len=0, sp: bool = True):
    """Residual-stream activation sharding.

    ``sp=True`` (default): Megatron-style sequence parallelism — the stream is
    [batch→dp, seq→model, d]; XLA inserts the all-gather before attention /
    MLP (TP) blocks and reduce-scatters after, cutting the remat carry by the
    model-axis size. ``sp=False`` keeps the stream replicated over model
    (the naive baseline measured in EXPERIMENTS.md §Perf).
    """
    dp = dp_axes(mesh)
    mp = mp_axes(mesh)
    seq_ax = None
    if sp and mp and seq_len and seq_len % mesh.shape[mp[0]] == 0:
        seq_ax = mp[0]
    return NamedSharding(mesh, P(dp if batch_div else None, seq_ax, None))


# gradient-accumulation microbatching for cells whose activations exceed HBM
# at full global batch (production practice for 100B+ dense training). Cost
# artifacts always run accum=1: total per-step FLOPs are identical, only the
# execution order / peak memory differ.
TRAIN_ACCUM = {"llama3_405b": 8, "nemotron_4_340b": 8, "olmoe_1b_7b": 2}


def _runtime(cfg, cell, mesh, policy_entry, cost_mode, sp, *, batch_div,
             seq_len, accum: int = 1) -> Runtime:
    """One Runtime per dry-run cell: the same front door production uses."""
    dp, mp = _mesh_axes(mesh)
    policy, tp_sketch = policy_entry if policy_entry is not None else (None, False)
    return Runtime(policy=policy, execution=ExecutionConfig(
        mesh=mesh, act_sharding=_act_sharding(mesh, batch_div, seq_len, sp),
        data_axes=dp, model_axes=mp, tp_sketch=tp_sketch, accum=accum,
        cost_mode=cost_mode))


def _build_train(cfg, cell, mesh, policy_entry, cost_mode, sp=True):
    dp, mp = _mesh_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    opt = adamw(cosine_warmup(3e-4, 2000, 100_000), weight_decay=0.1, clip=1.0,
                moment_dtype=jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32)
    accum = 1 if cost_mode else TRAIN_ACCUM.get(cfg.name.replace("-", "_"), 1)
    runtime = _runtime(cfg, cell, mesh, policy_entry, cost_mode, sp,
                       batch_div=cell.global_batch % n_dp == 0,
                       seq_len=cell.seq_len, accum=accum)
    step = runtime.train_step(cfg, opt, jitted=False)

    params_s = ispec.params_struct(cfg)
    pspecs = shard.param_shardings(params_s, mesh)
    opt_s = jax.eval_shape(opt.init, params_s)
    # optimizer state is a dict of params-congruent trees -> same shardings
    ospecs = {k: pspecs for k in opt_s}
    batch = ispec.train_inputs(cfg, cell)
    bspecs = {k: NamedSharding(mesh, s) for k, s in shard.batch_specs(cfg, cell, mesh).items()}

    from repro.train.train_step import TrainState
    state_struct = TrainState(params=params_s, opt_state=opt_s,
                              step=jax.ShapeDtypeStruct((), jnp.int32))
    state_shard = TrainState(params=pspecs, opt_state=ospecs,
                             step=NamedSharding(mesh, P()))
    key_struct = jax.ShapeDtypeStruct((), compat.key_dtype())

    fn = jax.jit(step, in_shardings=(state_shard, bspecs, NamedSharding(mesh, P())),
                 donate_argnums=(0,))
    return fn, (state_struct, batch, key_struct)


def _build_prefill(cfg, cell, mesh, cost_mode, sp=True):
    dp, mp = _mesh_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    runtime = _runtime(cfg, cell, mesh, None, cost_mode, sp,
                       batch_div=cell.global_batch % n_dp == 0,
                       seq_len=cell.seq_len)
    fn = runtime.prefill_step(cfg, cell.seq_len)
    params_s = ispec.params_struct(cfg)
    pspecs = shard.param_shardings(params_s, mesh)
    batch = ispec.train_inputs(cfg, cell)
    batch.pop("labels")
    bspecs = {k: NamedSharding(mesh, s)
              for k, s in shard.batch_specs(cfg, cell, mesh).items() if k in batch}
    jfn = jax.jit(fn, in_shardings=(pspecs, bspecs))
    return jfn, (params_s, batch)


def _build_decode(cfg, cell, mesh, cost_mode, sp=True):
    dp, mp = _mesh_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))
    runtime = _runtime(cfg, cell, mesh, None, cost_mode, sp,
                       batch_div=cell.global_batch % n_dp == 0, seq_len=0)
    fn = runtime.decode_step(cfg)
    params_s = ispec.params_struct(cfg)
    pspecs = shard.param_shardings(params_s, mesh)
    dec = ispec.decode_inputs(cfg, cell)
    cspecs = compat.tree_map(lambda s: NamedSharding(mesh, s),
                          shard.cache_specs(cfg, dec["caches"], mesh, cell.global_batch))
    tok_spec = NamedSharding(
        mesh, P(dp if cell.global_batch % n_dp == 0 else None, None, None)
        if cfg.frontend == "vision" else
        P(dp if cell.global_batch % n_dp == 0 else None, None))
    jfn = jax.jit(fn, in_shardings=(pspecs, cspecs, tok_spec, NamedSharding(mesh, P())),
                  donate_argnums=(1,))
    return jfn, (params_s, dec["caches"], dec["tokens"], dec["pos"])


def _builder(cfg, cell, mesh, policy, cost_mode, sp=True):
    if cost_mode and cfg.block_kind in ("zamba", "mamba", "rwkv"):
        # cost artifacts unroll the SSM chunk loops in python; enlarge chunks
        # to bound HLO size. RWKV: FLOP-neutral (sequential recurrence).
        # Mamba/SSD: the intra-chunk term grows with Q — ≤ ~2 % total FLOP
        # inflation at Q=1024 for the assigned configs (documented in
        # EXPERIMENTS.md §Methodology).
        cfg = cfg.replace(ssm_chunk=max(cfg.ssm_chunk, 1024))
    if cell.kind == "train":
        return _build_train(cfg, cell, mesh, policy, cost_mode, sp)
    if cell.kind == "prefill":
        return _build_prefill(cfg, cell, mesh, cost_mode, sp)
    return _build_decode(cfg, cell, mesh, cost_mode, sp)


def _lower_compile(fn, args):
    t0 = clock.now()
    lowered = fn.lower(*args)
    compiled = lowered.compile()
    return compiled, clock.now() - t0


def run_cell(arch: str, cell_name: str, *, multi_pod: bool, policy_name: str = "compact",
             skip_cost: bool = False, sp: bool = True, hw: HW = HW()) -> dict:
    cfg = get_config(arch)
    cell = SHAPE_CELLS[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    policy = _POLICIES[policy_name] if cell.kind == "train" else None
    rec = {"arch": arch, "cell": cell_name, "mesh": "x".join(map(str, mesh.shape.values())),
           "chips": chips, "kind": cell.kind, "policy": policy_name if cell.kind == "train" else "n/a",
           "status": "ok"}

    rec["sp"] = sp
    # ---- memory artifact: full depth, rolled scans -------------------------
    fn, args = _builder(cfg, cell, mesh, policy, cost_mode=False, sp=sp)
    compiled, dt = _lower_compile(fn, args)
    ma = compiled.memory_analysis()
    rec["compile_s"] = round(dt, 2)
    # same field set the obs memory ledger records per train_step executable
    rec["memory"] = memory_summary(ma, hbm_bytes=hw.hbm_bytes)
    rec["rolled_cost"] = cost_summary(compiled)
    rec["rolled_collectives"] = collective_bytes(compiled.as_text())
    del compiled, fn, args

    # ---- cost artifacts: unrolled depth slices -----------------------------
    if not skip_cost:
        pts = []
        for L, n_full, rem in _depth_points(cfg):
            cfg_L = _adjust_for_depth(cfg, L)
            fn, args = _builder(cfg_L, cell, mesh, policy, cost_mode=True, sp=sp)
            compiled, dtL = _lower_compile(fn, args)
            c = cost_summary(compiled)
            c["coll_bytes"] = collective_bytes(compiled.as_text())["total"]
            c["compile_s"] = dtL
            pts.append((n_full, rem, c))
            del compiled, fn, args
        coefs = fit_depth_model(pts)
        n_full_t, rem_t = _depth_target(cfg)
        full = predict_depth_model(coefs, n_full_t, rem_t)
        rec["cost_points"] = [
            {"n_full": nf, "rem": rm, **{k: v for k, v in c.items()}} for nf, rm, c in pts]
        rec["cost_full_depth"] = full
        rec["roofline"] = roofline_terms(full["flops"], full["bytes"],
                                         full["coll_bytes"], chips, hw)
        # MODEL_FLOPS (6·N_active·D train, 2·N_active·D inference) and ratio
        params_s = ispec.params_struct(cfg)
        n_total = sum(int(np.prod(x.shape)) for x in compat.tree_leaves(params_s))
        n_active = _active_params(params_s, cfg)
        tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
        mf = (6 if cell.kind == "train" else 2) * n_active * tokens
        rec["model_flops"] = mf
        rec["n_params"] = n_total
        rec["n_active_params"] = n_active
        hlo_flops_global = full["flops"] * chips
        rec["model_flops_ratio"] = mf / hlo_flops_global if hlo_flops_global else None
        if cell.kind == "train" and policy is not None and policy[0] is not None:
            # static per-site cost attribution (telemetry join key): modelled
            # backward FLOPs per sketched site, distributed over the
            # HLO-measured full-depth program FLOPs
            from repro.telemetry.sinks import (join_hlo_cost, site_cost_table,
                                               table_totals)

            table = site_cost_table(params_s, policy[0], tokens,
                                    n_layers=cfg.n_layers)
            rec["cost_attribution"] = {
                "sites": join_hlo_cost(table, full),
                "totals": table_totals(table),
            }
    if cell.kind == "train" and policy is not None and policy[0] is not None:
        # sketch-coverage gate: every backward matmul on the spine, or named
        # in analysis/baseline.json. Abstract tracing only — never executes
        # the cell (so it runs even under --skip-cost); defensive so an
        # analyzer bug can't sink a dry-run sweep. The HLO join uses the
        # full-depth FLOPs when the cost pass ran, else the rolled program.
        try:
            from repro.analysis.coverage import (analyze_runtime,
                                                 check_baseline)

            rep = analyze_runtime(Runtime(policy=policy[0]), cfg,
                                  batch_size=cell.global_batch,
                                  seq_len=cell.seq_len)
            gate = check_baseline(rep)
            hlo_flops = rec.get("cost_full_depth", rec["rolled_cost"])
            from repro.analysis.invariants import g_reader_ceiling

            backend = getattr(policy[0].base, "backend", None)
            rec["coverage"] = {
                **rep.summary(),
                "escaped_frac_vs_hlo": rep.escaped_frac_vs_hlo(
                    hlo_flops["flops"] * chips),
                "baseline_ok": gate.ok,
                "baseline_used": gate.used,
                "baseline_message": gate.message(),
                # per-estimator HBM accounting contract (docs/perf.md): the
                # compiled backward may read G at most this many times —
                # 1 for the plan-carry one-pass estimators, 2 legacy
                "g_reader_ceiling": (g_reader_ceiling(backend)
                                     if backend else None),
            }
        except Exception:
            rec["coverage"] = {"error": traceback.format_exc(limit=3)}
    return rec


def _active_params(params_s, cfg):
    total = sum(int(np.prod(x.shape)) for x in compat.tree_leaves(params_s))
    if cfg.n_experts == 0:
        return total
    e = 0
    for seg in params_s["segments"]:
        for sub in seg:
            if isinstance(sub, dict) and "moe" in sub:
                for k in ("wi", "wo", "wg"):
                    if k in sub["moe"]:
                        e += int(np.prod(sub["moe"][k].shape))
    return total - e + int(e * cfg.top_k / cfg.n_experts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--policy", default="mask", choices=list(_POLICIES))
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--no-sp", action="store_true", help="disable sequence-parallel activations")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    jobs = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        cfg = get_config(a)
        cells = [c.name for c in cells_for(cfg)]
        if args.cell:
            cells = [args.cell] if args.cell in cells else []
        jobs += [(a, c) for c in cells]

    for a, c in jobs:
        tag = f"{a}.{c}.{'2x16x16' if args.multipod else '16x16'}.{args.policy}"
        if args.no_sp:
            tag += ".nosp"
        out_path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(out_path):
            try:
                with open(out_path) as f:
                    cached = json.load(f)
            except (OSError, ValueError):
                cached = None  # unreadable/corrupt cache: recompute the cell
            if isinstance(cached, dict) and cached.get("status") == "ok":
                print(f"=== {tag} === (cached)", flush=True)
                continue
        print(f"=== {tag} ===", flush=True)
        try:
            rec = run_cell(a, c, multi_pod=args.multipod, policy_name=args.policy,
                           skip_cost=args.skip_cost, sp=not args.no_sp)
            mem = rec["memory"]
            print(f"  peak/dev: {mem['peak_GB_per_dev']:.2f} GB (fits={mem['fits_hbm']}) "
                  f"compile: {rec['compile_s']}s")
            if "roofline" in rec:
                r = rec["roofline"]
                print(f"  roofline: compute {r['compute_s']:.4f}s | memory {r['memory_s']:.4f}s "
                      f"| collective {r['collective_s']:.4f}s -> {r['dominant']}-bound")
        except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
            rec = {"arch": a, "cell": c, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"  FAILED: {rec['error']}")
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1, default=str)


if __name__ == "__main__":
    main()
