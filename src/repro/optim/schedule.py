"""LR schedules (paper: constant for MLP/SGD, cosine for BagNet/ViT)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "cosine_warmup"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return fn
