"""Pure-JAX optimizers with sharded state (ZeRO-3: states inherit param specs)."""
from repro.optim.optimizers import (Optimizer, adamw, clip_by_global_norm,
                                    global_grad_norm, sgd)
from repro.optim.schedule import constant, cosine_warmup

__all__ = ["Optimizer", "adamw", "sgd", "clip_by_global_norm",
           "global_grad_norm", "cosine_warmup", "constant"]
