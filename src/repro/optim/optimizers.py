"""SGD(+momentum) and AdamW as init/update function pairs (optax-style,
implemented from scratch — optax is not vendored here).

Optimizer state is a pytree congruent with params, so it shards with the same
PartitionSpecs (ZeRO-3). ``moment_dtype`` lets 100B+ archs keep bf16 moments
(documented HBM trade-off in DESIGN.md §4).

Compact gradients: any gradient leaf may be a
:class:`repro.core.compact_grad.CompactGrad` — ``dense + scatter(idx, rows)``
with *disjoint support* (exactly one part is nonzero; the dense part is
structural zeros whenever the compact backward ran, and XLA folds its
arithmetic away). Clipping and the updates below consume that form directly:

  * SGD               — pure sparse-row scatter update (touched rows only);
  * SGD + momentum    — elementwise momentum decay + sparse-row injection;
  * AdamW (default)   — elementwise moment decay + sparse-row injection;
    bit-equivalent to running the dense update on the densified gradient;
  * AdamW ``lazy=True`` — *lazy decay*: rows the sketch never touched skip
    the moment decay, the weight decay and the parameter update entirely
    (LazyAdam semantics — cheaper, not identical to dense AdamW; see
    docs/perf.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compact_grad import (CompactGrad, is_compact, row_gather,
                                     row_scatter)

__all__ = ["Optimizer", "sgd", "adamw", "clip_by_global_norm",
           "global_grad_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)


def _grad_leaves(grads):
    return jax.tree.leaves(grads, is_leaf=is_compact)


def _sq_norm(g):
    if is_compact(g):
        # disjoint support: ||dense + scatter(rows)||² = ||dense||² + ||rows||²
        t = jnp.sum(jnp.square(g.rows.astype(jnp.float32)))
        if g.dense is not None:
            t = t + jnp.sum(jnp.square(g.dense.astype(jnp.float32)))
        return t
    return jnp.sum(jnp.square(g.astype(jnp.float32)))


def global_grad_norm(grads):
    leaves = [g for g in _grad_leaves(grads) if is_compact(g) or hasattr(g, "astype")]
    return jnp.sqrt(sum(_sq_norm(g) for g in leaves))


def _scale_grad(g, scale):
    if is_compact(g):
        return CompactGrad(
            rows=g.rows.astype(jnp.float32) * scale,
            idx=g.idx,
            dense=None if g.dense is None else
            (g.dense.astype(jnp.float32) * scale).astype(g.dense.dtype))
    if hasattr(g, "astype"):
        return (g.astype(jnp.float32) * scale).astype(g.dtype)
    return g


def clip_by_global_norm(grads, max_norm: float):
    gn = global_grad_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: _scale_grad(g, scale), grads,
                        is_leaf=is_compact), gn


def _dense_part(g, p):
    return g.dense if g.dense is not None else jnp.zeros(p.shape, jnp.float32)


def sgd(lr: Callable | float, momentum: float = 0.0, clip: Optional[float] = None):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(
            lambda p: jnp.zeros_like(p) if _is_trainable(p) else jnp.zeros(()), params)}

    def update(grads, state, params, step):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        lr_t = lr_fn(step)
        if momentum == 0.0:
            def upd(p, g):
                if not _is_trainable(p):
                    return p
                if is_compact(g):
                    # dense part is structural zeros on the compact path —
                    # XLA folds it, leaving a pure sparse-row update.
                    p32 = p.astype(jnp.float32) - lr_t * _dense_part(g, p)
                    ii = g.idx.astype(jnp.int32)
                    return row_scatter(p32, ii, -lr_t * g.rows,
                                        add=True).astype(p.dtype)
                return (p.astype(jnp.float32) - lr_t * g.astype(jnp.float32)).astype(p.dtype)

            return jax.tree.map(upd, params, grads), state

        def upd_m(m, g):
            if not (hasattr(m, "ndim") and m.ndim):
                return m
            if is_compact(g):
                m1 = momentum * m + _dense_part(g, m).astype(m.dtype)
                return row_scatter(m1, g.idx.astype(jnp.int32), g.rows, add=True)
            if not hasattr(g, "astype"):
                return m
            return momentum * m + g.astype(m.dtype)

        new_m = jax.tree.map(upd_m, state["m"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m.astype(jnp.float32)).astype(p.dtype)
            if _is_trainable(p) else p,
            params, new_m)
        return new_params, {"m": new_m}

    return Optimizer(init, update)


def _is_trainable(p) -> bool:
    return hasattr(p, "dtype") and jnp.issubdtype(jnp.asarray(p).dtype, jnp.inexact)


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, clip: Optional[float] = None,
          moment_dtype=jnp.float32, lazy: bool = False):
    """AdamW. ``lazy=True`` applies LazyAdam semantics to CompactGrad leaves:
    untouched rows keep their moments and parameters unchanged (no decay, no
    update) — the fully-sparse counterpart of the compact backward. Dense
    leaves (and the default ``lazy=False``) use standard AdamW.

    Lazy mode relies on the CompactGrad contract that ``dense`` is structural
    zeros (it is ignored — a site whose backward fell back to a dense path
    would silently not train; ``with_grad_slots`` guards the known fallback
    triggers by not emitting slots for them)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype) if _is_trainable(p) else jnp.zeros(())
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def dense_step(p32, mhat, vhat):
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and p32.ndim >= 2:
                step_ = step_ + weight_decay * p32
            return step_

        def upd_lazy(p, g, m, v):
            # touched rows only: gather -> standard AdamW math -> scatter back
            ii = g.idx.astype(jnp.int32)
            rows = g.rows.astype(jnp.float32)
            m_r = b1 * row_gather(m, ii).astype(jnp.float32) + (1 - b1) * rows
            v_r = b2 * row_gather(v, ii).astype(jnp.float32) + (1 - b2) * jnp.square(rows)
            p_r = row_gather(p, ii).astype(jnp.float32)
            step_ = dense_step(p_r, m_r / c1, v_r / c2)
            return (row_scatter(p, ii, p_r - lr_t * step_, add=False),
                    row_scatter(m, ii, m_r, add=False),
                    row_scatter(v, ii, v_r, add=False))

        def upd(p, g, m, v):
            if not _is_trainable(p):
                return p, m, v  # static leaves (shapes, flags) pass through
            if is_compact(g):
                if lazy:
                    return upd_lazy(p, g, m, v)
                ii = g.idx.astype(jnp.int32)
                g32 = _dense_part(g, p)
                m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
                m_new = row_scatter(m_new, ii, (1 - b1) * g.rows, add=True)
                # disjoint support: (dense + scatter(rows))² has no cross term
                v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
                v_new = row_scatter(v_new, ii, (1 - b2) * jnp.square(g.rows), add=True)
            else:
                g32 = g.astype(jnp.float32)
                m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
                v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            p32 = p.astype(jnp.float32)
            step_ = dense_step(p32, m_new / c1, v_new / c2)
            return ((p32 - lr_t * step_).astype(p.dtype),
                    m_new.astype(moment_dtype), v_new.astype(moment_dtype))

        p_flat, treedef = jax.tree.flatten(params)
        g_flat = treedef.flatten_up_to(grads)
        m_flat = treedef.flatten_up_to(state["m"])
        v_flat = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(p_flat, g_flat, m_flat, v_flat)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)
