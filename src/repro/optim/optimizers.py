"""SGD(+momentum) and AdamW as init/update function pairs (optax-style,
implemented from scratch — optax is not vendored here).

Optimizer state is a pytree congruent with params, so it shards with the same
PartitionSpecs (ZeRO-3). ``moment_dtype`` lets 100B+ archs keep bf16 moments
(documented HBM trade-off in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adamw", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)


def clip_by_global_norm(grads, max_norm: float):
    leaves = [g for g in jax.tree.leaves(grads) if hasattr(g, "astype")]
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype)
                        if hasattr(g, "astype") else g, grads), gn


def sgd(lr: Callable | float, momentum: float = 0.0, clip: Optional[float] = None):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(
            lambda p: jnp.zeros_like(p) if _is_trainable(p) else jnp.zeros(()), params)}

    def update(grads, state, params, step):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        lr_t = lr_fn(step)
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - lr_t * g.astype(jnp.float32)).astype(p.dtype)
                if _is_trainable(p) else p,
                params, grads)
            return new_params, state
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(m.dtype) if hasattr(g, "astype") and m.ndim else m,
            state["m"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m.astype(jnp.float32)).astype(p.dtype)
            if _is_trainable(p) else p,
            params, new_m)
        return new_params, {"m": new_m}

    return Optimizer(init, update)


def _is_trainable(p) -> bool:
    return hasattr(p, "dtype") and jnp.issubdtype(jnp.asarray(p).dtype, jnp.inexact)


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, clip: Optional[float] = None,
          moment_dtype=jnp.float32):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype) if _is_trainable(p) else jnp.zeros(())
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            if not _is_trainable(p):
                return p, m, v  # static leaves (shapes, flags) pass through
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m_new / c1
            vhat = v_new / c2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            p32 = p.astype(jnp.float32)
            # decoupled weight decay on matrices only (ndim >= 2)
            if weight_decay and p.ndim >= 2:
                step_ = step_ + weight_decay * p32
            return ((p32 - lr_t * step_).astype(p.dtype),
                    m_new.astype(moment_dtype), v_new.astype(moment_dtype))

        p_flat, treedef = jax.tree.flatten(params)
        g_flat = treedef.flatten_up_to(grads)
        m_flat = treedef.flatten_up_to(state["m"])
        v_flat = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(p_flat, g_flat, m_flat, v_flat)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)
