"""Paper §5 larger architectures: ViT (Dosovitskiy 2021) and a BagNet-17-style
1×1-conv network (Brendel & Bethge 2019), sized per App. B.2.

BagNet's 1×1 convolutions "we assimilate as linear layers and sketch" (paper):
here they literally ARE sketched linear sites applied over the spatial grid
(a 1×1 conv ≡ dense over channels at every pixel). A few 3×3 stages reduce
resolution (exact backprop, matching the paper's exclusion of non-1×1 convs).
ViT sketches attention projections and MLP layers, excluding the classifier.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.attention import AttnCfg, attention, attn_init
from repro.nn.common import Ctx, dense, dense_init, layernorm, layernorm_init
from repro.nn.mlp import mlp as mlp_block, mlp_init

__all__ = ["vit_init", "vit_apply", "bagnet_init", "bagnet_apply", "cls_loss"]


# ---------------------------------------------------------------------------
# ViT — paper App. B.2: d=192, mlp 1024, depth 9, heads 12, patch 4 (CIFAR).
# ---------------------------------------------------------------------------


def vit_init(key, *, img=32, patch=4, d=192, depth=9, heads=12, d_ff=1024,
             n_classes=10, dtype=jnp.float32):
    ks = jax.random.split(key, depth + 4)
    n_tok = (img // patch) ** 2
    acfg = AttnCfg(n_heads=heads, n_kv=heads, d_head=d // heads, causal=False,
                   rope="none", impl="einsum")
    layers = []
    for i in range(depth):
        lk = jax.random.split(ks[i], 2)
        layers.append({
            "ln1": layernorm_init(d, dtype), "attn": attn_init(lk[0], d, acfg, dtype),
            "ln2": layernorm_init(d, dtype), "mlp": mlp_init(lk[1], d, d_ff, "gelu", dtype),
        })
    return {
        "patch": dense_init(ks[depth], patch * patch * 3, d, dtype, bias=True),
        "pos": jax.random.normal(ks[depth + 1], (1, n_tok + 1, d)) * 0.02,
        "cls": jnp.zeros((1, 1, d), dtype),
        "layers": layers,
        "ln_f": layernorm_init(d, dtype),
        "head": dense_init(ks[depth + 2], d, n_classes, dtype, bias=True),
    }


def _patchify(x, patch):
    B, H, W, C = x.shape
    x = x.reshape(B, H // patch, patch, W // patch, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, (H // patch) * (W // patch), patch * patch * C)


def vit_apply(params, x, ctx: Ctx, *, heads: int = 12):
    """x: [B, 32, 32, 3] images -> [B, n_classes] logits.

    ``heads`` is static config (params carry only arrays so they stay
    differentiable / optimizer-friendly); patch size derives from shapes.
    """
    patch = int(round((params["patch"]["w"].shape[1] // 3) ** 0.5))
    d = params["pos"].shape[-1]
    acfg = AttnCfg(n_heads=heads, n_kv=heads, d_head=d // heads, causal=False,
                   rope="none", impl="einsum")
    t = dense(params["patch"], _patchify(x, patch), ctx, "input_proj")
    B, n_tok, _ = t.shape
    cls = jnp.broadcast_to(params["cls"], (B, 1, d)).astype(t.dtype)
    t = jnp.concatenate([cls, t], axis=1) + params["pos"].astype(t.dtype)
    positions = jnp.broadcast_to(jnp.arange(t.shape[1])[None], (B, t.shape[1]))
    L = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        lctx = dataclasses.replace(ctx.for_layer(ctx.key, i), layer_index=i, n_layers=L)
        t = t + attention(lp["attn"], layernorm(lp["ln1"], t), lctx, acfg, positions)
        t = t + mlp_block(lp["mlp"], layernorm(lp["ln2"], t), lctx, "gelu")
    t = layernorm(params["ln_f"], t)
    return dense(params["head"], t[:, 0], ctx, "lm_head")


# ---------------------------------------------------------------------------
# BagNet-17-style: mostly 1×1 convs (= sketched linears over pixels) with a
# few exact 3×3/stride stages, ResNet-ish residual blocks.
# ---------------------------------------------------------------------------


def bagnet_init(key, *, width=64, n_blocks=(2, 2, 2), n_classes=10, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 64))
    params = {"stem": _conv_init(next(ks), 3, width, 3, dtype)}
    blocks = []
    w = width
    for si, n in enumerate(n_blocks):
        stage = []
        for bi in range(n):
            stage.append({
                "c1": dense_init(next(ks), w, w, dtype, bias=True),      # 1x1 (sketched)
                "c2": _conv_init(next(ks), w, w, 3, dtype),              # 3x3 (exact)
                "c3": dense_init(next(ks), w, w * 2 if bi == n - 1 and si < 2 else w,
                                 dtype, bias=True),                       # 1x1 (sketched)
            })
        blocks.append(stage)
        if si < 2:
            w *= 2
    params["blocks"] = blocks
    params["head"] = dense_init(next(ks), w, n_classes, dtype, bias=True)
    return params


def _conv_init(key, cin, cout, k, dtype):
    return {"w": (jax.random.normal(key, (k, k, cin, cout)) * (k * k * cin) ** -0.5).astype(dtype),
            "b": jnp.zeros((cout,), dtype)}


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(x, p["w"], (stride, stride), "SAME",
                                     dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def bagnet_apply(params, x, ctx: Ctx):
    """x: [B, 32, 32, 3] -> logits. 1×1 convs are sketched dense sites."""
    x = jax.nn.relu(_conv(params["stem"], x, stride=1))
    li = 0
    n_layers = sum(len(s) for s in params["blocks"])
    for si, stage in enumerate(params["blocks"]):
        for bi, bp in enumerate(stage):
            lctx = dataclasses.replace(ctx.for_layer(ctx.key, li),
                                       layer_index=li, n_layers=n_layers)
            li += 1
            h = jax.nn.relu(dense(bp["c1"], x, lctx, "mlp_in"))
            h = jax.nn.relu(_conv(bp["c2"], h))
            h = dense(bp["c3"], h, lctx, "mlp_out")
            if h.shape[-1] == x.shape[-1]:
                x = jax.nn.relu(x + h)
            else:
                x = jax.nn.relu(h)
        if si < len(params["blocks"]) - 1:
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                      (1, 2, 2, 1), "VALID")
    x = jnp.mean(x, axis=(1, 2))
    return dense(params["head"], x, ctx, "lm_head")


def cls_loss(apply_fn, params, batch, ctx: Ctx):
    logits = apply_fn(params, batch["x"], ctx)
    labels = batch["y"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - true)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc
