"""The paper's §5 MLP: 784 -> 64 -> 64 -> 10, cross-entropy, SGD, clip 1.0.

Every linear layer is a sketched VJP site (role "mlp_in"); the location study
(App. B.1, Fig. 4) uses the policy's first/last/all placement with *static*
layer indices (no scan), exactly as the paper applies it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.common import Ctx, dense, dense_init

__all__ = ["mlp_arch", "mlp_init", "mlp_apply", "mlp_loss", "mlp_sizes"]


def mlp_arch(sizes=(784, 64, 64, 10), name: str = "mlp"):
    """The §5 MLP as an :class:`~repro.configs.base.ArchConfig`
    (``family="mlp"``), so it rides the standard ``init_params``/``lm_loss``
    dispatch — and with it the trainer, checkpointing, elastic restart and
    the resilience supervisor. Field reuse: ``d_ff`` = input dim,
    ``d_model`` = hidden width, ``vocab`` = class count (recovered by
    :func:`mlp_sizes`); head fields are placeholders.
    """
    from repro.configs.base import ArchConfig

    sizes = tuple(int(s) for s in sizes)
    if len(sizes) < 2:
        raise ValueError(f"mlp_arch needs >= 2 sizes, got {sizes}")
    hidden = set(sizes[1:-1])
    if len(hidden) > 1:
        raise ValueError(f"mlp_arch encodes one hidden width, got {sizes}")
    return ArchConfig(name=name, family="mlp", n_layers=len(sizes) - 1,
                      d_model=(sizes[1] if len(sizes) > 2 else sizes[0]),
                      n_heads=1, n_kv=1, d_ff=sizes[0], vocab=sizes[-1])


def mlp_sizes(cfg) -> tuple:
    """Layer sizes back out of an ``mlp_arch``-built config."""
    return (cfg.d_ff,) + (cfg.d_model,) * (cfg.n_layers - 1) + (cfg.vocab,)


def mlp_init(key, sizes=(784, 64, 64, 10), dtype=jnp.float32):
    ks = jax.random.split(key, len(sizes) - 1)
    return [dense_init(k, a, b, dtype, bias=True)
            for k, a, b in zip(ks, sizes[:-1], sizes[1:])]


def mlp_apply(params, x, ctx: Ctx):
    import dataclasses

    L = len(params)
    for i, p in enumerate(params):
        # static layer index -> the location policy (first/last/all) applies
        lctx = dataclasses.replace(ctx.for_layer(ctx.key, i), layer_index=i, n_layers=L)
        role = "lm_head" if i == L - 1 else "mlp_in"
        x = dense(p, x, lctx, role)
        if i < L - 1:
            x = jax.nn.relu(x)
    return x


def mlp_loss(params, batch, ctx: Ctx):
    logits = mlp_apply(params, batch["x"], ctx)
    labels = batch["y"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - true)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc
