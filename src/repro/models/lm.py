"""Decoder-only / encoder-decoder LM assembly over heterogeneous layer stacks.

Architectures are compiled into a list of **segments**; each segment scans a
stack of identical **periods** (tuples of sub-blocks). Heterogeneous patterns
(gemma3's 5 local : 1 global, zamba2's 6 mamba : 1 shared-attention) become
homogeneous periods so `lax.scan` can stack them — the standard MaxText-style
trick that keeps HLO size O(1) in depth. ``ctx.cost_mode`` unrolls every loop
in python for scan-corrected HLO cost artifacts (see DESIGN.md / EXPERIMENTS).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import linear
from repro.nn.attention import AttnCfg, attention, attn_init, init_kv_cache
from repro.nn.common import Ctx, dense_init, rmsnorm, rmsnorm_init, trunc_normal
from repro.nn.mlp import mlp, mlp_init
from repro.nn.moe import MoECfg, moe_ffn, moe_init
from repro.nn.ssm import (MambaCfg, RWKVCfg, mamba_block, mamba_decode, mamba_init,
                          mamba_state_init, rwkv_channel_mix, rwkv_init,
                          rwkv_state_init, rwkv_time_mix)

__all__ = ["LayerKind", "plan_segments", "init_params", "forward", "decode_step",
           "init_cache", "lm_loss", "num_params", "active_params_per_token"]


@dataclasses.dataclass(frozen=True)
class LayerKind:
    kind: str  # attn | mamba | rwkv | shared_attn
    window: Optional[int] = None
    moe: bool = False
    cross: bool = False  # decoder cross-attention after self-attention
    causal: bool = True
    theta: Optional[float] = None  # rope theta override (gemma3 global layers)


def _attn_cfg(cfg: ArchConfig, kind: LayerKind) -> AttnCfg:
    return AttnCfg(
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
        causal=kind.causal, window=kind.window, rope=cfg.rope,
        theta=kind.theta or cfg.rope_theta, q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk, impl=cfg.attn_impl)


def _cross_cfg(cfg: ArchConfig) -> AttnCfg:
    return AttnCfg(n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
                   causal=False, rope="none", q_chunk=cfg.q_chunk,
                   kv_chunk=cfg.kv_chunk, impl=cfg.attn_impl, cross=True)


def _mamba_cfg(cfg: ArchConfig) -> MambaCfg:
    return MambaCfg(d_model=cfg.d_model, d_state=cfg.ssm_state,
                    head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk)


def _rwkv_cfg(cfg: ArchConfig) -> RWKVCfg:
    return RWKVCfg(d_model=cfg.d_model, head_dim=cfg.ssm_head_dim, d_ff=cfg.d_ff,
                   chunk=cfg.ssm_chunk)


def plan_segments(cfg: ArchConfig, *, encoder: bool = False):
    """Return [(period: tuple[LayerKind, ...], n_rep: int), ...]."""
    L = cfg.enc_layers if encoder else cfg.n_layers
    if encoder:
        return [((LayerKind("attn", causal=False),), L)]
    if cfg.block_kind == "rwkv":
        return [((LayerKind("rwkv"),), L)]
    if cfg.block_kind == "zamba":
        k = cfg.shared_attn_every
        period = tuple([LayerKind("mamba")] * k + [LayerKind("shared_attn")])
        n_full = L // k
        rem = L - n_full * k
        segs = [(period, n_full)] if n_full else []
        if rem:
            segs.append(((LayerKind("mamba"),), rem))
        return segs
    if cfg.local_global > 0:
        k = cfg.local_global
        local = LayerKind("attn", window=cfg.window)
        glob = LayerKind("attn", theta=cfg.rope_theta_global)
        period = tuple([local] * k + [glob])
        n_full = L // (k + 1)
        rem = L - n_full * (k + 1)
        segs = [(period, n_full)] if n_full else []
        if rem:
            segs.append(((local,), rem))
        return segs
    base = LayerKind("attn", window=cfg.window, moe=cfg.n_experts > 0,
                     cross=cfg.is_encdec)
    return [((base,), L)]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_sub(key, kind: LayerKind, cfg: ArchConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {"norm1": rmsnorm_init(d, dtype)}
    if kind.kind in ("attn", "shared_attn"):
        p["attn"] = attn_init(ks[0], d, _attn_cfg(cfg, kind), dtype)
        p["norm2"] = rmsnorm_init(d, dtype)
        if kind.moe:
            p["moe"] = moe_init(ks[1], d, MoECfg(cfg.n_experts, cfg.top_k, cfg.d_ff,
                                                 cfg.capacity_factor, cfg.mlp_type), dtype)
        else:
            p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_type, dtype)
        if kind.cross:
            p["cross"] = attn_init(ks[2], d, _cross_cfg(cfg), dtype)
            p["norm_c"] = rmsnorm_init(d, dtype)
    elif kind.kind == "mamba":
        p["mamba"] = mamba_init(ks[0], _mamba_cfg(cfg), dtype)
    elif kind.kind == "rwkv":
        p["rwkv"] = rwkv_init(ks[0], _rwkv_cfg(cfg), dtype)
        p["norm2"] = rmsnorm_init(d, dtype)
    return p


def _init_segment(key, period, n_rep, cfg: ArchConfig, dtype):
    subs = []
    for i, kind in enumerate(period):
        if kind.kind == "shared_attn":
            subs.append(None)  # parameters live in params["shared"]
            continue
        keys = jax.random.split(jax.random.fold_in(key, i), n_rep)
        subs.append(jax.vmap(lambda k: _init_sub(k, kind, cfg, dtype))(keys))
    return subs


def init_params(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    if cfg.family == "mlp":
        from repro.models import mlp as mlpmod

        return mlpmod.mlp_init(key, mlpmod.mlp_sizes(cfg), dtype)
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    params = {
        "embed": trunc_normal(ks[0], (cfg.vocab, d), d ** -0.5, dtype),
        "final_norm": rmsnorm_init(d, dtype),
    }
    segs = plan_segments(cfg)
    params["segments"] = [
        _init_segment(jax.random.fold_in(ks[1], si), period, n_rep, cfg, dtype)
        for si, (period, n_rep) in enumerate(segs)]
    if any(k.kind == "shared_attn" for period, _ in segs for k in period):
        params["shared"] = _init_sub(ks[2], LayerKind("shared_attn"), cfg, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], d, cfg.vocab, dtype, scale=d ** -0.5)
    if cfg.is_encdec:
        enc_segs = plan_segments(cfg, encoder=True)
        params["encoder"] = {
            "segments": [_init_segment(jax.random.fold_in(ks[4], si), period, n_rep, cfg, dtype)
                         for si, (period, n_rep) in enumerate(enc_segs)],
            "final_norm": rmsnorm_init(d, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Sub-block application
# ---------------------------------------------------------------------------


def _apply_sub(kind: LayerKind, p, x, ctx: Ctx, cfg: ArchConfig, positions,
               memory=None, cache=None, pos=None, segs=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind.kind in ("attn", "shared_attn"):
        acfg = _attn_cfg(cfg, kind)
        h = rmsnorm(p["norm1"], x)
        if cache is not None:
            o, new_self = attention(p["attn"], h, ctx, acfg, positions,
                                    cache=cache["kv"], pos=pos, segs=segs)
        else:
            o = attention(p["attn"], h, ctx, acfg, positions, segs=segs)
            new_self = None
        x = x + o
        new_cache = {"kv": new_self} if cache is not None else None
        if kind.cross:
            hc = rmsnorm(p["norm_c"], x)
            ccfg = _cross_cfg(cfg)
            if cache is not None and pos is not None:
                # decode: reuse cached cross K/V (computed at prefill)
                from repro.nn.attention import decode_attention, _split_heads  # noqa
                from repro.nn.common import dense
                q = dense(p["cross"]["q"], hc, ctx, "cross_q").reshape(
                    hc.shape[0], hc.shape[1], ccfg.n_heads, ccfg.d_head)
                kc, vc = cache["cross"]["k"], cache["cross"]["v"]
                o = decode_attention(q, kc, vc, kc.shape[1] - 1, dataclasses.replace(ccfg, window=None))
                o = dense(p["cross"]["o"], o.reshape(hc.shape[0], hc.shape[1], -1), ctx, "cross_o")
                x = x + o
                new_cache["cross"] = cache["cross"]
            else:
                o = attention(p["cross"], hc, ctx, ccfg, positions, memory=memory,
                              role_prefix="cross")
                x = x + o
                if cache is not None:
                    # prefill: cache cross K/V from memory
                    from repro.nn.common import dense
                    kc = dense(p["cross"]["k"], memory, ctx, "cross_k").reshape(
                        memory.shape[0], memory.shape[1], ccfg.n_kv, ccfg.d_head)
                    vc = dense(p["cross"]["v"], memory, ctx, "cross_v").reshape(
                        memory.shape[0], memory.shape[1], ccfg.n_kv, ccfg.d_head)
                    new_cache["cross"] = {"k": kc.astype(x.dtype), "v": vc.astype(x.dtype)}
        h2 = rmsnorm(p["norm2"], x)
        if kind.moe:
            mcfg = MoECfg(cfg.n_experts, cfg.top_k, cfg.d_ff, cfg.capacity_factor, cfg.mlp_type)
            o, aux = moe_ffn(p["moe"], h2, ctx, mcfg)
        else:
            o = mlp(p["mlp"], h2, ctx, cfg.mlp_type)
        return x + o, new_cache, aux

    if kind.kind == "mamba":
        mcfg = _mamba_cfg(cfg)
        h = rmsnorm(p["norm1"], x)
        if cache is not None and pos is not None:
            o, new_state = mamba_decode(p["mamba"], h, ctx, mcfg, cache)
            return x + o, new_state, aux
        o = mamba_block(p["mamba"], h, ctx, mcfg)
        new_cache = None
        if cache is not None:  # prefill: run decode-style to build state? use block + state capture
            # prefill builds state by running the chunked scan and keeping the
            # final state; redo cheaply via mamba_block internals is complex —
            # we recompute with state tracking below.
            o, new_cache = _mamba_prefill(p["mamba"], h, ctx, mcfg)
            return x + o, new_cache, aux
        return x + o, new_cache, aux

    if kind.kind == "rwkv":
        rcfg = _rwkv_cfg(cfg)
        h = rmsnorm(p["norm1"], x)
        tm_state = None
        if cache is not None:
            tm_state = {"wkv": cache["wkv"], "shift": cache["shift_tm"]}
        o, new_tm = rwkv_time_mix(p["rwkv"], h, ctx, rcfg, tm_state)
        x = x + o
        h2 = rmsnorm(p["norm2"], x)
        cm_state = cache["shift_cm"] if cache is not None else None
        o2, new_cm = rwkv_channel_mix(p["rwkv"], h2, ctx, rcfg, cm_state)
        x = x + o2
        new_cache = None
        if cache is not None:
            new_cache = {"wkv": new_tm["wkv"], "shift_tm": new_tm["shift"],
                         "shift_cm": new_cm}
        return x, new_cache, aux

    raise ValueError(kind.kind)


def _mamba_prefill(mp, h, ctx, mcfg):
    """mamba_block variant that also returns the final (ssm, conv) state."""
    from repro.nn.ssm import _mamba_pre, _ssd  # noqa: import inside to reuse internals
    Bsz, S, _ = h.shape
    H, P = mcfg.n_heads, mcfg.head_dim
    z, xs, Bc, Cc, dt, conv_tail = _mamba_pre(mp, h, ctx, mcfg, None)
    xh = xs.reshape(Bsz, S, H, P)
    A = jnp.exp(mp["A_log"])
    state0 = jnp.zeros((Bsz, H, P, mcfg.d_state), jnp.float32)
    y, state = _ssd(xh.astype(jnp.float32), dt, A, Bc.astype(jnp.float32),
                    Cc.astype(jnp.float32), mcfg, state0, ctx.cost_mode)
    y = y + mp["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, mcfg.d_inner).astype(h.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    y = rmsnorm(mp["norm"], y)
    from repro.nn.common import dense
    out = dense(mp["out"], y, ctx, "ssm_out")
    return out, {"ssm": state, "conv": conv_tail}


# ---------------------------------------------------------------------------
# Segment runner
# ---------------------------------------------------------------------------


def _layer_uid(seg_base: int, rep, period_len: int, sub_i: int):
    return seg_base + rep * period_len + sub_i


def _run_segments(seg_params, segments, x, ctx: Ctx, cfg: ArchConfig, step_key,
                  positions, shared=None, memory=None, caches=None, pos=None,
                  seg_base: int = 0, segs=None):
    """Run all segments; returns (x, aux_total, new_caches)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    base = seg_base
    for si, (period, n_rep) in enumerate(segments):
        plen = len(period)
        subs_params = seg_params[si]
        seg_caches = caches[si] if caches is not None else None

        def one_period(x, rep, sp, sc):
            aux = jnp.zeros((), jnp.float32)
            ncs = []
            for i, kind in enumerate(period):
                uid = _layer_uid(base, rep, plen, i)
                lctx = ctx.for_layer(step_key, uid)
                p = shared if kind.kind == "shared_attn" else sp[i]
                c = sc[i] if sc is not None else None
                x, nc, a = _apply_sub(kind, p, x, lctx, cfg, positions, memory, c,
                                      pos, segs)
                # re-pin the residual stream sharding so the scan carry keeps
                # the sequence-parallel layout across iterations
                x = ctx.constrain(x)
                aux = aux + a
                ncs.append(nc)
            return x, aux, ncs

        if ctx.cost_mode:
            ncs_all = [[] for _ in period]
            for rep in range(n_rep):
                sp = [None if sub is None else jax.tree.map(lambda a: a[rep], sub)
                      for sub in subs_params]
                sc = None
                if seg_caches is not None:
                    sc = [None if c is None else jax.tree.map(lambda a: a[rep], c)
                          for c in seg_caches]
                x, aux, ncs = one_period(x, rep, sp, sc)
                aux_total = aux_total + aux
                for i, nc in enumerate(ncs):
                    ncs_all[i].append(nc)
            if seg_caches is not None:
                new_caches.append([
                    None if ncs_all[i][0] is None else jax.tree.map(
                        lambda *a: jnp.stack(a), *ncs_all[i])
                    for i in range(plen)])
            else:
                new_caches.append(None)
        else:
            # scan over the stacked reps. Caches ride in the CARRY (not xs/ys):
            # loop-carried buffers are updated in place by XLA, so decode holds
            # ONE cache stack instead of xs+ys double buffers, and per-layer
            # slices stay loop-variant (no hoisted whole-stack converts).
            scan_params = [sub for sub in subs_params if sub is not None]
            has_cache = seg_caches is not None

            def _rebuild(sp_flat):
                sp, j = [], 0
                for sub in subs_params:
                    if sub is None:
                        sp.append(None)
                    else:
                        sp.append(sp_flat[j])
                        j += 1
                return sp

            def body(carry, xs):
                x, aux, cstack = carry
                rep, sp_flat = xs
                sp = _rebuild(sp_flat)
                sc = None
                if has_cache:
                    sc = [None if c is None else jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(a, rep, 0, keepdims=False), c)
                        for c in cstack]
                x, a, ncs = one_period(x, rep, sp, sc)
                if has_cache:
                    cstack = [
                        old if nc is None else jax.tree.map(
                            lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                                buf, new.astype(buf.dtype), rep, 0),
                            old, nc)
                        for old, nc in zip(cstack, ncs)]
                return (x, aux + a, cstack), None

            xs = (jnp.arange(n_rep), scan_params)
            # remat only matters under differentiation; serving scans (cache in
            # carry) skip it so XLA can update cache buffers strictly in place.
            body_fn = body if (cfg.remat == "none" or has_cache) else jax.checkpoint(
                body, policy=(jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                              if cfg.remat == "dots" else None))
            (x, aux, cstack_out), _ = jax.lax.scan(
                body_fn, (x, aux_total, seg_caches if has_cache else None), xs)
            aux_total = aux
            new_caches.append(cstack_out if has_cache else None)
        base += n_rep * plen
    return x, aux_total, new_caches


# ---------------------------------------------------------------------------
# Public model API
# ---------------------------------------------------------------------------


def _embed(params, tokens_or_embeds, cfg: ArchConfig):
    if jnp.issubdtype(tokens_or_embeds.dtype, jnp.integer):
        x = jnp.take(params["embed"], tokens_or_embeds, axis=0)
    else:
        x = tokens_or_embeds.astype(jnp.dtype(cfg.param_dtype))
    x = x.astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _head(params, x, ctx: Ctx, cfg: ArchConfig):
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]["w"]
    hcfg = ctx.cfg_for("lm_head")
    if getattr(ctx, "tp_sketch", False) and hcfg is None and ctx.mesh is not None             and not cfg.tie_embeddings:
        n_mp = 1
        for a in ctx.model_axes:
            n_mp *= ctx.mesh.shape[a]
        if w.shape[0] % n_mp == 0:
            from repro.core.sharded_sketch import tp_exact_linear

            return tp_exact_linear(x, w, ctx)
    return linear(x, w, key=ctx.site_key("lm_head"), cfg=hcfg)


def _default_positions(cfg: ArchConfig, B, S, offset=0):
    # offset: scalar, or int32 [B] per-row start positions (serving decode)
    pos = jnp.asarray(offset)[..., None] + jnp.arange(S)[None, :]
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def encode(params, src_embeds, ctx: Ctx, cfg: ArchConfig, step_key=None):
    """Encoder stack (enc-dec archs). src_embeds: [B, S_enc, d] (stub frontend)."""
    enc = params["encoder"]
    segs = plan_segments(cfg, encoder=True)
    B, S, _ = src_embeds.shape
    positions = _default_positions(cfg, B, S)
    x = ctx.constrain(src_embeds.astype(jnp.dtype(cfg.dtype)))
    x, _, _ = _run_segments(enc["segments"], segs, x, ctx, cfg, step_key,
                            positions, seg_base=10_000)
    return rmsnorm(enc["final_norm"], x)


def forward(params, batch, ctx: Ctx, cfg: ArchConfig, step_key=None):
    """Training / scoring forward. Returns (logits, aux).

    batch: {"tokens": int[B,S]} or {"embeds": f32[B,S,d]} (+ optional
    "positions", "src_embeds" for enc-dec).
    """
    inp = batch.get("tokens", batch.get("embeds"))
    B, S = inp.shape[0], inp.shape[1]
    x = ctx.constrain(_embed(params, inp, cfg))
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, S)
    memory = None
    if cfg.is_encdec:
        memory = encode(params, batch["src_embeds"], ctx, cfg, step_key)
    segs = plan_segments(cfg)
    x, aux, _ = _run_segments(params["segments"], segs, x, ctx, cfg, step_key,
                              positions, shared=params.get("shared"), memory=memory,
                              segs=batch.get("segments"))
    x = rmsnorm(params["final_norm"], x)
    logits = _head(params, x, ctx, cfg)
    return logits, aux


def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int = 0):
    """Decode caches for every segment/position (stacked over reps)."""
    dtype = jnp.dtype(cfg.dtype)
    segs = plan_segments(cfg)
    caches = []
    for period, n_rep in segs:
        seg = []
        for kind in period:
            if kind.kind in ("attn", "shared_attn"):
                acfg = _attn_cfg(cfg, kind)
                c = {"kv": init_kv_cache(batch, max_len, acfg, dtype)}
                if kind.cross:
                    c["cross"] = {"k": jnp.zeros((batch, enc_len, acfg.n_kv, acfg.d_head), dtype),
                                  "v": jnp.zeros((batch, enc_len, acfg.n_kv, acfg.d_head), dtype)}
                seg.append(jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape), c))
            elif kind.kind == "mamba":
                st = mamba_state_init(batch, _mamba_cfg(cfg), dtype)
                seg.append(jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape), st))
            elif kind.kind == "rwkv":
                st = rwkv_state_init(batch, _rwkv_cfg(cfg), dtype)
                seg.append(jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape), st))
        caches.append(seg)
    return caches


def decode_step(params, caches, tokens, pos, ctx: Ctx, cfg: ArchConfig, step_key=None):
    """One decode step. tokens: int[B, 1] (or embeds [B,1,d]); pos: scalar, or
    an int32 [B] per-slot position vector (continuous-batching serving — each
    row writes/attends at its own timestep; see docs/serving.md).

    Returns (logits [B,1,V], new_caches).
    """
    B = tokens.shape[0]
    x = _embed(params, tokens, cfg)
    positions = _default_positions(cfg, B, 1, offset=pos)
    segs = plan_segments(cfg)
    x, _, new_caches = _run_segments(params["segments"], segs, x, ctx, cfg, step_key,
                                     positions, shared=params.get("shared"),
                                     caches=caches, pos=pos)
    x = rmsnorm(params["final_norm"], x)
    return _head(params, x, ctx, cfg), new_caches


def prefill(params, batch, ctx: Ctx, cfg: ArchConfig, max_len: int, step_key=None):
    """Prefill: forward + populate caches. Returns (logits, caches).

    Optional ``batch["segments"]`` (int32 [B,S], 0 = padding) segment-masks
    self-attention so several packed prompts share one prefill call.
    """
    inp = batch.get("tokens", batch.get("embeds"))
    B, S = inp.shape[0], inp.shape[1]
    x = _embed(params, inp, cfg)
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, S)
    memory = None
    if cfg.is_encdec:
        memory = encode(params, batch["src_embeds"], ctx, cfg, step_key)
    segs = plan_segments(cfg)
    caches = init_cache(cfg, B, max_len, enc_len=memory.shape[1] if memory is not None else 0)
    x, _, new_caches = _run_segments(params["segments"], segs, x, ctx, cfg, step_key,
                                     positions, shared=params.get("shared"),
                                     memory=memory, caches=caches, pos=None,
                                     segs=batch.get("segments"))
    x = rmsnorm(params["final_norm"], x)
    return _head(params, x, ctx, cfg), new_caches


def lm_loss(params, batch, ctx: Ctx, cfg: ArchConfig, step_key=None):
    """Next-token cross-entropy (vocab-shard friendly masked reduce).

    Returns (loss, metrics dict).

    ``family="mlp"`` configs (:func:`repro.models.mlp.mlp_arch`) dispatch to
    the §5 classification MLP instead — batch is ``{"x", "y"}`` and the
    metrics gain ``acc`` — so the one trainer/checkpoint/resilience stack
    drives both model families.
    """
    if cfg.family == "mlp":
        from repro.models import mlp as mlpmod

        loss, acc = mlpmod.mlp_loss(params, batch, ctx)
        return loss, {"loss": loss, "acc": acc, "nll": loss}
    logits, aux = forward(params, batch, ctx, cfg, step_key)
    labels = batch["labels"]
    lg32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg32, axis=-1)
    V = lg32.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, lg32.shape, len(lg32.shape) - 1)
    true_logit = jnp.sum(jnp.where(iota == labels[..., None], lg32, 0.0), axis=-1)
    nll = lse - true_logit
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + aux
    return total, {"loss": loss, "aux": aux, "nll": loss}


def num_params(params) -> int:
    return int(sum(x.size for x in jax.tree.leaves(params)))


def active_params_per_token(params, cfg: ArchConfig) -> int:
    """Active parameter count (MoE: only top_k of n_experts per token)."""
    total = num_params(params)
    if cfg.n_experts == 0:
        return total

    def expert_leaves(p):
        n = 0
        for seg in p["segments"]:
            for sub in seg:
                if sub is None:
                    continue
                moe = sub.get("moe") if isinstance(sub, dict) else None
                if moe:
                    for k in ("wi", "wo", "wg"):
                        if k in moe:
                            n += moe[k].size
        return n

    e_total = expert_leaves(params)
    active = total - e_total + int(e_total * cfg.top_k / cfg.n_experts)
    return active
