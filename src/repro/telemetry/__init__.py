"""Telemetry: close the paper's cost–precision loop online.

The paper's central trade-off — per-step cost reduction vs the
gradient-variance-driven increase in steps-to-precision — ran *open loop* in
this repo: ``BudgetSchedule`` buckets were fixed up front and nothing measured
the realized estimator variance during training. This subsystem makes the
loop closable:

* :mod:`repro.telemetry.probes` — cheap **in-graph probes**: unbiased
  per-site estimates of VJP variance / gradient norm / sketched-vs-exact
  alignment, computed from quantities the estimators already materialize
  (kept dW rows and sampling probabilities) and smuggled out of ``jax.grad``
  as slot cotangents — no second backward, no extra pass over G.
* :mod:`repro.telemetry.sinks` — JSONL / CSV scalar writers, an in-memory
  ring buffer, and static per-site cost attribution joined with the HLO cost
  model from ``launch/hlo_analysis``.
* :mod:`repro.telemetry.controller` — the **closed-loop controller**:
  :class:`~repro.telemetry.controller.AdaptiveBudgetController` consumes
  probe summaries between steps and picks the cheapest pre-compiled budget
  bucket meeting a target gradient SNR (``BudgetSchedule.adaptive``).

:class:`TelemetryConfig` below is the static, hashable switchboard that rides
on :class:`repro.api.ExecutionConfig` (``ExecutionConfig.telemetry``); see
``docs/telemetry.md`` for probe math, SNR semantics and sink formats.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["TelemetryConfig"]


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static telemetry switchboard (frozen/hashable — safe on ExecutionConfig).

    Attributes:
      probes: enable the in-graph per-site probes (adds probe slots to the
        params tree; requires ``accum == 1``; sites on the TP shard_map
        plans probe in-body, psum'ed over the model axis — see
        docs/telemetry.md).
      per_site: include the per-site probe vectors in the step metrics
        (``metrics["probe_sites"]``) in addition to the step-level summary
        scalars (``probe_gsq`` / ``probe_var`` / ``probe_snr`` /
        ``probe_align``).
      jsonl / csv: optional output paths; the trainer builds the matching
        sinks and writes one record per ``interval`` steps.
      interval: sink write cadence in steps (history/controller cadence is
        unaffected).
    """

    probes: bool = True
    per_site: bool = True
    jsonl: Optional[str] = None
    csv: Optional[str] = None
    interval: int = 1

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")
