"""Telemetry sinks + per-site cost attribution.

Sinks consume one *record* per step — a flat dict of scalars (step, budget,
loss, probe summary) plus an optional nested ``probe_sites`` map — and
persist it: :class:`JsonlSink` (one JSON object per line, the
machine-readable format ``benchmarks``/offline analysis read),
:class:`CsvSink` (scalar columns only, for spreadsheets), and
:class:`RingSink` (bounded in-memory buffer, used by tests and the serving
engine's decode-path counters). The trainer builds them from
:class:`repro.telemetry.TelemetryConfig` via :func:`build_sinks`.

Cost attribution answers "what does each probed site *cost*": a static
per-site model of backward FLOPs (exact vs sketched, from the same
``static_rank`` / block math the estimators use) that can be joined with the
HLO-measured program totals from ``launch/hlo_analysis.cost_summary`` — the
modelled per-site fractions distribute the measured total, so probe rows and
cost rows share keys. ``launch/dryrun`` records the table per train cell;
``benchmarks/bench_adaptive`` integrates it over a realized budget schedule
to get the loss-vs-FLOPs axis.
"""
from __future__ import annotations

import csv
import json
import os
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.core.compact_grad import _site_role, compact_rank
from repro.core.sketching import COLUMN_METHODS

__all__ = ["Sink", "JsonlSink", "CsvSink", "RingSink", "MultiSink",
           "build_sinks", "percentiles", "recovery_record", "site_cost_table",
           "table_totals", "join_hlo_cost"]


def percentiles(records, field: str, qs=(50, 99)) -> dict:
    """Percentiles of one numeric field across sink records: ``{q: value}``,
    ``None`` values when no record carries the field. The serving engine
    summarizes its per-request ring this way (latency/TTFT p50/p99)."""
    vals = [float(r[field]) for r in records
            if isinstance(r.get(field), (int, float, np.integer, np.floating))]
    if not vals:
        return {q: None for q in qs}
    arr = np.percentile(np.asarray(vals), list(qs))
    return {q: float(v) for q, v in zip(qs, arr)}


def recovery_record(event: str, **fields) -> dict:
    """One resilience event as a sink record: ``{"event": <kind>, ...}``.

    The trainer/supervisor route every sentinel trip, rollback, checkpoint
    IO recovery and elastic re-shard through this shape so offline analysis
    (``benchmarks/bench_resilience.py``) can filter the JSONL stream on the
    ``event`` key alone; regular step records never carry one.
    """
    return dict({"event": str(event)}, **fields)


def _scalars(record: dict) -> dict:
    return {k: v for k, v in record.items()
            if isinstance(v, (int, float, np.integer, np.floating)) or v is None}


class Sink:
    """Protocol: ``write(record)`` once per step, ``close()`` at loop end."""

    def write(self, record: dict):  # noqa: B027 — protocol default
        pass

    def close(self):  # noqa: B027
        pass


class JsonlSink(Sink):
    """One JSON object per line (full record, nested ``probe_sites`` kept)."""

    def __init__(self, path: str):
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._f = open(path, "a")

    def write(self, record: dict):
        self._f.write(json.dumps(record, default=float) + "\n")
        self._f.flush()

    def close(self):
        self._f.close()


class CsvSink(Sink):
    """Scalar columns only; the header is fixed by the first record (later
    records fill missing columns with empty cells, extra keys are dropped —
    CSV is the quick-look format, JSONL is the lossless one)."""

    def __init__(self, path: str):
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._f = open(path, "a", newline="")
        self._writer: Optional[csv.DictWriter] = None

    def write(self, record: dict):
        row = _scalars(record)
        if self._writer is None:
            self._writer = csv.DictWriter(self._f, fieldnames=sorted(row),
                                          extrasaction="ignore", restval="")
            self._writer.writeheader()
        self._writer.writerow(row)
        self._f.flush()

    def close(self):
        self._f.close()


class RingSink(Sink):
    """Bounded in-memory buffer of the most recent records."""

    def __init__(self, capacity: int = 256):
        self._buf = deque(maxlen=int(capacity))

    def write(self, record: dict):
        self._buf.append(record)

    @property
    def records(self) -> List[dict]:
        return list(self._buf)

    def __len__(self):
        return len(self._buf)


class MultiSink(Sink):
    def __init__(self, sinks):
        self.sinks = list(sinks)

    def write(self, record: dict):
        for s in self.sinks:
            s.write(record)

    def close(self):
        for s in self.sinks:
            s.close()


def build_sinks(tcfg) -> Optional[MultiSink]:
    """Sinks for a :class:`~repro.telemetry.TelemetryConfig` (None if the
    config names no outputs — the probe summary still rides the metrics)."""
    if tcfg is None:
        return None
    sinks: List[Sink] = []
    if tcfg.jsonl:
        sinks.append(JsonlSink(tcfg.jsonl))
    if tcfg.csv:
        sinks.append(CsvSink(tcfg.csv))
    return MultiSink(sinks) if sinks else None


# ---------------------------------------------------------------------------
# Static per-site cost attribution
# ---------------------------------------------------------------------------


def site_cost_table(params, policy, n_tokens: int, *, n_layers: int = 1) -> Dict[str, dict]:
    """Analytic per-site backward-FLOP attribution for one train step.

    Walks ``params`` (arrays or ShapeDtypeStructs — the dry-run passes the
    latter) with the same path matching as the probe/gradient slot builders,
    so cost rows and probe rows share keys. Per linear site ``w: [*, n, d]``
    (leading dims = scan stacking) the backward is two matmuls:

      * exact:    ``4 · T · n · d`` FLOPs per layer (dX + dW),
      * sketched: ``4 · T · r · d + T · n`` — reduced-shape matmuls over the
        ``r`` kept columns plus one score pass over G (column-family
        methods; other methods keep dense-shaped masked matmuls, ``r = n``).
    """
    if policy is None:
        return {}
    table: Dict[str, dict] = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (k,))
            role = None if "shared" in path else _site_role(path)
            w = node.get("w")
            if role is None or w is None or len(getattr(w, "shape", ())) < 2:
                return
            cfg = policy.config_for(role, 0, n_layers)
            if cfg is None or cfg.is_noop:
                return
            lead = int(np.prod(w.shape[:-2], dtype=np.int64)) if len(w.shape) > 2 else 1
            n, d = int(w.shape[-2]), int(w.shape[-1])
            r = compact_rank(cfg, n) if cfg.method in COLUMN_METHODS else n
            exact = 4.0 * n_tokens * n * d * lead
            sketched = 4.0 * n_tokens * r * d * lead
            if cfg.method in COLUMN_METHODS and cfg.method != "per_column":
                sketched += float(n_tokens) * n * lead  # score pass over G
            table["/".join(map(str, path))] = {
                "role": role, "n": n, "d": d, "layers": lead, "r": r,
                "budget": cfg.budget,
                "bwd_exact_flops": exact, "bwd_sketched_flops": sketched,
                "savings_frac": 1.0 - sketched / exact,
            }
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (i,))

    walk(params, ())
    return table


def table_totals(table: Dict[str, dict]) -> dict:
    exact = sum(v["bwd_exact_flops"] for v in table.values())
    sketched = sum(v["bwd_sketched_flops"] for v in table.values())
    return {"bwd_exact_flops": exact, "bwd_sketched_flops": sketched,
            "savings_frac": (1.0 - sketched / exact) if exact else 0.0,
            "n_sites": len(table)}


def join_hlo_cost(table: Dict[str, dict], hlo_cost: dict) -> Dict[str, dict]:
    """Join the modelled table with HLO-measured program totals
    (``launch.hlo_analysis.cost_summary`` output): each site gains
    ``hlo_flops_share`` — its modelled exact-backward fraction of the
    measured per-device program FLOPs — so relative site weights come from
    the model while the absolute scale comes from the compiler."""
    total = sum(v["bwd_exact_flops"] for v in table.values())
    measured = float(hlo_cost.get("flops", 0.0))
    out = {}
    for k, v in table.items():
        share = (v["bwd_exact_flops"] / total) if total else 0.0
        out[k] = dict(v, hlo_flops_share=share * measured)
    return out
