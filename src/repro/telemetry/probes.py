"""In-graph telemetry probes: unbiased per-site VJP-variance estimates.

The probes answer, per sketched site and per step, "how noisy was the weight
gradient this estimator just produced?" — from quantities the backward
already materializes, with no second backward and no extra pass over G.

Probe math (column-family estimators)
-------------------------------------
A column sketch keeps column ``j`` of the output gradient ``G`` with marginal
probability ``p_j`` and rescales it by ``1/p_j``. Write ``u_j = g_jᵀ X`` for
row ``j`` of the exact weight gradient ``dW = Gᵀ X``; the sketched rows are
``dŴ_j = (z_j / p_j) u_j``. The backward materializes exactly the kept rows
``rows_j = u_j / p_j`` (compact backends) or the dense ``dŴ`` whose dropped
rows are zero (mask backend) — the same formulas cover both:

* ``g_sq   = Σ_kept p_j ‖rows_j‖²``   — unbiased estimate of ``‖dW‖²_F``
  (importance-sampling over the kept set: ``E[g_sq] = Σ_j ‖u_j‖²``).
* ``var    = Σ_kept (1 − p_j) ‖rows_j‖²`` — unbiased estimate of the per-site
  VJP variance ``E‖dŴ − dW‖²_F = Σ_j ((1−p_j)/p_j) ‖u_j‖²`` for
  *independent* gates (Lemma 3.4 sampling). Under correlated exact-r
  sampling (Lemma 3.1, the default) this estimates the **diagonal** term of
  the variance; the correlation cross-terms are not probed (they carry
  arbitrary sign but are small at production budgets — see
  docs/telemetry.md).
* ``ghat_sq = Σ_kept ‖rows_j‖²``      — realized ``‖dŴ‖²_F``.

Derived step statistics: ``snr = g_sq / var`` (the controller's signal) and
``align = sqrt(g_sq / ghat_sq)`` — an estimate of the sketched-vs-exact
gradient alignment ``⟨dŴ, dW⟩ / ‖dŴ‖²`` in root form, since the realized
inner product ``⟨dŴ, dW⟩ = Σ_kept p_j ‖rows_j‖²`` coincides with ``g_sq``.
Both are exactly 1-like for exact backprop in the limit ``p → 1``.

Transport out of ``jax.grad``
-----------------------------
A ``custom_vjp`` can only emit cotangents for its inputs, so each probed site
gets a **probe slot**: a zero ``[PROBE_WIDTH]`` f32 leaf under key
``"pslot"`` merged into the params tree (the same trick as
``core/compact_grad`` gradient slots). The forward ignores the slot; the
sketched backward *defines* its cotangent to be the probe vector. After
``jax.grad``, :func:`collect_probes` strips the slots back out of the
gradient tree and :func:`summarize` reduces them to step-level scalars.

Coverage: column-family methods (``per_column`` + score methods) on any
registered estimator implementing ``apply_with_probe``, plus every site
routed through a TP shard_map plan (the spine computes the probe inside the
backward body from the estimator's plan marginals and psums it over the
model axis — see ``core/site.py``); non-column methods (``per_element`` /
``per_sample`` / ``rcs``) and multi-use shared weights report zeros.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import estimators
from repro.core.sketching import COLUMN_METHODS

__all__ = ["PROBE_WIDTH", "PROBE_FIELDS", "probe_from_rows", "probe_capable",
           "with_probe_slots", "mlp_probe_slots", "collect_probes",
           "summarize"]

# Probe vector layout. ok is 1.0 when the estimator actually produced a probe
# (so a zero vector is distinguishable from a perfectly quiet site).
PROBE_FIELDS = ("g_sq", "var", "ghat_sq", "ok")
PROBE_WIDTH = len(PROBE_FIELDS)


def probe_from_rows(rows: jax.Array, probs: jax.Array) -> jax.Array:
    """The probe vector from materialized dW rows + their keep marginals.

    rows: ``[r, d_in]`` kept (rescaled) dW rows — or the dense ``[n, d_in]``
    sketched dW whose dropped rows are zero (they contribute nothing).
    probs: matching ``[r]`` (or ``[n]``) keep marginals ``p_j``.
    """
    r32 = rows.astype(jnp.float32)
    rs = jnp.einsum("rd,rd->r", r32, r32)  # ‖rows_j‖², no [r, d] temp
    p = probs.astype(jnp.float32)
    # one tiny dot emits all three statistics: rs · [p, 1−p, 1]
    w3 = jnp.stack([p, 1.0 - p, jnp.ones_like(p)], axis=-1)  # [r, 3]
    v3 = rs @ w3  # [g_sq, var, ghat_sq]
    return jnp.concatenate([v3, jnp.ones((1,), jnp.float32)])


def probe_capable(cfg) -> bool:
    """Can this site's estimator produce a probe? (slot-worthiness check)."""
    if cfg is None or cfg.is_noop or cfg.method not in COLUMN_METHODS:
        return False
    try:
        est = estimators.get_estimator(cfg.backend)
    except KeyError:
        return False
    # only estimators that override the optional hook emit probes
    return (type(est).apply_with_probe
            is not estimators.Estimator.apply_with_probe)


# ---------------------------------------------------------------------------
# Probe slots
# ---------------------------------------------------------------------------


def with_probe_slots(params, policy, *, n_layers: int = 1, mesh=None,
                     data_axes=("data",), model_axes=("model",),
                     tp_sketch: bool = False):
    """Merge zero probe slots into ``params`` at every probe-capable site.

    Mirrors ``core.compact_grad.with_grad_slots`` — both consume the same
    resolved :class:`~repro.core.site.SiteSpec` as ``nn.common.dense``
    (``core.site.resolve_tree_site``), so a slot appears exactly when the
    site's resolved execution plan can emit a probe: via the estimator's
    ``apply_with_probe`` hook on local plans, via the in-body plan marginals
    on the TP shard_map plans (psum'ed over the model axis). Only
    ``location="all"`` policies get slots (scan-stacked models cannot
    distinguish layers statically). Unlike gradient slots, multi-use shared
    weights MAY carry a probe slot — per-use probe cotangents sum, and probe
    vectors are additive statistics — but we mirror the gslot exclusion for
    the ``"shared"`` subtree anyway to keep the two slot trees congruent.
    """
    if policy is None or policy.location != "all":
        return params
    from repro.core.site import resolve_tree_site

    def walk(node, path):
        if isinstance(node, dict):
            out = {k: walk(v, path + (k,)) for k, v in node.items()}
            spec = resolve_tree_site(path, node, policy, n_layers=n_layers,
                                     mesh=mesh, data_axes=data_axes,
                                     model_axes=model_axes,
                                     tp_sketch=tp_sketch)
            if spec is not None and spec.probe_capable:
                lead = node["w"].shape[:-2]
                out["pslot"] = jnp.zeros(lead + (PROBE_WIDTH,), jnp.float32)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path) for v in node)
        return node

    return walk(params, ())


def mlp_probe_slots(params, policy):
    """Probe slots for the §5 MLP family (list of {"w","b"} dicts; roles
    ``mlp_in`` per hidden layer, ``lm_head`` for the output — the
    ``models.mlp`` convention). Static layer indices, so location policies
    (first/last) work here."""
    if policy is None:
        return params
    L = len(params)
    out = []
    for i, site in enumerate(params):
        role = "lm_head" if i == L - 1 else "mlp_in"
        cfg = policy.config_for(role, i, L)
        site = dict(site)
        if probe_capable(cfg):
            site["pslot"] = jnp.zeros((PROBE_WIDTH,), jnp.float32)
        out.append(site)
    return out


def collect_probes(grads) -> Tuple[object, Dict[str, jax.Array]]:
    """Strip ``"pslot"`` cotangents out of a gradient tree.

    Returns ``(clean_grads, probes)`` where ``clean_grads`` matches the
    original (slot-free) params structure and ``probes`` maps a
    ``/``-joined site path to its probe vector (``[PROBE_WIDTH]``, or
    ``[L, PROBE_WIDTH]`` for scan-stacked sites).
    """
    probes: Dict[str, jax.Array] = {}

    def walk(node, path):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "pslot":
                    probes["/".join(map(str, path))] = v
                else:
                    out[k] = walk(v, path + (k,))
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, path + (i,)) for i, v in enumerate(node))
        return node

    clean = walk(grads, ())
    return clean, probes


def summarize(probes: Dict[str, jax.Array], *, per_site: bool = True) -> dict:
    """Reduce per-site probe vectors to step-level metrics (in-graph).

    Returns ``probe_gsq`` / ``probe_var`` / ``probe_snr`` / ``probe_align``
    scalars plus (optionally) ``probe_sites``: site path -> summed
    ``[PROBE_WIDTH]`` vector (leading scan dims reduced).
    """
    if not probes:
        return {}
    site_tot = {k: v.reshape(-1, PROBE_WIDTH).sum(axis=0)
                for k, v in probes.items()}
    tot = sum(site_tot.values())
    g_sq, var, ghat_sq = tot[0], tot[1], tot[2]
    out = {
        "probe_gsq": g_sq,
        "probe_var": var,
        "probe_snr": g_sq / jnp.maximum(var, jnp.float32(1e-20)),
        "probe_align": jnp.sqrt(g_sq / jnp.maximum(ghat_sq, jnp.float32(1e-20))),
    }
    if per_site:
        out["probe_sites"] = site_tot
    return out
