"""Closed-loop budget control: pick the cheapest bucket meeting a target SNR.

The paper shows per-step cost and gradient variance trade off against each
other, and unbiasedness (§2.2) makes it safe to move along that trade-off
*during* a run. :class:`AdaptiveBudgetController` closes the loop: it
consumes the probe summary (``probe_snr`` — the step-level estimate
``‖dW‖² / E‖dŴ − dW‖²`` from ``repro/telemetry/probes.py``) between steps
and walks the schedule's **pre-compiled** budget buckets toward the cheapest
one whose *predicted* SNR still meets the target. No recompiles, ever: the
controller only selects among buckets the trainer built before the loop.

Prediction uses the column-sketch scaling law: at uniform budget ``b`` the
probed (diagonal) variance scales as ``(1 − b) / b`` while ``‖dW‖²`` is
budget-free, so a measurement at ``b₀`` extrapolates as

    snr(b) ≈ snr(b₀) · [b (1 − b₀)] / [b₀ (1 − b)].

Exact buckets (``None``) have infinite SNR and always qualify; they provide
no measurement, so after ``window`` quiet steps at an exact bucket the
controller steps down one level to start measuring. Hysteresis: the SNR is
EMA-smoothed, re-evaluated every ``window`` steps, and the level moves at
most one bucket per evaluation.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

__all__ = ["AdaptiveBudgetController"]


class AdaptiveBudgetController:
    # Conforms to the repro.api.schedule.Controller protocol by duck typing
    # (step_begin / step_end / budget / wants_metrics) — deliberately not a
    # subclass, so this module never imports repro.api and stays importable
    # on its own (repro.api imports *us* for the re-export).
    """Adaptive bucket selection against a target gradient SNR.

    Args:
      budgets: schedule bucket values, ordered highest-fidelity first
        (index 0) to cheapest last — ``None`` = exact, ``1.0`` = policy as
        configured, ``0<b<1`` = uniform budget override.
      target_snr: the floor the predicted step SNR must keep.
      effective: per-bucket *effective* column-keep fraction used by the
        scaling law (``None`` for exact buckets; the trainer maps the
        ``1.0`` bucket to the policy's own base budget). Defaults to the
        bucket values themselves.
      window: steps between level re-evaluations (also the patience at an
        exact bucket before stepping down to start measuring).
      ema: smoothing factor for the SNR measurement (1.0 = last value).
    """

    wants_metrics = True

    def __init__(self, budgets: Sequence[Optional[float]], target_snr: float, *,
                 effective: Optional[Sequence[Optional[float]]] = None,
                 window: int = 4, ema: float = 0.5):
        if not budgets:
            raise ValueError("adaptive controller needs at least one bucket")
        self.budgets: Tuple[Optional[float], ...] = tuple(budgets)
        self.effective = (tuple(effective) if effective is not None
                          else self.budgets)
        if len(self.effective) != len(self.budgets):
            raise ValueError("effective budgets must match buckets 1:1")
        if not (target_snr > 0):
            raise ValueError(f"target_snr must be > 0, got {target_snr}")
        self.target = float(target_snr)
        self.window = max(1, int(window))
        self.alpha = float(ema)
        self.level = 0
        self._ema: Optional[float] = None
        self._count = 0

    @property
    def budget(self) -> Optional[float]:
        return self.budgets[self.level]

    def step_begin(self):
        pass

    @staticmethod
    def predicted_snr(snr: float, b_from: Optional[float],
                      b_to: Optional[float]) -> float:
        """Extrapolate a measurement at ``b_from`` to budget ``b_to``."""
        if b_to is None:
            return math.inf
        if b_from is None:
            return 0.0  # exact buckets carry no variance measurement
        b_from = min(float(b_from), 1.0 - 1e-6)
        b_to = min(float(b_to), 1.0 - 1e-6)
        return snr * (b_to * (1.0 - b_from)) / (b_from * (1.0 - b_to))

    def _desired_level(self) -> int:
        b_cur = self.effective[self.level]
        best = 0  # no bucket meets the target -> highest fidelity
        for i in range(len(self.budgets)):
            if self.predicted_snr(self._ema, b_cur, self.effective[i]) >= self.target:
                best = i  # later = cheaper (ordering contract)
        return best

    def step_end(self, metrics: Optional[dict] = None) -> Optional[float]:
        snr = None
        if metrics is not None:
            v = metrics.get("probe_snr")
            if v is not None and math.isfinite(float(v)):
                snr = float(v)
        if snr is None:
            # No probe signal. At an exact bucket that is expected — step
            # down after a patience window to start measuring. Anywhere else
            # (policy with no probe-capable sites) hold the level: never
            # adapt blind.
            if (self.effective[self.level] is None
                    and self.level + 1 < len(self.budgets)):
                self._count += 1
                if self._count >= self.window:
                    self._count = 0
                    self.level += 1
            return self.budget
        self._ema = (snr if self._ema is None
                     else (1.0 - self.alpha) * self._ema + self.alpha * snr)
        self._count += 1
        if self._count < self.window:
            return self.budget
        self._count = 0
        desired = self._desired_level()
        if desired != self.level:
            self.level += 1 if desired > self.level else -1
            self._ema = None  # re-measure at the new bucket
        return self.budget

    def observe(self, snr: float):
        """Test hook: feed an externally measured step SNR."""
        return self.step_end({"probe_snr": snr})
