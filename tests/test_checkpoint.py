"""Checkpointing: atomicity, gc, async, resume determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "lst": [jnp.ones(2), jnp.zeros((2, 2))]}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t)
    out, step = ck.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, t, keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [4, 5]


def test_async_save(tmp_path):
    t = _tree()
    th = ck.save_async(str(tmp_path), 3, t)
    th.join()
    assert ck.latest_step(str(tmp_path)) == 3


def test_partial_tmp_dir_ignored(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    # simulate crash mid-save: stale tmp dir without manifest
    os.makedirs(tmp_path / "step_000000000009.tmp")
    assert ck.latest_step(str(tmp_path)) == 1
    out, step = ck.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t))
    assert step == 1


def test_manager_cadence(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), every=5, keep=2)
    t = _tree()
    saved = [s for s in range(1, 21) if mgr.maybe_save(s, t)]
    mgr.wait()
    assert saved == [5, 10, 15, 20]
    assert ck.latest_step(str(tmp_path)) == 20


def test_restore_respects_structure(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 2, t)
    like = jax.tree.map(jnp.zeros_like, t)
    out, _ = ck.restore(str(tmp_path), like)
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(t)


# ---------------------------------------------------------------------------
# integrity: CRC manifest, verified fallback, async error capture
# ---------------------------------------------------------------------------


def _corrupt_leaf(ckpt_dir, step, *, truncate=False):
    d = os.path.join(str(ckpt_dir), f"step_{step:012d}")
    npys = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    path = os.path.join(d, npys[0])
    if truncate:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
    else:
        with open(path, "r+b") as f:
            f.seek(-1, os.SEEK_END)
            last = f.read(1)
            f.seek(-1, os.SEEK_END)
            f.write(bytes([last[0] ^ 0xFF]))


def test_manifest_has_per_leaf_crc(tmp_path):
    import json

    t = _tree()
    ck.save(str(tmp_path), 1, t)
    with open(tmp_path / "step_000000000001" / "manifest.json") as f:
        m = json.load(f)
    assert m["version"] == 2
    assert sorted(m["crc"]) == m["keys"]
    assert all(isinstance(v, int) for v in m["crc"].values())
    assert ck.verify(str(tmp_path), 1)


@pytest.mark.parametrize("truncate", [False, True],
                         ids=["bitflip", "truncated"])
def test_corrupt_leaf_fails_verification(tmp_path, truncate):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    _corrupt_leaf(tmp_path, 1, truncate=truncate)
    assert not ck.verify(str(tmp_path), 1)
    assert ck.latest_verified_step(str(tmp_path)) is None
    # explicit step: the caller asked for that exact state -> raise
    with pytest.raises(ck.CheckpointError, match="CRC"):
        ck.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t), step=1)


def test_restore_falls_back_to_newest_verified(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    t2 = jax.tree.map(lambda x: x + 1, t)
    ck.save(str(tmp_path), 2, t2)
    _corrupt_leaf(tmp_path, 2, truncate=True)
    assert ck.latest_step(str(tmp_path)) == 2
    assert ck.latest_verified_step(str(tmp_path)) == 1
    with pytest.warns(UserWarning, match="falling back"):
        out, step = ck.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t))
    assert step == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_missing_leaf_fails_verification(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 3, t)
    d = tmp_path / "step_000000000003"
    npys = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    os.remove(d / npys[0])
    assert not ck.verify(str(tmp_path), 3)


def test_async_write_error_surfaces_on_wait(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), every=1)
    ck.inject_fault_once()
    assert mgr.maybe_save(1, _tree())  # writer fails in the background
    with pytest.raises(ck.CheckpointError, match="injected"):
        mgr.wait()
    # the manager recovers: the failure is not re-raised twice, and the next
    # save goes through
    mgr.wait()
    mgr.maybe_save(2, _tree())
    mgr.wait()
    assert ck.latest_verified_step(str(tmp_path)) == 2


def test_async_error_rides_the_writer_thread(tmp_path):
    ck.inject_fault_once()
    th = ck.save_async(str(tmp_path), 1, _tree())
    th.join()
    assert isinstance(th.error, ck.CheckpointError)
    assert ck.latest_step(str(tmp_path)) is None
