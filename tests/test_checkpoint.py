"""Checkpointing: atomicity, gc, async, resume determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "lst": [jnp.ones(2), jnp.zeros((2, 2))]}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t)
    out, step = ck.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, t, keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [4, 5]


def test_async_save(tmp_path):
    t = _tree()
    th = ck.save_async(str(tmp_path), 3, t)
    th.join()
    assert ck.latest_step(str(tmp_path)) == 3


def test_partial_tmp_dir_ignored(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    # simulate crash mid-save: stale tmp dir without manifest
    os.makedirs(tmp_path / "step_000000000009.tmp")
    assert ck.latest_step(str(tmp_path)) == 1
    out, step = ck.restore(str(tmp_path), jax.tree.map(jnp.zeros_like, t))
    assert step == 1


def test_manager_cadence(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), every=5, keep=2)
    t = _tree()
    saved = [s for s in range(1, 21) if mgr.maybe_save(s, t)]
    mgr.wait()
    assert saved == [5, 10, 15, 20]
    assert ck.latest_step(str(tmp_path)) == 20


def test_restore_respects_structure(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 2, t)
    like = jax.tree.map(jnp.zeros_like, t)
    out, _ = ck.restore(str(tmp_path), like)
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(t)
