"""Telemetry subsystem: probe unbiasedness (MC vs brute force), slot
plumbing, the adaptive controller, sinks, and cost attribution."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AdaptiveBudgetController, BudgetSchedule,
                       ExecutionConfig, Runtime, SketchConfig, SketchPolicy,
                       TelemetryConfig)
from repro.configs.base import ArchConfig
from repro.core.sketched_linear import sketched_linear
from repro.data.synthetic import LMStream
from repro.optim import sgd
from repro.telemetry import probes as tprobes
from repro.telemetry import sinks as tsinks

TINY = ArchConfig(name="tiny-tel", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv=2, d_ff=128, vocab=128, q_chunk=32,
                  kv_chunk=32)


def _site(key, N=32, n=24, d=16):
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (N, d))
    w = jax.random.normal(ks[1], (n, d)) / np.sqrt(d)
    g_out = jax.random.normal(ks[2], (N, n))
    return x, w, g_out


def _probe_and_dw(cfg, x, w, g_out):
    """(probe_vector, dW) per key through the real slot plumbing: the probe
    rides the pslot cotangent of the sketched site's custom_vjp."""

    def loss(w_, pslot, key):
        y = sketched_linear(x, w_, key=key, cfg=cfg, probe_slot=pslot)
        return jnp.sum(y * g_out)

    pslot0 = jnp.zeros((tprobes.PROBE_WIDTH,), jnp.float32)

    @jax.jit
    def one(key):
        dw, probe = jax.grad(loss, argnums=(0, 1))(w, pslot0, key)
        return probe, dw

    return one


@pytest.mark.parametrize("method", ["l1", "per_column"])
def test_variance_probe_unbiased_vs_bruteforce(key, method):
    """MC check (vectorized over keys, test_variance margin style): under
    independent gates the probe's expectation matches the brute-force
    per-site VJP variance E‖dŴ − dW‖² exactly, and the g_sq probe matches
    ‖dW‖²."""
    x, w, g_out = _site(key)
    cfg = SketchConfig(method=method, budget=0.4, exact_r=False, backend="mask")
    one = _probe_and_dw(cfg, x, w, g_out)
    keys = jax.random.split(jax.random.key(7), 800)
    probes, dws = jax.lax.map(one, keys, batch_size=200)

    dw_exact = np.asarray(g_out.T @ x)
    var_mc = float(np.mean(np.sum(np.square(np.asarray(dws) - dw_exact[None]),
                                  axis=(1, 2))))
    probe_mean = np.asarray(probes).mean(0)
    assert probe_mean[3] == pytest.approx(1.0)  # ok flag: probe was computed
    assert probe_mean[1] == pytest.approx(var_mc, rel=0.15), (probe_mean, var_mc)
    assert probe_mean[0] == pytest.approx(float(np.sum(dw_exact ** 2)), rel=0.15)


def test_variance_probe_matches_diagonal_under_exact_r(key):
    """Correlated exact-r sampling (the default): the probe estimates the
    diagonal variance term Σ_j ((1−p_j)/p_j)‖u_j‖² — asserted against the
    closed form (docs/telemetry.md states the caveat)."""
    from repro.core.sketching import column_plan

    x, w, g_out = _site(key)
    cfg = SketchConfig(method="l1", budget=0.4, backend="compact")
    plan = column_plan(cfg, g_out, w, jax.random.key(0), want_compact=True)
    p = np.asarray(plan.probs)
    u = np.asarray(g_out.T @ x)  # u_j = g_jᵀ X, rows of exact dW
    diag = float(np.sum((1.0 - p) / p * np.sum(u ** 2, axis=1)))

    one = _probe_and_dw(cfg, x, w, g_out)
    keys = jax.random.split(jax.random.key(9), 800)
    probes, _ = jax.lax.map(one, keys, batch_size=200)
    probe_mean = np.asarray(probes).mean(0)
    assert probe_mean[1] == pytest.approx(diag, rel=0.1), (probe_mean[1], diag)


def test_probes_do_not_change_training(key):
    """Telemetry is a pure side output: the train step with probes produces
    bit-identical params/loss to the probeless step (same key)."""
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.3))
    opt = sgd(0.1)
    batch = next(iter(LMStream(vocab=TINY.vocab, seed=0).batches(4, 32)))
    rt_tel = Runtime(policy=pol, execution=ExecutionConfig(telemetry=TelemetryConfig()))
    rt_plain = Runtime(policy=pol)
    state = rt_plain.init_state(jax.random.key(0), TINY, opt)
    s_tel, m_tel = rt_tel.train_step(TINY, opt, donate=False)(state, batch, key)
    s_pl, m_pl = rt_plain.train_step(TINY, opt, donate=False)(state, batch, key)
    assert float(m_tel["loss"]) == float(m_pl["loss"])
    for a, b in zip(jax.tree.leaves(s_tel.params), jax.tree.leaves(s_pl.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # summary scalars + per-site vectors present, finite, coherent
    assert float(m_tel["probe_var"]) > 0 and float(m_tel["probe_gsq"]) > 0
    assert math.isfinite(float(m_tel["probe_snr"]))
    sites = m_tel["probe_sites"]
    assert sites and all(np.asarray(v).shape == (tprobes.PROBE_WIDTH,)
                         for v in sites.values())
    tot = np.sum(np.stack([np.asarray(v) for v in sites.values()]), axis=0)
    assert tot[0] == pytest.approx(float(m_tel["probe_gsq"]), rel=1e-5)


def test_probes_compose_with_compact_grads(key):
    """Probe slots and gradient slots ride the same params tree: compact-
    gradient mode with telemetry stays bit-identical to compact-gradient
    mode without, and still emits the probe summary."""
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.3,
                                         backend="compact"))
    opt = sgd(0.1)
    batch = next(iter(LMStream(vocab=TINY.vocab, seed=0).batches(4, 32)))
    ex_cg = ExecutionConfig(compact_grads=True)
    rt_tel = Runtime(policy=pol, execution=ex_cg.replace(telemetry=TelemetryConfig()))
    rt_plain = Runtime(policy=pol, execution=ex_cg)
    state = rt_plain.init_state(jax.random.key(0), TINY, opt)
    s1, m1 = rt_tel.train_step(TINY, opt, donate=False)(state, batch, key)
    s0, m0 = rt_plain.train_step(TINY, opt, donate=False)(state, batch, key)
    assert float(m0["loss"]) == float(m1["loss"])
    for a, b in zip(jax.tree.leaves(s0.params), jax.tree.leaves(s1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m1["probe_var"]) > 0


def test_probes_survive_tp_sketch_and_skip_exact():
    """Since the one-spine refactor (core/site.py), tp_sketch no longer
    disables telemetry: TP-incompatible sites fall back to the probing mask
    estimator and TP shard_map plans probe in-body, so the probe summary is
    present and finite. Exact (no-policy) steps still emit nothing."""
    from repro.train.train_step import make_train_step

    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.3,
                                         backend="compact"))
    opt = sgd(0.1)
    batch = next(iter(LMStream(vocab=TINY.vocab, seed=0).batches(4, 32)))
    # tp_sketch without a mesh: every site falls back to the mask estimator,
    # which probes on the local plan — telemetry must flow
    ex = ExecutionConfig(tp_sketch=True, telemetry=TelemetryConfig())
    step = jax.jit(make_train_step(TINY, opt, pol, execution=ex),
                   donate_argnums=())
    rt = Runtime(policy=pol)
    state = rt.init_state(jax.random.key(0), TINY, opt)
    _, m = step(state, batch, jax.random.key(1))
    assert float(m["probe_var"]) > 0 and math.isfinite(float(m["probe_snr"]))
    rt_exact = Runtime(execution=ExecutionConfig(telemetry=TelemetryConfig()))
    _, m2 = rt_exact.train_step(TINY, opt, donate=False)(state, batch,
                                                         jax.random.key(1))
    assert "probe_snr" not in m2


def test_telemetry_config_validation():
    with pytest.raises(ValueError, match="accum"):
        ExecutionConfig(telemetry=TelemetryConfig(), accum=2)
    ex = ExecutionConfig(telemetry=TelemetryConfig(probes=False), accum=2)
    hash(ex)  # telemetry config stays hashable on the execution config
    with pytest.raises(ValueError, match="interval"):
        TelemetryConfig(interval=0)


# ---------------------------------------------------------------------------
# Adaptive controller
# ---------------------------------------------------------------------------


def test_adaptive_controller_walks_buckets_deterministically():
    c = AdaptiveBudgetController((1.0, 0.5, 0.2), target_snr=0.8,
                                 effective=(0.6, 0.5, 0.2), window=2, ema=1.0)
    assert c.budget == 1.0
    c.observe(1.6)
    assert c.budget == 1.0  # window not yet full
    c.observe(1.6)
    assert c.budget == 0.5  # predicted snr@0.5 = 1.07 >= 0.8, @0.2 = 0.27 < 0.8
    c.observe(1.1), c.observe(1.1)
    assert c.budget == 0.5  # cheapest bucket still fails the target
    c.observe(0.5), c.observe(0.5)
    assert c.budget == 1.0  # even current bucket fails -> back up
    # never leaves the bucket set
    for s in (10.0, 10.0, 10.0, 10.0, 10.0, 10.0, 0.01, 0.01, 0.01):
        assert c.observe(s) in (1.0, 0.5, 0.2)


def test_adaptive_controller_steps_down_from_exact():
    c = AdaptiveBudgetController((None, 0.5), target_snr=1.0, window=3)
    for _ in range(2):
        c.step_end({})  # exact bucket: no probe signal
        assert c.budget is None
    c.step_end({})
    assert c.budget == 0.5  # patience elapsed -> start measuring
    c2 = AdaptiveBudgetController((1.0, 0.5), target_snr=1.0, window=1)
    c2.step_end({})  # sketched bucket with no probe signal: hold, never blind
    assert c2.budget == 1.0


def test_adaptive_schedule_validation():
    s = BudgetSchedule.adaptive(2.0, budgets=(None, 1.0, 0.5))
    assert s.is_adaptive and not s.is_reactive
    assert s.buckets() == (None, 1.0, 0.5)
    with pytest.raises(ValueError, match="use make_controller"):
        s.budget_at(0)
    with pytest.raises(ValueError, match="descend"):
        BudgetSchedule.adaptive(2.0, budgets=(0.5, 1.0))
    with pytest.raises(ValueError, match="target_snr"):
        BudgetSchedule(adaptive_budgets=(1.0, 0.5))
    with pytest.raises(ValueError, match="target_snr"):
        BudgetSchedule(target_snr=2.0)
    with pytest.raises(ValueError, match="mutually exclusive"):
        BudgetSchedule(points=((0, 0.5),), adaptive_budgets=(1.0, 0.5),
                       target_snr=1.0)
    # controller maps the 1.0 bucket onto the policy's own budget
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.6))
    c = s.make_controller(policy=pol)
    assert c.effective == (None, 0.6, 0.5)
    # a policy budget that inverts the ordering: buckets are re-sorted by
    # effective fidelity (duplicates collapse, earlier-listed bucket wins),
    # so the 0.5 escalation path above the policy's own 0.2 stays reachable
    # and the controller's "later = cheaper" contract holds
    pol02 = SketchPolicy(base=SketchConfig(method="l1", budget=0.2))
    c2 = BudgetSchedule.adaptive(1.0, budgets=(1.0, 0.5, 0.2, 0.1)) \
        .make_controller(policy=pol02)
    assert c2.budgets == (0.5, 1.0, 0.1)
    assert c2.effective == (0.5, 0.2, 0.1)


def test_adaptive_warns_when_it_cannot_measure():
    """An adaptive schedule that can never see a probe (exact policy,
    non-column method, location-restricted policy) must say so loudly
    instead of silently running a constant budget; adaptive with
    accumulation is rejected up front. Since the one-spine refactor,
    tp_sketch is NOT such a case — TP plans probe in-body."""
    import warnings

    from repro.train.trainer import TrainerConfig

    def runs_with_warning(rt):
        data = LMStream(vocab=TINY.vocab, seed=0).batches(2, 16)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            rt.train(TINY, sgd(0.1), data, TrainerConfig(steps=2, log_every=1),
                     on_metrics=lambda m: None)
        return any("cannot measure gradient SNR" in str(w.message) for w in rec)

    sched = BudgetSchedule.adaptive(1.0, budgets=(1.0, 0.5))
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.3))
    # tp_sketch no longer blinds the controller: sites fall back to the
    # probing mask estimator (no mesh) or probe inside the TP plans
    assert not runs_with_warning(Runtime(policy=pol, schedule=sched,
                                         execution=ExecutionConfig(tp_sketch=True)))
    # non-column method: no site is probe-capable
    assert runs_with_warning(Runtime(
        policy=SketchPolicy(base=SketchConfig(method="per_element", budget=0.3)),
        schedule=sched))
    # exact default policy (base=None)
    assert runs_with_warning(Runtime(policy=SketchPolicy(), schedule=sched))
    # the healthy configuration does NOT warn
    assert not runs_with_warning(Runtime(policy=pol, schedule=sched))
    # adaptive + accumulation is a contradiction, rejected with a clear error
    with pytest.raises(ValueError, match="accum == 1"):
        Runtime(policy=pol, schedule=sched,
                execution=ExecutionConfig(accum=2)).train(
            TINY, sgd(0.1), LMStream(vocab=TINY.vocab, seed=0).batches(2, 16),
            TrainerConfig(steps=2))


def test_adaptive_trains_with_only_prebuilt_buckets():
    """Trainer-level closed loop: ``BudgetSchedule.adaptive`` through
    ``Runtime.train`` compiles exactly one step per bucket (compile counter
    as in test_api) and every step runs one of those buckets."""
    from repro.api import runtime as runtime_mod
    from repro.train.trainer import TrainerConfig

    runtime_mod._cache_clear()
    sched = BudgetSchedule.adaptive(0.05, budgets=(1.0, 0.5, 0.2), window=2)
    rt = Runtime(policy=SketchPolicy(base=SketchConfig(method="l1", budget=0.5)),
                 schedule=sched)
    data = LMStream(vocab=TINY.vocab, seed=0).batches(4, 32)
    tcfg = TrainerConfig(steps=8, log_every=1)
    _, hist = rt.train(TINY, sgd(0.1), data, tcfg, on_metrics=lambda m: None)
    assert len(runtime_mod._STEP_BUILDS) == len(sched.buckets()), \
        "adaptive must only ever run pre-compiled buckets (no recompiles)"
    assert all(m["budget"] in sched.buckets() for m in hist)
    # the lax target lets the controller walk down; probes rode along
    assert any(m["budget"] != 1.0 for m in hist)
    assert all(math.isfinite(m["probe_snr"]) for m in hist if "probe_snr" in m)
    assert len(set(m["budget"] for m in hist)) >= 2


# ---------------------------------------------------------------------------
# Sinks + cost attribution
# ---------------------------------------------------------------------------


def test_sinks_roundtrip(tmp_path):
    jsonl = str(tmp_path / "tel.jsonl")
    csvp = str(tmp_path / "tel.csv")
    sink = tsinks.build_sinks(TelemetryConfig(jsonl=jsonl, csv=csvp))
    ring = tsinks.RingSink(capacity=2)
    sink.sinks.append(ring)
    for step in range(3):
        sink.write({"step": step, "budget": 0.5, "loss": 1.0 / (step + 1),
                    "probe_sites": {"a/b": [1.0, 2.0, 3.0, 1.0]}})
    sink.close()
    lines = [json.loads(l) for l in open(jsonl)]
    assert len(lines) == 3 and lines[2]["step"] == 2
    assert lines[0]["probe_sites"]["a/b"] == [1.0, 2.0, 3.0, 1.0]
    rows = open(csvp).read().strip().splitlines()
    assert rows[0].split(",") == ["budget", "loss", "step"]  # scalars only
    assert len(rows) == 4
    assert len(ring) == 2 and ring.records[-1]["step"] == 2  # bounded
    assert tsinks.build_sinks(TelemetryConfig()) is None
    assert tsinks.build_sinks(None) is None


def test_site_cost_table_and_hlo_join():
    from repro.models import lm

    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.25,
                                         backend="compact"))
    params = lm.init_params(jax.random.key(0), TINY)
    table = tsinks.site_cost_table(params, pol, n_tokens=128,
                                   n_layers=TINY.n_layers)
    assert table, "sketched sites must be attributed"
    for rec in table.values():
        assert rec["bwd_sketched_flops"] < rec["bwd_exact_flops"]
        assert 0.0 < rec["savings_frac"] < 1.0
        assert rec["layers"] == TINY.n_layers  # scan-stacked leading dim
    tot = tsinks.table_totals(table)
    assert tot["n_sites"] == len(table) and tot["savings_frac"] > 0.5
    joined = tsinks.join_hlo_cost(table, {"flops": 1e9})
    assert sum(v["hlo_flops_share"] for v in joined.values()) == pytest.approx(1e9)
    assert tsinks.site_cost_table(params, None, 128) == {}


def test_probe_slot_builders():
    from repro.models import lm

    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.3))
    params = lm.init_params(jax.random.key(0), TINY)
    slotted = tprobes.with_probe_slots(params, pol, n_layers=TINY.n_layers)
    flat = jax.tree_util.tree_flatten_with_path(slotted)[0]
    n_slots = sum(1 for p, _ in flat if "pslot" in str(p))
    assert n_slots > 0
    # location policies can't be matched statically on scan models: no slots
    loc = SketchPolicy(base=SketchConfig(method="l1", budget=0.3),
                       location="first")
    assert tprobes.with_probe_slots(params, loc, n_layers=2) is params
    # non-column methods are not probe-capable
    rcs = SketchPolicy(base=SketchConfig(method="rcs", budget=0.3))
    flat2 = jax.tree_util.tree_flatten_with_path(
        tprobes.with_probe_slots(params, rcs, n_layers=2))[0]
    assert not any("pslot" in str(p) for p, _ in flat2)
    # collect strips every slot and returns the original structure
    grads, probes = tprobes.collect_probes(slotted)
    assert len(probes) == n_slots
    assert jax.tree_util.tree_structure(grads) == jax.tree_util.tree_structure(params)
    # MLP family builder (static layer indices -> location-aware)
    mlp_params = [{"w": jnp.zeros((64, 784))}, {"w": jnp.zeros((10, 64))}]
    out = tprobes.mlp_probe_slots(mlp_params, pol)
    assert "pslot" in out[0] and "pslot" not in out[1]  # lm_head excluded


def test_engine_decode_counters():
    from repro.models import lm
    from repro.serve.engine import Engine, Request

    cfg = ArchConfig(name="srv", family="dense", n_layers=1, d_model=32,
                     n_heads=4, n_kv=2, d_ff=64, vocab=64, q_chunk=16,
                     kv_chunk=16)
    params = lm.init_params(jax.random.key(0), cfg)
    eng = Engine(params, cfg, batch=2, max_len=32)
    reqs = [Request(prompt=np.asarray([1, 2, 3], np.int32), max_new=4),
            Request(prompt=np.asarray([4, 5], np.int32), max_new=4)]
    eng.run(reqs)
    assert all(r.out is not None and len(r.out) == 4 for r in reqs)
    t = eng.telemetry()
    assert t["batches"] == 1 and t["prefill_calls"] == 1
    # first token comes from the prefill logits, so 4 new tokens = 3 decodes
    assert t["decode_steps"] == 3 and t["tokens_out"] == 8
    assert t["decode_tok_per_s"] > 0 and t["prefill_tok_per_s"] > 0
    # the continuous engine rings one record per finished REQUEST
    assert len(eng.ring) == 2
    assert all(r["new_tokens"] == 4 and r["latency_s"] >= 0
               for r in eng.ring.records)
