"""Pipeline stage boundary: identity fwd, unbiased sketched cotangent bwd."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SketchConfig
from repro.launch.pipeline import boundary_wire_bytes, stage_boundary


def _loss(x, key, cfg):
    h = stage_boundary(jnp.tanh(x @ jnp.ones((8, 12)) / 8), key=key, cfg=cfg)
    return jnp.sum(jnp.sin(h))


def test_forward_identity():
    x = jax.random.normal(jax.random.key(0), (4, 8))
    cfg = SketchConfig(method="l1", budget=0.3)
    y = stage_boundary(x, key=jax.random.key(1), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_backward_unbiased():
    x = jax.random.normal(jax.random.key(0), (6, 8))
    cfg = SketchConfig(method="l1", budget=0.5)
    exact = jax.grad(lambda x_: _loss(x_, None, None))(x)
    gfn = jax.jit(lambda k: jax.grad(lambda x_, k_: _loss(x_, k_, cfg))(x, k))
    keys = jax.random.split(jax.random.key(3), 1500)
    gs = jax.lax.map(gfn, keys, batch_size=250)
    mean = np.asarray(gs.mean(0))
    se = np.asarray(gs.std(0)) / np.sqrt(len(keys)) + 1e-3 * np.abs(exact).max()
    t = np.abs(mean - np.asarray(exact)) / se
    assert np.mean(t) < 2.2, np.mean(t)


def test_budget_one_is_exact():
    x = jax.random.normal(jax.random.key(0), (6, 8))
    g0 = jax.grad(lambda x_: _loss(x_, None, None))(x)
    cfg1 = SketchConfig(method="l1", budget=1.0)
    g1 = jax.grad(lambda x_: _loss(x_, jax.random.key(5), cfg1))(x)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-6)


def test_wire_accounting():
    cfg = SketchConfig(method="l1", budget=0.1, block=128)
    out = boundary_wire_bytes(cfg, (16, 4096, 8192))
    assert 0.08 < out["ratio"] < 0.15  # ≈ budget + index overhead
    dense_gb = out["dense_bytes"] / 1e9
    assert dense_gb > 1.0  # a real inter-stage tensor
