"""Trainer loop: learnability (exact + sketched), resume, straggler control."""
import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import SketchConfig, SketchPolicy
from repro.data.synthetic import LMStream
from repro.optim import adamw, cosine_warmup
from repro.train.straggler import StragglerController
from repro.train.trainer import TrainerConfig, train

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv=2, d_ff=128, vocab=128, q_chunk=32, kv_chunk=32)


def _run(policy, steps=30, ckpt=None, start_state=None):
    opt = adamw(cosine_warmup(3e-3, 5, steps), clip=1.0)
    data = LMStream(vocab=TINY.vocab, seed=0).batches(4, 32)
    tcfg = TrainerConfig(steps=steps, log_every=max(1, steps // 10),
                         ckpt_dir=ckpt, ckpt_every=10)
    return train(TINY, opt, data, tcfg, policy, state=start_state,
                 on_metrics=lambda m: None)


def test_exact_training_reduces_loss():
    _, hist = _run(None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2


def test_sketched_training_reduces_loss():
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.3))
    _, hist = _run(pol)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.15


def test_resume_from_checkpoint(tmp_path):
    d = str(tmp_path)
    state1, hist1 = _run(None, steps=10, ckpt=d)
    # new trainer picks up at step 10 and continues to 20
    state2, hist2 = _run(None, steps=20, ckpt=d)
    assert hist2[0]["step"] >= 10
    assert hist2[-1]["step"] == 19


def test_straggler_controller_drops_and_recovers():
    c = StragglerController((1.0, 0.5, 0.2), window=4, target_step_s=1.0)
    for _ in range(4):
        c.observe(1.0)
    assert c.budget == 1.0
    for _ in range(4):
        c.observe(2.0)  # slow regime -> drop budget
    assert c.budget == 0.5
    for _ in range(4):
        c.observe(2.0)
    assert c.budget == 0.2
    for _ in range(6):
        c.observe(0.9)  # recovered -> climb back
    assert c.budget >= 0.5
