"""Empirical validation of Prop. 2.2 (variance propagation & decomposition)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, sketch_dense
from repro.core.variance import chain_variance_decomposition, mc_gradient_variance


def _sketch_vjp(cfg):
    def fn(layer, key, W, g):
        ghat = sketch_dense(cfg, g, W, jax.random.fold_in(key, 97 + layer))
        return ghat @ W

    return fn


@pytest.mark.parametrize("method", ["per_column", "l1"])
def test_prop22_decomposition(key, method):
    """total ≈ local + propagated at every node (cross term vanishes)."""
    rng = np.random.default_rng(0)
    Ws = [jnp.asarray(rng.normal(size=(12, 12)) / np.sqrt(12), jnp.float32)
          for _ in range(3)]
    G_out = jnp.asarray(rng.normal(size=(16, 12)), jnp.float32)
    cfg = SketchConfig(method=method, budget=0.5)
    keys = [jax.random.fold_in(key, i) for i in range(400)]
    d = chain_variance_decomposition(Ws, G_out, _sketch_vjp(cfg), keys)
    for k in range(3):
        total, expect = d["total"][k], d["local"][k] + d["propagated"][k]
        assert total == pytest.approx(expect, rel=0.15), (k, total, expect)


def test_variance_dampens_with_contractive_jacobians(key):
    """Prop. 2.2 remark: the *propagated* term scales with the operator norms
    of the downstream Jacobians — contractive chains damp upstream error
    relative to the locally injected distortion."""
    rng = np.random.default_rng(1)
    G_out = jnp.asarray(rng.normal(size=(16, 12)), jnp.float32)
    cfg = SketchConfig(method="per_column", budget=0.5)
    keys = [jax.random.fold_in(key, i) for i in range(200)]

    def prop_share(scale):
        Ws = [jnp.asarray(rng.normal(size=(12, 12)) / np.sqrt(12) * scale,
                          jnp.float32) for _ in range(4)]
        d = chain_variance_decomposition(Ws, G_out, _sketch_vjp(cfg), keys)
        # at the input node: propagated (upstream) vs locally injected
        return d["propagated"][0] / max(d["local"][0], 1e-12)

    assert prop_share(0.4) < prop_share(1.6)


def test_variance_decreases_with_budget(key):
    rng = np.random.default_rng(2)
    W = jnp.asarray(rng.normal(size=(20, 20)) / np.sqrt(20), jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 20)), jnp.float32)

    from repro.core import sketched_linear

    def gfn(cfg):
        def g(k):
            return jax.grad(lambda xx: jnp.sum(
                jnp.sin(sketched_linear(xx, W, key=k, cfg=cfg))))(x)
        return g

    exact = jax.grad(lambda xx: jnp.sum(jnp.sin(sketched_linear(xx, W))))(x)
    keys = jax.random.split(jax.random.key(5), 300)
    Vs = []
    for p in (0.1, 0.3, 0.7):
        cfg = SketchConfig(method="l1", budget=p)
        Vs.append(float(mc_gradient_variance(jax.jit(gfn(cfg)), exact, keys)["variance"]))
    assert Vs[0] > Vs[1] > Vs[2]


def test_data_dependent_beats_uniform_variance(key):
    """ℓ1 probabilities give lower gradient variance than uniform per-column
    at the same budget (the mechanism behind Fig. 1b).

    The sketch acts on the *output* gradient G = ∂L/∂y, so heterogeneity must
    live in G's columns — scaling the columns of x (as the seed test did)
    leaves G ≈ cos(y) homogeneous and the comparison at the noise floor.
    Weighting the loss per output coordinate makes G's column norms span
    several orders of magnitude; importance sampling must then win by a wide
    relative margin, robustly across seeds.
    """
    rng = np.random.default_rng(3)
    W = jnp.asarray(rng.normal(size=(24, 24)) / 5, jnp.float32)
    x = jnp.asarray(rng.normal(size=(16, 24)), jnp.float32)
    col_w = jnp.asarray(0.45 ** np.arange(24), jnp.float32)
    from repro.core import sketched_linear

    def loss(xx, k=None, cfg=None):
        return jnp.sum(jnp.sin(sketched_linear(xx, W, key=k, cfg=cfg)) * col_w[None, :])

    exact = jax.grad(loss)(x)
    keys = jax.random.split(jax.random.key(6), 600)

    def V(method):
        cfg = SketchConfig(method=method, budget=0.25)
        g = jax.jit(lambda k: jax.grad(lambda xx: loss(xx, k, cfg))(x))
        return float(mc_gradient_variance(g, exact, keys)["variance"])

    v_l1, v_uniform = V("l1"), V("per_column")
    assert v_l1 < 0.7 * v_uniform, (v_l1, v_uniform)
