"""The Runtime front door: registry round-trip, hash/eq + recompile counts,
budget schedules, and legacy-kwarg shim equivalence."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import (BudgetSchedule, EstimatorVJP, ExecutionConfig, Runtime,
                       SketchConfig, SketchPolicy)
from repro.api import runtime as runtime_mod
from repro.configs.base import ArchConfig
from repro.data.synthetic import LMStream
from repro.optim import sgd

TINY = ArchConfig(name="tiny-api", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv=2, d_ff=128, vocab=128, q_chunk=32,
                  kv_chunk=32)


def _batch(seed=0):
    return next(iter(LMStream(vocab=TINY.vocab, seed=seed).batches(4, 32)))


# ---------------------------------------------------------------------------
# Estimator registry
# ---------------------------------------------------------------------------


class _ToyColumnDrop(api.Estimator):
    """Third-party-style estimator: independent column gates z/p on G —
    unbiased (E[Ĝ|G] = G), implemented entirely outside repro/core."""

    name = "toy_coldrop"
    supports_compact_grad = False

    def validate(self, cfg):
        if cfg.budget >= 1.0:
            raise ValueError("toy_coldrop needs budget < 1")

    def apply(self, cfg, G2d, X2d, w, key, *, has_b, score_psum_axes=None):
        p = cfg.budget
        z = jax.random.bernoulli(key, p, (G2d.shape[-1],)).astype(G2d.dtype)
        Ghat = G2d * (z / p)[None, :]
        return EstimatorVJP(dx=Ghat @ w, dw=Ghat.T @ X2d,
                            db=jnp.sum(Ghat, axis=0) if has_b else None)


def _ensure_toy_registered():
    if "toy_coldrop" not in api.registered_backends():
        api.register_estimator(_ToyColumnDrop())


def test_registry_builtins_and_errors():
    assert set(api.registered_backends()) >= {"mask", "compact", "pallas"}
    assert api.get_estimator("compact").supports_compact_grad
    assert not api.get_estimator("mask").supports_compact_grad
    with pytest.raises(KeyError, match="register"):
        api.get_estimator("definitely_not_registered")
    # a SketchConfig naming an unregistered backend fails loudly
    with pytest.raises(ValueError, match="register"):
        SketchConfig(method="l1", budget=0.2, backend="definitely_not_registered")
    # builtins cannot be silently replaced
    with pytest.raises(ValueError, match="already registered"):
        api.register_estimator(api.get_estimator("mask"), name="mask")


def test_registry_roundtrip_toy_estimator_trains():
    """A toy third-party estimator registers and trains end-to-end through
    the Runtime — without modifying core/sketching.py or
    core/sketched_linear.py."""
    _ensure_toy_registered()
    # registered backends validate through the estimator's own hook
    with pytest.raises(ValueError, match="budget < 1"):
        SketchConfig(method="per_column", budget=1.0, backend="toy_coldrop")
    pol = SketchPolicy(base=SketchConfig(method="per_column", budget=0.5,
                                         backend="toy_coldrop"))
    rt = Runtime(policy=pol)
    opt = sgd(0.1)
    state = rt.init_state(jax.random.key(0), TINY, opt)
    step = rt.train_step(TINY, opt, donate=False)
    state2, metrics = step(state, _batch(), jax.random.key(1))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually moved
    w0 = state.params["embed"] if "embed" in state.params else None
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert moved


def test_toy_estimator_is_unbiased():
    """E over keys of the toy backward equals the exact gradient (on one
    sketched site) — the registry contract that keeps plugins safe."""
    _ensure_toy_registered()
    from repro.core import sketched_linear

    cfg = SketchConfig(method="per_column", budget=0.5, backend="toy_coldrop")
    ks = jax.random.split(jax.random.key(3), 3)
    x = jax.random.normal(ks[0], (32, 16))
    w = jax.random.normal(ks[1], (24, 16)) / 4.0
    g_out = jax.random.normal(ks[2], (32, 24))

    def loss(w_, key):
        return jnp.sum(sketched_linear(x, w_, key=key, cfg=cfg) * g_out)

    exact = jax.grad(lambda w_: jnp.sum(sketched_linear(x, w_) * g_out))(w)
    keys = jax.random.split(jax.random.key(7), 400)
    gs = jax.vmap(lambda k: jax.grad(loss)(w, k))(keys)
    mean = np.asarray(gs.mean(0))
    se = np.asarray(gs.std(0)) / np.sqrt(len(keys)) + 1e-9
    t = np.abs(mean - np.asarray(exact)) / se
    assert np.mean(t) < 1.8, np.mean(t)


# ---------------------------------------------------------------------------
# Runtime hash/eq + recompile counting
# ---------------------------------------------------------------------------


def _l1_runtime(schedule=None):
    return Runtime(policy=SketchPolicy(base=SketchConfig(method="l1", budget=0.3)),
                   schedule=schedule if schedule is not None else BudgetSchedule())


def test_runtime_hash_eq():
    a, b = _l1_runtime(), _l1_runtime()
    assert a == b and hash(a) == hash(b)
    assert a != a.replace(schedule=BudgetSchedule.warmup_exact(5))
    assert a != a.replace(execution=ExecutionConfig(tp_sketch=True))
    assert a != a.replace(policy=None)
    # usable as dict keys (the step-cache contract)
    assert len({a: 1, b: 2}) == 1


def test_runtime_step_cache_one_compile_per_bucket():
    runtime_mod._cache_clear()
    opt = sgd(0.1)
    rt = _l1_runtime()
    fn1 = rt.train_step(TINY, opt)
    fn2 = rt.train_step(TINY, opt)
    assert fn1 is fn2, "same Runtime must reuse the same compiled step"
    assert len(runtime_mod._STEP_BUILDS) == 1
    # a value-equal Runtime hits the same cache entry
    fn3 = _l1_runtime().train_step(TINY, opt)
    assert fn3 is fn1
    assert len(runtime_mod._STEP_BUILDS) == 1
    # a different budget is a different bucket
    rt.train_step(TINY, opt, budget=0.5)
    assert len(runtime_mod._STEP_BUILDS) == 2
    # with no policy every budget is the same exact step: one compile even
    # under a multi-bucket (straggler) schedule
    runtime_mod._cache_clear()
    rt0 = Runtime(schedule=BudgetSchedule.straggler((1.0, 0.5, 0.2)))
    fns = {b: rt0.train_step(TINY, opt, budget=b) for b in rt0.schedule.buckets()}
    assert len(set(map(id, fns.values()))) == 1
    assert len(runtime_mod._STEP_BUILDS) == 1


def test_budget_schedule_transition_uses_prebuilt_buckets():
    """warmup-exact -> sketched: the loop pre-builds exactly the schedule's
    buckets (one step per distinct budget) and switches at the boundary."""
    from repro.train.trainer import TrainerConfig

    runtime_mod._cache_clear()
    sched = BudgetSchedule.warmup_exact(2, 1.0)
    assert sched.buckets() == (None, 1.0)
    rt = _l1_runtime(schedule=sched)
    opt = sgd(0.1)
    data = LMStream(vocab=TINY.vocab, seed=0).batches(4, 32)
    tcfg = TrainerConfig(steps=4, log_every=1)
    _, hist = rt.train(TINY, opt, data, tcfg, on_metrics=lambda m: None)
    assert len(runtime_mod._STEP_BUILDS) == 2, "exactly the pre-built buckets"
    assert [m["budget"] for m in hist] == [None, None, 1.0, 1.0]


def test_budget_schedule_semantics():
    s = BudgetSchedule.piecewise((0, None), (10, 0.5), (20, 0.2))
    assert s.budget_at(0) is None and s.budget_at(9) is None
    assert s.budget_at(10) == 0.5 and s.budget_at(19) == 0.5
    assert s.budget_at(1000) == 0.2
    assert s.buckets() == (None, 0.5, 0.2)
    # a late first point runs at the implicit 1.0 before it — buckets must
    # include it or the loop would KeyError at step 0
    late = BudgetSchedule.piecewise((10, 0.5))
    assert late.budget_at(0) == 1.0
    assert late.buckets() == (1.0, 0.5)
    for step in range(12):
        assert late.budget_at(step) in late.buckets()
    a = BudgetSchedule.anneal(100, start=1.0, end=0.1, n_buckets=4)
    assert a.budget_at(0) == 1.0 and abs(a.budget_at(99) - 0.1) < 1e-9
    assert len(a.buckets()) == 4
    r = BudgetSchedule.straggler((1.0, 0.5))
    assert r.is_reactive and r.make_controller() is not None
    # degenerate constructor inputs collapse instead of crashing
    assert BudgetSchedule.warmup_exact(0, 0.5).buckets() == (0.5,)
    short = BudgetSchedule.anneal(3, start=1.0, end=0.1, n_buckets=4)
    assert short.budget_at(0) == 1.0 and short.budget_at(100) == pytest.approx(0.1)
    with pytest.raises(ValueError, match="ascend"):
        BudgetSchedule(points=((5, 0.5), (5, 0.2)))
    with pytest.raises(ValueError, match="mutually exclusive"):
        BudgetSchedule(points=((0, 0.5),), reactive=(1.0, 0.5))
    # runtime resolves budgets against the policy
    rt = _l1_runtime()
    assert rt.policy_at(None) is None
    assert rt.policy_at(1.0) is rt.policy
    assert rt.policy_at(0.1).base.budget == pytest.approx(0.1)


def test_no_grad_slots_when_tp_sketch_without_mesh():
    """tp_sketch without a mesh forces every compact site to the mask
    backend (nn.common.dense), so with_grad_slots must emit NO slots — a
    slot whose cotangent stays zero would silently freeze the site under
    adamw(lazy=True)."""
    from repro.core.compact_grad import with_grad_slots
    from repro.models import lm

    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.3,
                                         backend="compact"))
    params = lm.init_params(jax.random.key(0), TINY)
    with_slots = with_grad_slots(params, pol, mesh=None, tp_sketch=True,
                                 n_layers=TINY.n_layers)
    flat, _ = jax.tree_util.tree_flatten_with_path(with_slots)
    assert not any("gslot" in str(path) for path, _ in flat)
    # sanity: the same call WITHOUT tp_sketch does emit slots
    with_slots2 = with_grad_slots(params, pol, mesh=None, tp_sketch=False,
                                  n_layers=TINY.n_layers)
    flat2, _ = jax.tree_util.tree_flatten_with_path(with_slots2)
    assert any("gslot" in str(path) for path, _ in flat2)


def test_execution_config_validation():
    with pytest.raises(ValueError, match="accum"):
        ExecutionConfig(compact_grads=True, accum=2)
    ex = ExecutionConfig(data_axes=["data"], model_axes=["model"])
    assert ex.data_axes == ("data",) and isinstance(ex.data_axes, tuple)
    hash(ex)  # list axes were coerced; config stays hashable


# ---------------------------------------------------------------------------
# Legacy kwarg shim
# ---------------------------------------------------------------------------


def test_legacy_train_kwargs_bit_match_runtime():
    """The deprecated loose-kwarg train(...) warns once and produces
    bit-identical steps to the equivalent Runtime.train."""
    from repro.train import trainer

    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.3))
    opt = sgd(0.1)
    tcfg = trainer.TrainerConfig(steps=5, log_every=1)

    def data():
        return LMStream(vocab=TINY.vocab, seed=0).batches(4, 32)

    trainer._warned_legacy = False
    with pytest.warns(DeprecationWarning, match="Runtime"):
        s_old, h_old = trainer.train(TINY, opt, data(), tcfg, pol,
                                     on_metrics=lambda m: None)
    # warns once per process, not per call
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        s_old2, _ = trainer.train(TINY, opt, data(), tcfg, pol,
                                  on_metrics=lambda m: None)
    s_new, h_new = Runtime(policy=pol).train(TINY, opt, data(), tcfg,
                                             on_metrics=lambda m: None)
    assert [m["loss"] for m in h_old] == [m["loss"] for m in h_new]
    for a, b in zip(jax.tree.leaves(s_old.params), jax.tree.leaves(s_new.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_old.params), jax.tree.leaves(s_old2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_straggler_budgets_map_to_reactive_schedule():
    rt = Runtime.from_legacy_kwargs(
        SketchPolicy(base=SketchConfig(method="l1", budget=0.3)),
        straggler_budgets=(1.0, 0.5, 0.2))
    assert rt.schedule.is_reactive
    assert rt.schedule.buckets() == (1.0, 0.5, 0.2)
