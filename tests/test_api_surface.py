"""Public-API snapshot: the exported ``repro.api`` and ``repro.analysis``
names and signatures are asserted against a checked-in snapshot so
accidental surface breaks fail loudly (and intentional ones show up as a
reviewed snapshot diff).

Regenerate after an intentional change:

    PYTHONPATH=src REPRO_UPDATE_API_SNAPSHOT=1 python -m pytest \
        tests/test_api_surface.py
"""
import inspect
import os

SNAPSHOT = os.path.join(os.path.dirname(__file__), "api_surface.txt")


def _sig(fn) -> str:
    try:
        return str(inspect.signature(fn))
    except (TypeError, ValueError):
        return "(?)"


def _describe_class(cls) -> list:
    lines = []
    import dataclasses

    if dataclasses.is_dataclass(cls):
        for f in dataclasses.fields(cls):
            lines.append(f"  field {f.name}")
    for name, member in sorted(vars(cls).items()):
        if name.startswith("_"):
            continue
        if isinstance(member, (classmethod, staticmethod)):
            lines.append(f"  {name}{_sig(member.__func__)}")
        elif callable(member):
            lines.append(f"  {name}{_sig(member)}")
        elif isinstance(member, property):
            lines.append(f"  property {name}")
        elif not dataclasses.is_dataclass(cls):
            lines.append(f"  attr {name}")
    return lines


def _describe_module(mod) -> list:
    out = []
    for name in sorted(mod.__all__):
        obj = getattr(mod, name)
        if inspect.isclass(obj):
            out.append(f"class {name}")
            out.extend(_describe_class(obj))
        elif callable(obj):
            out.append(f"def {name}{_sig(obj)}")
        else:
            out.append(f"value {name}")
    return out


def describe_api() -> str:
    from repro import analysis, api

    out = ["== repro.api =="]
    out.extend(_describe_module(api))
    out.append("== repro.analysis ==")
    out.extend(_describe_module(analysis))
    return "\n".join(out) + "\n"


def test_api_surface_matches_snapshot():
    got = describe_api()
    if os.environ.get("REPRO_UPDATE_API_SNAPSHOT") == "1":
        with open(SNAPSHOT, "w") as f:
            f.write(got)
    assert os.path.exists(SNAPSHOT), (
        "missing tests/api_surface.txt — generate with "
        "REPRO_UPDATE_API_SNAPSHOT=1")
    with open(SNAPSHOT) as f:
        want = f.read()
    assert got == want, (
        "repro.api surface changed. If intentional, regenerate the snapshot "
        "(REPRO_UPDATE_API_SNAPSHOT=1) and review the diff.\n"
        "--- snapshot ---\n" + want + "\n--- current ---\n" + got)
