"""Unbiasedness + backend equivalence for every sketch method."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, column_plan, sketch_dense, sketched_linear, static_rank

N, DIN, DOUT = 48, 24, 40


@pytest.fixture(scope="module")
def problem():
    ks = jax.random.split(jax.random.key(0), 3)
    x = jax.random.normal(ks[0], (4, 12, DIN))
    w = jax.random.normal(ks[1], (DOUT, DIN)) / np.sqrt(DIN)
    b = jax.random.normal(ks[2], (DOUT,)) * 0.1
    return x, w, b


def _loss(x, w, b, key, cfg):
    return jnp.sum(jnp.sin(sketched_linear(x, w, b, key=key, cfg=cfg)))


def _exact(problem):
    x, w, b = problem
    return jax.grad(_loss, argnums=(0, 1, 2))(x, w, b, None, None)


# (method, backend, block, budget). RCS tests at budget 0.75: its spectral
# water-filling assigns some directions p ~ 1e-6 at 0.5 — mathematically
# optimal but uncertifiable by an 800-sample MC (rare-event tails; the
# direct apply_rcs unbiasedness check at moderate p lives in
# tests/test_optimality.py).
ALL = [("per_element", "mask", 0, 0.5), ("per_sample", "mask", 0, 0.5),
       ("per_column", "mask", 0, 0.5), ("l1", "mask", 0, 0.5),
       ("l1", "compact", 0, 0.5), ("l2", "mask", 0, 0.5), ("var", "mask", 0, 0.5),
       ("ds", "mask", 0, 0.5), ("ds", "compact", 0, 0.5), ("gsv", "mask", 0, 0.5),
       ("rcs", "mask", 0, 0.75), ("l1_sq", "mask", 0, 0.5),
       ("l1", "compact", 8, 0.5), ("l1", "pallas", 8, 0.5)]


@pytest.mark.parametrize("method,backend,block,budget", ALL)
def test_unbiased(problem, method, backend, block, budget):
    x, w, b = problem
    exact = _exact(problem)
    cfg = SketchConfig(method=method, budget=budget, backend=backend, block=block)
    gfn = jax.jit(lambda k: jax.grad(_loss, argnums=(0, 1, 2))(x, w, b, k, cfg))
    keys = jax.random.split(jax.random.key(7), 800)
    gs = jax.lax.map(gfn, keys, batch_size=100)
    for got, want in zip(gs, exact):
        mean = np.asarray(got.mean(0))
        std = np.asarray(got.std(0))
        want = np.asarray(want)
        scale = np.max(np.abs(want)) + 1e-9
        det = std < 1e-6 * scale  # deterministic coords (e.g. Alg.3 exact db)
        np.testing.assert_allclose(mean[det], want[det], rtol=1e-3, atol=1e-4 * scale)
        if det.all():
            continue
        # scale-aware floor: rare-event coords (tiny p) have skewed finite-n
        # distributions where the CLT t-stat misleads; the floor bounds the
        # detectable bias at ~0.5% of the gradient scale (the 12k-sample
        # sweep in EXPERIMENTS verified mean|t| < 0.5 without the floor)
        se = std[~det] / np.sqrt(len(keys)) + 1e-3 * scale
        t = np.abs(mean[~det] - want[~det]) / se
        # unbiased ⇒ t ≈ |N(0,1)| up to finite-n skew of the 1/p-scaled
        # estimators (a 12k-sample sweep gives mean|t| ≈ 0.45 for every
        # method; at n=800 the empirical std underestimates heavy-tailed σ,
        # inflating t ~1.4×). Thresholds sized for n=800 with that skew.
        assert np.mean(t) < 2.2, f"mean|t|={np.mean(t)}"
        assert np.percentile(t, 95) < 5.0


@pytest.mark.parametrize("method", ["l1", "ds", "per_column"])
def test_compact_equals_mask_same_key(problem, method):
    x, w, b = problem
    key = jax.random.key(3)
    gm = jax.grad(_loss, argnums=(0, 1, 2))(
        x, w, b, key, SketchConfig(method=method, budget=0.3, backend="mask"))
    gc = jax.grad(_loss, argnums=(0, 1, 2))(
        x, w, b, key, SketchConfig(method=method, budget=0.3, backend="compact"))
    for a, c in zip(gm, gc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-5, atol=2e-5)


def test_block_backends_agree(problem):
    x, w, b = problem
    key = jax.random.key(9)
    outs = []
    for backend in ("mask", "compact", "pallas"):
        cfg = SketchConfig(method="l1", budget=0.5, backend=backend, block=8)
        outs.append(jax.grad(_loss, argnums=(0, 1, 2))(x, w, b, key, cfg))
    for other in outs[1:]:
        for a, c in zip(outs[0], other):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-5, atol=2e-5)


def test_budget_one_equals_exact(problem):
    x, w, b = problem
    exact = _exact(problem)
    for method in ("l1", "per_column", "per_sample", "per_element"):
        g = jax.grad(_loss, argnums=(0, 1, 2))(
            x, w, b, jax.random.key(1), SketchConfig(method=method, budget=1.0))
        for a, e in zip(g, exact):
            np.testing.assert_allclose(np.asarray(a), np.asarray(e), rtol=1e-5, atol=1e-6)


def test_static_rank_round_to():
    cfg = SketchConfig(method="l1", budget=0.1, round_to=128)
    assert static_rank(cfg, 1000) == 128
    assert static_rank(cfg, 4096) == 512
    cfg2 = SketchConfig(method="l1", budget=0.1)
    assert static_rank(cfg2, 1000) == 100


def test_column_plan_probs_sum(problem):
    x, w, _ = problem
    G = jax.random.normal(jax.random.key(2), (N, DOUT))
    cfg = SketchConfig(method="l1", budget=0.25)
    plan = column_plan(cfg, G, w, jax.random.key(0), want_compact=True)
    r = static_rank(cfg, DOUT)
    assert plan.indices.shape == (r,)
    assert float(jnp.sum(plan.probs)) == pytest.approx(r, abs=1e-2)


def test_sketch_dense_zero_columns_stay_zero():
    """ℓ1 score 0 ⇔ column identically 0 ⇒ dropping it is exact."""
    G = jnp.zeros((16, 10)).at[:, :3].set(1.0)
    cfg = SketchConfig(method="l1", budget=0.3)
    for i in range(5):
        ghat = sketch_dense(cfg, G, None, jax.random.key(i))
        np.testing.assert_allclose(np.asarray(ghat[:, 3:]), 0.0)


def test_pallas_score_routing_matches_jnp_scores():
    """ℓ1/ℓ2 (and _sq) scores on the pallas backend route through the
    kernels.ops.col_l1_scores dispatcher (streaming fp32 reduction) and must
    produce the same sampling probabilities as the jnp scores used by the
    mask/compact backends."""
    import os

    from repro.core.sketching import _column_probs, _proxy_scores
    from repro.core.scores import column_scores

    G = jax.random.normal(jax.random.key(3), (64, 128), jnp.float32)
    for method in ("l1", "l2", "l1_sq", "l2_sq"):
        cfg_p = SketchConfig(method=method, budget=0.25, backend="pallas")
        cfg_m = SketchConfig(method=method, budget=0.25, backend="mask")
        sp = _proxy_scores(cfg_p, G, None)
        sm = column_scores(method, G)
        np.testing.assert_allclose(np.asarray(sp), np.asarray(sm), rtol=1e-5)
        pp = _column_probs(cfg_p, G, None, 32)
        pm = _column_probs(cfg_m, G, None, 32)
        np.testing.assert_allclose(np.asarray(pp), np.asarray(pm), rtol=1e-5)
    # and through the actual Pallas kernel (interpret mode): same scores
    os.environ["REPRO_FORCE_INTERPRET"] = "1"
    try:
        sp_k = _proxy_scores(SketchConfig(method="l1", budget=0.25, backend="pallas"),
                             G, None)
        np.testing.assert_allclose(np.asarray(sp_k),
                                   np.asarray(column_scores("l1", G)), rtol=1e-5)
    finally:
        del os.environ["REPRO_FORCE_INTERPRET"]
