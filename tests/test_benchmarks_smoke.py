"""Tiny-shape benchmark smoke (XLA paths only): every run.py entry point must
import, and the backward-fusion bench must run end-to-end in-process (the
conftest-forced 8 fake devices double as its mesh) and uphold the PR's
structural claim — the fused backward reads G at most twice."""
import importlib

import jax
import pytest


@pytest.mark.parametrize("mod", [
    "benchmarks.run",
    "benchmarks.bench_fig1a_correlation",
    "benchmarks.bench_fig1b_mask_vs_sketch",
    "benchmarks.bench_fig2a_proxies",
    "benchmarks.bench_fig2b_spectral",
    "benchmarks.bench_fig3_larger_archs",
    "benchmarks.bench_fig4_location",
    "benchmarks.bench_variance",
    "benchmarks.bench_cost",
    "benchmarks.bench_block_granularity",
    "benchmarks.bench_distributed",
    "benchmarks.bench_backward_fusion",
])
def test_bench_module_imports(mod):
    importlib.import_module(mod)


def test_backward_fusion_bench_tiny():
    from benchmarks import bench_backward_fusion as bf

    out = bf.run(tiny=True, budget=0.25)
    gp = out["g_passes"]
    # the fused backward streams G at most twice: score/plan + fused gather
    assert gp["g_passes_fused"] <= 2, gp
    assert gp["g_passes_fused"] <= gp["g_passes_unfused"], gp
    # the VMEM-overflow fallback streams G at most 3 times: score/plan +
    # the dX kernel pass + ONE shared dW/db gather (was 4 with the separate
    # db gather next to the unfused kernel pair)
    assert gp["g_passes_fallback"] <= 3, gp
    if jax.device_count() >= 8:
        ts = out["train_step"]
        assert set(ts) >= {"exact", "compact_pre", "compact_fused"}
        for rec in ts.values():
            assert rec["step_ms"] > 0


def test_g_reader_counter_parses_hlo():
    import jax.numpy as jnp

    from benchmarks.bench_backward_fusion import _g_reader_ops

    f = jax.jit(lambda g: (jnp.sum(jnp.abs(g)), g @ g.T))
    txt = f.lower(jax.ShapeDtypeStruct((32, 48), jnp.float32)).compile().as_text()
    assert _g_reader_ops(txt, 32, 48) >= 1
