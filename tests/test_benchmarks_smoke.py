"""Tiny-shape benchmark smoke (XLA paths only): every run.py entry point must
import, and the backward-fusion bench must run end-to-end in-process (the
conftest-forced 8 fake devices double as its mesh) and uphold the PR's
structural claim — the fused backward reads G at most twice."""
import importlib

import jax
import pytest


@pytest.mark.parametrize("mod", [
    "benchmarks.run",
    "benchmarks.bench_fig1a_correlation",
    "benchmarks.bench_fig1b_mask_vs_sketch",
    "benchmarks.bench_fig2a_proxies",
    "benchmarks.bench_fig2b_spectral",
    "benchmarks.bench_fig3_larger_archs",
    "benchmarks.bench_fig4_location",
    "benchmarks.bench_variance",
    "benchmarks.bench_cost",
    "benchmarks.bench_block_granularity",
    "benchmarks.bench_distributed",
    "benchmarks.bench_backward_fusion",
    "benchmarks.bench_adaptive",
    "benchmarks.bench_resilience",
    "benchmarks.bench_serve",
    "benchmarks.bench_obs",
])
def test_bench_module_imports(mod):
    importlib.import_module(mod)


def test_adaptive_bench_tiny():
    """Closed-loop MLP training end-to-end: the controller only selects
    among pre-compiled buckets (every bucket step traced exactly once)."""
    from benchmarks import bench_adaptive as ba

    out = ba.run(tiny=True)
    for name in ("fixed", "warmup_exact", "adaptive"):
        r = out[name]
        # <= 1: jit traces lazily, so a never-selected bucket traces 0 times
        assert all(v <= 1 for v in r["traces"].values()), (name, r["traces"])
        assert r["total_bwd_flops"] > 0
    assert out["adaptive"]["total_bwd_flops"] <= out["fixed"]["total_bwd_flops"]
    # the realized trajectory stays inside the schedule's bucket set
    assert set(b for b in out["adaptive"]["budget_hist"]) <= {1.0, 0.5, 0.25}


def test_bench_summary_is_machine_readable(tmp_path):
    """benchmarks/run.py distills results/bench/*.json into a top-level
    JSONL summary: one line per benchmark with name, key metric and the
    delta vs the previous artifact."""
    import json
    import os

    from benchmarks import run as brun

    summary = tmp_path / "BENCH_summary.json"
    assert os.path.isdir(brun.RESULTS), "committed bench artifacts expected"
    recs = brun.write_summary(summary_path=str(summary))
    assert recs and {"name", "metric", "value", "prev", "delta"} <= set(recs[0])
    lines = [json.loads(l) for l in open(summary) if l.strip()]
    assert [l["name"] for l in lines] == [r["name"] for r in recs]
    by_name = {l["name"]: l for l in lines}
    assert "backward_fusion" in by_name
    # second write computes deltas against the first (tmp paths are outside
    # the repo, so the git-committed baseline does not apply)
    recs2 = brun.write_summary(summary_path=str(summary))
    assert all(r["delta"] == 0.0 for r in recs2 if r["value"] is not None)


def test_bench_summary_baseline_is_git_seeded():
    """Cross-PR trajectory: prev/delta for the canonical BENCH_summary.json
    come from the *committed* summary (the previous PR's values), so
    rewriting the summary twice in one session cannot zero the deltas; tmp
    paths keep the file-based fallback."""
    from benchmarks import run as brun

    committed = brun._committed_summary(brun.SUMMARY_PATH)
    if committed is None:
        pytest.skip("no git checkout (source export) — file-based fallback "
                    "is covered above")
    assert committed, "committed BENCH_summary.json must parse via git show"
    assert "distributed" in committed
    assert committed["distributed"]["value"] is not None
    # outside the repo: no git baseline (tests above rely on the fallback)
    assert brun._committed_summary("/tmp/nowhere/BENCH_summary.json") is None


def test_serve_bench_tiny():
    """The serving bench end-to-end at toy scale: all three engines emit the
    same tokens, the continuous engines waste at most what run-to-completion
    wastes, and the paged engine keeps its one-compile-per-bucket promise."""
    from benchmarks import bench_serve as bs

    out = bs.run(tiny=True)
    assert out["outputs_equal"]
    v = out["variants"]
    for name in ("legacy", "contiguous", "paged"):
        assert v[name]["tok_per_s"] > 0
    assert v["paged"]["wasted_decode_steps"] <= v["legacy"]["wasted_decode_steps"]
    tc = v["paged"]["trace_counts"]
    assert tc["decode"] == 1 and all(n == 1 for n in tc.values()), tc
    # per-request latency stamps only exist on the continuous engines
    assert v["paged"]["latency_p50_s"] is not None
    assert v["legacy"]["latency_p50_s"] is None


def test_obs_bench_tiny():
    """The obs-overhead bench end-to-end at toy scale: both paths produce a
    pairwise-ratio overhead estimate and the headline is their max. (The
    <2% claim itself is gated on the committed full-size artifact by
    run.py --check, not on this noisy tiny run.)"""
    from benchmarks import bench_obs as bo

    out = bo.run(tiny=True)
    assert out["reps"] == 3
    for path in ("serve", "train"):
        r = out[path]
        assert r["reps"] == 3
        assert r["off_s"] > 0 and r["on_s"] > 0
        assert r["overhead_frac"] is not None
    assert out["obs_overhead_frac"] == max(out["serve"]["overhead_frac"],
                                           out["train"]["overhead_frac"])


def test_check_regressions_units():
    """The --check gate's comparison logic: ceilings bind even without
    history, both directions flag past their tolerance band, and missing
    values/prevs/tolerances never flag."""
    from benchmarks.run import check_regressions

    tol = {"step_ms": {"direction": "lower", "rel_tol": 0.10, "abs_slack": 1.0},
           "tok_per_s": {"direction": "higher", "rel_tol": 0.10, "abs_slack": 0.0},
           "frac": {"direction": "lower", "rel_tol": 0.0, "abs_slack": 0.0,
                    "ceiling": 0.02}}

    def rec(metric, value, prev=None, name="b"):
        return {"name": name, "metric": metric, "value": value, "prev": prev}

    # within band: 10% rel + 1.0 abs slack on a prev of 100 allows 111
    assert check_regressions([rec("step_ms", 111.0, 100.0)], tol) == []
    [f] = check_regressions([rec("step_ms", 111.1, 100.0)], tol)
    assert "regressed" in f and "step_ms" in f
    # higher-is-better: 90 is allowed on prev 100, 89.9 is not
    assert check_regressions([rec("tok_per_s", 90.0, 100.0)], tol) == []
    assert len(check_regressions([rec("tok_per_s", 89.9, 100.0)], tol)) == 1
    # ceiling binds with no prev at all; under-ceiling first appearance is ok
    [f] = check_regressions([rec("frac", 0.03)], tol)
    assert "ceiling" in f
    assert check_regressions([rec("frac", 0.015)], tol) == []
    # ceiling + regression can both fire on one record
    assert len(check_regressions([rec("frac", 0.03, prev=0.01)], tol)) == 2
    # silent skips: no value, no tolerance entry
    assert check_regressions([rec("step_ms", None, 100.0),
                              rec("unknown_metric", 5.0, 1.0)], tol) == []


def test_check_gate_passes_on_committed_artifacts():
    """run.py --check against the repo's own committed artifacts + summary
    must pass — it is the regression gate this PR turns on."""
    import subprocess
    import sys

    r = subprocess.run([sys.executable, "-m", "benchmarks.run", "--check"],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 regression(s)" in r.stdout


def test_backward_fusion_bench_tiny():
    from benchmarks import bench_backward_fusion as bf

    out = bf.run(tiny=True, budget=0.25)
    gp = out["g_passes"]
    # the fused backward streams G at most twice: score/plan + fused gather
    assert gp["g_passes_fused"] <= 2, gp
    assert gp["g_passes_fused"] <= gp["g_passes_unfused"], gp
    # the VMEM-overflow fallback now also streams G at most twice: score/plan
    # + ONE barriered gather feeding dX and the dW matmul with db folded into
    # its stream (was 3 readers when the dX kernel made its own pass, 4
    # before the shared dW/db gather)
    assert gp["g_passes_fallback"] <= 2, gp
    # the plan-carry estimators are the headline: ONE HBM pass over G —
    # the plan comes from carried scores (no score read), and the backward
    # kernel's single sweep produces the gradient and the score refresh.
    # Asserted against the per-estimator ceiling table the dryrun coverage
    # record and run.py --check consume.
    from repro.analysis.invariants import G_READER_CEILINGS

    assert gp["g_passes_onepass"] <= G_READER_CEILINGS["onepass"] == 1, gp
    assert gp["g_passes_stale"] <= G_READER_CEILINGS["stale"] == 1, gp
    assert gp["g_passes_fused"] <= G_READER_CEILINGS["pallas"], gp
    # stale-plan excess variance: probe-measured, finite, and >= ~1 (a stale
    # plan can only add variance relative to fresh scores, up to MC noise)
    sp = out["stale_plan"]
    assert sp["probe_var_stale"] > 0 and sp["probe_var_fresh"] > 0
    assert sp["excess_var_ratio"] > 0.5, sp
    ts_local = out["train_step_local"]
    assert {"block_twopass", "block_onepass", "block_stale"} <= set(ts_local)
    for rec in ts_local.values():
        assert rec["step_ms"] > 0
    if jax.device_count() >= 8:
        ts = out["train_step"]
        assert set(ts) >= {"exact", "compact_pre", "compact_fused"}
        for rec in ts.values():
            assert rec["step_ms"] > 0


def test_g_reader_ceiling_table():
    """The per-estimator HBM-accounting contract consumed by the smoke
    assertions above, the dryrun coverage record, and run.py --check: every
    builtin backend has a ceiling, the plan-carry estimators claim exactly
    one G reader, and unknown third-party backends get the conservative
    legacy bound."""
    from repro.analysis import G_READER_CEILINGS, g_reader_ceiling
    from repro.core.estimators import BUILTIN_BACKENDS

    assert set(G_READER_CEILINGS) == set(BUILTIN_BACKENDS)
    assert g_reader_ceiling("onepass") == g_reader_ceiling("stale") == 1
    assert g_reader_ceiling("pallas") == g_reader_ceiling("mask") == 2
    assert g_reader_ceiling("some_third_party_backend") == 2


def test_g_reader_counter_parses_hlo():
    import jax.numpy as jnp

    # canonical home since the analysis subsystem absorbed the helper;
    # the bench imports the same function
    from benchmarks.bench_backward_fusion import g_reader_passes

    f = jax.jit(lambda g: (jnp.sum(jnp.abs(g)), g @ g.T))
    txt = f.lower(jax.ShapeDtypeStruct((32, 48), jnp.float32)).compile().as_text()
    assert g_reader_passes(txt, 32, 48) >= 1
