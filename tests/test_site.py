"""The unified sketched-site spine (core/site.py).

Three invariants pin the refactor that collapsed the four separately-built
``custom_vjp`` spines (local sketched_linear + the three shard_map builds)
into the single ``core/site.py`` spine:

1. **Local-path bit-identity**: training through the spine is bit-identical
   to the pre-refactor code, asserted against a checked-in golden capture
   (``tests/data/site_golden.npz``, generated from the pre-refactor tree —
   regenerate only on purpose with ``REPRO_UPDATE_SITE_GOLDEN=1``) for
   mask/compact/pallas × with/without compact_grads × with/without probes.
2. **Dispatch/slot-builder no-drift**: ``nn.common.dense`` and the
   CompactGrad slot builder consume the *same* resolved :class:`SiteSpec`,
   so a gslot is emitted iff the resolved plan produces compact rows — for
   every registered arch config on the 8-fake-device TP mesh.
3. **Spec resolution semantics**: the TP column/row/fallback routing that
   used to live as per-call heuristics in ``dense``.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.api import (ExecutionConfig, Runtime, SketchConfig, SketchPolicy,
                       TelemetryConfig)
from repro.configs.base import ArchConfig
from repro.data.synthetic import LMStream
from repro.optim import sgd

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "site_golden.npz")

ARCH = ArchConfig(name="site-golden", family="dense", n_layers=1, d_model=32,
                  n_heads=4, n_kv=2, d_ff=64, vocab=64, q_chunk=16,
                  kv_chunk=16)

# backend × block × compact_grads × probes; mask has no compact form.
# The plan-carry estimators (onepass/stale, ISSUE 10) extend the grid with
# NEW entries only — the pre-existing mask/compact/pallas captures stay
# byte-identical, proving the sslot plumbing leaves legacy paths untouched.
_GRID = (
    [("mask", 0, False, p) for p in (False, True)]
    + [("compact", b, cg, p) for b in (0, 4) for cg in (False, True)
       for p in (False, True)]
    + [("pallas", 4, cg, p) for cg in (False, True) for p in (False, True)]
    + [(be, 4, cg, p) for be in ("onepass", "stale")
       for cg in (False, True) for p in (False, True)]
)


def _grid_name(backend, block, cg, probes):
    return f"{backend}_b{block}_cg{int(cg)}_p{int(probes)}"


def _run_local(backend, block, cg, probes):
    """Two sgd steps on the tiny arch; returns (losses, flat_params)."""
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.4,
                                         backend=backend, block=block))
    ex = ExecutionConfig(
        compact_grads=cg,
        telemetry=TelemetryConfig(per_site=False) if probes else None)
    rt = Runtime(policy=pol, execution=ex)
    opt = sgd(0.1)
    state = rt.init_state(compat.prng_key(0), ARCH, opt)
    batch = next(iter(LMStream(vocab=ARCH.vocab, seed=0).batches(4, 16)))
    step = rt.train_step(ARCH, opt, donate=False)
    losses = []
    for i in range(2):
        state, m = step(state, batch, compat.prng_key(i + 1))
        losses.append(float(m["loss"]))
    flat = np.concatenate([np.asarray(v, np.float32).ravel()
                           for v in jax.tree_util.tree_leaves(state.params)])
    return np.asarray(losses, np.float32), flat


def test_local_training_bit_identical_to_pre_refactor_golden():
    """The refactor guarantee: collapsing the spines must not move a single
    bit on the local path — same estimators, same keys, same order of
    operations, for every backend × compact_grads × probes combination."""
    if os.environ.get("REPRO_UPDATE_SITE_GOLDEN") == "1":
        out = {}
        for combo in _GRID:
            losses, flat = _run_local(*combo)
            name = _grid_name(*combo)
            out[f"{name}_losses"] = losses
            out[f"{name}_params"] = flat
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        np.savez_compressed(GOLDEN, **out)
        pytest.skip("regenerated tests/data/site_golden.npz")
    assert os.path.exists(GOLDEN), (
        "golden capture missing — generate from a known-good tree with "
        "REPRO_UPDATE_SITE_GOLDEN=1")
    data = np.load(GOLDEN)
    for combo in _GRID:
        name = _grid_name(*combo)
        losses, flat = _run_local(*combo)
        np.testing.assert_array_equal(
            losses, data[f"{name}_losses"],
            err_msg=f"{name}: per-step losses moved vs pre-refactor")
        np.testing.assert_array_equal(
            flat, data[f"{name}_params"],
            err_msg=f"{name}: updated params moved vs pre-refactor")


# ---------------------------------------------------------------------------
# Dispatch / slot-builder drift guard
# ---------------------------------------------------------------------------


needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 (fake) devices; conftest forces the count")


@needs8
def test_slot_builders_match_resolved_specs_for_all_archs():
    """The invariant that used to live in a comment ("must mirror exactly"):
    for every registered arch under ``tp_sketch`` on the 2x4 mesh, the
    CompactGrad slot builder emits a gslot *iff* the site's resolved
    :class:`SiteSpec` produces compact rows (with the matching rank), and
    the probe slot builder emits a pslot *iff* the spec can probe. Both
    builders and ``dense`` now consume the same resolution, so this pins
    the shared dispatch across the whole config registry."""
    from repro.configs.registry import ARCH_IDS, smoke_config
    from repro.core import compact_grad as cgrad
    from repro.core.site import resolve_tree_site
    from repro.launch.mesh import make_mesh
    from repro.models import lm
    from repro.telemetry import probes as tprobes

    mesh = make_mesh((2, 4), ("data", "model"))
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.5,
                                         backend="compact"))
    kw = dict(mesh=mesh, data_axes=("data",), model_axes=("model",),
              tp_sketch=True)
    n_sites = n_gslots = n_dense = 0
    for name in ARCH_IDS:
        cfg = smoke_config(name)
        params = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                                compat.prng_key(0))
        slotted = cgrad.with_grad_slots(params, pol, n_layers=cfg.n_layers,
                                        **kw)
        pslotted = tprobes.with_probe_slots(params, pol,
                                            n_layers=cfg.n_layers, **kw)

        def walk(gnode, pnode, path):
            nonlocal n_sites, n_gslots, n_dense
            if isinstance(gnode, dict):
                spec = resolve_tree_site(path, gnode, pol,
                                         n_layers=cfg.n_layers, **kw)
                if spec is not None:
                    n_sites += 1
                    want_g = spec.compact_rows is not None
                    assert ("gslot" in gnode) == want_g, (name, path, spec)
                    assert ("pslot" in pnode) == spec.probe_capable, \
                        (name, path, spec)
                    if want_g:
                        n_gslots += 1
                        assert gnode["gslot"].rows.shape[-2] == spec.compact_rows, \
                            (name, path, spec)
                    else:
                        n_dense += 1
                for k, v in gnode.items():
                    if k not in ("gslot", "pslot"):
                        walk(v, pnode[k], path + (k,))
            elif isinstance(gnode, (list, tuple)):
                for i, v in enumerate(gnode):
                    walk(v, pnode[i], path + (i,))

        walk(slotted, pslotted, ())
    assert n_sites > 40 and n_gslots > 0, (n_sites, n_gslots)

    # every registry smoke site happens to be TP-compatible on the 2x4 mesh,
    # so force the fallback branch with an odd-width site: no gslot (the
    # backward mask-falls-back, emitting no compact rows) but still a pslot
    # (the mask estimator probes on the local plan)
    odd = {"attn": {"q": {"w": jax.ShapeDtypeStruct((30, 16), jnp.float32)},
                    "k": {"w": jax.ShapeDtypeStruct((32, 16), jnp.float32)}}}
    gs = cgrad.with_grad_slots(odd, pol, n_layers=1, **kw)
    ps = tprobes.with_probe_slots(odd, pol, n_layers=1, **kw)
    assert "gslot" not in gs["attn"]["q"] and "pslot" in ps["attn"]["q"]
    assert "gslot" in gs["attn"]["k"] and "pslot" in ps["attn"]["k"]


# ---------------------------------------------------------------------------
# Spec resolution semantics (the dispatch that used to be dense() heuristics)
# ---------------------------------------------------------------------------


@needs8
def test_resolve_site_semantics():
    from repro.api import resolve_site
    from repro.core.compact_grad import compact_rank
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    kw = dict(mesh=mesh, data_axes=("data",), model_axes=("model",),
              tp_sketch=True)
    cfg = SketchConfig(method="l1", budget=0.5, backend="compact")

    # column-parallel site: d_out divides the model axis
    s = resolve_site("attn_q", cfg, d_out=32, d_in=16, **kw)
    assert s.plan.kind == "tp_column" and s.cfg == cfg
    assert s.compact_rows == 4 * compact_rank(cfg, 32 // 4)
    assert s.probe_capable

    # row-parallel site: d_in divides the model axis
    s = resolve_site("mlp_out", cfg, d_out=16, d_in=32, **kw)
    assert s.plan.kind == "tp_row"
    assert s.compact_rows == compact_rank(cfg, 16)

    # bias no longer forces the site off the TP plan (satellite: the
    # ``b is None`` restriction died — db rides the TP streams)
    s = resolve_site("attn_q", cfg, d_out=32, d_in=16, has_bias=True, **kw)
    assert s.plan.kind == "tp_column" and s.has_bias
    assert s.compact_rows is not None

    # TP-incompatible width: falls back to the dense mask estimator — no
    # compact rows (so no gslot), but still probe-capable via the mask hook
    s = resolve_site("attn_q", cfg, d_out=30, d_in=16, **kw)
    assert s.plan.kind == "local" and s.cfg.backend == "mask"
    assert s.compact_rows is None and s.probe_capable

    # non-3D activations stay off the shard_map plans
    s = resolve_site("attn_q", cfg, d_out=32, d_in=16, x_ndim=2, **kw)
    assert s.plan.kind == "local" and s.cfg.backend == "mask"

    # roles outside the TP sets keep the (mask-forced) local plan
    s = resolve_site("expert_in", cfg, d_out=32, d_in=16, **kw)
    assert s.plan.kind == "local" and s.cfg.backend == "mask"

    # mask backend is not tp_shardable: local, unchanged
    mcfg = SketchConfig(method="l1", budget=0.5, backend="mask")
    s = resolve_site("attn_q", mcfg, d_out=32, d_in=16, **kw)
    assert s.plan.kind == "local" and s.cfg == mcfg and s.compact_rows is None

    # tp_sketch without a mesh: every compact site mask-falls-back (a gslot
    # here would silently freeze the site)
    s = resolve_site("attn_q", cfg, d_out=32, d_in=16, mesh=None,
                     data_axes=("data",), model_axes=("model",),
                     tp_sketch=True)
    assert s.plan.kind == "local" and s.cfg.backend == "mask"
    assert s.compact_rows is None

    # no tp_sketch: plain local compact with a slot rank
    s = resolve_site("attn_q", cfg, d_out=32, d_in=16)
    assert s.plan.kind == "local" and s.cfg == cfg
    assert s.compact_rows == compact_rank(cfg, 32)
