"""Per-arch smoke tests (assignment deliverable f): reduced configs of every
assigned architecture run one forward + one sketched train step on CPU, with
shape and finiteness assertions; decoder archs also verify that prefill+decode
reproduces the full causal forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, cells_for, get_config, smoke_config
from repro.core import SketchConfig, SketchPolicy
from repro.models import lm
from repro.nn.common import Ctx

POLICY = SketchPolicy(base=SketchConfig(method="l1", budget=0.5))

# The sketched-train-step smoke of these archs is grad-compile bound (25-40 s
# each: 6-sub-block local:global period / mamba+shared-attn period under
# remat) and dominates tier-1 wall time. Their forward, decode-parity and
# struct tests stay in tier-1; the train step runs under `-m slow` (ROADMAP
# wall-time item). All other archs keep full tier-1 coverage of the same
# sketched-backward code paths.
_SLOW_TRAIN_STEP = ("gemma3_1b", "zamba2_7b", "seamless_m4t_large_v2")


def _batch(cfg, B=2, S=24):
    ks = jax.random.split(jax.random.key(0), 3)
    batch = {"labels": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["embeds"] = jax.random.normal(ks[1], (B, S, cfg.d_model)) * 0.02
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    if cfg.is_encdec:
        batch["src_embeds"] = jax.random.normal(ks[2], (B, S, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward(arch):
    cfg = smoke_config(arch)
    params = lm.init_params(jax.random.key(1), cfg)
    batch = _batch(cfg)
    B, S = batch["labels"].shape

    logits, aux = lm.forward(params, batch, Ctx(), cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_TRAIN_STEP else a
    for a in ARCH_IDS])
def test_sketched_train_step(arch):
    cfg = smoke_config(arch)
    params = lm.init_params(jax.random.key(1), cfg)
    batch = _batch(cfg)

    loss, grads = jax.jit(lambda p, k: jax.value_and_grad(
        lambda q: lm.lm_loss(q, batch, Ctx(policy=POLICY), cfg, k)[0])(p))(
            params, jax.random.key(2))
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
    # at least one parameter leaf receives nonzero gradient
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = smoke_config(arch)
    if cfg.frontend == "vision":
        pytest.skip("vision stub feeds embeddings; decode parity covered via tokens path")
    params = lm.init_params(jax.random.key(1), cfg)
    batch = _batch(cfg, S=24)
    toks = batch["tokens"]
    fb = {k: v for k, v in batch.items() if k != "labels"}
    logits_full, _ = lm.forward(params, fb, Ctx(), cfg)
    pb = dict(fb)
    pb["tokens"] = toks[:, :-1]
    _, caches = lm.prefill(params, pb, Ctx(), cfg, max_len=30)
    lg_dec, new_caches = lm.decode_step(params, caches, toks[:, -1:], 23, Ctx(), cfg)
    err = float(jnp.max(jnp.abs(lg_dec[:, 0] - logits_full[:, -1])))
    scale = float(jnp.max(jnp.abs(logits_full[:, -1]))) + 1e-6
    assert err / scale < 3e-5, f"decode mismatch {err} (scale {scale})"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_struct_and_cells(arch):
    """The FULL config builds its param structure (eval_shape, no allocation)
    and declares the right shape cells (long_500k only for sub-quadratic)."""
    cfg = get_config(arch)
    struct = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.key(0))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(struct))
    expected_minimum = {
        "olmoe_1b_7b": 5e9, "mixtral_8x22b": 1e11, "qwen2_vl_2b": 1e9,
        "seamless_m4t_large_v2": 8e8, "nemotron_4_340b": 2.5e11, "gemma3_1b": 7e8,
        "yi_6b": 5e9, "llama3_405b": 3.5e11, "zamba2_7b": 5e9, "rwkv6_3b": 2e9,
    }[arch]
    assert n > expected_minimum, f"{arch}: {n:.3g} params"
    cells = {c.name for c in cells_for(cfg)}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= cells
    if arch in ("mixtral_8x22b", "gemma3_1b", "zamba2_7b", "rwkv6_3b"):
        assert "long_500k" in cells
    else:
        assert "long_500k" not in cells


def test_zamba_shared_block_actually_shared():
    cfg = smoke_config("zamba2_7b")
    params = lm.init_params(jax.random.key(0), cfg)
    assert "shared" in params
    # grads flow into the shared block from multiple applications
    batch = _batch(cfg)
    g = jax.grad(lambda p: lm.lm_loss(p, batch, Ctx(), cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g["shared"]))
    assert gn > 0
