"""Observability layer (`repro.obs`): spans/tracing, the unified metrics
registry, compile/memory ledgers, and the flight recorder — plus the
end-to-end claims the docs make: Chrome-trace/Perfetto export round-trips,
serve ring records join to request-lifecycle spans by ``span_id``, a
checkpoint-IO fault leaves a crash bundle containing the ``fault_injected``
span, and observability (on or off) never changes trained numerics — the
obs-on state is bit-identical to ``obs=None``.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.api import ExecutionConfig, Runtime
from repro.configs.base import ArchConfig
from repro.data.synthetic import ClassStream
from repro.models import lm
from repro.models.mlp import mlp_arch
from repro.obs import NULL_OBS, ObsConfig, observability
from repro.obs.ledgers import CompileLedger, memory_summary
from repro.obs.metrics import CounterView, MetricsRegistry
from repro.obs.tracing import NULL_TRACER, Tracer
from repro.optim import adamw, constant
from repro.resilience import FaultPlan, FaultSpec, ResilienceConfig
from repro.resilience import Supervisor
from repro.serve.config import ServeConfig
from repro.serve.engine import Engine, Request
from repro.train.trainer import TrainerConfig, train_loop

SIZES = (32, 16, 16, 4)

SERVE_CFG = ArchConfig(name="obs-test", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                       q_chunk=32, kv_chunk=32)


def _cfg():
    return mlp_arch(SIZES)


def _opt():
    return adamw(constant(1e-2), clip=1.0)


def _data(batch=16, seed=0):
    return ClassStream(dim=SIZES[0], n_classes=SIZES[-1], seed=seed).batches(batch)


def _obs_cfg(tmp_path, **kw):
    """A per-test ObsConfig: `observability()` shares state between EQUAL
    configs (by design), so the unique tmp_path crash_dir keeps each test's
    tracer/registries isolated."""
    kw.setdefault("crash_dir", str(tmp_path / "crash"))
    return ObsConfig(**kw)


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(
        {"p": state.params, "o": state.opt_state})]


# ---------------------------------------------------------------------------
# config + shared-state plumbing
# ---------------------------------------------------------------------------


def test_obsconfig_validation_and_keyed_sharing(tmp_path):
    with pytest.raises(ValueError):
        ObsConfig(trace_capacity=0)
    with pytest.raises(ValueError):
        ObsConfig(flight_capacity=0)
    cfg = _obs_cfg(tmp_path)
    assert hash(cfg) == hash(_obs_cfg(tmp_path))  # frozen & hashable
    # equal configs -> the SAME mutable Observability (keyed-state idiom)
    assert observability(cfg) is observability(_obs_cfg(tmp_path))
    assert observability(None) is NULL_OBS
    assert not NULL_OBS.enabled
    assert NULL_OBS.tracer is NULL_TRACER
    assert NULL_OBS.report() == {"enabled": False}
    assert NULL_OBS.dump_crash("anything") is None


def test_runtime_observability_accessor(tmp_path):
    cfg = _obs_cfg(tmp_path)
    rt = Runtime(execution=ExecutionConfig(obs=cfg))
    assert rt.observability() is observability(cfg)
    assert Runtime().observability() is NULL_OBS


def test_disabled_features_are_none(tmp_path):
    ob = observability(_obs_cfg(tmp_path, trace=False, metrics=False,
                                compile_ledger=False, memory_ledger=False,
                                flight=False))
    assert ob.tracer is NULL_TRACER
    assert ob.metrics is None and ob.flight is None
    assert ob.compile_ledger is None and ob.memory_ledger is None
    assert ob.dump_crash("no-flight") is None


# ---------------------------------------------------------------------------
# tracer units + Chrome-trace/Perfetto round-trip
# ---------------------------------------------------------------------------


def test_tracer_nesting_and_ring_bound():
    tr = Tracer(capacity=4)
    with tr.span("outer", step=1) as outer:
        assert tr.current_id() == outer.sid
        with tr.span("inner") as inner:
            assert inner.parent == outer.sid
            assert tr.current_id() == inner.sid
    assert tr.current_id() is None
    [inner_done, outer_done] = tr.spans()  # completion order
    assert (inner_done.name, outer_done.name) == ("inner", "outer")
    assert outer_done.attrs == {"step": 1}
    assert 0.0 <= inner_done.duration_s <= outer_done.duration_s
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.spans()) == 4  # bounded ring: oldest dropped
    tr.clear()
    assert tr.spans() == []


def test_tracer_records_error_spans():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("doomed"):
            raise RuntimeError("boom")
    [s] = tr.spans("doomed")
    assert s.attrs["error"] == "RuntimeError"


def test_add_span_returns_joinable_id():
    tr = Tracer()
    sid = tr.add_span("request", 1.0, 3.0, stop="eos")
    tr.add_span("decode", 2.0, 3.0, parent=sid)
    [req] = tr.spans("request")
    [dec] = tr.spans("decode")
    assert req.sid == sid and dec.parent == sid
    assert req.duration_s == 2.0


def test_chrome_trace_roundtrip(tmp_path):
    """export_chrome writes the JSON object Perfetto/chrome://tracing load:
    complete events (ph "X"), µs timestamps relative to the tracer origin,
    span/parent ids under args — and it survives a json round-trip."""
    tr = Tracer()
    with tr.span("parent", step=3):
        with tr.span("child"):
            pass
    path = tr.export_chrome(str(tmp_path / "sub" / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert [e["name"] for e in events] == ["child", "parent"]
    by_name = {e["name"]: e for e in events}
    for e in events:
        assert e["ph"] == "X" and e["pid"] == 1
        assert e["dur"] >= 0.0 and e["ts"] >= 0.0  # µs, origin-relative
    assert by_name["child"]["args"]["parent_id"] == \
        by_name["parent"]["args"]["span_id"]
    assert by_name["parent"]["args"]["step"] == 3
    # the child interval nests inside the parent interval
    p, c = by_name["parent"], by_name["child"]
    assert p["ts"] <= c["ts"]
    assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-6


def test_jsonl_export_one_record_per_span(tmp_path):
    tr = Tracer()
    for i in range(3):
        with tr.span("step", step=i):
            pass
    path = tr.export_jsonl(str(tmp_path / "spans.jsonl"))
    recs = [json.loads(l) for l in open(path) if l.strip()]
    assert [r["name"] for r in recs] == ["step"] * 3
    assert [r["step"] for r in recs] == [0, 1, 2]
    assert all(r["dur_s"] >= 0 and "sid" in r for r in recs)


def test_null_tracer_is_falsy_noop():
    assert not NULL_TRACER and not NULL_TRACER.enabled
    with NULL_TRACER.span("x", a=1) as s:
        assert s is None
    assert NULL_TRACER.add_span("x", 0.0, 1.0) is None
    assert NULL_TRACER.spans() == [] and NULL_TRACER.records() == []
    assert NULL_TRACER.to_chrome()["traceEvents"] == []


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_kinds_snapshot_prometheus():
    reg = MetricsRegistry()
    c = reg.counter("serve.tokens_out")
    c.inc(5)
    assert reg.counter("serve.tokens_out") is c  # idempotent constructor
    reg.gauge("serve.live_slots").set(3)
    h = reg.histogram("serve.latency_s", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["serve.tokens_out"] == 5.0
    assert snap["serve.live_slots"] == 3.0
    assert snap["serve.latency_s.count"] == 3
    assert snap["serve.latency_s.max"] == 5.0
    assert snap["serve.latency_s.mean"] == pytest.approx(5.55 / 3)
    text = reg.to_prometheus()
    assert "# TYPE serve_tokens_out counter" in text
    assert "serve_live_slots 3" in text
    assert 'serve_latency_s_bucket{le="+Inf"} 3' in text
    assert "serve_latency_s_count 3" in text
    with pytest.raises(TypeError):
        reg.gauge("serve.tokens_out")  # kind mismatch is a bug


def test_counter_view_keeps_dict_ergonomics():
    reg = MetricsRegistry()
    view = reg.view("serve", ["tokens_out", "decode_s"])
    view["tokens_out"] += 7
    view["decode_s"] += 0.25
    view["new_key"] = 2  # assignment grows the view, like a dict
    assert dict(view) == {"tokens_out": 7, "decode_s": 0.25, "new_key": 2}
    assert view["tokens_out"] == 7 and isinstance(view["tokens_out"], int)
    assert reg.snapshot()["serve.tokens_out"] == 7.0  # lives in the registry
    with pytest.raises(KeyError):
        view["never_registered"]
    with pytest.raises(TypeError):
        del view["tokens_out"]
    assert isinstance(view, CounterView) and len(view) == 3


def test_observability_merges_adopted_registries(tmp_path):
    ob = observability(_obs_cfg(tmp_path))
    ob.metrics.counter("train.steps").inc(4)
    eng = MetricsRegistry()
    eng.counter("serve.tokens_out").inc(9)
    ob.adopt("engine0", eng)
    snap = ob.metrics_snapshot()
    assert snap["train.steps"] == 4.0 and snap["serve.tokens_out"] == 9.0
    prom = ob.prometheus()
    assert "train_steps 4" in prom and "serve_tokens_out 9" in prom


# ---------------------------------------------------------------------------
# ledgers
# ---------------------------------------------------------------------------


def test_compile_ledger_summary_and_write(tmp_path):
    led = CompileLedger()
    led.record_compile("k1", trace_s=0.5, compile_s=2.0)
    led.record_compile("k2", first_call_s=1.0)
    led.record_hit("k1")
    led.record_hit("k1")
    s = led.summary()
    assert s == {"compiles": 2, "hits": 2, "distinct_keys": 2,
                 "total_compile_s": 2.0, "total_first_call_s": 1.0}
    path = led.write(str(tmp_path / "ledger.json"))
    doc = json.load(open(path))
    assert doc["summary"] == s
    assert doc["hits_by_key"] == {"k1": 2}
    assert [e["key"] for e in doc["entries"]] == ["k1", "k2"]


def test_memory_summary_fields():
    class MA:  # the stable slice of jax's memory_analysis result
        argument_size_in_bytes = 4e9
        output_size_in_bytes = 1e9
        temp_size_in_bytes = 2e9
        alias_size_in_bytes = 1e9

    out = memory_summary(MA(), hbm_bytes=int(8e9))
    assert out["peak_GB_per_dev"] == pytest.approx(6.0)
    assert out["fits_hbm"] is True
    assert memory_summary(MA(), hbm_bytes=int(4e9))["fits_hbm"] is False
    assert "fits_hbm" not in memory_summary(MA())


def test_runtime_train_step_feeds_ledgers(tmp_path):
    """One Runtime.train_step build -> one compile-ledger entry with the
    trace/compile wall split and a memory-ledger record under the same key;
    a second train_step call is a step-cache hit."""
    cfg = _obs_cfg(tmp_path)
    rt = Runtime(execution=ExecutionConfig(obs=cfg))
    arch, opt = _cfg(), _opt()  # the step cache keys on these identities
    step = rt.train_step(arch, opt)
    state = rt.init_state(jax.random.key(0), arch, opt)
    batch = next(iter(_data()))
    state, _ = step(state, batch, jax.random.key(1))
    ob = rt.observability()
    [entry] = ob.compile_ledger.entries
    assert entry["key"].startswith("train_step/mlp")
    assert (entry["compile_s"] or 0) > 0 or (entry["first_call_s"] or 0) > 0
    assert rt.train_step(arch, opt) is step  # cached
    assert ob.compile_ledger.summary()["hits"] == 1
    [(mkey, mem)] = ob.memory_ledger.to_json()["by_key"].items()
    assert mkey == entry["key"]
    assert mem["peak_GB_per_dev"] > 0
    rep = ob.report()
    assert rep["enabled"] and rep["compile"]["summary"]["compiles"] == 1
    assert mkey in rep["memory"]["by_key"]


# ---------------------------------------------------------------------------
# serve: ring records join to lifecycle spans by span_id
# ---------------------------------------------------------------------------


def test_serve_ring_span_ids_reconstruct_lifecycles(tmp_path):
    """Every finished request's ring record carries the sid of its `request`
    span; the queued/prefill/decode children parent onto it and their
    durations ARE the ring's queue_s/ttft_s/latency_s stamps (the spans are
    reconstructed post-hoc from the same scheduler timestamps)."""
    cfg = _obs_cfg(tmp_path)
    rt = Runtime(execution=ExecutionConfig(obs=cfg))
    params = lm.init_params(jax.random.key(0), SERVE_CFG)
    eng = Engine(params, SERVE_CFG,
                 serve=ServeConfig(n_slots=2, max_len=64, page_size=16),
                 runtime=rt)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, SERVE_CFG.vocab, size=n)
                    .astype(np.int32), max_new=m)
            for n, m in [(5, 4), (9, 3), (3, 6), (7, 2)]]
    eng.run(reqs)
    tracer = rt.observability().tracer
    by_sid = {s.sid: s for s in tracer.spans()}
    recs = [r for r in eng.ring.records if "span_id" in r]
    assert len(recs) == 4
    for rec in recs:
        req_span = by_sid[rec["span_id"]]
        assert req_span.name == "request"
        assert req_span.attrs["stop"] in ("length", "eos")
        assert req_span.attrs["new_tokens"] == rec["new_tokens"]
        assert req_span.duration_s == rec["latency_s"]
        kids = {s.name: s for s in tracer.spans()
                if s.parent == rec["span_id"]}
        assert set(kids) == {"queued", "prefill", "decode"}
        assert kids["queued"].duration_s == rec["queue_s"]
        # ttft = queue + prefill (both intervals share the admit stamp)
        assert kids["queued"].duration_s + kids["prefill"].duration_s == \
            pytest.approx(rec["ttft_s"])
    # the engine's hot-loop spans landed too, under serve.run
    assert tracer.spans("serve.run") and tracer.spans("decode_step")
    # counters reached the shared registry through the adopted view
    snap = rt.observability().metrics_snapshot()
    assert snap["serve.requests_done"] == 4.0
    assert snap["serve.tokens_out"] == sum(r.max_new for r in reqs)


# ---------------------------------------------------------------------------
# crash bundles + trainer integration
# ---------------------------------------------------------------------------


def test_ckpt_io_fault_leaves_crash_bundle(tmp_path):
    """An injected checkpoint-IO fault dumps a flight-recorder bundle whose
    spans.json (Chrome-trace form) contains the fault_injected span."""
    cfg = _obs_cfg(tmp_path)
    rcfg = ResilienceConfig(rollback_after=0)
    plan = FaultPlan(faults=(FaultSpec(step=3, kind="ckpt_io"),))
    rt = Runtime(execution=ExecutionConfig(resilience=rcfg, obs=cfg))
    train_loop(rt, _cfg(), _opt(), _data(),
               TrainerConfig(steps=10, log_every=5,
                             ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=4),
               faults=plan)
    bundle = os.path.join(cfg.crash_dir, "crash_000_ckpt_io")
    assert os.path.isdir(bundle)
    meta = json.load(open(os.path.join(bundle, "meta.json")))
    assert meta["reason"] == "ckpt_io"
    # the fault arms at step 3; the async writer's failure surfaces at a
    # later checkpoint wait — the bundle records the step that observed it
    assert meta["n_spans"] > 0 and meta["extra"]["step"] >= 3
    spans = json.load(open(os.path.join(bundle, "spans.json")))
    names = {e["name"] for e in spans["traceEvents"]}
    assert "fault_injected" in names and "train_step" in names
    for fname in ("metrics.json", "events.json"):
        json.load(open(os.path.join(bundle, fname)))  # valid JSON, present


def test_supervisor_rollback_bundle_and_recovery_span(tmp_path):
    cfg = _obs_cfg(tmp_path)
    rcfg = ResilienceConfig(rollback_after=2, escalate_steps=2)
    plan = FaultPlan(faults=(FaultSpec(step=6, kind="nonfinite"),
                             FaultSpec(step=7, kind="nonfinite")))
    tcfg = TrainerConfig(steps=12, log_every=4,
                         ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=3)
    rt = Runtime(execution=ExecutionConfig(resilience=rcfg, obs=cfg))
    sup = Supervisor(rt, _cfg(), _opt(), tcfg, fault_plan=plan)
    state, _ = sup.run(_data())
    assert int(np.asarray(state.step)) == 12
    assert sup.recoveries == 1
    # recovery counters live in the unified registry (adopted component)
    snap = rt.observability().metrics_snapshot()
    assert snap["resilience.recoveries"] == 1.0
    assert snap["resilience.events"] >= 1.0
    # the rollback crash bundle + the recovery span
    bundle = os.path.join(cfg.crash_dir, "crash_000_rollback")
    meta = json.load(open(os.path.join(bundle, "meta.json")))
    assert meta["extra"]["cause"] == "nonfinite_or_norm"
    events = json.load(open(os.path.join(bundle, "events.json")))
    assert any(e.get("event") == "fault_injected" for e in events)
    [rec] = rt.observability().tracer.spans("recovery.rollback")
    assert rec.attrs["step"] == 7 and rec.duration_s > 0


def test_trainer_exports_configured_traces(tmp_path):
    chrome = str(tmp_path / "trace.json")
    jsonl = str(tmp_path / "spans.jsonl")
    cfg = _obs_cfg(tmp_path, chrome_trace=chrome, trace_jsonl=jsonl)
    rt = Runtime(execution=ExecutionConfig(obs=cfg))
    train_loop(rt, _cfg(), _opt(), _data(), TrainerConfig(steps=4))
    doc = json.load(open(chrome))
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"train_loop", "train_step", "jit_trace", "xla_compile"} <= names
    steps = [e for e in doc["traceEvents"] if e["name"] == "train_step"]
    assert sorted(e["args"]["step"] for e in steps) == [0, 1, 2, 3]
    recs = [json.loads(l) for l in open(jsonl) if l.strip()]
    assert {r["name"] for r in recs} == names


def test_observability_never_changes_numerics(tmp_path):
    """obs=None vs the full ObsConfig: bit-identical final state (spans,
    registries and ledgers are host-side — the computation is untouched)."""
    tcfg = TrainerConfig(steps=6, log_every=3, seed=0)
    off, _ = train_loop(Runtime(execution=ExecutionConfig(obs=None)),
                        _cfg(), _opt(), _data(), tcfg)
    on, _ = train_loop(
        Runtime(execution=ExecutionConfig(obs=_obs_cfg(tmp_path))),
        _cfg(), _opt(), _data(), tcfg)
    for a, b in zip(_leaves(off), _leaves(on)):
        np.testing.assert_array_equal(a, b)
