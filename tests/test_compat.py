"""Guards against JAX API-drift reintroductions.

The seed repo shipped with its whole distributed suite dead because one
module referenced ``jax.sharding.AxisType`` (absent on the installed JAX).
These tests pin the two invariants that prevent a recurrence:

  1. ``repro.compat`` + ``repro.launch.mesh`` import and build meshes on the
     *installed* JAX — whatever its version;
  2. no module outside ``repro/compat.py`` touches a version-gated JAX
     symbol directly.

The second family used to be regex greps living here; they are now thin
wrappers over the AST lint engine (``repro.analysis.lint``), which resolves
import aliases (``from jax.experimental import shard_map as sm`` no longer
slips through) and does not false-positive on docstring prose. The
allowlists live on the rules themselves in ``repro/analysis/rules.py``.
"""
import os

import jax
import numpy as np
import pytest

from repro.analysis import run_lint

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _lint(rule_id):
    return run_lint([SRC], select=[rule_id])


def test_no_version_gated_jax_symbols_outside_compat():
    result = _lint("jax-version-gated")
    offenders = [str(f) for f in result.findings] + [str(f) for f in result.waived]
    assert not offenders, (
        "version-gated JAX symbols outside repro/compat.py:\n" + "\n".join(offenders))


def test_no_custom_vjp_spines_outside_core_site():
    """Exactly ONE sketched-site ``custom_vjp`` spine exists: the local and
    TP execution plans all route through ``core/site.py``. Any new
    ``jax.custom_vjp`` in ``src/`` is a second spine in the making — the
    exact duplication (sketched_linear + the three sharded_sketch builds)
    this repo just collapsed — unless explicitly allowlisted on the rule.

    Allowlist (see CustomVjpRule): core/site.py (THE spine);
    launch/pipeline.py (the pipeline-parallel stage-boundary vjp — not a
    sketched site). The serve/ and kernels/ trees currently define none; a
    Pallas kernel or decode path that genuinely needs its own vjp must be
    added there explicitly, with a comment.

    Inline ``# lint: waive=`` comments are also treated as offenders here:
    a second spine cannot be self-waived at the call site."""
    result = _lint("custom-vjp-outside-site")
    offenders = [str(f) for f in result.findings] + [str(f) for f in result.waived]
    assert not offenders, (
        "new custom_vjp spine outside core/site.py — route the site through "
        "the one spine (SiteSpec/ExecutionPlan) or extend the allowlist "
        "explicitly:\n" + "\n".join(offenders))


def test_no_ctx_construction_outside_api_and_nn():
    """The Runtime front door owns Ctx construction: outside ``repro/nn``
    (where Ctx lives and re-derives per-layer children) and ``repro/api``
    (whose ExecutionConfig.make_ctx is the sanctioned factory), no module may
    build a ``Ctx(...)`` directly — that is how train() kwargs smeared across
    the codebase in the first place. Use ``Runtime.ctx`` /
    ``ExecutionConfig.make_ctx`` instead."""
    result = _lint("ctx-outside-api-nn")
    offenders = [str(f) for f in result.findings] + [str(f) for f in result.waived]
    assert not offenders, (
        "direct Ctx(...) construction outside repro/api + repro/nn "
        "(route through ExecutionConfig.make_ctx / Runtime.ctx):\n"
        + "\n".join(offenders))


def test_compat_and_mesh_import_and_build_2x2():
    """The exact seed failure mode: mesh construction on the installed JAX."""
    from repro import compat
    from repro.launch import mesh as meshlib

    if jax.device_count() < 4:
        pytest.skip("needs >=4 (fake) devices")
    m = meshlib.make_mesh((2, 2), ("data", "model"))
    assert m.axis_names == ("data", "model")
    assert dict(m.shape) == {"data": 2, "model": 2}
    assert meshlib.dp_axes(m) == ("data",)
    assert meshlib.mp_axes(m) == ("model",)
    # compat.make_mesh is the same construction path
    m2 = compat.make_mesh((2, 2), ("data", "model"))
    assert m2.axis_names == m.axis_names

    # meshes are usable: a trivial sharded reduction runs on the installed JAX
    from jax.sharding import NamedSharding, PartitionSpec as P

    x = np.arange(8, dtype=np.float32).reshape(4, 2)
    y = jax.jit(lambda a: a.sum(),
                in_shardings=(NamedSharding(m, P("data", "model")),))(x)
    assert float(y) == x.sum()


def test_compat_shard_map_runs():
    from repro import compat

    if jax.device_count() < 4:
        pytest.skip("needs >=4 (fake) devices")
    from jax.sharding import PartitionSpec as P

    m = compat.make_mesh((4,), ("data",))
    x = np.arange(16, dtype=np.float32).reshape(4, 4)

    def body(x_l):
        return jax.lax.psum(x_l.sum(), "data")

    out = compat.shard_map(body, mesh=m, in_specs=(P("data", None),),
                           out_specs=P())(x)
    assert float(out) == x.sum()


def test_compat_tree_and_key_helpers():
    from repro import compat

    t = {"a": np.ones(2), "b": [np.zeros(1)]}
    leaves = compat.tree_leaves(t)
    assert len(leaves) == 2
    flat, treedef = compat.tree_flatten(t)
    back = compat.tree_unflatten(treedef, flat)
    assert compat.tree_structure(back) == treedef
    doubled = compat.tree_map(lambda x: x * 2, t)
    np.testing.assert_array_equal(doubled["a"], np.full(2, 2.0))

    k = compat.prng_key(0)
    assert jax.random.bits(jax.random.fold_in(k, 1), (2,)).shape == (2,)
    assert compat.key_dtype() == k.dtype
