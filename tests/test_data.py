"""Data pipeline: determinism, stream structure, prefetch."""
import numpy as np

from repro.data.pipeline import prefetch
from repro.data.synthetic import LMStream, classification


def test_lm_stream_deterministic():
    a = next(LMStream(vocab=64, seed=3).batches(2, 16))
    b = next(LMStream(vocab=64, seed=3).batches(2, 16))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_lm_stream_resume_midstream():
    it = LMStream(vocab=64, seed=3).batches(2, 16)
    next(it)
    second = next(it)
    resumed = next(LMStream(vocab=64, seed=3).batches(2, 16, start_step=1))
    np.testing.assert_array_equal(second["tokens"], resumed["tokens"])


def test_lm_stream_bigram_structure():
    s = LMStream(vocab=64, seed=0)
    b = next(s.batches(8, 128, p_bigram=0.9))
    follows = (s._succ[b["tokens"]] == b["labels"]).mean()
    assert follows > 0.8  # planted bigram is learnable signal


def test_labels_are_next_tokens():
    b = next(LMStream(vocab=64, seed=1).batches(2, 32))
    assert b["tokens"].shape == b["labels"].shape == (2, 32)


def test_classification_shared_means_across_splits():
    xtr, ytr = classification(512, 32, 4, seed=0)
    xte, yte = classification(512, 32, 4, seed=9)
    mu_tr = np.stack([xtr[ytr == c].mean(0) for c in range(4)])
    mu_te = np.stack([xte[yte == c].mean(0) for c in range(4)])
    # same class means up to sampling noise
    assert np.abs(mu_tr - mu_te).mean() < 0.2


def test_prefetch_preserves_order_and_count():
    items = [{"i": np.asarray([k])} for k in range(7)]
    out = list(prefetch(iter(items), size=3))
    assert [int(o["i"][0]) for o in out] == list(range(7))
