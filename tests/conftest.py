import os
import sys

# repo-root imports (benchmarks package) in addition to PYTHONPATH=src
ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, ROOT)
sys.path.insert(0, os.path.join(ROOT, "src"))

# The distributed tests run IN-PROCESS on fake host devices, so the device
# count must be forced before the JAX backend initializes — i.e. before any
# test module (or conftest) triggers a computation. pyproject.toml documents
# this; pytest has no built-in env mechanism, so the suite-wide setting lives
# here, ahead of the first jax import.
from repro import compat  # noqa: E402

compat.ensure_host_devices(8)
# persistent XLA compilation cache: warm suite reruns skip recompiles of
# unchanged programs. No-op on releases without it AND on the blacklisted
# jax 0.4.37 CPU, where reloaded executables corrupt donated buffers (see
# compat.enable_compilation_cache) — the call stays so other releases keep
# their warm reruns.
compat.enable_compilation_cache()

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def key():
    return compat.prng_key(0)


def pytest_sessionfinish(session, exitstatus):
    """Opt-in compile-cost report for the suite itself: run with
    ``REPRO_COMPILE_LEDGER=1`` and every Runtime.train_step compile the
    tests trigger is tallied into ``results/compile_ledger.json``
    (trace/compile wall seconds + hit/miss per executable key)."""
    from repro.obs import ledgers

    if not ledgers.global_active():
        return
    out = os.path.join(ROOT, "results", "compile_ledger.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    ledgers.GLOBAL_COMPILE_LEDGER.write(out)
    summ = ledgers.GLOBAL_COMPILE_LEDGER.summary()
    print(f"\n[obs] compile ledger -> {out}: {summ['compiles']} compile(s), "
          f"{summ['hits']} hit(s), {summ['total_compile_s']:.1f}s compiling")
