import os
import sys

# repo-root imports (benchmarks package) in addition to PYTHONPATH=src
ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, ROOT)
sys.path.insert(0, os.path.join(ROOT, "src"))

# The distributed tests run IN-PROCESS on fake host devices, so the device
# count must be forced before the JAX backend initializes — i.e. before any
# test module (or conftest) triggers a computation. pyproject.toml documents
# this; pytest has no built-in env mechanism, so the suite-wide setting lives
# here, ahead of the first jax import.
from repro import compat  # noqa: E402

compat.ensure_host_devices(8)
# persistent XLA compilation cache: warm suite reruns skip recompiles of
# unchanged programs (feature-detected no-op on releases without it)
compat.enable_compilation_cache()

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def key():
    return compat.prng_key(0)
