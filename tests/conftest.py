import os
import sys

# repo-root imports (benchmarks package) in addition to PYTHONPATH=src
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import pytest


@pytest.fixture
def key():
    return jax.random.key(0)
