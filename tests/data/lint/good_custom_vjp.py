"""Fixture: mentions jax.custom_vjp in prose only, and calls a non-jax
function that happens to be named custom_vjp — neither is a finding."""
from repro.core import site


def use(f):
    return site.custom_vjp_like_helper(f)
