"""Fixture: prose mentions of shard_map and AxisType in a docstring are not
findings, and routing through the shard_map compact path in repro.compat is
the sanctioned spelling."""
from repro import compat


def build():
    # "the shard_map compact path" — comment prose, also not a finding
    mesh = compat.make_mesh((2,), ("data",))
    return compat.shard_map, mesh
