"""Fixture: the same key consumed twice (prng-key-reuse)."""
import jax
import jax.random as jrandom


def sample(key):
    a = jax.random.normal(key, (2,))
    b = jrandom.uniform(key, (2,))
    return a + b
