"""Fixture: the sanctioned clock — and time.sleep, which is not a clock
read (prose like "time.perf_counter" in a docstring is not a finding)."""
import time

from repro.obs import clock


def measure(fn):
    t0 = clock.now()
    fn()
    time.sleep(0.0)  # pacing, not timing — allowed
    return clock.now() - t0, clock.wall()
