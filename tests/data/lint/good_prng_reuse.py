"""Fixture: split / fold_in / rebinding / exclusive branches — no reuse."""
import jax


def sample(key, flag):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (2,))
    if flag:
        b = jax.random.uniform(k2, (2,))
    else:
        b = jax.random.normal(k2, (2,))
    key = jax.random.fold_in(key, 1)
    c = jax.random.normal(key, (2,))
    return a + b + c


def loop(key):
    out = []
    for i in range(3):
        key = jax.random.fold_in(key, i)
        out.append(jax.random.normal(key, (2,)))
    return out
