"""Bad: thread targets whose exceptions die with the daemon thread."""
import threading


def worker(q):
    q.put(1)


def spawn(q):
    t = threading.Thread(target=worker, args=(q,), daemon=True)
    t.start()
    return t


def spawn_lambda(q):
    t = threading.Thread(target=lambda: q.put(1))
    t.start()
    return t
