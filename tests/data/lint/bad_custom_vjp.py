"""Fixture: a second custom_vjp spine (custom-vjp-outside-site)."""
from jax import custom_vjp as cv
import jax


def make(f):
    g = jax.custom_vjp(f)
    return cv, g
