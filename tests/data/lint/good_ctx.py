"""Fixture: sanctioned Ctx access — factory methods, not the constructor."""


def make(rt, execution, key):
    a = rt.ctx(key)
    b = execution.make_ctx(key)
    return a, b
