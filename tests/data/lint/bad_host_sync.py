"""Fixture: host syncs inside jitted step functions (host-sync-in-jit)."""
from functools import partial

import jax
import numpy as np


@jax.jit
def step(x):
    loss = x.sum()
    scalar = float(loss)
    host = np.asarray(x)
    return scalar, host, loss.item()


@partial(jax.jit, donate_argnums=(0,))
def step2(x):
    return x.tolist()
