"""Fixture: static checks (`is None`, .ndim, .shape) branch fine under jit."""
import jax


@jax.jit
def step(x, y=None):
    if y is None:
        y = x
    if x.ndim == 2:
        y = y.sum(axis=0)
    if isinstance(y, tuple):
        y = y[0]
    return y
