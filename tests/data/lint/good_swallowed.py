"""Good: narrow handlers, and broad ones that re-raise or record."""


def read_cache(path):
    try:
        with open(path) as f:
            return f.read()
    except OSError:
        pass  # narrow: the one expected failure; absence IS the answer


def guarded(fn, log):
    try:
        return fn()
    except Exception as e:
        log.append(e)
        raise


def fallback(fn):
    try:
        return fn()
    except Exception:
        return None
