"""Fixture: an inline waiver suppresses (but records) the finding."""
import jax


def make(f):
    return jax.custom_vjp(f)  # lint: waive=custom-vjp-outside-site
