"""Good: error-capturing thread targets and Thread subclasses."""
import threading


def compute():
    return 42


def spawn(q):
    def worker():
        try:
            q.put(compute())
        except BaseException as e:  # forwarded; the consumer re-raises
            q.put(e)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    return t


class Writer(threading.Thread):
    """Subclass style: run() captures, join-side re-raises via .error."""

    def __init__(self, job):
        super().__init__(daemon=True)
        self.job = job
        self.error = None

    def run(self):
        try:
            self.job()
        except BaseException as e:
            self.error = e
