"""Fixture: constants and static metadata inside jit, host ops outside."""
import jax
import numpy as np


@jax.jit
def step(x):
    scale = float(2)
    width = float(x.shape[0])
    return x * scale * width


def outside(x):
    return float(x.sum()), np.asarray(x), x.item()
