"""Fixture: version-gated JAX surfaces used directly (jax-version-gated)."""
from jax.experimental import shard_map as sm
import jax


def build(devices):
    mesh = jax.make_mesh((2,), ("data",))
    axis_kind = jax.sharding.AxisType
    mapped = sm
    m2 = jax.sharding.Mesh(devices, ("data",), axis_types=(axis_kind,))
    return mesh, mapped, m2, jax.lax.optimization_barrier
