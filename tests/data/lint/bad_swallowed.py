"""Bad: broad handlers that make failures vanish."""


def read_cache(path):
    try:
        with open(path) as f:
            return f.read()
    except Exception:
        pass


def poll(q):
    while True:
        try:
            return q.get_nowait()
        except:  # noqa: E722
            continue
