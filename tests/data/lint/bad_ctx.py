"""Fixture: direct Ctx construction outside api/nn (ctx-outside-api-nn)."""
from repro.nn.blocks import Ctx
from repro.nn import blocks


def make(key):
    a = Ctx(key=key)
    b = blocks.Ctx(key=key)
    return a, b
