"""Fixture: wall-clock reads outside repro/obs (wall-clock-outside-obs)."""
from time import perf_counter
import time


def measure(fn):
    t0 = time.perf_counter()
    fn()
    started_at = time.time()
    return perf_counter() - t0, started_at
