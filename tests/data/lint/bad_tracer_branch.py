"""Fixture: Python control flow on traced values (tracer-branch)."""
import jax


@jax.jit
def step(x, y):
    if x.sum() > 0:
        y = y + 1
    while y > 0:
        y = y - 1
    return y
