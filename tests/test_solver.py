"""Water-filling solver (Alg. 1) + correlated exact-r sampler (Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solver


def brute_force_probs(w, r, iters=20000):
    """Bisection on sqrt(lambda) for min Σ w/p s.t. Σp=r, p∈(0,1]."""
    t = np.sqrt(np.maximum(w, 1e-30))
    lo, hi = 1e-12, t.max() * len(w)
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        s = np.minimum(1.0, t / mid).sum()
        if s > r:
            lo = mid
        else:
            hi = mid
    return np.minimum(1.0, t / hi)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,r", [(32, 4), (100, 20), (64, 63)])
def test_waterfilling_matches_bruteforce(seed, n, r):
    w = np.random.default_rng(seed).uniform(size=n) ** 3
    p = np.asarray(solver.optimal_probabilities(jnp.asarray(w), r))
    p_ref = brute_force_probs(w, r)
    assert abs(p.sum() - r) < 1e-3
    obj = (w / np.maximum(p, 1e-12)).sum()
    obj_ref = (w / np.maximum(p_ref, 1e-12)).sum()
    assert obj <= obj_ref * (1 + 1e-3)


def test_waterfilling_kkt_structure():
    w = np.array([100.0, 50.0, 1.0, 0.5, 0.1, 0.01])
    p = np.asarray(solver.optimal_probabilities(jnp.asarray(w), 3))
    # saturated large entries, p ∝ sqrt(w) below threshold
    assert p[0] == pytest.approx(1.0, abs=1e-5)
    unsat = p < 1.0 - 1e-6
    ratio = p[unsat] / np.sqrt(w[unsat])
    assert np.allclose(ratio, ratio[0], rtol=1e-3)


def test_waterfilling_full_budget():
    p = solver.optimal_probabilities(jnp.ones(8), 8)
    assert np.allclose(np.asarray(p), 1.0)


def test_waterfilling_zero_weights_uniform():
    p = np.asarray(solver.optimal_probabilities(jnp.zeros(10), 4))
    assert p.sum() == pytest.approx(4.0, abs=1e-3)


def test_sampler_exact_count_and_distinct(key):
    w = jnp.asarray(np.random.default_rng(0).uniform(size=50) ** 2)
    p = solver.optimal_probabilities(w, 12)
    for i in range(20):
        idx = np.asarray(solver.sample_exact_r(jax.random.fold_in(key, i), p, 12))
        assert len(idx) == 12
        assert len(np.unique(idx)) == 12
        assert np.all(np.diff(idx) > 0)  # ascending


def test_sampler_marginals(key):
    n, r, n_mc = 24, 6, 4000
    w = jnp.asarray(np.random.default_rng(1).uniform(size=n) ** 2)
    p = solver.optimal_probabilities(w, r)
    counts = np.zeros(n)
    for i in range(n_mc):
        idx = np.asarray(solver.sample_exact_r(jax.random.fold_in(key, i), p, r))
        counts[idx] += 1
    emp = counts / n_mc
    se = np.sqrt(np.asarray(p) * (1 - np.asarray(p)) / n_mc) + 1e-4
    assert np.all(np.abs(emp - np.asarray(p)) < 6 * se)


def test_expected_distortion_decreases_with_budget():
    w = jnp.asarray(np.random.default_rng(2).uniform(size=40))
    d = [float(solver.expected_distortion(w, solver.optimal_probabilities(w, r)))
         for r in (4, 10, 20, 39)]
    assert all(a >= b - 1e-5 for a, b in zip(d, d[1:]))


def test_waterfilling_concentrated_weights_sum_exact():
    """Regression: concentrated weights used to leave sum(p) < r after a
    one-shot renormalise+clip, biasing the systematic sampler's marginals."""
    for seed in range(20):
        rng = np.random.default_rng(seed)
        w = rng.uniform(size=8) ** 6  # heavy concentration
        for r in (2, 4, 6):
            p = np.asarray(solver.optimal_probabilities(jnp.asarray(w), r))
            assert abs(p.sum() - r) < 1e-3, (seed, r, p.sum())
            assert p.max() <= 1.0 + 1e-6


def test_sampler_marginals_concentrated(key):
    n, r, n_mc = 8, 4, 8000
    w = jnp.asarray(np.random.default_rng(7).uniform(size=n) ** 6)
    p = solver.optimal_probabilities(w, r)
    counts = np.zeros(n)
    for i in range(n_mc):
        idx = np.asarray(solver.sample_exact_r(jax.random.fold_in(key, i), p, r))
        counts[idx] += 1
    emp = counts / n_mc
    se = np.sqrt(np.asarray(p) * (1 - np.asarray(p)) / n_mc) + 1e-4
    assert np.all(np.abs(emp - np.asarray(p)) < 6 * se), (emp, np.asarray(p))
