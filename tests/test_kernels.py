"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.col_scores import col_l1_scores
from repro.kernels.flash_attention import flash_attention
from repro.kernels.sketch_matmul import (block_gather_matmul, block_gather_matmul_dw,
                                         block_gather_matmul_fused,
                                         block_stream_matmul_fused)


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("N,n,d,rb,bs,dt", [
    (64, 512, 384, 2, 128, jnp.float32),
    (100, 256, 130, 1, 128, jnp.float32),
    (256, 1024, 512, 4, 128, jnp.bfloat16),
    (32, 256, 96, 2, 64, jnp.float32),
    (8, 128, 64, 1, 128, jnp.float32),
])
def test_block_gather_matmul(N, n, d, rb, bs, dt):
    ks = jax.random.split(jax.random.key(N * n + d), 4)
    G = jax.random.normal(ks[0], (N, n), dt)
    W = jax.random.normal(ks[1], (n, d), dt)
    X = jax.random.normal(ks[2], (N, d), dt)
    nb = n // bs
    idx = jnp.sort(jax.random.choice(ks[3], nb, (rb,), replace=False)).astype(jnp.int32)
    sc = jax.random.uniform(ks[3], (rb,), minval=0.5, maxval=2.0)
    got = block_gather_matmul(G, idx, sc, W, block=bs, interpret=True)
    want = ref.block_gather_matmul_ref(G, idx, sc, W, block=bs)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               rtol=_tol(dt), atol=_tol(dt))
    got2 = block_gather_matmul_dw(G, idx, sc, X, block=bs, interpret=True)
    want2 = ref.block_gather_matmul_dw_ref(G, idx, sc, X, block=bs)
    np.testing.assert_allclose(np.asarray(got2, np.float32), np.asarray(want2, np.float32),
                               rtol=_tol(dt), atol=_tol(dt))


@pytest.mark.parametrize("N,n,d,rb,bs,dt", [
    (64, 512, 384, 2, 128, jnp.float32),
    (100, 256, 130, 1, 128, jnp.float32),
    (256, 1024, 512, 4, 128, jnp.bfloat16),
    (32, 256, 96, 2, 64, jnp.float32),
    (8, 128, 64, 1, 128, jnp.float32),
])
def test_block_gather_matmul_fused(N, n, d, rb, bs, dt):
    """Fused one-pass kernel: BIT-identical to the unfused pair for the same
    plan (same tiles, same accumulation order), allclose to the jnp oracle."""
    ks = jax.random.split(jax.random.key(N * n + d), 4)
    G = jax.random.normal(ks[0], (N, n), dt)
    W = jax.random.normal(ks[1], (n, d), dt)
    X = jax.random.normal(ks[2], (N, d), dt)
    nb = n // bs
    idx = jnp.sort(jax.random.choice(ks[3], nb, (rb,), replace=False)).astype(jnp.int32)
    sc = jax.random.uniform(ks[3], (rb,), minval=0.5, maxval=2.0)

    dX, dWc, db = block_gather_matmul_fused(G, idx, sc, W, X, block=bs, interpret=True)
    dX_u = block_gather_matmul(G, idx, sc, W, block=bs, interpret=True)
    dW_u = block_gather_matmul_dw(G, idx, sc, X, block=bs, interpret=True)
    np.testing.assert_array_equal(np.asarray(dX, np.float32), np.asarray(dX_u, np.float32))
    np.testing.assert_array_equal(np.asarray(dWc, np.float32), np.asarray(dW_u, np.float32))

    rdX, rdW, rdb = ref.block_gather_matmul_fused_ref(G, idx, sc, W, X, block=bs)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(dX, np.float32), np.asarray(rdX, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(dWc, np.float32), np.asarray(rdW, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(db), np.asarray(rdb), rtol=tol, atol=tol * 10)


def test_fused_ref_matches_manual():
    """The fused oracle's three outputs equal the independent formulas."""
    ks = jax.random.split(jax.random.key(7), 4)
    N, n, d, bs = 24, 64, 40, 16
    G = jax.random.normal(ks[0], (N, n))
    W = jax.random.normal(ks[1], (n, d))
    X = jax.random.normal(ks[2], (N, d))
    idx = jnp.asarray([0, 2], jnp.int32)
    sc = jnp.asarray([1.5, 0.5], jnp.float32)
    dX, dWc, db = ref.block_gather_matmul_fused_ref(G, idx, sc, W, X, block=bs)
    np.testing.assert_allclose(
        np.asarray(dX), np.asarray(ref.block_gather_matmul_ref(G, idx, sc, W, block=bs)),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(dWc), np.asarray(ref.block_gather_matmul_dw_ref(G, idx, sc, X, block=bs)),
        rtol=1e-5, atol=1e-5)
    cols = (idx[:, None] * bs + jnp.arange(bs)).reshape(-1)
    want_db = (jnp.take(G, cols, axis=1) * jnp.repeat(sc, bs)[None, :]).sum(0)
    np.testing.assert_allclose(np.asarray(db).reshape(-1), np.asarray(want_db),
                               rtol=1e-5, atol=1e-5)


def test_dw_db_ref_matches_fused_halves():
    """The VMEM-fallback's shared-gather dW/db oracle equals the dW/db halves
    of the fused oracle (ops.block_gather_matmul_fused composes it with the
    dX kernel when the fused accumulators overflow VMEM on TPU)."""
    ks = jax.random.split(jax.random.key(11), 3)
    N, n, d, bs = 32, 96, 24, 16
    G = jax.random.normal(ks[0], (N, n))
    W = jax.random.normal(ks[1], (n, d))
    X = jax.random.normal(ks[2], (N, d))
    idx = jnp.asarray([1, 4, 5], jnp.int32)
    sc = jnp.asarray([2.0, 0.5, 1.25], jnp.float32)
    dWc, db = ref.block_gather_matmul_dw_db_ref(G, idx, sc, X, block=bs)
    _, want_dw, want_db = ref.block_gather_matmul_fused_ref(G, idx, sc, W, X,
                                                            block=bs)
    np.testing.assert_allclose(np.asarray(dWc), np.asarray(want_dw),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(db), np.asarray(want_db),
                               rtol=1e-5, atol=1e-5)
    assert dWc.shape == (3, bs, d) and db.shape == (3, bs)


@pytest.mark.parametrize("N,n,d,rb,bs,dt", [
    (64, 512, 384, 2, 128, jnp.float32),
    (32, 256, 96, 2, 64, jnp.float32),
    (256, 1024, 512, 4, 128, jnp.bfloat16),
])
def test_stream_kernel_bit_identical_to_fused(N, n, d, rb, bs, dt):
    """Streaming selection (one pass over ALL of G) is BIT-identical to the
    kept-only fused kernel on dX/dWc/db for the same keep decisions: kept
    blocks accumulate in the same order with the same operands, and dropped
    blocks only touch the score reduction. Fresh scores match numpy."""
    ks = jax.random.split(jax.random.key(N * n + d + 1), 4)
    G = jax.random.normal(ks[0], (N, n), dt)
    W = jax.random.normal(ks[1], (n, d), dt)
    X = jax.random.normal(ks[2], (N, d), dt)
    nb = n // bs
    idx = jnp.sort(jax.random.choice(ks[3], nb, (rb,), replace=False)).astype(jnp.int32)
    sc = jax.random.uniform(ks[3], (rb,), minval=0.5, maxval=2.0)
    gates = jnp.zeros((nb,), jnp.float32).at[idx].set(sc.astype(jnp.float32))
    slot_map = jnp.zeros((nb,), jnp.int32).at[idx].set(jnp.arange(rb, dtype=jnp.int32))

    dX_s, dWc_s, db_s, scores = block_stream_matmul_fused(
        G, gates, slot_map, W, X, rb=rb, block=bs, interpret=True)
    dX_f, dWc_f, db_f = block_gather_matmul_fused(G, idx, sc, W, X, block=bs,
                                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(dX_s, np.float32), np.asarray(dX_f, np.float32))
    np.testing.assert_array_equal(np.asarray(dWc_s, np.float32), np.asarray(dWc_f, np.float32))
    np.testing.assert_array_equal(np.asarray(db_s), np.asarray(db_f))

    want_s = np.abs(np.asarray(G, np.float32)).sum(0)
    tol = 2e-2 if dt == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(scores), want_s, rtol=tol, atol=tol)


def test_fused_with_scores_outputs_unchanged():
    """with_scores=True is a free rider: the three gradient outputs are
    byte-identical with the flag on or off (Pallas kernel AND oracle), and
    the appended kept-block scores equal the raw column reduction."""
    ks = jax.random.split(jax.random.key(23), 4)
    N, n, d, bs, rb = 32, 256, 96, 64, 2
    G = jax.random.normal(ks[0], (N, n))
    W = jax.random.normal(ks[1], (n, d))
    X = jax.random.normal(ks[2], (N, d))
    idx = jnp.asarray([1, 3], jnp.int32)
    sc = jnp.asarray([1.5, 0.75], jnp.float32)
    base = block_gather_matmul_fused(G, idx, sc, W, X, block=bs, interpret=True)
    plus = block_gather_matmul_fused(G, idx, sc, W, X, block=bs, interpret=True,
                                     with_scores=True)
    for a, b in zip(base, plus[:3]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    cols = (np.asarray(idx)[:, None] * bs + np.arange(bs)).reshape(-1)
    want = np.abs(np.asarray(G, np.float32))[:, cols].sum(0).reshape(rb, bs)
    np.testing.assert_allclose(np.asarray(plus[3]), want, rtol=1e-4, atol=1e-4)

    rbase = ref.block_gather_matmul_fused_ref(G, idx, sc, W, X, block=bs)
    rplus = ref.block_gather_matmul_fused_ref(G, idx, sc, W, X, block=bs,
                                              with_scores=True)
    for a, b in zip(rbase, rplus[:3]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(rplus[3]), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["l1", "l2"])
def test_onepass_ref_matches_fused_ref(mode):
    """The streaming one-pass XLA oracle produces the same gradients as the
    kept-only fused oracle for the same plan, plus full fresh scores equal to
    the direct column reduction."""
    ks = jax.random.split(jax.random.key(31), 4)
    N, n, d, bs = 24, 128, 40, 32
    G = jax.random.normal(ks[0], (N, n))
    W = jax.random.normal(ks[1], (n, d))
    X = jax.random.normal(ks[2], (N, d))
    idx = jnp.asarray([0, 3], jnp.int32)
    sc = jnp.asarray([2.0, 0.5], jnp.float32)
    dX, dWc, db, scores = ref.block_stream_matmul_onepass_ref(
        G, idx, sc, W, X, block=bs, score_mode=mode)
    rdX, rdW, rdb = ref.block_gather_matmul_fused_ref(G, idx, sc, W, X, block=bs)
    np.testing.assert_allclose(np.asarray(dX), np.asarray(rdX), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dWc), np.asarray(rdW), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(db), np.asarray(rdb), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(scores),
                               np.asarray(ref.col_scores_ref(G, mode=mode)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["l1", "l2"])
def test_col_scores_fp32_accumulation_property(mode):
    """The fp32-accumulation promise in col_scores.py as a tested property:
    at N = 10^5 rows the fp32 tree reduction of |G| / G² matches a float64
    reference to ~1e-6 relative — naive fp16/bf16 accumulation would be off
    by orders of magnitude more."""
    rng = np.random.default_rng(0)
    N, n = 100_000, 8
    G64 = rng.standard_normal((N, n))
    G = jnp.asarray(G64, jnp.float32)
    got = np.asarray(col_l1_scores(G, mode=mode, interpret=True), np.float64)
    red = np.abs if mode == "l1" else np.square
    want = red(np.asarray(G, np.float64)).sum(0)  # f64 over the f32 values
    # fp32 sequential tile accumulation: ~sqrt(steps)*eps relative; bf16
    # accumulation would sit at ~1e-2 and fail this by three decades.
    np.testing.assert_allclose(got, want, rtol=2e-5)


def test_ops_fused_vmem_limit_resolution(monkeypatch):
    """fused_vmem_limit(): configure() override > REPRO_FUSED_VMEM_LIMIT env
    > built-in default; invalid values raise; dispatch decisions land in the
    bound metrics registry."""
    from repro.kernels import ops
    from repro.obs.metrics import MetricsRegistry

    monkeypatch.setattr(ops, "_VMEM_LIMIT_OVERRIDE", None)
    monkeypatch.setattr(ops, "_METRICS", None)
    monkeypatch.delenv("REPRO_FUSED_VMEM_LIMIT", raising=False)
    assert ops.fused_vmem_limit() == ops._FUSED_VMEM_LIMIT

    monkeypatch.setenv("REPRO_FUSED_VMEM_LIMIT", str(7 * 2 ** 20))
    assert ops.fused_vmem_limit() == 7 * 2 ** 20
    monkeypatch.setenv("REPRO_FUSED_VMEM_LIMIT", "not-a-number")
    with pytest.raises(ValueError):
        ops.fused_vmem_limit()
    monkeypatch.setenv("REPRO_FUSED_VMEM_LIMIT", str(7 * 2 ** 20))

    reg = MetricsRegistry()
    ops.configure(vmem_limit=5 * 2 ** 20, metrics=reg)
    assert ops.fused_vmem_limit() == 5 * 2 ** 20  # override beats env
    assert reg.gauge("kernels.fused_vmem_limit").value == 5 * 2 ** 20
    with pytest.raises(ValueError):
        ops.configure(vmem_limit=0)

    ks = jax.random.split(jax.random.key(3), 3)
    N, n, d, bs = 16, 128, 32, 64
    G = jax.random.normal(ks[0], (N, n))
    W = jax.random.normal(ks[1], (n, d))
    X = jax.random.normal(ks[2], (N, d))
    idx = jnp.asarray([1], jnp.int32)
    sc = jnp.asarray([2.0], jnp.float32)
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    ops.block_gather_matmul_fused(G, idx, sc, W, X, block=bs)
    ops.block_stream_matmul_fused(G, idx, sc, W, X, block=bs)
    assert reg.counter("kernels.fused.dispatch").value == 1
    assert reg.counter("kernels.stream.dispatch").value == 1


@pytest.mark.parametrize("N,n,dt,mode", [
    (300, 700, jnp.float32, "l1"), (64, 128, jnp.bfloat16, "l1"),
    (128, 384, jnp.float32, "l2"), (17, 130, jnp.float32, "l1"),
])
def test_col_scores(N, n, dt, mode):
    G = jax.random.normal(jax.random.key(N + n), (N, n), dt)
    got = col_l1_scores(G, mode=mode, interpret=True)
    if mode == "l1":
        want = ref.col_l1_scores_ref(G)
    else:
        want = jnp.sum(jnp.square(G.astype(jnp.float32)), axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2 if dt == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("B,Sq,Skv,H,Kv,dh,causal,window,dt", [
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 96, 96, 4, 4, 64, True, 32, jnp.float32),
    (2, 64, 192, 4, 1, 128, True, None, jnp.float32),
    (1, 128, 128, 2, 2, 64, False, None, jnp.float32),
    (1, 128, 128, 4, 2, 64, True, None, jnp.bfloat16),
    (1, 100, 100, 2, 2, 64, True, None, jnp.float32),  # ragged
])
def test_flash_attention(B, Sq, Skv, H, Kv, dh, causal, window, dt):
    ks = jax.random.split(jax.random.key(B * Sq + H), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), dt)
    k = jax.random.normal(ks[1], (B, Skv, Kv, dh), dt)
    v = jax.random.normal(ks[2], (B, Skv, Kv, dh), dt)
    got = flash_attention(q, k, v, causal=causal, window=window, interpret=True,
                          tile_q=64, tile_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
