"""Resilience subsystem tests: fault injection, sentinel, supervisor recovery.

The acceptance drill from the issue runs here in tier-1, deterministically,
on fake devices: a seeded :class:`~repro.resilience.FaultPlan` covering
non-finite gradients, a loss spike, a checkpoint IO error and (in the
elastic test) a device loss is driven through the §5 MLP; the supervisor
must recover from every fault, land within tolerance of the fault-free run,
and record every recovery event through the telemetry sinks. The
bit-identical contract — sentinel on, no faults == sentinel off, bit for
bit — is asserted directly on the final parameter bytes.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro import compat
from repro.api import (ExecutionConfig, Runtime, SketchConfig, SketchPolicy,
                      TelemetryConfig)
from repro.data.synthetic import ClassStream
from repro.models.mlp import mlp_arch
from repro.optim import adamw, constant
from repro.resilience import (DeviceLossFault, FaultInjector, FaultPlan,
                              FaultSpec, GradSentinel, ResilienceConfig)
from repro.resilience import Supervisor
from repro.train.trainer import TrainerConfig, train_loop

SIZES = (32, 16, 16, 4)


def _cfg():
    return mlp_arch(SIZES)


def _opt():
    return adamw(constant(1e-2), clip=1.0)


def _data(batch=16, seed=0):
    return ClassStream(dim=SIZES[0], n_classes=SIZES[-1], seed=seed).batches(batch)


def _runtime(resilience=None, policy="l1", telemetry=None):
    pol = (SketchPolicy(base=SketchConfig(method="l1", budget=0.5))
           if policy == "l1" else None)
    return Runtime(policy=pol, execution=ExecutionConfig(
        resilience=resilience, telemetry=telemetry))


def _leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves(
        {"p": state.params, "o": state.opt_state})]


# ---------------------------------------------------------------------------
# config + plan plumbing
# ---------------------------------------------------------------------------


def test_resilience_config_validation():
    with pytest.raises(ValueError):
        ResilienceConfig(max_grad_norm=0.0)
    with pytest.raises(ValueError):
        ResilienceConfig(spike_factor=1.0)
    with pytest.raises(ValueError):
        ResilienceConfig(ema_decay=1.5)
    with pytest.raises(ValueError):
        ExecutionConfig(resilience="not-a-config")


def test_fault_plan_validation_and_determinism():
    with pytest.raises(ValueError):
        FaultSpec(step=1, kind="meteor")
    with pytest.raises(ValueError):
        FaultSpec(step=1, kind="device_loss")  # needs mesh_shape
    with pytest.raises(ValueError):  # one fault per step
        FaultPlan(faults=(FaultSpec(step=2, kind="spike"),
                          FaultSpec(step=2, kind="nonfinite")))
    a = FaultPlan.random(seed=7, steps=50, n=4)
    b = FaultPlan.random(seed=7, steps=50, n=4)
    assert a == b
    assert len(a.faults) == 4


def test_fault_injector_fires_once():
    plan = FaultPlan(faults=(FaultSpec(step=3, kind="nonfinite"),))
    inj = FaultInjector(plan)
    assert inj.take(2) is None
    assert inj.take(3).kind == "nonfinite"
    assert inj.take(3) is None  # spent: a retried trajectory runs clean
    assert inj.pending == 0


def test_faults_kwarg_requires_resilience():
    with pytest.raises(ValueError, match="resilience"):
        train_loop(_runtime(None), _cfg(), _opt(), _data(),
                   TrainerConfig(steps=2),
                   faults=FaultPlan(faults=(FaultSpec(step=1, kind="spike"),)))


# ---------------------------------------------------------------------------
# sentinel: bit-identity + skip/escalate
# ---------------------------------------------------------------------------


def test_sentinel_untripped_is_bit_identical(tmp_path):
    tcfg = TrainerConfig(steps=6, log_every=2, seed=3)
    s_off, _ = train_loop(_runtime(None), _cfg(), _opt(), _data(), tcfg)
    s_on, hist = train_loop(_runtime(ResilienceConfig()), _cfg(), _opt(),
                            _data(), tcfg)
    for a, b in zip(_leaves(s_off), _leaves(s_on)):
        assert a.tobytes() == b.tobytes()  # bitwise, not approx
    assert all(m["sentinel_trip"] == 0.0 for m in hist)


def test_nonfinite_fault_skips_update_and_escalates():
    rcfg = ResilienceConfig(escalate_steps=3, rollback_after=0)
    plan = FaultPlan(faults=(FaultSpec(step=2, kind="nonfinite"),))
    budgets, events = [], []
    state, hist = train_loop(
        _runtime(rcfg), _cfg(), _opt(), _data(),
        TrainerConfig(steps=8, log_every=1), faults=plan,
        on_event=events.append,
        on_metrics=lambda m: budgets.append(m["budget"]))
    by_step = {m["step"]: m for m in hist}
    # the poisoned step reports the trip; params survived (loss stays finite)
    assert by_step[2]["sentinel_trip"] == 1.0
    assert np.isfinite(by_step[3]["loss"])
    # escalation window: exact (None) for the next escalate_steps steps
    assert [by_step[s]["budget"] for s in (3, 4, 5)] == [None, None, None]
    assert by_step[6]["budget"] == 1.0
    kinds = [e["event"] for e in events]
    assert kinds == ["fault_injected", "sentinel_trip"]
    assert events[1]["cause"] == "nonfinite_or_norm"
    # step counter still advanced through the skipped update
    assert int(np.asarray(state.step)) == 8


def test_spike_detection_via_host_ema():
    rcfg = ResilienceConfig(max_grad_norm=1e9, warmup_steps=2,
                            escalate_steps=2, rollback_after=0)
    sent = GradSentinel(rcfg)
    for step in range(5):
        assert sent.observe(step, {"loss": 1.0, "sentinel_trip": 0.0}) is None
    cause = sent.observe(5, {"loss": 50.0, "sentinel_trip": 0.0})
    assert cause == "loss_spike"
    assert sent.override(0.5) is None  # escalated to exact
    sent.observe(6, {"loss": 1.0, "sentinel_trip": 0.0})
    sent.observe(7, {"loss": 1.0, "sentinel_trip": 0.0})
    assert sent.override(0.5) == 0.5  # window closed


# ---------------------------------------------------------------------------
# checkpoint IO + rollback recovery
# ---------------------------------------------------------------------------


def test_ckpt_io_fault_recovers_with_sync_retry(tmp_path):
    from repro.train import checkpoint as ckptlib

    rcfg = ResilienceConfig(rollback_after=0)
    plan = FaultPlan(faults=(FaultSpec(step=3, kind="ckpt_io"),))
    events = []
    train_loop(_runtime(rcfg), _cfg(), _opt(), _data(),
               TrainerConfig(steps=10, log_every=5, ckpt_dir=str(tmp_path),
                             ckpt_every=4),
               faults=plan, on_event=events.append)
    kinds = [e["event"] for e in events]
    assert "ckpt_io_recovered" in kinds
    # the sync retry landed the checkpoint despite the injected failure
    assert ckptlib.latest_verified_step(str(tmp_path)) == 8


def test_rollback_restores_verified_checkpoint(tmp_path):
    rcfg = ResilienceConfig(rollback_after=2, escalate_steps=2)
    plan = FaultPlan(faults=(FaultSpec(step=6, kind="nonfinite"),
                             FaultSpec(step=7, kind="nonfinite")))
    tcfg = TrainerConfig(steps=12, log_every=4, ckpt_dir=str(tmp_path),
                         ckpt_every=3)
    sup = Supervisor(_runtime(rcfg), _cfg(), _opt(), tcfg, fault_plan=plan)
    state, hist = sup.run(_data())
    assert int(np.asarray(state.step)) == 12
    assert sup.recoveries == 1
    rb = [e for e in sup.events if e["event"] == "rollback"]
    assert len(rb) == 1
    assert rb[0]["cause"] == "nonfinite_or_norm"
    assert rb[0]["resume_step"] == 6  # newest verified ckpt before the burst
    assert rb[0]["steps_lost"] == 2


def test_supervisor_caps_recoveries(tmp_path):
    rcfg = ResilienceConfig(rollback_after=1, max_recoveries=1)
    plan = FaultPlan(faults=(FaultSpec(step=2, kind="nonfinite"),
                             FaultSpec(step=4, kind="nonfinite")))
    tcfg = TrainerConfig(steps=8, log_every=4, ckpt_dir=str(tmp_path),
                         ckpt_every=2)
    sup = Supervisor(_runtime(rcfg), _cfg(), _opt(), tcfg, fault_plan=plan)
    with pytest.raises(RuntimeError, match="max_recoveries"):
        sup.run(_data())


# ---------------------------------------------------------------------------
# the full acceptance drill
# ---------------------------------------------------------------------------


def test_full_drill_recovers_and_matches_fault_free(tmp_path):
    """The issue's acceptance drill: seeded plan over {nonfinite, spike,
    ckpt_io}; every fault recovered, final loss within tolerance of the
    fault-free run, every recovery event on the JSONL sink."""
    steps, ckpt_every = 30, 5
    tel = TelemetryConfig(jsonl=str(tmp_path / "events.jsonl"), interval=1)
    rcfg = ResilienceConfig(rollback_after=3, escalate_steps=4)

    def one(workdir, plan):
        tcfg = TrainerConfig(steps=steps, log_every=5,
                             ckpt_dir=str(workdir), ckpt_every=ckpt_every,
                             seed=0)
        sup = Supervisor(_runtime(rcfg, telemetry=tel), _cfg(), _opt(), tcfg,
                         fault_plan=plan)
        state, hist = sup.run(_data())
        return state, hist, sup

    _, hist_clean, _ = one(tmp_path / "clean", None)
    plan = FaultPlan.drill(ckpt_every=ckpt_every)
    state, hist, sup = one(tmp_path / "faulted", plan)

    assert int(np.asarray(state.step)) == steps
    fired = {e["step"] for e in sup.events if e["event"] == "fault_injected"}
    assert fired == {f.step for f in plan.faults}
    kinds = [e["event"] for e in sup.events]
    assert "ckpt_io_recovered" in kinds
    assert "rollback" in kinds
    assert kinds.count("sentinel_trip") >= 4

    # recovered, not merely survived: close to the fault-free trajectory
    clean_loss = hist_clean[-1]["loss"]
    assert abs(hist[-1]["loss"] - clean_loss) < 0.5 * clean_loss + 0.1

    # every recovery event also reached the telemetry sink
    with open(tmp_path / "events.jsonl") as f:
        recs = [json.loads(line) for line in f if line.strip()]
    sunk = [r["event"] for r in recs if "event" in r]
    for k in ("fault_injected", "sentinel_trip", "ckpt_io_recovered",
              "rollback"):
        assert k in sunk, f"{k} missing from sink"


# ---------------------------------------------------------------------------
# device loss -> elastic re-shard (satellite c)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 fake devices (conftest)")
def test_device_loss_reshards_and_keeps_descending(tmp_path):
    """Kill a (4,2)-mesh run mid-loop; the supervisor resumes on (2,4) via
    elastic.resume_on_mesh. The re-sharded state matches the checkpoint bit
    for bit and the loss keeps descending."""
    from repro.launch.mesh import make_mesh
    from repro.train import checkpoint as ckptlib

    mesh = make_mesh((4, 2), ("data", "model"))
    rcfg = ResilienceConfig()
    # policy=None: exact steps only — keeps the two mesh compiles cheap
    rt = Runtime(policy=None, execution=ExecutionConfig(
        mesh=mesh, resilience=rcfg))
    plan = FaultPlan(faults=(
        FaultSpec(step=7, kind="device_loss", mesh_shape=(2, 4)),))
    tcfg = TrainerConfig(steps=14, log_every=2, ckpt_dir=str(tmp_path),
                         ckpt_every=3, seed=1)
    sup = Supervisor(rt, _cfg(), _opt(), tcfg, fault_plan=plan)
    state, hist = sup.run(_data(batch=16))

    assert int(np.asarray(state.step)) == 14
    ev = [e for e in sup.events if e["event"] == "device_loss_reshard"]
    assert len(ev) == 1
    assert ev[0]["old_mesh"] == [4, 2] and ev[0]["new_mesh"] == [2, 4]
    assert ev[0]["resume_step"] == 6
    assert ev[0]["steps_lost"] == 1
    assert tuple(sup.runtime.execution.mesh.devices.shape) == (2, 4)

    # bit-for-bit: re-sharding the checkpoint onto the surviving mesh (the
    # exact call the supervisor made at the seam) loses nothing vs the host
    # restore of the same step
    import jax.numpy as jnp

    from repro.train import elastic
    from repro.train.train_step import init_state

    like = compat.tree_map(jnp.zeros_like,
                           init_state(compat.prng_key(0), _cfg(), _opt()))
    host, hstep = ckptlib.restore(str(tmp_path), like)
    resharded, rstep = elastic.resume_on_mesh(
        str(tmp_path), like, sup.runtime.execution.mesh)
    assert rstep == hstep
    for a, b in zip(compat.tree_leaves(resharded.params),
                    compat.tree_leaves(host.params)):
        assert np.asarray(jax.device_get(a)).tobytes() == \
            np.asarray(b).tobytes()

    # loss descends across the recovery seam
    losses = [m["loss"] for m in hist]
    assert losses[-1] < losses[0]
