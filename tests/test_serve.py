"""Serving: the continuous-batching engine must emit byte-identical greedy
tokens per request vs sequential reference decoding AND vs the legacy
run-to-completion engine — under heterogeneous prompt lengths, permuted
arrival order, mid-stream slot refill, paged or contiguous KV layout, and a
mesh-bearing Runtime — while compiling exactly once per (prefill-bucket,
decode, insert). Plus the hardened admission path (empty prompts, over-long
prompts, page-pool exhaustion) and the scheduler/page-allocator units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.nn.common import Ctx
from repro.serve.config import ServeConfig
from repro.serve.engine import Engine, Request
from repro.serve.legacy import RunToCompletionEngine
from repro.serve.scheduler import Scheduler
from repro.serve.serve_step import greedy_sample

CFG = ArchConfig(name="serve-test", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv=2, d_ff=128, vocab=256, q_chunk=32, kv_chunk=32)

_PARAMS = None


def _params():
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = lm.init_params(jax.random.key(0), CFG)
    return _PARAMS


_REF_CACHE = {}


def _reference_decode(params, prompt, max_new, max_len):
    key = (tuple(int(t) for t in prompt), max_new, max_len)
    if key in _REF_CACHE:
        return _REF_CACHE[key]
    toks = jnp.asarray(prompt)[None]
    _, caches = lm.prefill(params, {"tokens": toks}, Ctx(), CFG, max_len)
    # next token from a full forward (prefill logits path == forward path)
    logits, _ = lm.forward(params, {"tokens": toks}, Ctx(), CFG)
    cur = greedy_sample(logits[:, -1:])
    out = []
    pos = toks.shape[1]
    for _ in range(max_new):
        out.append(int(cur[0, 0]))
        logits, caches = lm.decode_step(params, caches, cur, pos, Ctx(), CFG)
        cur = greedy_sample(logits)
        pos += 1
    _REF_CACHE[key] = out
    return out


def _mixed_requests(seed=0, lens=(11, 5, 23, 3, 17, 9, 30, 7),
                    news=(6, 3, 9, 2, 12, 4, 5, 8)):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(1, CFG.vocab, size=n).astype(np.int32),
                    max_new=m) for n, m in zip(lens, news)]


# ---------------------------------------------------------------------------
# model-stack plumbing (prefill/decode parity with forward)
# ---------------------------------------------------------------------------


def test_prefill_logits_match_forward():
    params = _params()
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0, CFG.vocab)
    lg_fwd, _ = lm.forward(params, {"tokens": toks}, Ctx(), CFG)
    lg_pre, _ = lm.prefill(params, {"tokens": toks}, Ctx(), CFG, max_len=32)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(lg_fwd),
                               rtol=2e-4, atol=2e-4)


def test_multi_step_decode_matches_full_forward():
    """Decode 5 tokens step-by-step; logits must match teacher-forced forward."""
    params = _params()
    toks = jax.random.randint(jax.random.key(2), (2, 20), 0, CFG.vocab)
    full, _ = lm.forward(params, {"tokens": toks}, Ctx(), CFG)
    _, caches = lm.prefill(params, {"tokens": toks[:, :15]}, Ctx(), CFG, max_len=24)
    for i in range(15, 20):
        lg, caches = lm.decode_step(params, caches, toks[:, i:i + 1], i, Ctx(), CFG)
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, i]),
                                   rtol=3e-4, atol=3e-4)


def test_segment_masked_prefill_is_byte_identical_per_prompt():
    """Right-padded rows with segment ids produce EXACTLY the single-prompt
    logits: -1e30 masking makes pad contributions exp to exact 0.0, so the
    engines' bucketed prefill cannot perturb greedy decoding."""
    params = _params()
    rng = np.random.default_rng(7)
    p1 = rng.integers(1, CFG.vocab, size=11).astype(np.int32)
    p2 = rng.integers(1, CFG.vocab, size=5).astype(np.int32)
    S = 16
    toks = np.zeros((2, S), np.int32)
    segs = np.zeros((2, S), np.int32)
    toks[0, :11], toks[1, :5] = p1, p2
    segs[0, :11], segs[1, :5] = 1, 1
    lg, _ = lm.prefill(params, {"tokens": jnp.asarray(toks),
                                "segments": jnp.asarray(segs)}, Ctx(), CFG, 32)
    for row, p in ((0, p1), (1, p2)):
        solo, _ = lm.forward(params, {"tokens": jnp.asarray(p)[None]}, Ctx(), CFG)
        np.testing.assert_array_equal(np.asarray(lg[row, :len(p)]),
                                      np.asarray(solo[0]))


def test_decode_step_vector_positions():
    """Per-slot position vectors: two rows decoding at different timesteps
    match their own scalar-pos references bitwise."""
    params = _params()
    rng = np.random.default_rng(8)
    p1 = rng.integers(1, CFG.vocab, size=9).astype(np.int32)
    p2 = rng.integers(1, CFG.vocab, size=4).astype(np.int32)
    want1 = _reference_decode(params, p1, 5, 32)
    want2 = _reference_decode(params, p2, 5, 32)
    toks = np.zeros((2, 9), np.int32)
    segs = np.zeros((2, 9), np.int32)
    toks[0, :9], toks[1, :4] = p1, p2
    segs[0, :9], segs[1, :4] = 1, 1
    lg, caches = lm.prefill(params, {"tokens": jnp.asarray(toks),
                                     "segments": jnp.asarray(segs)}, Ctx(), CFG, 32)
    cur = jnp.stack([greedy_sample(lg[0:1, 8:9])[0], greedy_sample(lg[1:2, 3:4])[0]])
    pos = jnp.asarray([9, 4], jnp.int32)
    outs = [[], []]
    for _ in range(5):
        for b in range(2):
            outs[b].append(int(cur[b, 0]))
        lg2, caches = lm.decode_step(params, caches, cur, pos, Ctx(), CFG)
        cur = greedy_sample(lg2)
        pos = pos + 1
    assert outs[0] == want1
    assert outs[1] == want2


# ---------------------------------------------------------------------------
# engine equivalence: continuous == legacy == sequential reference
# ---------------------------------------------------------------------------


def test_engine_matches_reference():
    params = _params()
    reqs = _mixed_requests()
    Engine(params, CFG, serve=ServeConfig(n_slots=4, max_len=64)).run(reqs)
    for r in reqs:
        assert r.out.tolist() == _reference_decode(params, r.prompt, r.max_new, 64)
        assert r.stop == "length"


def test_continuous_matches_legacy_under_permuted_arrival():
    """Byte-identical greedy tokens per request vs the run-to-completion
    baseline, for every arrival order — outputs are a property of the
    request, never of scheduling."""
    params = _params()
    for perm_seed in (0, 1):
        reqs_c = _mixed_requests()
        reqs_l = _mixed_requests()
        order = np.random.default_rng(perm_seed).permutation(len(reqs_c))
        reqs_c = [reqs_c[i] for i in order]
        reqs_l = [reqs_l[i] for i in order]
        Engine(params, CFG, serve=ServeConfig(n_slots=4, max_len=64)).run(reqs_c)
        RunToCompletionEngine(params, CFG, batch=4, max_len=64).run(reqs_l)
        for rc, rl in zip(reqs_c, reqs_l):
            assert rc.out.tolist() == rl.out.tolist()


def test_mid_stream_refill():
    """8 requests through 4 slots with wildly mixed max_new: short requests
    finish and their slots refill from the queue mid-decode; every output
    still matches the sequential reference, and the engine provably
    refilled (more prefill waves than one) without idling slots."""
    params = _params()
    reqs = _mixed_requests(news=(2, 20, 2, 20, 2, 20, 2, 3))
    eng = Engine(params, CFG, serve=ServeConfig(n_slots=4, max_len=64))
    eng.run(reqs)
    for r in reqs:
        assert r.out.tolist() == _reference_decode(params, r.prompt, r.max_new, 64)
    c = eng.counters
    assert c["batches"] >= 2  # refill happened mid-stream
    assert c["requests_done"] == len(reqs)
    # continuous batching's whole point: waste only the drain-out tail,
    # far below the legacy engine's run-to-completion + dead-lane waste
    leg = RunToCompletionEngine(params, CFG, batch=4, max_len=64)
    leg.run(_mixed_requests(news=(2, 20, 2, 20, 2, 20, 2, 3)))
    assert c["wasted_decode_steps"] < leg.counters["wasted_decode_steps"]


def test_paged_vs_contiguous_parity():
    """Paged pool + page-map decode == contiguous slot-major decode, bitwise."""
    params = _params()
    reqs_p = _mixed_requests(seed=3)
    reqs_c = _mixed_requests(seed=3)
    ep = Engine(params, CFG, serve=ServeConfig(n_slots=4, max_len=64, page_size=16))
    ec = Engine(params, CFG, serve=ServeConfig(n_slots=4, max_len=64, page_size=None))
    assert ep.layout.paged and not ec.layout.paged
    ep.run(reqs_p)
    ec.run(reqs_c)
    for rp, rc in zip(reqs_p, reqs_c):
        assert rp.out.tolist() == rc.out.tolist()


def test_packed_prefill_matches_unpacked():
    """Segment-masked packed prefill (several prompts in one row) changes
    call count but not one output token."""
    params = _params()
    reqs_pk = _mixed_requests(seed=5, lens=(3, 5, 4, 7, 6, 2), news=(4,) * 6)
    reqs_un = _mixed_requests(seed=5, lens=(3, 5, 4, 7, 6, 2), news=(4,) * 6)
    sv = ServeConfig(n_slots=3, max_len=64, page_size=16)
    ep = Engine(params, CFG, serve=sv)
    eu = Engine(params, CFG, serve=sv.replace(pack_prefill=False))
    ep.run(reqs_pk)
    eu.run(reqs_un)
    for a, b in zip(reqs_pk, reqs_un):
        assert a.out.tolist() == b.out.tolist()
    assert ep.counters["prefill_calls"] < eu.counters["prefill_calls"]


def test_eos_stops_early_and_is_recorded():
    params = _params()
    rng = np.random.default_rng(11)
    p = rng.integers(1, CFG.vocab, size=9).astype(np.int32)
    ref = _reference_decode(params, p, 10, 64)
    eos = ref[3]  # stop at the 4th generated token
    cut = ref.index(eos)  # first occurrence wins
    eng = Engine(params, CFG, serve=ServeConfig(n_slots=2, max_len=64))
    [req] = eng.run([Request(prompt=p, max_new=10, eos=int(eos))])
    assert req.out.tolist() == ref[:cut + 1]  # eos token included
    assert req.stop == "eos"
    assert eng.ring.records[-1]["stop"] == "eos"
    # engine-default eos via ServeConfig
    eng2 = Engine(params, CFG,
                  serve=ServeConfig(n_slots=2, max_len=64, eos=int(eos)))
    [req2] = eng2.run([Request(prompt=p, max_new=10)])
    assert req2.out.tolist() == ref[:cut + 1]


def test_mesh_runtime_equivalence():
    """The same engine code path under a mesh-bearing Runtime: continuous
    and legacy agree token-for-token under dp x tp sharding."""
    from repro.api.execution import ExecutionConfig
    from repro.api.runtime import Runtime
    from repro.launch.mesh import make_mesh

    if jax.device_count() < 8:
        pytest.skip("needs the conftest-forced 8 fake devices")
    params = _params()
    mesh = make_mesh((2, 4), ("data", "model"))
    rt = Runtime(execution=ExecutionConfig(mesh=mesh))
    reqs_c = _mixed_requests(seed=9, lens=(11, 5, 17, 8), news=(5, 8, 3, 6))
    reqs_l = _mixed_requests(seed=9, lens=(11, 5, 17, 8), news=(5, 8, 3, 6))
    rt.serve(params, CFG, serve=ServeConfig(n_slots=4, max_len=64)).run(reqs_c)
    RunToCompletionEngine(params, CFG, batch=4, max_len=64, runtime=rt).run(reqs_l)
    for rc, rl in zip(reqs_c, reqs_l):
        assert rc.out.tolist() == rl.out.tolist()


# ---------------------------------------------------------------------------
# compile-bucket contract: one XLA trace per (prefill bucket, decode, insert)
# ---------------------------------------------------------------------------


def test_one_compile_per_bucket_and_single_decode_trace():
    """Heterogeneous prompt lengths must NOT retrace: prompts bucket to
    powers of two (one prefill compile per bucket hit), decode and insert
    each compile exactly once — mirroring the BudgetSchedule
    one-compile-per-bucket tests via the engine's trace counters."""
    params = _params()
    reqs = _mixed_requests(lens=(3, 5, 9, 17, 30, 11, 23, 4),
                           news=(3, 4, 5, 3, 4, 5, 3, 4))
    eng = Engine(params, CFG, serve=ServeConfig(n_slots=4, max_len=64,
                                                page_size=16))
    eng.run(reqs)
    tc = eng.trace_counts
    assert tc["decode"] == 1, tc
    assert tc["insert"] == 1, tc
    prefills = {k: v for k, v in tc.items() if k.startswith("prefill[")}
    assert prefills and all(v == 1 for v in prefills.values()), tc
    buckets = ServeConfig(n_slots=4, max_len=64, page_size=16).buckets()
    assert all(int(k[len("prefill["):-1]) in buckets for k in prefills), tc
    # second run with fresh lengths: already-traced shapes NEVER retrace —
    # every label still sits at exactly one compile
    eng.run(_mixed_requests(seed=2, lens=(6, 10, 29, 13), news=(3, 3, 3, 3)))
    assert all(v == 1 for v in eng.trace_counts.values()), eng.trace_counts


def test_serve_config_buckets():
    sv = ServeConfig(n_slots=2, max_len=64, page_size=16)
    assert sv.buckets() == (16, 32, 64)
    assert sv.bucket_for(1) == 16 and sv.bucket_for(17) == 32
    assert sv.bucket_for(64) == 64
    with pytest.raises(ValueError):
        sv.bucket_for(65)
    with pytest.raises(ValueError, match="multiple of"):
        ServeConfig(max_len=50, page_size=16)
    assert ServeConfig(n_slots=2, max_len=64, page_size=16).pool_pages == 9


# ---------------------------------------------------------------------------
# scheduler + page allocator units
# ---------------------------------------------------------------------------


def test_scheduler_page_lifecycle():
    sv = ServeConfig(n_slots=2, max_len=64, page_size=16)
    sched = Scheduler(sv, paged=True)
    assert len(sched.free_pages) == sv.pool_pages - 1  # page 0 reserved
    r = Request(prompt=np.ones(20, np.int32), max_new=10)
    sched.submit([r], now=0.0)
    [taken] = sched.take_wave(pack=True, align=16)
    slot = sched.place(taken, first_tok=1, now=0.0)
    assert len(slot.pages) == 2  # ceil((20 + 10) / 16)
    assert (sched.page_map[slot.idx][:2] > 0).all()
    assert (sched.page_map[slot.idx][2:] == 0).all()  # tail -> trash page
    assert len(sched.free_pages) == sv.pool_pages - 3
    sched.finish(slot, "length", now=1.0)
    assert len(sched.free_pages) == sv.pool_pages - 1  # all released
    assert (sched.page_map[slot.idx] == 0).all()
    assert r.stop == "length" and r.t_done == 1.0


def test_scheduler_fifo_head_of_line_blocking():
    """A head request that doesn't fit the page free list blocks the queue
    (strict FIFO — no overtaking), and fits again after frees."""
    sv = ServeConfig(n_slots=2, max_len=64, page_size=16, n_pages=5)
    sched = Scheduler(sv, paged=True)
    big = Request(prompt=np.ones(30, np.int32), max_new=30)   # 4 pages
    small = Request(prompt=np.ones(4, np.int32), max_new=4)   # 1 page
    sched.submit([big, small], now=0.0)
    s1 = sched.place(sched.take_wave(pack=True, align=16)[0], 1, 0.0)
    assert sched.take_wave(pack=True, align=16) == []  # 0 free pages: blocked
    assert sched.pending() == 1
    sched.finish(s1, "length", 1.0)
    assert [r is small for r in sched.take_wave(pack=True, align=16)] == [True]


def test_engine_completes_under_page_pressure():
    """A pool with room for only ~one request at a time degrades throughput,
    never correctness: strict FIFO + worst-case reservation is deadlock-free."""
    params = _params()
    reqs = _mixed_requests(seed=4, lens=(20, 9, 14, 6), news=(8, 6, 4, 6))
    sv = ServeConfig(n_slots=4, max_len=64, page_size=16, n_pages=5)
    eng = Engine(params, CFG, serve=sv)
    eng.run(reqs)
    for r in reqs:
        assert r.out.tolist() == _reference_decode(params, r.prompt, r.max_new, 64)


# ---------------------------------------------------------------------------
# hardening: admission checks, truncation, wasted-step accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine_cls", [Engine, RunToCompletionEngine])
def test_engine_rejects_empty_prompt(engine_cls):
    eng = engine_cls(_params(), CFG, batch=2, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.run([Request(prompt=np.zeros(0, np.int32), max_new=4)])
    assert eng.counters["batches"] == 0  # rejected before any device work


@pytest.mark.parametrize("engine_cls", [Engine, RunToCompletionEngine])
def test_engine_rejects_unservable_max_new(engine_cls):
    eng = engine_cls(_params(), CFG, batch=2, max_len=16)
    p = np.ones(4, np.int32)
    with pytest.raises(ValueError, match="max_new"):
        eng.run([Request(prompt=p, max_new=16)])
    with pytest.raises(ValueError, match="max_new"):
        eng.run([Request(prompt=p, max_new=0)])


def test_overlong_prompt_left_truncated_and_recorded():
    params = _params()
    rng = np.random.default_rng(3)
    long = rng.integers(1, CFG.vocab, size=40).astype(np.int32)
    max_new = 4
    eng = Engine(params, CFG, batch=2, max_len=32)
    [req] = eng.run([Request(prompt=long, max_new=max_new)])
    # left-truncation: the engine served the most recent max_len - max_new
    # tokens; output equals the reference decode of that suffix
    keep = long[-(32 - max_new):]
    assert req.out.tolist() == _reference_decode(params, keep, max_new, 32)
    dropped = len(long) - len(keep)
    assert req.truncated == dropped
    assert eng.counters["truncated_tokens"] == dropped
    assert eng.ring.records[-1]["truncated_tokens"] == dropped


def test_wasted_steps_counted_for_empty_lanes():
    """Two live requests in a 4-slot engine with an empty queue: the two
    free lanes decode garbage every step and are counted, not hidden —
    and never per-slot-synced to the host (one [B] transfer per step)."""
    params = _params()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, CFG.vocab, size=9).astype(np.int32)
               for _ in range(2)]
    eng = Engine(params, CFG, serve=ServeConfig(n_slots=4, max_len=32))
    reqs = eng.run([Request(prompt=p, max_new=4) for p in prompts])
    c = eng.counters
    assert c["decode_steps"] == 3  # first token comes from prefill
    assert c["wasted_decode_steps"] == 2 * c["decode_steps"]
    assert c["requests_done"] == 2
    for r, p in zip(reqs, prompts):
        assert r.out.tolist() == _reference_decode(params, p, 4, 32)


def test_telemetry_summary_fields():
    params = _params()
    eng = Engine(params, CFG, serve=ServeConfig(n_slots=2, max_len=32))
    eng.run(_mixed_requests(seed=6, lens=(5, 9, 7), news=(3, 4, 2)))
    t = eng.telemetry()
    assert t["layout"] == "paged"
    assert t["requests_done"] == 3
    assert t["decode_tok_per_s"] > 0 and t["prefill_tok_per_s"] > 0
    assert t["latency_p50_s"] is not None and t["latency_p99_s"] >= t["latency_p50_s"]
    assert t["ttft_p50_s"] is not None
    assert t["trace_counts"]["decode"] == 1
    # per-request ring records carry the latency stamps
    rec = eng.ring.records[-1]
    assert {"prompt_len", "new_tokens", "stop", "queue_s", "ttft_s",
            "latency_s"} <= set(rec)


def test_paged_cache_specs():
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import paged_cache_specs
    from repro.serve import kv_cache

    if jax.device_count() < 8:
        pytest.skip("needs the conftest-forced 8 fake devices")
    from jax.sharding import PartitionSpec as P

    def spec_leaves(tree):
        return jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P))

    sv = ServeConfig(n_slots=4, max_len=64, page_size=16)
    pools = jax.eval_shape(lambda: kv_cache.init_pools(CFG, sv))
    mesh = make_mesh((2, 4), ("data", "model"))
    leaves = spec_leaves(paged_cache_specs(pools, mesh, sv.pool_pages))
    assert leaves  # pool_pages=9 doesn't divide dp=2 -> replicated pages
    assert all(s == P(None, None, None, None, None) for s in leaves)
    sv2 = sv.replace(n_pages=16)  # 16 pages / dp=2 -> pages shard over data
    leaves2 = spec_leaves(paged_cache_specs(
        jax.eval_shape(lambda: kv_cache.init_pools(CFG, sv2)), mesh, 16))
    assert all(s in (P(None, ("data",), None, None, None),
                     P(None, "data", None, None, None)) for s in leaves2)
