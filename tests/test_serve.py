"""Serving: engine batched decode == sequential reference decoding, plus
the hardened admission path (empty prompts, over-long prompts, dead slots)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.nn.common import Ctx
from repro.serve.engine import Engine, Request
from repro.serve.serve_step import greedy_sample

CFG = ArchConfig(name="serve-test", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv=2, d_ff=128, vocab=256, q_chunk=32, kv_chunk=32)


def _reference_decode(params, prompt, max_new, max_len):
    toks = jnp.asarray(prompt)[None]
    _, caches = lm.prefill(params, {"tokens": toks}, Ctx(), CFG, max_len)
    # next token from a full forward (prefill logits path == forward path)
    logits, _ = lm.forward(params, {"tokens": toks}, Ctx(), CFG)
    cur = greedy_sample(logits[:, -1:])
    out = []
    pos = toks.shape[1]
    for _ in range(max_new):
        out.append(int(cur[0, 0]))
        logits, caches = lm.decode_step(params, caches, cur, pos, Ctx(), CFG)
        cur = greedy_sample(logits)
        pos += 1
    return out


def test_engine_matches_reference():
    params = lm.init_params(jax.random.key(0), CFG)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, CFG.vocab, size=n).astype(np.int32) for n in (11, 11, 11)]
    reqs = [Request(prompt=p, max_new=6) for p in prompts]
    Engine(params, CFG, batch=4, max_len=64).run(reqs)
    for r in reqs:
        want = _reference_decode(params, r.prompt, 6, 64)
        assert r.out.tolist() == want


def test_prefill_logits_match_forward():
    params = lm.init_params(jax.random.key(0), CFG)
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0, CFG.vocab)
    lg_fwd, _ = lm.forward(params, {"tokens": toks}, Ctx(), CFG)
    lg_pre, _ = lm.prefill(params, {"tokens": toks}, Ctx(), CFG, max_len=32)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(lg_fwd),
                               rtol=2e-4, atol=2e-4)


def test_multi_step_decode_matches_full_forward():
    """Decode 5 tokens step-by-step; logits must match teacher-forced forward."""
    params = lm.init_params(jax.random.key(0), CFG)
    toks = jax.random.randint(jax.random.key(2), (2, 20), 0, CFG.vocab)
    full, _ = lm.forward(params, {"tokens": toks}, Ctx(), CFG)
    _, caches = lm.prefill(params, {"tokens": toks[:, :15]}, Ctx(), CFG, max_len=24)
    for i in range(15, 20):
        lg, caches = lm.decode_step(params, caches, toks[:, i:i + 1], i, Ctx(), CFG)
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, i]),
                                   rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# hardening: admission checks, truncation, dead slots
# ---------------------------------------------------------------------------


def _params():
    return lm.init_params(jax.random.key(0), CFG)


def test_engine_rejects_empty_prompt():
    eng = Engine(_params(), CFG, batch=2, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.run([Request(prompt=np.zeros(0, np.int32), max_new=4)])
    assert eng.counters["batches"] == 0  # rejected before any device work


def test_engine_rejects_unservable_max_new():
    eng = Engine(_params(), CFG, batch=2, max_len=16)
    p = np.ones(4, np.int32)
    with pytest.raises(ValueError, match="max_new"):
        eng.run([Request(prompt=p, max_new=16)])
    with pytest.raises(ValueError, match="max_new"):
        eng.run([Request(prompt=p, max_new=0)])


def test_overlong_prompt_left_truncated_and_recorded():
    params = _params()
    rng = np.random.default_rng(3)
    long = rng.integers(1, CFG.vocab, size=40).astype(np.int32)
    max_new = 4
    eng = Engine(params, CFG, batch=2, max_len=32)
    [req] = eng.run([Request(prompt=long, max_new=max_new)])
    # left-truncation: the engine served the most recent max_len - max_new
    # tokens; output equals the reference decode of that suffix
    keep = long[-(32 - max_new):]
    assert req.out.tolist() == _reference_decode(params, keep, max_new, 32)
    dropped = len(long) - len(keep)
    assert eng.counters["truncated_tokens"] == dropped
    assert eng.ring.records[-1]["truncated_tokens"] == dropped


def test_dead_slots_recorded_and_not_collected():
    params = _params()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, CFG.vocab, size=9).astype(np.int32)
               for _ in range(2)]
    eng = Engine(params, CFG, batch=4, max_len=32)
    reqs = eng.run([Request(prompt=p, max_new=4) for p in prompts])
    # two live slots in a batch of four: padding decoded on device but never
    # per-slot-synced to host
    assert eng.counters["dead_slot_steps"] == 2 * 4
    assert eng.ring.records[-1]["dead_slots"] == 2
    for r, p in zip(reqs, prompts):
        assert r.out.tolist() == _reference_decode(params, p, 4, 32)
