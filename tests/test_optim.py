"""Optimizers: convergence on a quadratic, clipping, dtype handling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, clip_by_global_norm, constant, cosine_warmup, sgd


def _quad_target(opt, steps=200):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for i in range(steps):
        g = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(g, state, params, jnp.asarray(i))
    return float(jnp.max(jnp.abs(params["w"] - target)))


def test_sgd_converges():
    assert _quad_target(sgd(0.1)) < 1e-3


def test_sgd_momentum_converges():
    assert _quad_target(sgd(0.05, momentum=0.9)) < 1e-3


def test_adamw_converges():
    assert _quad_target(adamw(0.1), steps=400) < 1e-2


def test_adamw_bf16_moments():
    opt = adamw(0.1, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert _quad_target(opt, steps=400) < 5e-2


def test_clip_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == 20.0
    np.testing.assert_allclose(float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_weight_decay_applies_to_matrices_only():
    opt = adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = opt.init(params)
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    new_params, _ = opt.update(g, state, params, jnp.asarray(0))
    assert float(jnp.max(new_params["w"])) < 1.0  # decayed
    assert float(jnp.max(new_params["b"])) == 1.0  # not decayed


def test_schedules():
    f = cosine_warmup(1.0, 10, 100)
    assert float(f(0)) == 0.0
    np.testing.assert_allclose(float(f(10)), 1.0, rtol=1e-5)
    assert float(f(100)) < 1e-3
    np.testing.assert_allclose(float(constant(0.3)(77)), 0.3, rtol=1e-6)
