"""Fixture tests for the AST lint engine (`repro.analysis.lint`).

Every rule gets a bad fixture (exact rule id + line pinned) and a good
fixture (idiomatic spellings of the same territory, zero findings), under
``tests/data/lint/``. The final test is the repo gate itself: ``src/repro``
lints clean — it runs in well under 10 s (no JAX import) and fails fast
before the tracing suites.
"""
import os

import pytest

from repro.analysis import run_lint, rule_ids
from repro.analysis.lint import main, package_relpath

DATA = os.path.join(os.path.dirname(__file__), "data", "lint")
SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _fixture(name):
    return os.path.join(DATA, name)


# (bad fixture, rule id, expected finding lines)
_BAD = [
    ("bad_version_gated.py", "jax-version-gated", {2, 7, 8, 9, 10, 11}),
    ("bad_custom_vjp.py", "custom-vjp-outside-site", {2, 7, 8}),
    ("bad_ctx.py", "ctx-outside-api-nn", {7, 8}),
    ("bad_prng_reuse.py", "prng-key-reuse", {8}),
    ("bad_host_sync.py", "host-sync-in-jit", {11, 12, 13, 18}),
    ("bad_tracer_branch.py", "tracer-branch", {7, 9}),
    ("bad_swallowed.py", "swallowed-exception", {8, 16}),
    ("bad_thread.py", "thread-uncaptured-target", {10, 16}),
    ("bad_wall_clock.py", "wall-clock-outside-obs", {2, 7, 9, 10}),
]

_GOOD = [
    "good_version_gated.py",
    "good_custom_vjp.py",
    "good_ctx.py",
    "good_prng_reuse.py",
    "good_host_sync.py",
    "good_tracer_branch.py",
    "good_swallowed.py",
    "good_thread.py",
    "good_wall_clock.py",
]


@pytest.mark.parametrize("fname,rule,lines", _BAD,
                         ids=[b[0] for b in _BAD])
def test_bad_fixture_trips_exactly(fname, rule, lines):
    result = run_lint([_fixture(fname)])
    assert not result.waived
    assert {f.rule for f in result.findings} == {rule}
    assert {f.line for f in result.findings} == lines
    # findings render as clickable path:line with the rule id
    for f in result.findings:
        assert str(f).startswith(f"{f.path}:{f.line}: [{rule}]")


@pytest.mark.parametrize("fname", _GOOD)
def test_good_fixture_is_clean_under_all_rules(fname):
    result = run_lint([_fixture(fname)])
    assert not result.findings, [str(f) for f in result.findings]
    assert not result.waived


def test_inline_waiver_suppresses_but_records():
    result = run_lint([_fixture("waived.py")])
    assert not result.findings
    assert [(f.line, f.rule) for f in result.waived] == \
        [(6, "custom-vjp-outside-site")]
    assert result.ok


def test_waiver_for_other_rule_does_not_suppress(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text("import jax\n\n\ndef f(g):\n"
                 "    return jax.custom_vjp(g)  # lint: waive=tracer-branch\n")
    result = run_lint([str(p)])
    assert [f.rule for f in result.findings] == ["custom-vjp-outside-site"]
    assert not result.waived


def test_syntax_error_becomes_parse_error_finding(tmp_path):
    p = tmp_path / "broken.py"
    p.write_text("def f(:\n    pass\n")
    result = run_lint([str(p)])
    assert [f.rule for f in result.findings] == ["parse-error"]


def test_select_unknown_rule_raises():
    with pytest.raises(ValueError, match="no-such-rule"):
        run_lint([DATA], select=["no-such-rule"])


def test_select_restricts_to_named_rule():
    result = run_lint([_fixture("bad_version_gated.py")],
                      select=["ctx-outside-api-nn"])
    assert not result.findings


def test_package_relpath_normalizes_to_package_root():
    assert package_relpath("src/repro/compat.py") == "compat.py"
    assert package_relpath("./src/repro/core/site.py") == "core/site.py"
    # fixtures outside a repro/ dir keep their basename — never allowlisted
    assert package_relpath("tests/data/lint/bad_ctx.py") == "bad_ctx.py"


def test_cli_exit_codes(capsys):
    assert main([_fixture("good_ctx.py")]) == 0
    assert "0 finding(s)" in capsys.readouterr().out
    assert main([_fixture("bad_ctx.py")]) == 1
    out = capsys.readouterr().out
    assert "[ctx-outside-api-nn]" in out and "2 finding(s)" in out
    assert main(["--list-rules"]) == 0
    listed = capsys.readouterr().out
    for rid in rule_ids():
        assert rid in listed


def test_src_tree_lints_clean():
    """The repo gate: zero findings AND zero waivers across src/repro."""
    result = run_lint([SRC])
    assert not result.findings, "\n".join(str(f) for f in result.findings)
    assert not result.waived, "\n".join(str(f) for f in result.waived)
