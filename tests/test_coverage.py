"""The jaxpr sketch-coverage analyzer + baseline gate (`repro.analysis.coverage`).

Four families:

1. **Known escapes are found, costed, and waived**: the MoE router and the
   RWKV decay-LoRA are the two dense matmuls genuinely off the spine; the
   expert/SSM projections are sketched at runtime but invisible to
   ``resolve_tree_site`` (the ROADMAP gap). Each must be reported with
   nonzero modelled FLOPs and matched by ``baseline.json`` — the gate is
   green only because the baseline names them.
2. **Dense archs are fully covered**: every weight matmul resolves, zero
   escaped FLOPs, gate green with no waiver consumed.
3. **A fresh un-waived escape fails the gate** naming the offending
   file/site — the ratchet this subsystem exists for.
4. **Tracing is read-only**: running the analyzer between train steps
   leaves training bit-identical (abstract ``ShapeDtypeStruct`` tracing
   never executes the model).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.analysis import (analyze_loss, analyze_runtime, check_baseline,
                            load_baseline)
from repro.api import (ExecutionConfig, Runtime, SketchConfig, SketchPolicy)
from repro.configs.base import ArchConfig
from repro.configs.registry import smoke_config
from repro.data.synthetic import LMStream
from repro.optim import sgd


def _runtime():
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.4,
                                         backend="compact", block=4))
    return Runtime(policy=pol, execution=ExecutionConfig())


def test_moe_escapes_reported_and_waived():
    rep = analyze_runtime(_runtime(), smoke_config("olmoe_1b_7b"))
    cats = rep.by_category()
    escaped = {s.param: s for s in cats.get("escaped", [])}
    assert set(escaped) == {"segments/0/0/moe/router/w"}
    router = escaped["segments/0/0/moe/router/w"]
    assert router.flops > 0
    assert any("nn/moe.py" in p.replace("\\", "/") for p in router.provenance)
    unresolved = {s.param.rsplit("/", 1)[-1] for s in cats.get("unresolved", [])}
    assert unresolved == {"wi", "wg", "wo"}
    # the attention/out projections DO resolve even in the MoE arch
    assert any(s.param.endswith("attn/q/w") for s in cats.get("resolved", []))
    assert 0 < rep.escaped_flop_frac < 0.05
    br = check_baseline(rep)
    assert br.ok, br.message()
    assert not br.unwaived
    assert {"moe-router-dense", "moe-expert-unresolved"} <= set(br.used)


def test_ssm_escapes_reported_and_waived():
    rep = analyze_runtime(_runtime(), smoke_config("rwkv6_3b"))
    cats = rep.by_category()
    escaped = {s.param.rsplit("/", 2)[-2] for s in cats.get("escaped", [])}
    assert escaped == {"w1", "w2"}
    for s in cats.get("escaped", []):
        assert s.flops > 0
        assert any("nn/ssm.py" in p.replace("\\", "/") for p in s.provenance)
    assert len(cats.get("unresolved", [])) == 8  # r/k/v/g/out + cm_k/cm_v/cm_r
    # the fused w1/w2 pair shares one provenance line — counted once
    assert rep.escaped_flops == max(s.flops for s in cats["escaped"])
    br = check_baseline(rep)
    assert br.ok, br.message()
    assert {"rwkv-decay-lora-dense", "rwkv-projection-unresolved"} <= set(br.used)


def test_dense_arch_fully_covered():
    rep = analyze_runtime(_runtime(), smoke_config("llama3_405b"))
    cats = rep.by_category()
    assert not rep.escapes()
    assert rep.escaped_flops == 0 and rep.unresolved_flops == 0
    assert len(cats["resolved"]) == 7  # q/k/v/o + mlp in/gate/out
    for s in cats["resolved"]:
        assert s.flops > 0 and s.detail.startswith("plan=")
    br = check_baseline(rep)
    assert br.ok and not br.used


def test_fresh_unwaived_escape_fails_gate():
    """Inject a dense matmul off the spine: the gate must go red and the
    report must name this file as the provenance."""
    d, vocab, T = 16, 32, 8
    params = {"w_rogue": jax.ShapeDtypeStruct((d, d), jnp.float32),
              "head": jax.ShapeDtypeStruct((d, vocab), jnp.float32)}
    x = jax.ShapeDtypeStruct((2, T, d), jnp.float32)

    def loss(p, xx):
        h = xx @ p["w_rogue"]  # the escape under test
        return jnp.sum(h @ p["head"]) / T

    rep = analyze_loss(loss, params, x)
    escaped = {s.param: s for s in rep.by_category().get("escaped", [])}
    assert "w_rogue" in escaped and "head" in escaped
    assert escaped["w_rogue"].flops > 0
    assert any("test_coverage.py" in p for p in escaped["w_rogue"].provenance)
    br = check_baseline(rep)
    assert not br.ok
    assert any(s.param == "w_rogue" for s in br.unwaived)
    assert "w_rogue" in br.message() and "escaped" in br.message()


def test_baseline_unused_waivers_are_reported_not_fatal():
    rep = analyze_runtime(_runtime(), smoke_config("llama3_405b"))
    br = check_baseline(rep, baseline=load_baseline())
    assert br.ok
    # every waiver is stale for a dense arch — reported, never fatal
    assert "moe-router-dense" in set(br.unused)


def test_tracing_is_read_only():
    """Train 2 steps; analyze; train 2 fresh steps — losses and params must
    be bit-identical with and without the analyzer in between."""
    arch = ArchConfig(name="cov-tiny", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv=2, d_ff=64, vocab=64, q_chunk=16,
                      kv_chunk=16)

    def run(analyze):
        rt = _runtime()
        opt = sgd(0.1)
        state = rt.init_state(compat.prng_key(0), arch, opt)
        batch = next(iter(LMStream(vocab=arch.vocab, seed=0).batches(4, 16)))
        step = rt.train_step(arch, opt, donate=False)
        losses = []
        for i in range(2):
            if analyze:
                rep = analyze_runtime(rt, arch)
                assert not rep.escapes()
            state, m = step(state, batch, compat.prng_key(i + 1))
            losses.append(float(m["loss"]))
        flat = np.concatenate([np.asarray(v, np.float32).ravel()
                               for v in jax.tree_util.tree_leaves(state.params)])
        return np.asarray(losses, np.float32), flat

    base_losses, base_params = run(analyze=False)
    cov_losses, cov_params = run(analyze=True)
    np.testing.assert_array_equal(base_losses, cov_losses)
    np.testing.assert_array_equal(base_params, cov_params)
