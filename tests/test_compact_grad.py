"""CompactGrad pipeline: pytree/densify semantics, optimizer equivalence
dense-vs-compact (SGD / momentum / AdamW, incl. lazy decay), clipping, and
end-to-end train-step equivalence between compact-grad mode and the dense
scatter path for the same key."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import ArchConfig
from repro.core import CompactGrad, SketchConfig, SketchPolicy
from repro.core.compact_grad import (compact_rank, densify, fold_slot_grads,
                                     with_grad_slots)
from repro.optim import adamw, clip_by_global_norm, global_grad_norm, sgd


def _cg(n=8, d=4, idx=(1, 5), seed=0):
    rng = np.random.default_rng(seed)
    rows = jnp.asarray(rng.normal(size=(len(idx), d)), jnp.float32)
    return CompactGrad(rows=rows, idx=jnp.asarray(idx, jnp.float32)), (n, d)


def test_densify_and_norm_match_dense():
    cg, (n, d) = _cg()
    like = jnp.zeros((n, d))
    dense = densify(cg, like)
    assert dense.shape == (n, d)
    np.testing.assert_allclose(np.asarray(dense[1]), np.asarray(cg.rows[0]))
    assert float(jnp.sum(jnp.abs(dense))) == pytest.approx(
        float(jnp.sum(jnp.abs(cg.rows))), rel=1e-6)
    # norm treats CompactGrad == its densified form
    got = float(global_grad_norm({"w": cg}))
    want = float(global_grad_norm({"w": dense}))
    assert got == pytest.approx(want, rel=1e-6)


def test_densify_stacked():
    rows = jnp.arange(2 * 2 * 3, dtype=jnp.float32).reshape(2, 2, 3)
    idx = jnp.asarray([[0, 2], [1, 3]], jnp.float32)
    cg = CompactGrad(rows=rows, idx=idx)
    dense = densify(cg, jnp.zeros((2, 4, 3)))
    np.testing.assert_allclose(np.asarray(dense[0, 2]), np.asarray(rows[0, 1]))
    np.testing.assert_allclose(np.asarray(dense[1, 1]), np.asarray(rows[1, 0]))
    assert float(jnp.sum(dense)) == pytest.approx(float(jnp.sum(rows)))


def test_clip_matches_dense():
    cg, (n, d) = _cg()
    dense = densify(cg, jnp.zeros((n, d)))
    (c_cg,), gn_cg = clip_by_global_norm((cg,), 0.1)
    (c_de,), gn_de = clip_by_global_norm((dense,), 0.1)
    assert float(gn_cg) == pytest.approx(float(gn_de), rel=1e-6)
    np.testing.assert_allclose(np.asarray(densify(c_cg, jnp.zeros((n, d)))),
                               np.asarray(c_de), rtol=1e-6)


@pytest.mark.parametrize("mk", [lambda: sgd(0.1), lambda: sgd(0.1, momentum=0.9),
                                lambda: adamw(1e-2, weight_decay=0.1)],
                         ids=["sgd", "sgd_momentum", "adamw"])
def test_optimizer_update_dense_vs_compact(mk):
    """Updating with a CompactGrad equals updating with its densified form
    (dense part structurally zero — the compact-backward invariant)."""
    cg, (n, d) = _cg(n=16, d=8, idx=(0, 3, 9))
    cg = CompactGrad(rows=cg.rows, idx=cg.idx, dense=jnp.zeros((16, 8)))
    params = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(16, 8)),
                               jnp.float32)}
    step = jnp.asarray(0)
    opt_c, opt_d = mk(), mk()
    st_c, st_d = opt_c.init(params), opt_d.init(params)
    pc, pd = params, params
    for t in range(3):
        pc, st_c = opt_c.update({"w": cg}, st_c, pc, step + t)
        pd, st_d = opt_d.update({"w": densify(cg, params["w"])}, st_d, pd, step + t)
    np.testing.assert_allclose(np.asarray(pc["w"]), np.asarray(pd["w"]),
                               rtol=1e-6, atol=1e-7)


def test_adamw_lazy_decay_semantics():
    """lazy=True: touched rows get the standard AdamW update; untouched rows
    keep params AND moments frozen (no decay)."""
    cg, (n, d) = _cg(n=10, d=4, idx=(2, 7))
    params = {"w": jnp.ones((10, 4))}
    opt = adamw(1e-2, weight_decay=0.1, lazy=True)
    st = opt.init(params)
    # seed nonzero moments so frozen-decay is observable
    st = {"m": {"w": jnp.full((10, 4), 0.5)}, "v": {"w": jnp.full((10, 4), 0.25)}}
    new_p, new_st = opt.update({"w": cg}, st, params, jnp.asarray(3))

    untouched = np.asarray([i for i in range(10) if i not in (2, 7)])
    np.testing.assert_array_equal(np.asarray(new_p["w"])[untouched],
                                  np.asarray(params["w"])[untouched])
    np.testing.assert_array_equal(np.asarray(new_st["m"]["w"])[untouched],
                                  np.asarray(st["m"]["w"])[untouched])
    # touched rows match the dense update restricted to those rows
    opt_d = adamw(1e-2, weight_decay=0.1)
    pd, std = opt_d.update({"w": densify(cg, params["w"])}, st, params, jnp.asarray(3))
    for i in (2, 7):
        np.testing.assert_allclose(np.asarray(new_p["w"][i]), np.asarray(pd["w"][i]),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(new_st["v"]["w"][i]),
                                   np.asarray(std["v"]["w"][i]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Slot building / folding
# ---------------------------------------------------------------------------


def _arch():
    return ArchConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                      n_kv=2, d_ff=64, vocab=64, q_chunk=16, kv_chunk=16)


def test_with_grad_slots_places_and_sizes_slots():
    from repro.models import lm

    cfg = _arch()
    params = lm.init_params(compat.prng_key(0), cfg)
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.5, backend="compact"))
    aug = with_grad_slots(params, pol, n_layers=cfg.n_layers)
    site = aug["segments"][0][0]["mlp"]["in"]
    assert isinstance(site["gslot"], CompactGrad)
    # stacked over the 2 scanned layers; r = budget * d_ff
    assert site["gslot"].rows.shape == (2, compact_rank(pol.base, cfg.d_ff), cfg.d_model)
    assert site["gslot"].idx.shape == (2, compact_rank(pol.base, cfg.d_ff))
    # head/embed are excluded (policy excludes lm_head; embed is not a site)
    assert "gslot" not in aug.get("lm_head", {})
    # mask policy ⇒ no slots anywhere
    aug_mask = with_grad_slots(
        params, SketchPolicy(base=SketchConfig(method="l1", budget=0.5)), n_layers=2)
    assert jax.tree.structure(aug_mask) == jax.tree.structure(params)


def test_no_slots_for_shared_or_location_policies():
    """Multi-use weights (zamba2-style shared attention, applied every period
    repetition) must NOT get slots: JAX sums per-use slot cotangents
    leafwise, adding the index vectors of different plans. Likewise
    location-based policies (per-layer config differs from the layer-0 one
    the builder mirrors) keep the dense path."""
    from repro.models import lm
    from repro.configs.registry import smoke_config

    cfg = smoke_config("zamba2_7b")
    params = lm.init_params(compat.prng_key(0), cfg)
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.5, backend="compact"))
    aug = with_grad_slots(params, pol, n_layers=cfg.n_layers)
    shared_leaves = jax.tree.leaves(aug["shared"], is_leaf=lambda x: isinstance(x, CompactGrad))
    assert not any(isinstance(x, CompactGrad) for x in shared_leaves)

    loc_pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.5,
                                             backend="compact"), location="first")
    aug_loc = with_grad_slots(params, loc_pol, n_layers=cfg.n_layers)
    assert jax.tree.structure(aug_loc) == jax.tree.structure(params)


def test_shared_arch_compact_train_step_runs_and_matches():
    """End-to-end guard for the shared-weight exclusion: zamba2 smoke under
    compact_grads must match the dense-path step (shared block dense, mamba
    sites dense, mlp sites compact)."""
    from repro.configs.registry import smoke_config
    from repro.train.train_step import init_state, make_train_step

    cfg = smoke_config("zamba2_7b").replace(n_layers=4, remat="none")
    policy = SketchPolicy(base=SketchConfig(method="l1", budget=0.5, backend="compact"))
    opt, opt2 = sgd(0.1), sgd(0.1)
    state = init_state(compat.prng_key(0), cfg, opt)
    toks = jax.random.randint(compat.prng_key(1), (2, 8), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    key = compat.prng_key(2)
    s_d, m_d = jax.jit(make_train_step(cfg, opt, policy))(state, batch, key)
    s_c, m_c = jax.jit(make_train_step(cfg, opt2, policy,
                                       compact_grads=True))(state, batch, key)
    np.testing.assert_allclose(float(m_d["loss"]), float(m_c["loss"]), rtol=1e-6)
    for a, b in zip(compat.tree_leaves(s_d.params), compat.tree_leaves(s_c.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-6)


def test_fold_slot_grads_roundtrip():
    g = {"site": {"w": jnp.zeros((4, 3)),
                  "gslot": CompactGrad(rows=jnp.ones((2, 3)),
                                       idx=jnp.asarray([0.0, 2.0]))},
         "other": {"w": jnp.ones((2, 2))}}
    folded = fold_slot_grads(g)
    assert isinstance(folded["site"]["w"], CompactGrad)
    assert folded["site"]["w"].dense is not None
    assert "gslot" not in folded["site"]
    assert not isinstance(folded["other"]["w"], CompactGrad)
    np.testing.assert_allclose(
        np.asarray(densify(folded["site"]["w"])),
        np.asarray(jnp.zeros((4, 3)).at[jnp.asarray([0, 2])].add(jnp.ones((2, 3)))))


# ---------------------------------------------------------------------------
# End-to-end: compact-grad train step == dense train step (same key)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,block,optname", [
    ("compact", 0, "adamw"),   # per-column XLA path, moment updates
    ("compact", 4, "sgd"),     # block-fused XLA oracle path, momentum
    ("pallas", 4, "sgd"),      # fused Pallas-dispatch path
])
def test_train_step_compact_equals_dense(backend, block, optname):
    from repro.train.train_step import init_state, make_train_step

    cfg = _arch()
    mk = {"sgd": lambda: sgd(0.1, momentum=0.9), "adamw": lambda: adamw(1e-2)}[optname]
    policy = SketchPolicy(base=SketchConfig(method="l1", budget=0.5,
                                            backend=backend, block=block))
    opt = mk()
    state = init_state(compat.prng_key(0), cfg, opt)
    toks = jax.random.randint(compat.prng_key(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    key = compat.prng_key(2)
    s_dense, m_dense = jax.jit(make_train_step(cfg, opt, policy))(state, batch, key)
    s_comp, m_comp = jax.jit(make_train_step(cfg, mk(), policy,
                                             compact_grads=True))(state, batch, key)
    np.testing.assert_allclose(float(m_dense["loss"]), float(m_comp["loss"]), rtol=1e-6)
    np.testing.assert_allclose(float(m_dense["grad_norm"]), float(m_comp["grad_norm"]),
                               rtol=1e-4)
    for a, b in zip(compat.tree_leaves(s_dense.params), compat.tree_leaves(s_comp.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-6)


def test_compact_grads_rejects_accum():
    from repro.train.train_step import make_train_step

    with pytest.raises(ValueError, match="accum"):
        make_train_step(_arch(), sgd(0.1),
                        SketchPolicy(base=SketchConfig(backend="compact")),
                        compact_grads=True, accum=2)
