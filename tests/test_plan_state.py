"""Plan-carry transport (core/plan_state.py) + one-pass estimator statistics.

The carry invariants this file enforces:

* MC-unbiasedness: conditioned on ANY carried scores (uniform prior or an
  arbitrarily stale non-uniform carry), E[dX/dW/db] equals the exact
  gradient — staleness moves variance only.
* Refresh semantics: "onepass" refreshes every column's score each step;
  "stale" refreshes only the kept columns (partial refresh).
* Transport: sslot leaves are emitted exactly at carry-capable sites, ride
  the params tree through a jitted train step, never pollute the gradient
  norm or optimizer moments, and survive gradient accumulation.
* TP fallback: plan-carry estimators are not tp_shardable — under
  ``tp_sketch`` the site falls back to the dense mask backend and no carry
  leaf exists.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecutionConfig, Runtime, SketchConfig, SketchPolicy
from repro.configs.base import ArchConfig
from repro.core import plan_state as pstate
from repro.core import sketched_linear
from repro.core.estimators import get_estimator
from repro.core.site import resolve_site
from repro.data.synthetic import LMStream
from repro.optim import sgd

N, DIN, DOUT = 32, 16, 24

TINY = ArchConfig(name="tiny-plan", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv=2, d_ff=64, vocab=64, q_chunk=16, kv_chunk=16)


def _batch(seed=0):
    return next(iter(LMStream(vocab=TINY.vocab, seed=seed).batches(2, 16)))


def _carry_policy(backend):
    return SketchPolicy(base=SketchConfig(method="l1", budget=0.4,
                                          backend=backend, block=4))


# ---------------------------------------------------------------------------
# Estimator statistics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,stale_carry", [
    ("onepass", False), ("onepass", True),
    ("stale", False), ("stale", True),
])
def test_mc_unbiased_under_any_carry(backend, stale_carry):
    """E over keys of the plan-carry backward equals the exact gradient for
    BOTH the uniform prior and a deliberately wrong (stale) non-uniform
    carry — the floor on keep probabilities makes the conditional
    expectation exact regardless of carry quality."""
    cfg = SketchConfig(method="l1", budget=0.5, backend=backend, block=4)
    ks = jax.random.split(jax.random.key(5), 3)
    x = jax.random.normal(ks[0], (N, DIN))
    w = jax.random.normal(ks[1], (DOUT, DIN)) / np.sqrt(DIN)
    b = jax.random.normal(ks[2], (DOUT,)) * 0.1
    g_out = jax.random.normal(jax.random.key(11), (N, DOUT))
    # heteroscedastic-ish stale carry: wrong relative ordering on purpose
    carry = (jnp.linspace(3.0, 0.2, DOUT).astype(jnp.float32)
             if stale_carry else None)

    def loss(x_, w_, b_, key):
        return jnp.sum(sketched_linear(x_, w_, b_, key=key, cfg=cfg,
                                       plan_state=carry) * g_out)

    exact = jax.grad(lambda x_, w_, b_: jnp.sum(
        sketched_linear(x_, w_, b_) * g_out), argnums=(0, 1, 2))(x, w, b)
    gfn = jax.jit(lambda k: jax.grad(loss, argnums=(0, 1, 2))(x, w, b, k))
    keys = jax.random.split(jax.random.key(7), 600)
    gs = jax.lax.map(gfn, keys, batch_size=100)
    for got, want in zip(gs, exact):
        mean = np.asarray(got.mean(0))
        std = np.asarray(got.std(0))
        want = np.asarray(want)
        scale = np.max(np.abs(want)) + 1e-9
        det = std < 1e-6 * scale
        np.testing.assert_allclose(mean[det], want[det], rtol=1e-3,
                                   atol=1e-4 * scale)
        if det.all():
            continue
        se = std[~det] / np.sqrt(len(keys)) + 1e-3 * scale
        t = np.abs(mean[~det] - want[~det]) / se
        assert np.mean(t) < 2.2, f"{backend} stale={stale_carry}: mean|t|={np.mean(t)}"
        assert np.percentile(t, 95) < 5.0


def test_onepass_full_refresh_stale_partial_refresh():
    """"onepass" returns fresh scores for EVERY column (full refresh from the
    streaming sweep); "stale" refreshes only the kept columns and carries the
    rest through unchanged."""
    cfg = lambda be: SketchConfig(method="l1", budget=0.4, backend=be, block=4)
    ks = jax.random.split(jax.random.key(2), 3)
    G = jax.random.normal(ks[0], (N, DOUT))
    X = jax.random.normal(ks[1], (N, DIN))
    w = jax.random.normal(ks[2], (DOUT, DIN))
    carry = jnp.full((DOUT,), 7.0, jnp.float32)
    want_fresh = np.abs(np.asarray(G, np.float32)).sum(0)

    out1 = get_estimator("onepass").apply_with_state(
        cfg("onepass"), G, X, w, jax.random.key(3), carry, has_b=True)
    np.testing.assert_allclose(np.asarray(out1.state), want_fresh,
                               rtol=1e-4, atol=1e-4)

    out2 = get_estimator("stale").apply_with_state(
        cfg("stale"), G, X, w, jax.random.key(3), carry, has_b=True)
    s2 = np.asarray(out2.state)
    kept = np.zeros(DOUT, bool)
    kept[np.asarray(out2.cols)] = True
    np.testing.assert_allclose(s2[kept], want_fresh[kept], rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(s2[~kept], np.full((~kept).sum(), 7.0))
    assert not kept.all(), "budget 0.4 must drop some blocks for this test"


# ---------------------------------------------------------------------------
# Transport: collect/write roundtrip, slot emission
# ---------------------------------------------------------------------------


def test_collect_write_roundtrip():
    params = {"layers": [{"w": jnp.zeros((4, 4)), "sslot": jnp.full((4,), 2.0)}],
              "embed": jnp.zeros((3, 3))}
    grads = {"layers": [{"w": jnp.ones((4, 4)), "sslot": jnp.asarray([1., 2., 3., 4.])}],
             "embed": jnp.ones((3, 3))}
    clean, fresh = pstate.collect_plan_state(grads)
    # sslot cotangent zeroed (invisible to grad norm / optimizer moments)
    np.testing.assert_array_equal(np.asarray(clean["layers"][0]["sslot"]),
                                  np.zeros(4))
    np.testing.assert_array_equal(np.asarray(clean["layers"][0]["w"]),
                                  np.ones((4, 4)))
    assert list(fresh) == ["layers/0/sslot"]
    out = pstate.write_plan_state(params, fresh)
    np.testing.assert_array_equal(np.asarray(out["layers"][0]["sslot"]),
                                  np.asarray([1., 2., 3., 4.]))
    np.testing.assert_array_equal(np.asarray(out["embed"]), np.zeros((3, 3)))
    # no fresh scores -> identity
    assert pstate.write_plan_state(params, {}) is params


def test_policy_carry_gates():
    assert not pstate.policy_uses_carry(None)
    assert not pstate.policy_uses_carry(
        SketchPolicy(base=SketchConfig(method="l1", budget=0.4, backend="pallas",
                                       block=4)))
    assert pstate.policy_uses_carry(_carry_policy("onepass"))
    assert pstate.policy_uses_carry(_carry_policy("stale"))
    # override-only carry counts too
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.4),
                       overrides={"mlp_in": SketchConfig(
                           method="l1", budget=0.4, backend="stale", block=4)})
    assert pstate.policy_uses_carry(pol)


def test_tp_sketch_falls_back_to_mask_and_carries_nothing():
    """Plan-carry estimators are not tp_shardable: under tp_sketch the site
    resolves to the dense mask backend with no compact rows and no carry."""
    cfg = SketchConfig(method="l1", budget=0.4, backend="onepass", block=4)
    spec = resolve_site("mlp_in", cfg, d_out=DOUT, d_in=DIN, x_ndim=3,
                        mesh=None, tp_sketch=True)
    assert spec.cfg.backend == "mask"
    assert spec.compact_rows is None and spec.carry_rows is None
    # and the slot builder consumes the same resolution: no sslot emitted
    params = {"mlp": {"in": {"w": jnp.zeros((DOUT, DIN))}}}
    out = pstate.with_plan_state(params, _carry_policy("onepass"),
                                 tp_sketch=True)
    assert pstate.PLAN_SLOT not in out["mlp"]["in"]
    # positive control: same site without tp_sketch carries [d_out] scores
    out = pstate.with_plan_state(params, _carry_policy("onepass"))
    assert out["mlp"]["in"][pstate.PLAN_SLOT].shape == (DOUT,)


# ---------------------------------------------------------------------------
# End-to-end: carry through jitted train steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["onepass", "stale"])
def test_train_step_carry_persistence(backend):
    rt = Runtime(policy=_carry_policy(backend))
    opt = sgd(0.1)
    state = rt.init_state(jax.random.key(0), TINY, opt)
    slots0 = {p: v for p, v in _named_leaves(state.params)
              if p.endswith(pstate.PLAN_SLOT)}
    assert slots0, "carry policy must emit sslot leaves at init"
    for v in slots0.values():
        np.testing.assert_array_equal(np.asarray(v), np.ones(v.shape))

    step = rt.train_step(TINY, opt, donate=False)
    state1, m1 = step(state, _batch(0), jax.random.key(1))
    assert np.isfinite(float(m1["loss"]))
    assert np.isfinite(float(m1["grad_norm"])) and float(m1["grad_norm"]) > 0
    slots1 = {p: v for p, v in _named_leaves(state1.params)
              if p.endswith(pstate.PLAN_SLOT)}
    assert set(slots1) == set(slots0)
    for p, v in slots1.items():
        arr = np.asarray(v)
        assert np.isfinite(arr).all()
        assert not np.array_equal(arr, np.ones(arr.shape)), \
            f"carry at {p} was not refreshed"
        if backend == "stale":
            # partial refresh: at budget 0.4 the uniform prior keeps a strict
            # subset of blocks, so some columns must still hold the prior
            assert (arr == 1.0).any(), f"stale carry at {p} fully refreshed"

    # the carry keeps evolving on the next step
    state2, _ = step(state1, _batch(1), jax.random.key(2))
    slots2 = {p: v for p, v in _named_leaves(state2.params)
              if p.endswith(pstate.PLAN_SLOT)}
    assert any(not np.array_equal(np.asarray(slots2[p]), np.asarray(slots1[p]))
               for p in slots2)


def test_grad_norm_excludes_carry():
    """The sslot cotangent (fresh scores, magnitude ~N·E|g|) must not leak
    into the reported gradient norm: a carry backend and the equivalent
    non-carry pallas backend see the same-scale grad_norm."""
    opt = sgd(0.1)
    norms = {}
    for backend in ("pallas", "stale"):
        rt = Runtime(policy=_carry_policy(backend))
        state = rt.init_state(jax.random.key(0), TINY, opt)
        step = rt.train_step(TINY, opt, donate=False)
        _, m = step(state, _batch(0), jax.random.key(1))
        norms[backend] = float(m["grad_norm"])
    # same arch/key/data; sketches differ so norms differ, but an sslot leak
    # (hundreds of f32 scores of magnitude ~sum|G|) would inflate by >10x
    assert norms["stale"] < 10 * norms["pallas"]


def test_accum_carries_plan_state():
    rt = Runtime(policy=_carry_policy("stale"),
                 execution=ExecutionConfig(accum=2))
    opt = sgd(0.1)
    state = rt.init_state(jax.random.key(0), TINY, opt)
    step = rt.train_step(TINY, opt, donate=False)
    batch = _batch(0)
    big = jax.tree.map(lambda a: jnp.concatenate([a, a], axis=0), batch)
    state1, m = step(state, big, jax.random.key(1))
    assert np.isfinite(float(m["loss"]))
    slots = [v for p, v in _named_leaves(state1.params)
             if p.endswith(pstate.PLAN_SLOT)]
    assert slots and all(np.isfinite(np.asarray(v)).all() for v in slots)
    assert any(not np.array_equal(np.asarray(v), np.ones(v.shape))
               for v in slots)


def test_execution_config_vmem_limit_validation():
    assert ExecutionConfig().fused_vmem_limit is None
    assert ExecutionConfig(fused_vmem_limit=4 << 20).fused_vmem_limit == 4 << 20
    for bad in (0, -1, 2.5, "8MiB"):
        with pytest.raises((ValueError, TypeError)):
            ExecutionConfig(fused_vmem_limit=bad)


def _named_leaves(tree):
    out = []

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))
        else:
            out.append(("/".join(path), node))

    walk(tree, ())
    return out
