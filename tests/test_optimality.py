"""Optimality properties: Lemma 3.1 (rank-r sketch), Lemma 3.4 (diagonal)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SketchConfig, solver
from repro.core.sketching import apply_rcs


def _lemma31_sketch_error(M, r, key, n_mc=300):
    """E||M - S||_F² for the Lemma 3.1 optimal sketch of M.

    Sampling is vmapped over the MC keys (one device call instead of n_mc
    eager dispatches — same draws, same estimate)."""
    u, s, vt = np.linalg.svd(M, full_matrices=False)
    p = np.asarray(solver.optimal_probabilities(jnp.asarray(s ** 2), r))
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_mc))
    pj = jnp.asarray(p)
    idxs = np.asarray(jax.jit(jax.vmap(lambda k: solver.sample_exact_r(k, pj, r)))(keys))
    errs = []
    for idx in idxs:
        S = (u[:, idx] * (s[idx] / p[idx])) @ vt[idx]
        errs.append(np.sum((M - S) ** 2))
    return np.mean(errs)


def test_lemma31_matches_closed_form(key):
    """E||M-S||² should equal Σσ²/p − ||M||² (tightness of the lower bound)."""
    rng = np.random.default_rng(0)
    M = rng.normal(size=(12, 9)) @ np.diag(rng.uniform(0.1, 2.0, 9))
    r = 4
    s = np.linalg.svd(M, compute_uv=False)
    p = np.asarray(solver.optimal_probabilities(jnp.asarray(s ** 2), r))
    closed = float((s ** 2 / p).sum() - (s ** 2).sum())
    emp = _lemma31_sketch_error(M, r, key, n_mc=2000)
    assert emp == pytest.approx(closed, rel=0.15)


def test_lemma31_beats_uniform_direction_sampling(key):
    rng = np.random.default_rng(1)
    # decaying spectrum -> optimal allocation clearly beats uniform
    M = (rng.normal(size=(16, 16)) * (0.5 ** np.arange(16))[None, :])
    r = 4
    s = np.linalg.svd(M, compute_uv=False)
    p_opt = np.asarray(solver.optimal_probabilities(jnp.asarray(s ** 2), r))
    closed_opt = float((s ** 2 / p_opt).sum() - (s ** 2).sum())
    p_unif = np.full(16, r / 16)
    closed_unif = float((s ** 2 / p_unif).sum() - (s ** 2).sum())
    assert closed_opt < 0.7 * closed_unif


def test_lemma34_diagonal_weights_optimal(key):
    """DS probabilities minimise Σ a_i/p_i vs random alternatives."""
    rng = np.random.default_rng(2)
    a = rng.uniform(size=20) ** 2
    r = 5
    p_opt = np.asarray(solver.optimal_probabilities(jnp.asarray(a), r))
    obj_opt = (a / p_opt).sum()
    for i in range(30):
        q = rng.uniform(0.01, 1.0, 20)
        q = q / q.sum() * r
        q = np.clip(q, 1e-6, 1.0)
        if q.sum() > r + 1e-6:
            continue
        assert obj_opt <= (a / q).sum() * (1 + 1e-3)


def test_rcs_lower_distortion_than_per_column(key):
    """Prop. 3.3 sketch should have lower E||J(I-R)g||² than diagonal masks."""
    rng = np.random.default_rng(3)
    n, m, B, r = 16, 12, 32, 4
    W = rng.normal(size=(n, m)) * (0.5 ** np.arange(m))[None, :]  # J = Wᵀ-ish
    G = rng.normal(size=(B, n)) * (0.7 ** np.arange(n))[None, :]
    Gj = jnp.asarray(G, jnp.float32)
    Wj = jnp.asarray(W, jnp.float32)
    cfg = SketchConfig(method="rcs", budget=r / n, ridge=1e-6)
    exact = G @ W

    def dist(ghat):
        return np.sum((np.asarray(ghat, np.float64) @ W - exact) ** 2)

    n_mc = 400
    from repro.core.sketching import sketch_dense
    cfg_col = SketchConfig(method="per_column", budget=r / n)
    # batch the MC draws into one jitted map (same keys/draws as the loop)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n_mc))
    rcs_draws, col_draws = jax.jit(lambda ks: jax.lax.map(
        lambda k: (apply_rcs(cfg, Gj, Wj, k), sketch_dense(cfg_col, Gj, Wj, k)), ks))(keys)
    d_rcs = np.mean([dist(g) for g in np.asarray(rcs_draws)])
    d_col = np.mean([dist(g) for g in np.asarray(col_draws)])
    assert d_rcs < d_col
