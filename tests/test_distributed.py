"""Multi-device tests, IN-PROCESS on 8 fake host devices.

conftest.py forces ``--xla_force_host_platform_device_count=8`` before the
JAX backend initializes, so shard_map / pjit tests run directly in the pytest
process — no subprocess spawn on the default path (the seed harness spawned a
fresh interpreter per test, ~7.5 min of the tier-1 run). One ``slow``-marked
subprocess test remains to cover the isolated-interpreter dry-run path.

Covers: EP MoE == local MoE, sharded train step == unsharded (exact equality,
same key), an end-to-end sharded *sketched* train step per backend (mask /
compact / block — the compact ones exercising the TP-local sketch with the
compressed DP gradient reduce-scatter from core/sharded_sketch.py), elastic
restore across mesh shapes, and TP-sketch unbiasedness.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import ArchConfig
from repro.core import SketchConfig, SketchPolicy
from repro.launch.mesh import make_mesh

ROOT = os.path.join(os.path.dirname(__file__), "..")

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 (fake) devices; conftest forces "
    "--xla_force_host_platform_device_count=8 unless XLA_FLAGS overrides it")


@pytest.fixture(scope="module")
def mesh24():
    return make_mesh((2, 4), ("data", "model"))


def _arch():
    return ArchConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                      n_kv=2, d_ff=64, vocab=64, q_chunk=16, kv_chunk=16)


def _batch(cfg, batch=8, seq=16):
    toks = jax.random.randint(compat.prng_key(1), (batch, seq), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


def test_moe_ep_matches_local(mesh24):
    from repro.nn.common import Ctx
    from repro.nn.moe import MoECfg, moe_ffn, moe_init

    cfg = MoECfg(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0)
    params = moe_init(compat.prng_key(0), 16, cfg)
    x = jax.random.normal(compat.prng_key(1), (4, 8, 16))
    y_local, aux_local = moe_ffn(params, x, Ctx(), cfg)
    ctx = Ctx(mesh=mesh24, data_axes=("data",), model_axes=("model",))
    y_ep, aux_ep = jax.jit(lambda p, xx: moe_ffn(p, xx, ctx, cfg))(params, x)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                               rtol=3e-5, atol=3e-5)
    # grads flow through the EP path
    g = jax.grad(lambda p: moe_ffn(p, x, ctx, cfg)[0].sum())(params)
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in compat.tree_leaves(g))


def _single_and_sharded_steps(mesh, policy=None, tp_sketch=False):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch import sharding as shard
    from repro.optim import sgd
    from repro.train.train_step import TrainState, init_state, make_train_step

    cfg = _arch()
    opt = sgd(0.1)
    state = init_state(compat.prng_key(0), cfg, opt)
    batch = _batch(cfg)
    key = compat.prng_key(2)

    step_1d = jax.jit(make_train_step(cfg, opt, policy))

    pspecs = shard.param_shardings(state.params, mesh)
    sshard = TrainState(params=pspecs, opt_state={k: pspecs for k in state.opt_state},
                        step=NamedSharding(mesh, P()))
    act = NamedSharding(mesh, P(("data",), None, None))
    step_nd = make_train_step(cfg, opt, policy, mesh=mesh, act_sharding=act,
                              data_axes=("data",), model_axes=("model",),
                              tp_sketch=tp_sketch)
    bspec = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    step_nd = jax.jit(step_nd, in_shardings=(sshard, bspec, NamedSharding(mesh, P())))
    return cfg, state, batch, key, step_1d, step_nd


def test_sharded_train_step_matches_single_device(mesh24):
    """Exact (no-policy) path: sharded step == single-device step, same key."""
    _, state, batch, key, step_1d, step_nd = _single_and_sharded_steps(mesh24)
    s1, m1 = step_1d(state, batch, key)
    s2, m2 = step_nd(state, batch, key)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(compat.tree_leaves(s1.params), compat.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)


_BACKENDS = {
    # paper-faithful dense-mask estimator under pjit-auto sharding
    "mask": dict(policy=SketchPolicy(base=SketchConfig(method="l1", budget=0.5,
                                                       backend="mask")),
                 tp_sketch=False),
    # TP-local compact sketch + compressed DP gradient reduce-scatter
    "compact": dict(policy=SketchPolicy(base=SketchConfig(method="l1", budget=0.5,
                                                          backend="compact")),
                    tp_sketch=True),
    # block-granular compact sketch (lane-aligned slabs; pallas-kernel layout)
    "block": dict(policy=SketchPolicy(base=SketchConfig(method="l1", budget=0.5,
                                                        backend="compact", block=4)),
                  tp_sketch=True),
}


@pytest.mark.parametrize("backend", sorted(_BACKENDS))
def test_sharded_sketched_train_step(mesh24, backend):
    """End-to-end sharded *sketched* train step per backend.

    Sketching only touches the backward pass, so every backend's sharded loss
    must equal the exact single-device loss for the same params/batch; the
    update must be finite and actually move the params. The mask backend uses
    the same estimator as the single-device step (same keys ⇒ same plan), so
    there the updated params must match too.
    """
    kw = _BACKENDS[backend]
    _, state, batch, key, step_1d, step_nd = _single_and_sharded_steps(
        mesh24, policy=kw["policy"], tp_sketch=kw["tp_sketch"])
    s2, m2 = step_nd(state, batch, key)

    # forward exactness: sketched loss == exact loss (sketch is backward-only)
    from repro.optim import sgd
    from repro.train.train_step import make_train_step
    exact_step = jax.jit(make_train_step(_arch(), sgd(0.1), None))
    _, m_exact = exact_step(state, batch, key)
    np.testing.assert_allclose(float(m2["loss"]), float(m_exact["loss"]), rtol=1e-4)

    assert int(s2.step) == 1
    moved = False
    for a, b in zip(compat.tree_leaves(state.params), compat.tree_leaves(s2.params)):
        assert bool(jnp.all(jnp.isfinite(b)))
        moved = moved or not np.allclose(np.asarray(a), np.asarray(b))
    assert moved
    assert np.isfinite(float(m2["grad_norm"]))

    if backend == "mask":
        s1, m1 = step_1d(state, batch, key)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
        for a, b in zip(compat.tree_leaves(s1.params), compat.tree_leaves(s2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-4)


def test_sharded_compact_grads_match_scatter_path(mesh24):
    """Compact-gradient mode on the 2x4 mesh: the TP-local sketch emits
    CompactGrad (rows + global indices, reduce-scattered over dp) and the
    optimizer applies the sparse-row update — the result must equal the
    pre-existing path that scatters dW inside shard_map and updates densely,
    for the same step key (identical plans)."""
    from repro.optim import sgd
    from repro.train.train_step import make_train_step

    policy = SketchPolicy(base=SketchConfig(method="l1", budget=0.5,
                                            backend="compact", block=4))
    _, state, batch, key, _, step_scatter = _single_and_sharded_steps(
        mesh24, policy=policy, tp_sketch=True)

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch import sharding as shard
    from repro.train.train_step import TrainState

    pspecs = shard.param_shardings(state.params, mesh24)
    sshard = TrainState(params=pspecs, opt_state={k: pspecs for k in state.opt_state},
                        step=NamedSharding(mesh24, P()))
    act = NamedSharding(mesh24, P(("data",), None, None))
    bspec = {k: NamedSharding(mesh24, P("data", None)) for k in batch}
    step_cg = make_train_step(_arch(), sgd(0.1), policy, mesh=mesh24,
                              act_sharding=act, data_axes=("data",),
                              model_axes=("model",), tp_sketch=True,
                              compact_grads=True)
    step_cg = jax.jit(step_cg, in_shardings=(sshard, bspec, NamedSharding(mesh24, P())))

    s0, m0 = step_scatter(state, batch, key)
    s1, m1 = step_cg(state, batch, key)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(m0["grad_norm"]), float(m1["grad_norm"]), rtol=1e-3)
    for a, b in zip(compat.tree_leaves(s0.params), compat.tree_leaves(s1.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-6)


def test_elastic_restore_across_meshes(tmp_path):
    from repro.optim import adamw
    from repro.train import checkpoint as ck
    from repro.train.elastic import resume_on_mesh
    from repro.train.train_step import init_state

    cfg = _arch()
    opt = adamw(1e-3)
    state = init_state(compat.prng_key(0), cfg, opt)
    ck.save(str(tmp_path), 5, state)

    for shape, axes in [((4, 2), ("data", "model")),
                        ((2, 2, 2), ("pod", "data", "model")),
                        ((8,), ("data",))]:
        mesh = make_mesh(shape, axes)
        restored, step = resume_on_mesh(
            str(tmp_path), compat.tree_map(jnp.zeros_like, state), mesh)
        assert step == 5
        for a, b in zip(compat.tree_leaves(state.params),
                        compat.tree_leaves(restored.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tp_sharded_sketch_unbiased_and_fwd_exact(mesh24):
    from repro.core.sharded_sketch import tp_applicable, tp_sketched_linear
    from repro.nn.common import Ctx

    ctx = Ctx(mesh=mesh24, data_axes=("data",), model_axes=("model",),
              tp_sketch=True, act_sharding=object())
    cfg = SketchConfig(method="l1", budget=0.5, backend="compact")
    B, S, din, n = 4, 8, 16, 32
    x = jax.random.normal(compat.prng_key(0), (B, S, din))
    w = jax.random.normal(compat.prng_key(1), (n, din)) / 4
    assert tp_applicable(ctx, cfg, n)

    def loss(x, w, key):
        return jnp.sum(jnp.sin(tp_sketched_linear(x, w, ctx, cfg, key)))

    # forward is exact
    y = tp_sketched_linear(x, w, ctx, cfg, compat.prng_key(2))
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.einsum("bsi,oi->bso", x, w)),
                               rtol=1e-5, atol=1e-5)
    # backward unbiased (MC)
    exact = jax.grad(lambda x_, w_: jnp.sum(jnp.sin(jnp.einsum("bsi,oi->bso", x_, w_))),
                     argnums=(0, 1))(x, w)
    keys = jax.random.split(compat.prng_key(5), 480)
    gs = jax.lax.map(lambda k: jax.grad(loss, argnums=(0, 1))(x, w, k), keys,
                     batch_size=48)
    for got, want in zip(gs, exact):
        mean = np.asarray(got.mean(0))
        std = np.asarray(got.std(0))
        want = np.asarray(want)
        scale = np.abs(want).max() + 1e-9
        det = std < 1e-5 * scale
        np.testing.assert_allclose(mean[det], want[det], rtol=1e-3, atol=1e-3 * scale)
        if det.all():
            continue
        se = std[~det] / np.sqrt(len(keys))
        t = np.abs(mean[~det] - want[~det]) / se
        assert np.mean(t) < 1.8, np.mean(t)


def test_registry_estimator_routes_through_tp_sharded_path(mesh24):
    """Satellite of the registry routing: core/sharded_sketch no longer
    bypasses the estimator registry. A third-party estimator that opts in
    (``tp_shardable=True``) has its ``plan`` hook drive the shard_map
    backward (proved by a deterministic plan whose kept-column support shows
    up in dW); one that does not opt in is rejected by ``tp_applicable``;
    and ``validate`` rejects a bad config identically on the sharded and
    single-device paths."""
    import jax.numpy as jnp

    from repro import api
    from repro.core.sharded_sketch import tp_applicable, tp_sketched_linear
    from repro.core.sketched_linear import _CompactEstimator
    from repro.core.sketching import ColumnPlan, static_rank
    from repro.nn.common import Ctx

    class _ToyTPFirstR(_CompactEstimator):
        """Compact semantics, but the plan deterministically keeps the FIRST
        r columns (uniform marginals) — distinguishable from the builtin
        data-dependent plan by the support of dW."""

        name = "toy_tp_firstr"
        tp_shardable = True

        def validate(self, cfg):
            super().validate(cfg)
            if cfg.budget > 0.9:
                raise ValueError("toy_tp_firstr needs budget <= 0.9")

        def plan(self, cfg, G2d, w, key, *, want_compact=True,
                 score_psum_axes=None):
            n = G2d.shape[-1]
            r = static_rank(cfg, n)
            p = jnp.full((n,), jnp.float32(r) / n)
            idx = jnp.arange(r, dtype=jnp.int32)
            return ColumnPlan(indices=idx, scales=1.0 / jnp.take(p, idx),
                              gate=None, probs=p)

    if "toy_tp_firstr" not in api.registered_backends():
        api.register_estimator(_ToyTPFirstR())

    # validate: rejected consistently (single-device construction and the
    # sharded applicability check run the same hook)
    with pytest.raises(ValueError, match="budget <= 0.9"):
        SketchConfig(method="per_column", budget=0.95, backend="toy_tp_firstr")

    ctx = Ctx(mesh=mesh24, data_axes=("data",), model_axes=("model",),
              tp_sketch=True, act_sharding=object())
    cfg = SketchConfig(method="per_column", budget=0.5, backend="toy_tp_firstr")
    B, S, din, n = 4, 8, 16, 32
    n_mp = mesh24.shape["model"]
    x = jax.random.normal(compat.prng_key(0), (B, S, din))
    w = jax.random.normal(compat.prng_key(1), (n, din)) / 4
    assert tp_applicable(ctx, cfg, n)

    dx, dw = jax.grad(lambda x_, w_: jnp.sum(
        jnp.sin(tp_sketched_linear(x_, w_, ctx, cfg, compat.prng_key(2)))),
        argnums=(0, 1))(x, w)
    assert bool(jnp.all(jnp.isfinite(dx))) and bool(jnp.all(jnp.isfinite(dw)))
    # routing proof: each model shard kept its FIRST r_loc local columns, so
    # dW support is exactly the leading r_loc rows of every shard slice
    n_loc = n // n_mp
    r_loc = static_rank(cfg, n_loc)
    dw_np = np.asarray(dw).reshape(n_mp, n_loc, din)
    assert np.abs(dw_np[:, :r_loc]).sum() > 0
    np.testing.assert_array_equal(dw_np[:, r_loc:], 0.0)

    # an estimator that does NOT opt in is consistently rejected by the TP
    # path (dense() would fall back; builtin mask behaves the same way)
    class _ToyDense(api.Estimator):
        name = "toy_tp_dense"

        def apply(self, cfg, G2d, X2d, w, key, *, has_b, score_psum_axes=None):
            return api.EstimatorVJP(dx=G2d @ w, dw=G2d.T @ X2d)

    if "toy_tp_dense" not in api.registered_backends():
        api.register_estimator(_ToyDense())
    cfg_dense = SketchConfig(method="per_column", budget=0.5,
                             backend="toy_tp_dense")
    assert not tp_applicable(ctx, cfg_dense, n)
    assert not tp_applicable(ctx, SketchConfig(method="l1", budget=0.5,
                                               backend="mask"), n)


# ---------------------------------------------------------------------------
# TP probes + bias streams + adaptive-under-TP (the one-spine refactor):
# telemetry and compact gradients are properties of every sketched site,
# including the shard_map plans.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["column", "column_block", "row"])
def test_tp_probe_unbiased_vs_bruteforce(mesh24, kind):
    """MC check: the per-shard probe computed inside the shard_map backward
    body and psum'ed over the model axis is unbiased — its ``var`` entry
    matches the brute-force per-site VJP variance E‖dŴ − dW‖² and its
    ``g_sq`` entry matches ‖dW‖², on both the column- and row-parallel
    plans (ROADMAP open item: "probe the TP-local sharded sketch")."""
    from repro.core.sharded_sketch import (tp_row_sketched_linear,
                                           tp_sketched_linear)
    from repro.nn.common import Ctx
    from repro.telemetry.probes import PROBE_WIDTH

    ctx = Ctx(mesh=mesh24, data_axes=("data",), model_axes=("model",),
              tp_sketch=True)
    block = 4 if kind == "column_block" else 0
    cfg = SketchConfig(method="l1", budget=0.5, backend="compact", block=block)
    B, S, din, n = 2, 8, 16, 32
    x = jax.random.normal(compat.prng_key(0), (B, S, din))
    w = jax.random.normal(compat.prng_key(1), (n, din)) / 4
    fn = tp_row_sketched_linear if kind == "row" else tp_sketched_linear
    g_out = jax.random.normal(compat.prng_key(2), (B, S, n))
    pslot0 = jnp.zeros((PROBE_WIDTH,), jnp.float32)

    def loss(w_, pslot, key):
        return jnp.sum(fn(x, w_, ctx, cfg, key, pslot=pslot) * g_out)

    @jax.jit
    def one(key):
        dw, probe = jax.grad(loss, argnums=(0, 1))(w, pslot0, key)
        return dw, probe

    keys = jax.random.split(compat.prng_key(7), 384)
    dws, probes = jax.lax.map(one, keys, batch_size=48)

    G2d = np.asarray(g_out).reshape(-1, n)
    X2d = np.asarray(x).reshape(-1, din)
    dw_exact = G2d.T @ X2d
    var_mc = float(np.mean(np.sum(np.square(np.asarray(dws) - dw_exact[None]),
                                  axis=(1, 2))))
    probe_mean = np.asarray(probes).mean(0)
    assert probe_mean[3] == pytest.approx(1.0)  # ok flag, exactly once
    assert probe_mean[1] == pytest.approx(var_mc, rel=0.15), \
        (kind, probe_mean, var_mc)
    assert probe_mean[0] == pytest.approx(float(np.sum(dw_exact ** 2)),
                                          rel=0.15)


@pytest.mark.parametrize("role,kind", [("attn_q", "tp_column"),
                                       ("mlp_out", "tp_row")])
def test_tp_bias_sites_route_sharded_and_grads_unbiased(mesh24, role, kind):
    """Satellite: ``dense`` used to silently skip the shard_map plans when
    ``params["b"]`` was present. Now bias sites resolve to the TP plans, the
    forward stays exact (bias added inside the body), and dw AND db come
    out unbiased — db folded into the same kept-column stream."""
    import dataclasses

    from repro.nn.common import Ctx, dense

    cfg = SketchConfig(method="l1", budget=0.5, backend="compact")
    pol = SketchPolicy(base=cfg)
    ctx = Ctx(policy=pol, key=compat.prng_key(3), mesh=mesh24,
              data_axes=("data",), model_axes=("model",), tp_sketch=True)
    B, S, din, n = 2, 8, 16, 32
    x = jax.random.normal(compat.prng_key(0), (B, S, din))
    params = {"w": jax.random.normal(compat.prng_key(1), (n, din)) / 4,
              "b": jax.random.normal(compat.prng_key(2), (n,)) / 4}

    spec = ctx.site_spec(role, cfg, params["w"], has_bias=True)
    assert spec.plan.kind == kind and spec.has_bias
    assert spec.compact_rows is not None  # bias TP sites slot too

    # forward exact incl. the bias
    y = dense(params, x, ctx, role)
    np.testing.assert_allclose(
        np.asarray(y),
        np.asarray(jnp.einsum("bsi,oi->bso", x, params["w"]) + params["b"]),
        rtol=1e-5, atol=1e-5)

    def loss(p, key):
        c = dataclasses.replace(ctx, key=key)
        return jnp.sum(jnp.sin(dense(p, x, c, role)))

    exact = jax.grad(lambda p: jnp.sum(jnp.sin(
        jnp.einsum("bsi,oi->bso", x, p["w"]) + p["b"])))(params)
    keys = jax.random.split(compat.prng_key(5), 480)
    gs = jax.lax.map(lambda k: jax.grad(loss)(params, k), keys, batch_size=48)
    for name in ("w", "b"):
        got, want = gs[name], np.asarray(exact[name])
        mean, std = np.asarray(got.mean(0)), np.asarray(got.std(0))
        scale = np.abs(want).max() + 1e-9
        det = std < 1e-5 * scale
        np.testing.assert_allclose(mean[det], want[det], rtol=1e-3,
                                   atol=1e-3 * scale)
        if det.all():
            continue
        se = std[~det] / np.sqrt(len(keys))
        t = np.abs(mean[~det] - want[~det]) / se
        assert np.mean(t) < 1.8, (name, np.mean(t))


def test_adaptive_schedule_under_tp_sketch(mesh24):
    """The ROADMAP north-star configuration: ``BudgetSchedule.adaptive``
    under ``tp_sketch`` must measure SNR from the TP probes (no "can never
    see a probe" warning), run exactly one compiled step per bucket (zero
    retraces), and actually switch buckets."""
    import math
    import warnings

    from repro.api import (BudgetSchedule, ExecutionConfig, Runtime)
    from repro.api import runtime as runtime_mod
    from repro.data.synthetic import LMStream
    from repro.optim import sgd
    from repro.train.trainer import TrainerConfig
    from jax.sharding import NamedSharding, PartitionSpec as P

    runtime_mod._cache_clear()
    cfg = _arch()
    sched = BudgetSchedule.adaptive(0.05, budgets=(1.0, 0.5, 0.2), window=2)
    pol = SketchPolicy(base=SketchConfig(method="l1", budget=0.5,
                                         backend="compact"))
    act = NamedSharding(mesh24, P(("data",), None, None))
    rt = Runtime(policy=pol, schedule=sched,
                 execution=ExecutionConfig(mesh=mesh24, act_sharding=act,
                                           tp_sketch=True))
    data = LMStream(vocab=cfg.vocab, seed=0).batches(8, 16)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        _, hist = rt.train(cfg, sgd(0.1), data,
                           TrainerConfig(steps=8, log_every=1),
                           on_metrics=lambda m: None)
    assert not any("cannot measure gradient SNR" in str(w.message)
                   for w in rec), "TP probes must feed the adaptive controller"
    assert len(runtime_mod._STEP_BUILDS) == len(sched.buckets()), \
        "adaptive under tp_sketch must only ever run pre-compiled buckets"
    assert all(m["budget"] in sched.buckets() for m in hist)
    assert len(set(m["budget"] for m in hist)) >= 2, \
        "the controller must actually switch buckets under TP"
    assert all(math.isfinite(m["probe_snr"]) for m in hist
               if "probe_snr" in m)


# ---------------------------------------------------------------------------
# Subprocess isolation path (slow, opt-in with -m slow): a fresh interpreter
# with its own XLA_FLAGS, exercising the dry-run machinery end to end.
# ---------------------------------------------------------------------------


def _run(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       timeout=timeout, env=env, cwd=ROOT)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


@pytest.mark.slow
def test_rope_remat_warning_gone_in_dryrun_compile():
    """ROADMAP items: compiling a production train cell must no longer log
    `[spmd] Involuntary full rematerialization` for nn/rope.py (the position
    broadcast carries a sharding annotation) NOR for nn/attention.py (the
    GQA k/v repeat is pinned on both sides). XLA logs to the C++ stderr,
    so this check needs a subprocess."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", """
import repro.launch.dryrun as dr
from repro.configs.base import ShapeCell
from repro.configs.registry import smoke_config
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg = smoke_config("yi_6b").replace(n_layers=4)
fn, args = dr._builder(cfg, ShapeCell("t", 64, 8, "train"), mesh,
                       dr._POLICIES["compact"], cost_mode=False)
fn.lower(*args).compile()
print("COMPILED")
"""], capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert "COMPILED" in r.stdout, r.stderr[-4000:]
    remats = [l for l in r.stderr.splitlines()
              if "Involuntary full rematerialization" in l
              and ("rope.py" in l or "attention.py" in l)]
    assert not remats, remats[:2]


@pytest.mark.slow
def test_tiny_dryrun_cell():
    """End-to-end dry-run machinery on an 8-device mesh with a reduced arch."""
    _run("""
import jax, numpy as np
import repro.launch.dryrun as dr
from repro.configs.base import SHAPE_CELLS, ShapeCell
from repro.configs.registry import smoke_config
from repro.launch.mesh import make_mesh
from repro.launch.hlo_analysis import collective_bytes, cost_summary

mesh = make_mesh((2, 4), ("data", "model"))
cfg = smoke_config("yi_6b").replace(n_layers=4)
cell = ShapeCell("t", 64, 8, "train")
fn, args = dr._builder(cfg, cell, mesh, dr._POLICIES["compact"], cost_mode=False)
compiled = fn.lower(*args).compile()
ma = compiled.memory_analysis()
assert ma.temp_size_in_bytes > 0
cb = collective_bytes(compiled.as_text())
assert cb["total"] > 0  # TP must communicate
cs = cost_summary(compiled)
assert cs["flops"] > 0
# decode path
cell_d = ShapeCell("d", 64, 8, "decode")
fn2, args2 = dr._builder(cfg, cell_d, mesh, None, cost_mode=False)
c2 = fn2.lower(*args2).compile()
assert c2.cost_analysis() is not None
print("TINY DRYRUN OK")
""", devices=8, timeout=1200)
