"""Multi-device tests (subprocess with fake devices — XLA device count must be
set before jax initialises, so these cannot run in the main pytest process).
Covers: EP MoE == local MoE, sharded train step == unsharded, elastic restore
across mesh shapes, and a tiny end-to-end dry-run cell."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                       timeout=timeout, env=env, cwd=ROOT)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_moe_ep_matches_local():
    _run("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.nn.moe import MoECfg, moe_init, moe_ffn
from repro.nn.common import Ctx
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
cfg = MoECfg(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0)
params = moe_init(jax.random.key(0), 16, cfg)
x = jax.random.normal(jax.random.key(1), (4, 8, 16))
y_local, aux_local = moe_ffn(params, x, Ctx(), cfg)
ctx = Ctx(mesh=mesh, data_axes=("data",), model_axes=("model",))
y_ep, aux_ep = jax.jit(lambda p, xx: moe_ffn(p, xx, ctx, cfg))(params, x)
np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep), rtol=3e-5, atol=3e-5)
# grads flow through the EP path
g = jax.grad(lambda p: moe_ffn(p, x, ctx, cfg)[0].sum())(params)
assert all(bool(jnp.all(jnp.isfinite(t))) for t in jax.tree.leaves(g))
print("EP OK")
""")


def test_sharded_train_step_matches_single_device():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ArchConfig
from repro.launch.mesh import make_mesh
from repro.launch import sharding as shard
from repro.models import lm
from repro.nn.common import Ctx
from repro.optim import sgd
from repro.train.train_step import TrainState, init_state, make_train_step

cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                 n_kv=2, d_ff=64, vocab=64, q_chunk=16, kv_chunk=16)
opt = sgd(0.1)
state = init_state(jax.random.key(0), cfg, opt)
toks = jax.random.randint(jax.random.key(1), (8, 16), 0, cfg.vocab)
batch = {"tokens": toks, "labels": toks}
key = jax.random.key(2)

step_1d = make_train_step(cfg, opt, None)
s1, m1 = jax.jit(step_1d)(state, batch, key)

mesh = make_mesh((2, 4), ("data", "model"))
pspecs = shard.param_shardings(state.params, mesh)
sshard = TrainState(params=pspecs, opt_state={k: pspecs for k in state.opt_state},
                    step=NamedSharding(mesh, P()))
act = NamedSharding(mesh, P(("data",), None, None))
step_nd = make_train_step(cfg, opt, None, mesh=mesh, act_sharding=act,
                          data_axes=("data",), model_axes=("model",))
bspec = {k: NamedSharding(mesh, P("data", None)) for k in batch}
s2, m2 = jax.jit(step_nd, in_shardings=(sshard, bspec, NamedSharding(mesh, P())))(state, batch, key)
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
print("SHARDED STEP OK")
""")


def test_elastic_restore_across_meshes(tmp_path):
    _run(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig
from repro.launch.mesh import make_mesh
from repro.optim import adamw
from repro.train.train_step import init_state
from repro.train import checkpoint as ck
from repro.train.elastic import resume_on_mesh, state_shardings

cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                 n_kv=2, d_ff=64, vocab=64, q_chunk=16, kv_chunk=16)
opt = adamw(1e-3)
state = init_state(jax.random.key(0), cfg, opt)
ck.save({str(tmp_path)!r}, 5, state)

for shape, axes in [((4, 2), ("data", "model")), ((2, 2, 2), ("pod", "data", "model")), ((8,), ("data",))]:
    mesh = make_mesh(shape, axes)
    restored, step = resume_on_mesh({str(tmp_path)!r}, jax.tree.map(jnp.zeros_like, state), mesh)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("elastic restore onto", shape, "OK")
""")


@pytest.mark.slow
def test_tiny_dryrun_cell():
    """End-to-end dry-run machinery on an 8-device mesh with a reduced arch."""
    _run("""
import jax, numpy as np
import repro.launch.dryrun as dr
from repro.configs.base import SHAPE_CELLS, ShapeCell
from repro.configs.registry import smoke_config
from repro.launch.mesh import make_mesh
from repro.launch.hlo_analysis import collective_bytes, cost_summary

mesh = make_mesh((2, 4), ("data", "model"))
cfg = smoke_config("yi_6b").replace(n_layers=4)
cell = ShapeCell("t", 64, 8, "train")
fn, args = dr._builder(cfg, cell, mesh, dr._POLICIES["compact"], cost_mode=False)
compiled = fn.lower(*args).compile()
ma = compiled.memory_analysis()
assert ma.temp_size_in_bytes > 0
cb = collective_bytes(compiled.as_text())
assert cb["total"] > 0  # TP must communicate
cs = cost_summary(compiled)
assert cs["flops"] > 0
# decode path
cell_d = ShapeCell("d", 64, 8, "decode")
fn2, args2 = dr._builder(cfg, cell_d, mesh, None, cost_mode=False)
c2 = fn2.lower(*args2).compile()
assert c2.cost_analysis() is not None
print("TINY DRYRUN OK")
""", devices=8, timeout=1200)


def test_tp_sharded_sketch_unbiased_and_fwd_exact():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import SketchConfig
from repro.core.sharded_sketch import tp_applicable, tp_sketched_linear
from repro.launch.mesh import make_mesh
from repro.nn.common import Ctx

mesh = make_mesh((2, 4), ("data", "model"))
ctx = Ctx(mesh=mesh, data_axes=("data",), model_axes=("model",), tp_sketch=True,
          act_sharding=object())
cfg = SketchConfig(method="l1", budget=0.5, backend="compact")
B, S, din, n = 4, 8, 16, 32
x = jax.random.normal(jax.random.key(0), (B, S, din))
w = jax.random.normal(jax.random.key(1), (n, din)) / 4
assert tp_applicable(ctx, cfg, n)

def loss(x, w, key):
    return jnp.sum(jnp.sin(tp_sketched_linear(x, w, ctx, cfg, key)))

# forward is exact
y = tp_sketched_linear(x, w, ctx, cfg, jax.random.key(2))
np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.einsum("bsi,oi->bso", x, w)),
                           rtol=1e-5, atol=1e-5)
# backward unbiased (MC)
exact = jax.grad(lambda x_, w_: jnp.sum(jnp.sin(jnp.einsum("bsi,oi->bso", x_, w_))),
                 argnums=(0, 1))(x, w)
gfn = jax.jit(lambda k: jax.grad(loss, argnums=(1, 2))(x, w, k))
keys = jax.random.split(jax.random.key(5), 600)
gs = jax.lax.map(lambda k: jax.grad(loss, argnums=(0, 1))(x, w, k), keys, batch_size=50)
for got, want in zip(gs, exact):
    mean = np.asarray(got.mean(0)); std = np.asarray(got.std(0))
    want = np.asarray(want)
    scale = np.abs(want).max() + 1e-9
    det = std < 1e-5 * scale
    np.testing.assert_allclose(mean[det], want[det], rtol=1e-3, atol=1e-3 * scale)
    if det.all():
        continue
    se = std[~det] / np.sqrt(len(keys))
    t = np.abs(mean[~det] - want[~det]) / se
    assert np.mean(t) < 1.8, np.mean(t)
print("TP SKETCH OK")
""", devices=8, timeout=1200)
