"""Chunked XLA attention vs naive reference: GQA, window, ragged, offsets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import flash_attention_ref
from repro.nn.attention import AttnCfg, decode_attention, multi_head_attention


@pytest.mark.parametrize("B,Sq,Skv,H,Kv,dh,causal,window", [
    (2, 64, 64, 8, 2, 32, True, None),
    (1, 96, 96, 4, 1, 16, True, 24),
    (2, 50, 50, 4, 4, 16, True, None),     # ragged vs chunks
    (1, 64, 64, 6, 3, 16, False, None),    # bidirectional
    (1, 33, 77, 4, 2, 16, False, None),    # cross-attention shapes
])
def test_chunked_matches_reference(B, Sq, Skv, H, Kv, dh, causal, window):
    ks = jax.random.split(jax.random.key(B * Sq + H), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh))
    k = jax.random.normal(ks[1], (B, Skv, Kv, dh))
    v = jax.random.normal(ks[2], (B, Skv, Kv, dh))
    cfg = AttnCfg(n_heads=H, n_kv=Kv, d_head=dh, causal=causal, window=window,
                  q_chunk=16, kv_chunk=16)
    got = multi_head_attention(q, k, v, cfg)
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_cost_mode_matches_rolled():
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    cfg = AttnCfg(n_heads=4, n_kv=2, d_head=16, q_chunk=16, kv_chunk=16)
    a = multi_head_attention(q, k, v, cfg, cost_mode=False)
    b = multi_head_attention(q, k, v, cfg, cost_mode=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_chunked_backward_finite():
    ks = jax.random.split(jax.random.key(6), 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    cfg = AttnCfg(n_heads=4, n_kv=2, d_head=16, q_chunk=8, kv_chunk=8)
    g = jax.grad(lambda q_: jnp.sum(multi_head_attention(q_, k, v, cfg)))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_decode_matches_full_last_position():
    """decode_attention(pos) == reference attention at the last query row."""
    ks = jax.random.split(jax.random.key(7), 3)
    S, H, Kv, dh = 40, 4, 2, 16
    q_full = jax.random.normal(ks[0], (2, S, H, dh))
    k = jax.random.normal(ks[1], (2, S, Kv, dh))
    v = jax.random.normal(ks[2], (2, S, Kv, dh))
    want = flash_attention_ref(q_full, k, v, causal=True)[:, -1:]
    cfg = AttnCfg(n_heads=H, n_kv=Kv, d_head=dh)
    got = decode_attention(q_full[:, -1:], k, v, S - 1, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_gqa_repeat_gets_sharding_annotation():
    """ROADMAP item: the GQA k/v head repeat must be pinned on BOTH sides
    under a mesh ctx — the pre-repeat [B, S, Kv, dh] tensors arrive
    seq-sharded from the sequence-parallel projections while the repeated
    output is head-sharded, and without the operand annotation SPMD logs an
    `[spmd] Involuntary full rematerialization` in the forward and the
    remat'd backward of production train cells (4 warnings at
    nn/attention.py; the dryrun stderr check lives in test_distributed's
    slow subprocess test)."""
    from repro.launch.mesh import make_mesh
    from repro.nn.attention import AttnCfg, multi_head_attention
    from repro.nn.common import Ctx

    if jax.device_count() < 8:
        pytest.skip("needs the 8 fake host devices forced by conftest")
    mesh = make_mesh((2, 4), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P

    ctx = Ctx(mesh=mesh, data_axes=("data",), model_axes=("model",),
              act_sharding=NamedSharding(mesh, P(("data",), None, None)))
    cfg = AttnCfg(n_heads=4, n_kv=2, d_head=8, q_chunk=8, kv_chunk=8)
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, 16, 4, 8))
    k = jax.random.normal(ks[1], (2, 16, 2, 8))
    v = jax.random.normal(ks[2], (2, 16, 2, 8))

    def f(constrain):
        return lambda q, k, v: multi_head_attention(q, k, v, cfg,
                                                    constrain=constrain)

    jaxpr = str(jax.make_jaxpr(f(ctx.constrain_heads))(q, k, v))
    # pre-repeat k and v pins + post-repeat q/k/v pins (and per-chunk pins)
    assert jaxpr.count("sharding_constraint") >= 5
    # no ctx -> no constraint (single-device paths unchanged)
    jaxpr0 = str(jax.make_jaxpr(f(None))(q, k, v))
    assert "sharding_constraint" not in jaxpr0
    # annotated and unannotated paths compute the same thing
    np.testing.assert_allclose(
        np.asarray(f(ctx.constrain_heads)(q, k, v)),
        np.asarray(f(None)(q, k, v)), rtol=1e-5, atol=1e-5)


def test_rope_broadcast_gets_sharding_annotation():
    """ROADMAP item: RoPE's [B, S, 1, d/2] cos/sin broadcast must carry a
    sharding annotation under a mesh ctx so SPMD stops involuntarily
    rematerializing it in the backward of production train cells (the
    dryrun stderr check lives in test_distributed's slow subprocess test)."""
    from repro.launch.mesh import make_mesh
    from repro.nn.common import Ctx
    from repro.nn.rope import apply_rope

    if jax.device_count() < 8:
        pytest.skip("needs the 8 fake host devices forced by conftest")
    mesh = make_mesh((2, 4), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P

    ctx = Ctx(mesh=mesh, data_axes=("data",), model_axes=("model",),
              act_sharding=NamedSharding(mesh, P(("data",), None, None)))
    x = jnp.ones((4, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (4, 8))
    jaxpr = str(jax.make_jaxpr(lambda xx, pp: apply_rope(xx, pp, 1e4, ctx=ctx))(x, pos))
    assert "sharding_constraint" in jaxpr
    # no ctx -> no constraint (decode / single-device paths unchanged)
    jaxpr0 = str(jax.make_jaxpr(lambda xx, pp: apply_rope(xx, pp, 1e4))(x, pos))
    assert "sharding_constraint" not in jaxpr0
    # annotated and unannotated paths compute the same thing
    np.testing.assert_allclose(
        np.asarray(apply_rope(x, pos, 1e4, ctx=ctx)),
        np.asarray(apply_rope(x, pos, 1e4)), rtol=1e-6)
