"""HLO collective parser + depth model + roofline terms."""
import numpy as np
import pytest

from repro.launch.hlo_analysis import (HW, collective_bytes, fit_depth_model,
                                       predict_depth_model, roofline_terms)

FAKE_HLO = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256] %x), replica_groups=[32,16]<=[512], to_apply=%add
  %ag.1 = bf16[1024,64]{1,0} all-gather(bf16[64,64] %y), replica_groups=[2,16]<=[32], dimensions={0}
  %rs = f32[32,8]{1,0} reduce-scatter(f32[256,8] %z), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %cp = bf16[16,16]{0,1} collective-permute(bf16[16,16] %w), source_target_pairs={{0,1}}
  %a2a = f32[64]{0} all-to-all(f32[64] %v), replica_groups=[8,4]<=[32]
  %ard = f32[2,2] all-reduce-done(f32[2,2] %h)
"""


def test_collective_bytes_parser():
    out = collective_bytes(FAKE_HLO)
    # all-reduce: 128*256*4 bytes, n=16 -> 2*(15/16)*size
    ar = 128 * 256 * 4
    assert out["all-reduce"] == pytest.approx(2 * ar * 15 / 16)
    ag = 1024 * 64 * 2
    assert out["all-gather"] == pytest.approx(ag * 15 / 16)
    rs = 32 * 8 * 4
    assert out["reduce-scatter"] == pytest.approx(rs * 7)
    assert out["collective-permute"] == 16 * 16 * 2
    a2a = 64 * 4
    assert out["all-to-all"] == pytest.approx(a2a * 3 / 4)
    assert out["counts"]["all-reduce"] == 1  # -done line not double counted


def test_depth_model_exact_for_linear_costs():
    # cost(L) = 5 + 3*n_full + 2*rem
    pts = [(0, 1, {"flops": 5 + 2}), (1, 0, {"flops": 5 + 3}), (2, 0, {"flops": 5 + 6})]
    coefs = fit_depth_model(pts)
    pred = predict_depth_model(coefs, 13, 3)
    assert pred["flops"] == pytest.approx(5 + 3 * 13 + 2 * 3, rel=1e-6)


def test_depth_model_homogeneous_two_points():
    pts = [(1, 0, {"bytes": 10.0}), (2, 0, {"bytes": 16.0}), (4, 0, {"bytes": 28.0})]
    coefs = fit_depth_model(pts)
    pred = predict_depth_model(coefs, 32, 0)
    assert pred["bytes"] == pytest.approx(4 + 6 * 32, rel=1e-6)


def test_roofline_terms_dominance():
    hw = HW()
    r = roofline_terms(flops=197e12, bytes_hbm=819e9 * 0.5, coll_bytes=0.0, chips=1, hw=hw)
    assert r["dominant"] == "compute"
    assert r["compute_s"] == pytest.approx(1.0)
    r2 = roofline_terms(flops=1e9, bytes_hbm=819e9 * 2, coll_bytes=0.0, chips=1, hw=hw)
    assert r2["dominant"] == "memory"
    r3 = roofline_terms(flops=1e9, bytes_hbm=1e6, coll_bytes=hw.ici_bw * 3, chips=1, hw=hw)
    assert r3["dominant"] == "collective"
